"""Transliteration check: K-way pipelined cold-load slice math.

The Rust simulator (rust/src/sim/coldstart.rs) splits a first-touch
backbone load into K equal fair-share flows and later consolidates the
borrowed (K-1)/K of the payload over the target's NIC. The Rust side
locks this against a brute-force oracle (rust/src/sim/flow.rs); this
file re-derives the same max-min fair-share integration in pure Python
so the conservation argument is checked by an independent
implementation, with no shared code.

Model (identical to FlowNet): each (node, link) pair is one shared
channel; n concurrent flows each progress at 1/n of their solo rate.
A flow with `remaining` solo-seconds drains `dt / n` of them over a
wall-clock epoch of width dt with n flows active.
"""

import math
import random


def run_fair_share(flows):
    """Event-driven fair-share integration on one shared link.

    `flows` is a list of (start_s, solo_s) pairs. Returns a list of
    (finish_s, drained_solo_s) per flow, in input order.
    """
    events = sorted(range(len(flows)), key=lambda i: flows[i][0])
    active = {}  # index -> remaining solo-seconds
    drained = [0.0] * len(flows)
    finish = [None] * len(flows)
    t = flows[events[0]][0] if events else 0.0
    pending = list(events)
    while pending or active:
        # Next arrival vs earliest projected completion at current share.
        next_arrival = flows[pending[0]][0] if pending else math.inf
        n = len(active)
        next_finish = math.inf
        if n:
            next_finish = t + min(active.values()) * n
        t_next = min(next_arrival, next_finish)
        if n:
            dt = t_next - t
            for i in list(active):
                active[i] -= dt / n
                drained[i] += dt / n
                if active[i] <= 1e-12:
                    finish[i] = t_next
                    del active[i]
        t = t_next
        while pending and flows[pending[0]][0] <= t:
            i = pending.pop(0)
            active[i] = flows[i][1]
    return list(zip(finish, drained))


def consolidation_gb(payload_gb, k):
    """The borrowed share that must transfer back to the target."""
    return payload_gb * (k - 1) / k


def consolidation_trigger(frac, n_shards):
    """Shards that must retire before consolidation starts."""
    return max(math.ceil(frac * n_shards), 1)


def test_equal_slices_alone_finish_together_and_conserve():
    # K slices of S/k joining one link at once: each runs at 1/k share,
    # so every slice takes exactly S wall-clock and the drained
    # solo-seconds sum back to S — the pipelined split loses no bytes
    # and gains no artificial speedup on a single shared link (the win
    # comes from using K *different* links, one per sibling node).
    for k in (2, 3, 4, 7):
        total = 13.7
        res = run_fair_share([(1.5, total / k)] * k)
        for finish_s, _ in res:
            assert abs(finish_s - (1.5 + total)) < 1e-9
        assert abs(sum(d for _, d in res) - total) < 1e-9


def test_slices_on_distinct_links_finish_in_a_kth_of_the_time():
    # One slice per link (the actual pipelined placement: each sibling
    # node pulls over its own NIC): solo rate each, so wall time is S/k.
    for k in (2, 4, 8):
        total = 13.7
        res = [run_fair_share([(2.0, total / k)])[0] for _ in range(k)]
        for finish_s, drained in res:
            assert abs(finish_s - (2.0 + total / k)) < 1e-9
        assert abs(sum(d for _, d in res) - total) < 1e-9


def test_conservation_holds_under_random_background_traffic():
    # Slices contending with arbitrary background flows still drain
    # exactly their solo demand — fair sharing reschedules, never
    # destroys, work. Mirrors flow.rs's
    # pipelined_k_way_slices_conserve_bytes_and_match_oracle.
    rng = random.Random(29)
    for _ in range(25):
        k = rng.randint(2, 6)
        total = 5.0 + rng.random() * 20.0
        flows = [(0.25, total / k)] * k
        n_bg = rng.randint(0, 8)
        bg_solo = 0.0
        for _ in range(n_bg):
            s = 0.2 + rng.random() * 6.0
            bg_solo += s
            flows.append((rng.random() * 10.0, s))
        res = run_fair_share(flows)
        drained = sum(d for _, d in res)
        assert abs(drained - (total + bg_solo)) < 1e-9 * max(1.0, drained)
        slice_drain = sum(d for _, d in res[:k])
        assert abs(slice_drain - total) < 1e-9 * max(1.0, total)
        # Symmetric slices finish together even under contention.
        ends = [f for f, _ in res[:k]]
        assert max(ends) - min(ends) < 1e-9


def test_consolidation_math_matches_the_rust_side():
    # consol_gb = payload·(K−1)/K: what the siblings pulled on the
    # target's behalf, and nothing more.
    assert consolidation_gb(13.5, 4) == 13.5 * 3 / 4
    for k in range(2, 9):
        borrowed = consolidation_gb(1.0, k)
        own = 1.0 / k
        assert abs(borrowed + own - 1.0) < 1e-12
    # Trigger: ceil(frac·n) clamped to at least one retired shard.
    assert consolidation_trigger(1.0, 3) == 3
    assert consolidation_trigger(0.5, 3) == 2
    assert consolidation_trigger(0.01, 3) == 1
    assert consolidation_trigger(0.5, 1) == 1
    for n in range(1, 8):
        for frac in (0.01, 0.25, 0.5, 0.75, 1.0):
            t = consolidation_trigger(frac, n)
            assert 1 <= t <= n
