"""L2 correctness: tiny-Llama prefill/decode graphs.

Checks: Pallas path vs pure-jnp oracle, KV-cache consistency (prefill(n)+
decode == prefill(n+1)), adapter isolation (different adapters ⇒ different
logits; same backbone bytes), and shape contracts the Rust runtime relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.configs import CONFIGS, LoraConfig, ModelConfig

CFG = ModelConfig(
    name="test-micro", vocab=64, d_model=32, n_layers=2,
    n_heads=4, n_kv_heads=2, d_ff=48, max_seq=32,
)
LORA = LoraConfig(rank=4, alpha=8.0)


@pytest.fixture(scope="module")
def backbone():
    return M.init_backbone(CFG, seed=0)


@pytest.fixture(scope="module")
def adapter():
    return M.init_adapter(CFG, LORA, seed=0)


def _toks(b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, s)), jnp.int32)


class TestParamSpecs:
    def test_backbone_spec_count(self):
        specs = M.backbone_param_specs(CFG)
        assert len(specs) == 1 + 9 * CFG.n_layers + 2

    def test_adapter_spec_count(self):
        assert len(M.adapter_param_specs(CFG, LORA)) == 8 * CFG.n_layers

    def test_param_count_matches_specs(self):
        total = sum(
            int(np.prod(s)) for _, s in M.backbone_param_specs(CFG)
        )
        assert total == CFG.param_count()

    def test_init_matches_specs(self, backbone, adapter):
        for p, (_, s) in zip(backbone, M.backbone_param_specs(CFG)):
            assert p.shape == s
        for p, (_, s) in zip(adapter, M.adapter_param_specs(CFG, LORA)):
            assert p.shape == s

    def test_7b_param_count_close_to_7b(self):
        c = CONFIGS["llama2-7b"]
        assert 6.5e9 < c.param_count() < 7.5e9

    def test_13b_param_count(self):
        c = CONFIGS["llama2-13b"]
        assert 12.5e9 < c.param_count() < 13.5e9


class TestPrefill:
    def test_shapes(self, backbone, adapter):
        logits, kc, vc = M.prefill(CFG, LORA, backbone, adapter, _toks(2, 8))
        assert logits.shape == (2, CFG.vocab)
        assert kc.shape == (CFG.n_layers, 2, CFG.n_kv_heads, CFG.max_seq,
                            CFG.head_dim)
        assert vc.shape == kc.shape

    def test_matches_pure_jnp_oracle(self, backbone, adapter):
        toks = _toks(1, 8)
        logits, _, _ = M.prefill(CFG, LORA, backbone, adapter, toks)
        ref = M.prefill_ref(CFG, LORA, backbone, adapter, toks)
        assert_allclose(np.asarray(logits), np.asarray(ref), rtol=1e-3, atol=1e-3)

    def test_cache_padding_zero(self, backbone, adapter):
        _, kc, _ = M.prefill(CFG, LORA, backbone, adapter, _toks(1, 8))
        assert float(jnp.abs(kc[:, :, :, 8:, :]).max()) == 0.0

    def test_batch_rows_independent(self, backbone, adapter):
        """Row i of a batched prefill equals the same prompt alone."""
        toks = _toks(3, 8, seed=7)
        lb, _, _ = M.prefill(CFG, LORA, backbone, adapter, toks)
        l0, _, _ = M.prefill(CFG, LORA, backbone, adapter, toks[1:2])
        assert_allclose(np.asarray(lb[1]), np.asarray(l0[0]), rtol=1e-4,
                        atol=1e-4)

    def test_deterministic(self, backbone, adapter):
        t = _toks(1, 8)
        l1, _, _ = M.prefill(CFG, LORA, backbone, adapter, t)
        l2, _, _ = M.prefill(CFG, LORA, backbone, adapter, t)
        assert_allclose(np.asarray(l1), np.asarray(l2), rtol=0, atol=0)


class TestDecode:
    def test_kv_consistency_with_prefill(self, backbone, adapter):
        """prefill(S) + decode_step == prefill(S+1): the contract that lets
        the Rust serving loop alternate artifacts."""
        toks = _toks(2, 8, seed=3)
        logits, kc, vc = M.prefill(CFG, LORA, backbone, adapter, toks)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        l2, _, _ = M.decode_step(CFG, LORA, backbone, adapter, nxt, kc, vc,
                                 jnp.asarray(8, jnp.int32))
        toks9 = jnp.concatenate([toks, nxt[:, None]], axis=1)
        l9, _, _ = M.prefill(CFG, LORA, backbone, adapter, toks9)
        assert_allclose(np.asarray(l2), np.asarray(l9), rtol=2e-3, atol=2e-3)

    def test_multi_step_chain(self, backbone, adapter):
        """Three greedy decode steps equal prefill of the full sequence."""
        toks = _toks(1, 4, seed=11)
        logits, kc, vc = M.prefill(CFG, LORA, backbone, adapter, toks)
        seq = toks
        pos = 4
        for _ in range(3):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
            logits, kc, vc = M.decode_step(
                CFG, LORA, backbone, adapter, nxt, kc, vc,
                jnp.asarray(pos, jnp.int32),
            )
            pos += 1
        lf, _, _ = M.prefill(CFG, LORA, backbone, adapter, seq)
        assert_allclose(np.asarray(logits), np.asarray(lf), rtol=5e-3, atol=5e-3)

    def test_updates_cache_at_pos(self, backbone, adapter):
        toks = _toks(1, 8)
        logits, kc, vc = M.prefill(CFG, LORA, backbone, adapter, toks)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        _, kc2, _ = M.decode_step(CFG, LORA, backbone, adapter, nxt, kc, vc,
                                  jnp.asarray(8, jnp.int32))
        # pos 8 now non-zero, later slots still zero.
        assert float(jnp.abs(kc2[:, :, :, 8, :]).max()) > 0.0
        assert float(jnp.abs(kc2[:, :, :, 9:, :]).max()) == 0.0


class TestAdapterSemantics:
    def test_adapters_change_output(self, backbone):
        """Two different adapters over one shared backbone must produce
        different logits — the multi-tenant property."""
        a0 = M.init_adapter(CFG, LORA, seed=0)
        a1 = M.init_adapter(CFG, LORA, seed=1)
        t = _toks(1, 8)
        l0, _, _ = M.prefill(CFG, LORA, backbone, a0, t)
        l1, _, _ = M.prefill(CFG, LORA, backbone, a1, t)
        assert float(jnp.abs(l0 - l1).max()) > 1e-3

    def test_zero_adapter_equals_base_model(self, backbone):
        """An all-zero adapter must reproduce the raw backbone — sharing
        never perturbs the backbone weights (read-only property)."""
        zeros = [jnp.zeros_like(p) for p in
                 M.init_adapter(CFG, LORA, seed=0)]
        t = _toks(1, 8)
        lz, _, _ = M.prefill(CFG, LORA, backbone, zeros, t)
        # Oracle with scale 0 ≡ no adapter at all.
        lb = M.prefill_ref(CFG, LoraConfig(rank=4, alpha=0.0), backbone,
                           zeros, t)
        assert_allclose(np.asarray(lz), np.asarray(lb), rtol=1e-3, atol=1e-3)

    def test_adapter_init_deterministic(self):
        a = M.init_adapter(CFG, LORA, seed=5)
        b = M.init_adapter(CFG, LORA, seed=5)
        for x, y in zip(a, b):
            assert_allclose(np.asarray(x), np.asarray(y), rtol=0, atol=0)
