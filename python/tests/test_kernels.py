"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/ranks/scales; assert_allclose against the oracle is
THE core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref as R
from compile.kernels.attention import attention, attention_bh
from compile.kernels.lora_matmul import lora_matmul, lora_matmul_batched

RNG = np.random.default_rng(42)


def _arr(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------- LoRA


class TestLoraMatmul:
    def test_matches_ref_basic(self):
        x, w = _arr(32, 64), _arr(64, 48)
        a, b = _arr(64, 8), _arr(8, 48)
        assert_allclose(
            lora_matmul(x, w, a, b, 2.0), R.lora_matmul_ref(x, w, a, b, 2.0),
            rtol=1e-5, atol=1e-5,
        )

    def test_zero_adapter_is_backbone_only(self):
        """With B = 0 the output must equal the plain backbone matmul —
        the 'fresh adapter is a no-op' property of LoRA."""
        x, w, a = _arr(16, 32), _arr(32, 24), _arr(32, 4)
        b = jnp.zeros((4, 24), jnp.float32)
        assert_allclose(
            lora_matmul(x, w, a, b, 2.0), jnp.matmul(x, w), rtol=1e-5, atol=1e-6
        )

    def test_zero_scale_is_backbone_only(self):
        x, w = _arr(16, 32), _arr(32, 24)
        a, b = _arr(32, 4), _arr(4, 24)
        assert_allclose(
            lora_matmul(x, w, a, b, 0.0), jnp.matmul(x, w), rtol=1e-5, atol=1e-6
        )

    def test_equivalent_to_merged_weights(self):
        """Unmerged LoRA must equal inference with W' = W + scale*A@B.
        This is the §4.4 claim that separation does not change accuracy."""
        x, w = _arr(16, 32), _arr(32, 24)
        a, b = _arr(32, 4), _arr(4, 24)
        merged = w + 1.5 * (a @ b)
        assert_allclose(
            lora_matmul(x, w, a, b, 1.5), jnp.matmul(x, merged),
            rtol=1e-4, atol=1e-4,
        )

    def test_batched_wrapper(self):
        x = _arr(2, 5, 32)
        w, a, b = _arr(32, 24), _arr(32, 4), _arr(4, 24)
        y = lora_matmul_batched(x, w, a, b, 2.0)
        assert y.shape == (2, 5, 24)
        yr = R.lora_matmul_ref(x.reshape(-1, 32), w, a, b, 2.0).reshape(2, 5, 24)
        assert_allclose(y, yr, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 48),
        k=st.integers(1, 64),
        n=st.integers(1, 48),
        r=st.integers(1, 16),
        scale=st.floats(0.0, 4.0),
    )
    def test_hypothesis_shapes(self, m, k, n, r, scale):
        rng = np.random.default_rng(m * 1000 + k * 100 + n * 10 + r)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        a = jnp.asarray(rng.normal(size=(k, r)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(r, n)).astype(np.float32))
        assert_allclose(
            lora_matmul(x, w, a, b, scale),
            R.lora_matmul_ref(x, w, a, b, scale),
            rtol=2e-4, atol=2e-4,
        )

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
    def test_explicit_blocks(self, bm, bn, bk):
        x, w = _arr(128, 128), _arr(128, 128)
        a, b = _arr(128, 8), _arr(8, 128)
        y = lora_matmul(x, w, a, b, 1.0, block_m=bm, block_n=bn, block_k=bk)
        assert_allclose(
            y, R.lora_matmul_ref(x, w, a, b, 1.0), rtol=1e-4, atol=1e-4
        )

    def test_under_jit(self):
        """The kernel must be jittable — it lowers into the AOT module."""
        x, w = _arr(16, 32), _arr(32, 24)
        a, b = _arr(32, 4), _arr(4, 24)
        y = jax.jit(lambda *t: lora_matmul(*t, 1.0))(x, w, a, b)
        assert_allclose(y, R.lora_matmul_ref(x, w, a, b, 1.0), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- attention


class TestAttention:
    def test_causal_matches_ref(self):
        q, k, v = _arr(32, 16), _arr(32, 16), _arr(32, 16)
        assert_allclose(
            attention(q, k, v, causal=True), R.attention_ref(q, k, v, True),
            rtol=1e-5, atol=1e-5,
        )

    def test_non_causal_matches_ref(self):
        q, k, v = _arr(8, 16), _arr(24, 16), _arr(24, 16)
        assert_allclose(
            attention(q, k, v, causal=False), R.attention_ref(q, k, v, False),
            rtol=1e-5, atol=1e-5,
        )

    def test_causal_first_row_is_v0(self):
        """Causal row 0 can only attend position 0 ⇒ output == v[0]."""
        q, k, v = _arr(8, 8), _arr(8, 8), _arr(8, 8)
        out = attention(q, k, v, causal=True)
        assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-6)

    def test_softmax_rows_convex(self):
        """Output rows live in the convex hull of V rows: bounded by V."""
        q, k = _arr(16, 8), _arr(16, 8)
        v = jnp.asarray(RNG.uniform(0.0, 1.0, size=(16, 8)).astype(np.float32))
        out = attention(q, k, v, causal=False)
        assert float(out.min()) >= float(v.min()) - 1e-5
        assert float(out.max()) <= float(v.max()) + 1e-5

    @settings(max_examples=20, deadline=None)
    @given(s=st.integers(1, 40), d=st.sampled_from([4, 8, 16, 32]))
    def test_hypothesis_causal(self, s, d):
        rng = np.random.default_rng(s * 100 + d)
        q = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(s, d)).astype(np.float32))
        assert_allclose(
            attention(q, k, v, causal=True), R.attention_ref(q, k, v, True),
            rtol=2e-4, atol=2e-4,
        )

    def test_batched_heads(self):
        q, k, v = _arr(2, 3, 16, 8), _arr(2, 3, 16, 8), _arr(2, 3, 16, 8)
        out = attention_bh(q, k, v)
        assert out.shape == (2, 3, 16, 8)
        for bi in range(2):
            for hi in range(3):
                assert_allclose(
                    out[bi, hi], R.attention_ref(q[bi, hi], k[bi, hi], v[bi, hi]),
                    rtol=1e-4, atol=1e-4,
                )

    @pytest.mark.parametrize("block_q", [4, 8, 16])
    def test_query_blocking(self, block_q):
        q, k, v = _arr(32, 8), _arr(32, 8), _arr(32, 8)
        assert_allclose(
            attention(q, k, v, causal=True, block_q=block_q),
            R.attention_ref(q, k, v, True), rtol=1e-4, atol=1e-4,
        )


# --------------------------------------------------------------- micro-ops


class TestMicroOps:
    def test_rmsnorm_unit_gamma_unit_norm(self):
        x = _arr(4, 16)
        y = R.rmsnorm_ref(x, jnp.ones(16))
        rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
        assert_allclose(rms, jnp.ones(4), rtol=1e-3)

    def test_swiglu_zero_gate(self):
        x = jnp.zeros((4, 8))
        y = R.swiglu_ref(x, _arr(8, 16), _arr(8, 16), _arr(16, 8))
        assert_allclose(y, jnp.zeros((4, 8)), atol=1e-7)
