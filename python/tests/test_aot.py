"""AOT pipeline tests: HLO text artifacts, manifest completeness, weight
files, and golden reproducibility.

These run against whatever ``artifacts/`` content exists (built by
``make artifacts``); if absent, a quick in-process build of the smallest
bucket is exercised instead so the suite is self-contained.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.configs import CONFIGS, LoraConfig

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                   "llama-tiny")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestHloText:
    def test_lower_prefill_produces_parseable_hlo(self):
        cfg = CONFIGS["llama-tiny"]
        text = aot.to_hlo_text(aot.lower_prefill(cfg, LoraConfig(), 1, 16))
        assert text.startswith("HloModule"), text[:80]
        # return_tuple=True ⇒ root is a 3-tuple (logits, k, v).
        assert "(f32[1,512]" in text.replace(" ", "")

    def test_lower_decode_produces_parseable_hlo(self):
        cfg = CONFIGS["llama-tiny"]
        text = aot.to_hlo_text(aot.lower_decode(cfg, LoraConfig(), 1))
        assert text.startswith("HloModule")

    def test_no_custom_calls(self):
        """interpret=True must lower Pallas to plain HLO — a Mosaic
        custom-call would be unloadable by the CPU PJRT runtime."""
        cfg = CONFIGS["llama-tiny"]
        text = aot.to_hlo_text(aot.lower_prefill(cfg, LoraConfig(), 1, 16))
        assert "custom-call" not in text or "mosaic" not in text.lower()


class TestManifest:
    def test_artifact_inventory_complete(self):
        m = _manifest()
        names = {a["name"] for a in m["artifacts"]}
        for b in m["batch_buckets"]:
            assert f"decode_b{b}" in names
            for s in m["seq_buckets"]:
                assert f"prefill_b{b}_s{s}" in names

    def test_artifact_files_exist_and_hash(self):
        import hashlib
        m = _manifest()
        for a in m["artifacts"]:
            p = os.path.join(ART, a["file"])
            assert os.path.exists(p), a["file"]
            text = open(p).read()
            assert hashlib.sha256(text.encode()).hexdigest()[:16] == a["sha256"]

    def test_backbone_bin_size_matches_specs(self):
        m = _manifest()
        expect = 4 * sum(
            int(np.prod(s["shape"])) for s in m["backbone_params"]
        )
        assert os.path.getsize(os.path.join(ART, "backbone.bin")) == expect

    def test_adapter_bins(self):
        m = _manifest()
        expect = 4 * sum(
            int(np.prod(s["shape"])) for s in m["adapter_params"]
        )
        for i in range(m["n_adapters"]):
            assert os.path.getsize(
                os.path.join(ART, f"adapter_{i}.bin")) == expect

    def test_config_matches_python(self):
        m = _manifest()
        cfg = CONFIGS["llama-tiny"]
        assert m["config"]["param_count"] == cfg.param_count()
        assert m["config"]["head_dim"] == cfg.head_dim


class TestGoldens:
    def test_goldens_reproduce(self):
        """Re-run prefill from the exported weight bytes and match the
        stored goldens — proves .bin files are faithful."""
        m = _manifest()
        cfg = CONFIGS["llama-tiny"]
        lora = LoraConfig()
        raw = np.fromfile(os.path.join(ART, "backbone.bin"), "<f4")
        bb, off = [], 0
        for s in m["backbone_params"]:
            n = int(np.prod(s["shape"]))
            bb.append(jnp.asarray(raw[off:off + n].reshape(s["shape"])))
            off += n
        g = m["goldens"][0]
        rawa = np.fromfile(os.path.join(ART, f"adapter_{g['adapter']}.bin"),
                           "<f4")
        ad, off = [], 0
        for s in m["adapter_params"]:
            n = int(np.prod(s["shape"]))
            ad.append(jnp.asarray(rawa[off:off + n].reshape(s["shape"])))
            off += n
        toks = aot.golden_prompt(g["batch"], g["seq"], cfg.vocab, g["adapter"])
        logits, _, _ = M.prefill(cfg, lora, bb, ad, jnp.asarray(toks))
        np.testing.assert_allclose(
            np.asarray(logits)[0, :8], g["prefill_logits_head"],
            rtol=1e-4, atol=1e-4,
        )

    def test_golden_prompt_deterministic(self):
        a = aot.golden_prompt(2, 16, 512, 1)
        b = aot.golden_prompt(2, 16, 512, 1)
        assert (a == b).all()
        assert a.min() >= 0 and a.max() < 512
