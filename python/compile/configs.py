"""Model configurations (see DESIGN.md §5).

``llama-tiny`` is the real serving model (PJRT CPU path, e2e example,
tests). ``llama-100m`` is the larger end-to-end driver. The 7B/13B entries
exist so the L3 simulator and the artifact model share one source of truth
for parameter counts and memory sizes; they are never AOT-compiled here.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    max_seq: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Exact parameter count of the backbone (tied-free lm head)."""
        c = self
        per_layer = (
            c.d_model * c.d_model  # wq
            + 2 * c.d_model * (c.n_kv_heads * self.head_dim)  # wk, wv
            + c.d_model * c.d_model  # wo
            + 3 * c.d_model * c.d_ff  # gate, up, down
            + 2 * c.d_model  # two RMSNorm gammas
        )
        return c.vocab * c.d_model * 2 + c.d_model + c.n_layers * per_layer

    def bytes_fp16(self) -> int:
        return 2 * self.param_count()

    def bytes_fp32(self) -> int:
        return 4 * self.param_count()


@dataclasses.dataclass(frozen=True)
class LoraConfig:
    """LoRA adapter hyper-parameters. Targets q/k/v/o as in the common
    Llama2 adapter recipe the paper pulls from HuggingFace."""

    rank: int = 8
    alpha: float = 16.0

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


CONFIGS = {
    "llama-tiny": ModelConfig(
        name="llama-tiny", vocab=512, d_model=256, n_layers=4,
        n_heads=8, n_kv_heads=4, d_ff=688, max_seq=256,
    ),
    "llama-100m": ModelConfig(
        name="llama-100m", vocab=4096, d_model=768, n_layers=12,
        n_heads=12, n_kv_heads=4, d_ff=2048, max_seq=512,
    ),
    # Modeled only (simulator coefficients) — never compiled in this repo.
    "llama2-7b": ModelConfig(
        name="llama2-7b", vocab=32000, d_model=4096, n_layers=32,
        n_heads=32, n_kv_heads=32, d_ff=11008, max_seq=4096,
    ),
    "llama2-13b": ModelConfig(
        name="llama2-13b", vocab=32000, d_model=5120, n_layers=40,
        n_heads=40, n_kv_heads=40, d_ff=13824, max_seq=4096,
    ),
}
