"""L2: Llama-architecture transformer with unmerged LoRA (JAX, build-time).

The forward graphs defined here — ``prefill`` and ``decode_step`` — are the
compute the Rust coordinator serves.  They call the L1 Pallas kernels
(`kernels.lora_matmul`, `kernels.attention`) so the kernels lower into the
same HLO module that `aot.py` exports as text for the PJRT runtime.

Design points that mirror the paper:

* **Unmerged LoRA** (§4.4): every attention projection computes
  ``x @ W + scale * (x @ A) @ B`` with the backbone ``W`` untouched — the
  exact property that lets the Rust runtime share one set of backbone
  buffers (read-only) across many isolated function instances while each
  instance supplies its own adapter buffers.
* **Backbone / adapter parameter split**: ``prefill``/``decode_step`` take
  the backbone parameter list and the adapter parameter list as *separate
  runtime inputs* (never baked as constants), so the Rust side can bind the
  shared backbone buffers and per-function adapter buffers independently.
* **Function-level batching** (§4.2): all requests in a batch enter prefill
  together and decode in lockstep, so a single scalar ``pos`` suffices.

Parameter layout (positional, mirrored by `aot.py`'s manifest and the Rust
loader `rust/src/runtime/weights.rs`):

    backbone: embed,
              [per layer] rms_attn, wq, wk, wv, wo, rms_mlp, w_gate, w_up, w_down,
              rms_final, lm_head
    adapter:  [per layer] a_q, b_q, a_k, b_k, a_v, b_v, a_o, b_o
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import CONFIGS, LoraConfig, ModelConfig
from .kernels.attention import attention_bh
from .kernels.lora_matmul import lora_matmul_batched
from .kernels.ref import rmsnorm_ref

# ---------------------------------------------------------------------------
# Parameter plumbing


def backbone_param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list for the backbone. Single source of truth
    for model.py, aot.py's manifest, and (via the manifest) the Rust loader."""
    d, kv = cfg.d_model, cfg.n_kv_heads * cfg.head_dim
    specs = [("embed", (cfg.vocab, d))]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.rms_attn", (d,)),
            (f"l{l}.wq", (d, d)),
            (f"l{l}.wk", (d, kv)),
            (f"l{l}.wv", (d, kv)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.rms_mlp", (d,)),
            (f"l{l}.w_gate", (d, cfg.d_ff)),
            (f"l{l}.w_up", (d, cfg.d_ff)),
            (f"l{l}.w_down", (cfg.d_ff, d)),
        ]
    specs += [("rms_final", (d,)), ("lm_head", (d, cfg.vocab))]
    return specs


def adapter_param_specs(cfg: ModelConfig, lora: LoraConfig):
    """Ordered (name, shape) list for one LoRA adapter (q/k/v/o targets)."""
    d, kv, r = cfg.d_model, cfg.n_kv_heads * cfg.head_dim, lora.rank
    specs = []
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.a_q", (d, r)), (f"l{l}.b_q", (r, d)),
            (f"l{l}.a_k", (d, r)), (f"l{l}.b_k", (r, kv)),
            (f"l{l}.a_v", (d, r)), (f"l{l}.b_v", (r, kv)),
            (f"l{l}.a_o", (d, r)), (f"l{l}.b_o", (r, d)),
        ]
    return specs


def init_backbone(cfg: ModelConfig, seed: int = 0):
    """Deterministic random backbone weights (scaled for stable logits)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in backbone_param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("rms_attn", "rms_mlp", "rms_final")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return params


def init_adapter(cfg: ModelConfig, lora: LoraConfig, seed: int):
    """Deterministic adapter weights. B starts non-zero (a *trained* adapter:
    freshly-initialised LoRA has B=0, which would make every adapter a
    no-op and hide sharing bugs)."""
    key = jax.random.PRNGKey(1000 + seed)
    params = []
    for name, shape in adapter_param_specs(cfg, lora):
        key, sub = jax.random.split(key)
        params.append(
            jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(shape[0])
        )
    return params


def _unflatten(cfg: ModelConfig, backbone):
    it = iter(backbone)
    embed = next(it)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append([next(it) for _ in range(9)])
    rms_final = next(it)
    lm_head = next(it)
    return embed, layers, rms_final, lm_head


def _unflatten_adapter(cfg: ModelConfig, adapter):
    it = iter(adapter)
    return [[next(it) for _ in range(8)] for _ in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# RoPE


def _rope(x, positions, theta):
    """Rotary embedding. x [B, H, S, D]; positions [S] (absolute)."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]  # [S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocks


def _proj(x, w, a, b, scale):
    """Unmerged LoRA projection via the fused Pallas kernel."""
    return lora_matmul_batched(x, w, a, b, scale)


def _heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)  # [B, H, S, hd]


def _attn_block(cfg, lora_scale, layer, adapter, x, positions, kv_slot):
    """Attention with unmerged LoRA on q/k/v/o.

    Returns (out [B,S,d], k_new [B,KVH,S,hd], v_new [B,KVH,S,hd]).
    ``kv_slot`` is None for prefill (self-attend over x) or
    (k_cache, v_cache, pos) for decode (attend over prefix <= pos).
    """
    rms_attn, wq, wk, wv, wo, *_ = layer
    a_q, b_q, a_k, b_k, a_v, b_v, a_o, b_o = adapter
    h = rmsnorm_ref(x, rms_attn, cfg.norm_eps)
    q = _proj(h, wq, a_q, b_q, lora_scale)
    k = _proj(h, wk, a_k, b_k, lora_scale)
    v = _proj(h, wv, a_v, b_v, lora_scale)
    hd = cfg.head_dim
    q = _heads(q, cfg.n_heads, hd)
    k = _heads(k, cfg.n_kv_heads, hd)
    v = _heads(v, cfg.n_kv_heads, hd)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)

    rep = cfg.n_heads // cfg.n_kv_heads
    if kv_slot is None:
        # Prefill: causal attention over the (aligned) sequence via the
        # Pallas flash-style kernel.
        kx = jnp.repeat(k, rep, axis=1)
        vx = jnp.repeat(v, rep, axis=1)
        o = attention_bh(q, kx, vx, causal=True)  # [B, H, S, hd]
    else:
        # Decode: masked attention over the static-length cache.
        k_cache, v_cache, pos = kv_slot  # [B, KVH, Smax, hd], scalar pos
        kx = jnp.repeat(k_cache, rep, axis=1)
        vx = jnp.repeat(v_cache, rep, axis=1)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kx) / jnp.sqrt(
            jnp.asarray(hd, jnp.float32)
        )
        idx = jnp.arange(kx.shape[2])
        mask = idx[None, None, None, :] <= pos
        scores = jnp.where(mask, scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, vx)

    bsz, _, s, _ = o.shape
    o = o.transpose(0, 2, 1, 3).reshape(bsz, s, cfg.d_model)
    out = _proj(o, wo, a_o, b_o, lora_scale)
    return out, k, v


def _mlp_block(cfg, layer, x):
    rms_mlp, w_gate, w_up, w_down = layer[5], layer[6], layer[7], layer[8]
    h = rmsnorm_ref(x, rms_mlp, cfg.norm_eps)
    g = jnp.matmul(h, w_gate)
    u = jnp.matmul(h, w_up)
    return jnp.matmul(jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# Public graphs (AOT entry points)


def prefill(cfg: ModelConfig, lora: LoraConfig, backbone, adapter, tokens):
    """Prefill a batch of aligned prompts.

    tokens [B, S] int32  ->  (logits [B, vocab] for the last position,
                              k_cache [L, B, KVH, Smax, hd],
                              v_cache [L, B, KVH, Smax, hd])

    The caches are padded to ``cfg.max_seq`` so `decode_step` consumes them
    without reshaping; positions past S are zero and masked off by pos.
    """
    embed, layers, rms_final, lm_head = _unflatten(cfg, backbone)
    adapters = _unflatten_adapter(cfg, adapter)
    bsz, s = tokens.shape
    x = jnp.take(embed, tokens, axis=0)  # [B, S, d]
    positions = jnp.arange(s)
    k_caches, v_caches = [], []
    for layer, ad in zip(layers, adapters):
        attn, k, v = _attn_block(cfg, lora.scale, layer, ad, x, positions, None)
        x = x + attn
        x = x + _mlp_block(cfg, layer, x)
        pad = cfg.max_seq - s
        k_caches.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        v_caches.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
    x = rmsnorm_ref(x, rms_final, cfg.norm_eps)
    logits = jnp.matmul(x[:, -1, :], lm_head)  # [B, vocab]
    return logits, jnp.stack(k_caches), jnp.stack(v_caches)


def decode_step(cfg: ModelConfig, lora: LoraConfig, backbone, adapter,
                token, k_cache, v_cache, pos):
    """One lock-step decode step for a batch.

    token [B] int32; k_cache/v_cache [L, B, KVH, Smax, hd]; pos scalar int32
    (index the new token is written at; it attends to positions <= pos).
    Returns (logits [B, vocab], k_cache', v_cache').
    """
    embed, layers, rms_final, lm_head = _unflatten(cfg, backbone)
    adapters = _unflatten_adapter(cfg, adapter)
    x = jnp.take(embed, token[:, None], axis=0)  # [B, 1, d]
    positions = jnp.atleast_1d(pos).astype(jnp.int32)
    new_k, new_v = [], []
    for li, (layer, ad) in enumerate(zip(layers, adapters)):
        kc, vc = k_cache[li], v_cache[li]
        # Write the new K/V at pos first, then attend over the prefix.
        rms_attn, wq, wk, wv, wo, *_ = layer
        # _attn_block computes k,v for the new token; do the cache insert here
        # so the block sees the updated cache.
        h = rmsnorm_ref(x, layer[0], cfg.norm_eps)
        a_q, b_q, a_k, b_k, a_v, b_v, a_o, b_o = ad
        k1 = _proj(h, wk, a_k, b_k, lora.scale)
        v1 = _proj(h, wv, a_v, b_v, lora.scale)
        k1 = _heads(k1, cfg.n_kv_heads, cfg.head_dim)
        k1 = _rope(k1, positions, cfg.rope_theta)
        v1 = _heads(v1, cfg.n_kv_heads, cfg.head_dim)
        kc = jax.lax.dynamic_update_slice(kc, k1, (0, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(vc, v1, (0, 0, pos, 0))
        attn, _, _ = _attn_block(
            cfg, lora.scale, layer, ad, x, positions, (kc, vc, pos)
        )
        x = x + attn
        x = x + _mlp_block(cfg, layer, x)
        new_k.append(kc)
        new_v.append(vc)
    x = rmsnorm_ref(x, rms_final, cfg.norm_eps)
    logits = jnp.matmul(x[:, -1, :], lm_head)
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def prefill_ref(cfg, lora, backbone, adapter, tokens):
    """Reference prefill using only jnp ops (no Pallas) — the L2 oracle."""
    from .kernels import ref as R

    embed, layers, rms_final, lm_head = _unflatten(cfg, backbone)
    adapters = _unflatten_adapter(cfg, adapter)
    bsz, s = tokens.shape
    x = jnp.take(embed, tokens, axis=0)
    positions = jnp.arange(s)
    for layer, ad in zip(layers, adapters):
        rms_attn, wq, wk, wv, wo, rms_mlp, w_gate, w_up, w_down = layer
        a_q, b_q, a_k, b_k, a_v, b_v, a_o, b_o = ad
        h = R.rmsnorm_ref(x, rms_attn, cfg.norm_eps)
        sc = lora.scale
        q = R.lora_matmul_ref(h.reshape(-1, cfg.d_model), wq, a_q, b_q, sc)
        k = R.lora_matmul_ref(h.reshape(-1, cfg.d_model), wk, a_k, b_k, sc)
        v = R.lora_matmul_ref(h.reshape(-1, cfg.d_model), wv, a_v, b_v, sc)
        hd = cfg.head_dim
        q = _heads(q.reshape(bsz, s, -1), cfg.n_heads, hd)
        k = _heads(k.reshape(bsz, s, -1), cfg.n_kv_heads, hd)
        v = _heads(v.reshape(bsz, s, -1), cfg.n_kv_heads, hd)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        rep = cfg.n_heads // cfg.n_kv_heads
        kx, vx = jnp.repeat(k, rep, axis=1), jnp.repeat(v, rep, axis=1)
        o = jnp.stack([
            jnp.stack([
                R.attention_ref(q[bi, hi], kx[bi, hi], vx[bi, hi], causal=True)
                for hi in range(cfg.n_heads)
            ])
            for bi in range(bsz)
        ])
        o = o.transpose(0, 2, 1, 3).reshape(bsz, s, cfg.d_model)
        o = R.lora_matmul_ref(o.reshape(-1, cfg.d_model), wo, a_o, b_o, sc)
        x = x + o.reshape(bsz, s, cfg.d_model)
        h2 = R.rmsnorm_ref(x, rms_mlp, cfg.norm_eps)
        x = x + R.swiglu_ref(h2, w_gate, w_up, w_down)
    x = R.rmsnorm_ref(x, rms_final, cfg.norm_eps)
    return jnp.matmul(x[:, -1, :], lm_head)
