"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts for the Rust
runtime, export weights, and write golden outputs for cross-language tests.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Outputs (under ``artifacts/``):

    <model>/prefill_b{B}_s{S}.hlo.txt   prefill graph per (batch, seq) bucket
    <model>/decode_b{B}.hlo.txt         lock-step decode graph per batch bucket
    <model>/backbone.bin                backbone weights, f32 LE, manifest order
    <model>/adapter_{i}.bin             one per LoRA adapter (4, as the paper)
    <model>/manifest.json               shapes, buckets, artifact inventory
    <model>/golden.json                 prefill/decode logits for fixed prompts

Run via ``make artifacts`` (no-op when inputs are unchanged) — Python never
runs on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .configs import CONFIGS, LoraConfig
from . import model as M

try:  # jax moved the private xla_client around across versions
    from jax._src.lib import xla_client as xc
except ImportError:  # pragma: no cover
    from jax.lib import xla_client as xc  # type: ignore

BATCH_BUCKETS = [1, 2, 4, 8]
SEQ_BUCKETS = [16, 64]
N_ADAPTERS = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path).

    ``as_hlo_text(True)`` = print_large_constants. CRITICAL: the default
    elides big constant literals as ``{...}``, which xla_extension 0.5.1's
    text parser silently turns into garbage — e.g. the RoPE angle tables
    (baked as constants by jax's constant folding) came back as zeros and
    corrupted every attention layer. Always print constants in full.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write_flat(path, params):
    """Concatenate f32 arrays little-endian in spec order."""
    with open(path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())


def lower_prefill(cfg, lora, batch, seq):
    fn = lambda bb, ad, toks: M.prefill(cfg, lora, bb, ad, toks)
    bb_specs = [_spec(s) for _, s in M.backbone_param_specs(cfg)]
    ad_specs = [_spec(s) for _, s in M.adapter_param_specs(cfg, lora)]
    return jax.jit(fn).lower(bb_specs, ad_specs, _spec((batch, seq), jnp.int32))


def lower_decode(cfg, lora, batch):
    fn = lambda bb, ad, tok, kc, vc, pos: M.decode_step(
        cfg, lora, bb, ad, tok, kc, vc, pos
    )
    bb_specs = [_spec(s) for _, s in M.backbone_param_specs(cfg)]
    ad_specs = [_spec(s) for _, s in M.adapter_param_specs(cfg, lora)]
    kv = _spec(
        (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)
    )
    return jax.jit(fn).lower(
        bb_specs, ad_specs, _spec((batch,), jnp.int32), kv, kv,
        _spec((), jnp.int32),
    )


def golden_prompt(batch, seq, vocab, adapter_id):
    """Deterministic prompt, reproduced bit-exactly by the Rust tests."""
    # Simple LCG so the Rust side can regenerate without numpy.
    state = 0x9E3779B9 ^ (batch * 1000003 + seq * 101 + adapter_id)
    toks = []
    for _ in range(batch * seq):
        state = (state * 1664525 + 1013904223) % (1 << 32)
        toks.append(state % vocab)
    return np.asarray(toks, np.int32).reshape(batch, seq)


def build(model_name: str, out_root: str, quick: bool) -> None:
    cfg = CONFIGS[model_name]
    lora = LoraConfig()
    out = os.path.join(out_root, model_name)
    os.makedirs(out, exist_ok=True)

    batches = [1, 2] if quick else BATCH_BUCKETS
    seqs = [16] if quick else SEQ_BUCKETS

    backbone = M.init_backbone(cfg)
    adapters = [init for init in (
        M.init_adapter(cfg, lora, i) for i in range(N_ADAPTERS)
    )]

    _write_flat(os.path.join(out, "backbone.bin"), backbone)
    for i, ad in enumerate(adapters):
        _write_flat(os.path.join(out, f"adapter_{i}.bin"), ad)

    artifacts = []
    for b in batches:
        for s in seqs:
            name = f"prefill_b{b}_s{s}"
            t0 = time.time()
            text = to_hlo_text(lower_prefill(cfg, lora, b, s))
            path = os.path.join(out, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            artifacts.append({
                "name": name, "kind": "prefill", "batch": b, "seq": s,
                "file": f"{name}.hlo.txt",
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            })
            print(f"lowered {name}: {len(text)} chars in {time.time()-t0:.1f}s")
        name = f"decode_b{b}"
        t0 = time.time()
        text = to_hlo_text(lower_decode(cfg, lora, b))
        path = os.path.join(out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts.append({
            "name": name, "kind": "decode", "batch": b, "seq": cfg.max_seq,
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"lowered {name}: {len(text)} chars in {time.time()-t0:.1f}s")

    # Goldens: prefill logits (+ one decode step) for fixed prompts, one per
    # adapter, smallest bucket — cross-checked by rust/tests/runtime_golden.rs.
    goldens = []
    b, s = batches[0], seqs[0]
    pf = jax.jit(lambda bb, ad, t: M.prefill(cfg, lora, bb, ad, t))
    dc = jax.jit(
        lambda bb, ad, t, kc, vc, p: M.decode_step(cfg, lora, bb, ad, t, kc, vc, p)
    )
    for ai in range(min(2, N_ADAPTERS) if quick else N_ADAPTERS):
        toks = golden_prompt(b, s, cfg.vocab, ai)
        logits, kc, vc = pf(backbone, adapters[ai], jnp.asarray(toks))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        l2, _, _ = dc(backbone, adapters[ai], nxt, kc, vc,
                      jnp.asarray(s, jnp.int32))
        goldens.append({
            "adapter": ai, "batch": b, "seq": s,
            "prefill_logits_head": np.asarray(logits)[0, :8].tolist(),
            "prefill_argmax": np.asarray(jnp.argmax(logits, -1)).tolist(),
            "decode_logits_head": np.asarray(l2)[0, :8].tolist(),
            "decode_argmax": np.asarray(jnp.argmax(l2, -1)).tolist(),
        })

    bb_specs = [
        {"name": n, "shape": list(s)} for n, s in M.backbone_param_specs(cfg)
    ]
    ad_specs = [
        {"name": n, "shape": list(s)}
        for n, s in M.adapter_param_specs(cfg, lora)
    ]
    manifest = {
        "model": model_name,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq, "head_dim": cfg.head_dim,
            "param_count": cfg.param_count(),
        },
        "lora": {"rank": lora.rank, "alpha": lora.alpha, "scale": lora.scale},
        "n_adapters": N_ADAPTERS,
        "batch_buckets": batches,
        "seq_buckets": seqs,
        "backbone_params": bb_specs,
        "adapter_params": ad_specs,
        "artifacts": artifacts,
        "goldens": goldens,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json ({len(artifacts)} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="llama-tiny", choices=list(CONFIGS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small bucket set (CI / smoke)")
    args = ap.parse_args()
    build(args.model, args.out, args.quick)


if __name__ == "__main__":
    main()
