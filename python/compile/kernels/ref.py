"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only.  The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` over a hypothesis sweep of
shapes, ranks and dtypes — this is the core correctness signal for L1.
"""

from __future__ import annotations

import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale):
    """Unmerged LoRA projection:  y = x @ W + scale * (x @ A) @ B.

    This is the paper's §4.4 "unmerged inference": the backbone matmul and
    the low-rank adapter matmul are computed separately and summed, so the
    shared backbone weight ``W`` stays read-only.

    Shapes: x [M, K], w [K, N], a [K, r], b [r, N]  ->  [M, N].
    """
    return jnp.matmul(x, w) + scale * jnp.matmul(jnp.matmul(x, a), b)


def attention_ref(q, k, v, causal=True):
    """Scaled dot-product attention over a single (batch, head) slice.

    Shapes: q [Sq, D], k [Sk, D], v [Sk, D]  ->  [Sq, D].
    ``causal`` masks position j > i + (Sk - Sq) (standard causal offset so a
    decode step with Sq=1 attends to the full prefix).
    """
    d = q.shape[-1]
    scores = jnp.matmul(q, k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        sq, sk = q.shape[0], k.shape[0]
        offset = sk - sq
        i = jnp.arange(sq)[:, None]
        j = jnp.arange(sk)[None, :]
        mask = j <= i + offset
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.matmul(p, v)


def rmsnorm_ref(x, gamma, eps=1e-5):
    """RMSNorm: x * gamma / rms(x)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * gamma * (1.0 / jnp.sqrt(ms + eps))


def swiglu_ref(x, w_gate, w_up, w_down):
    """Llama SwiGLU MLP: (silu(x Wg) * (x Wu)) Wd."""
    g = jnp.matmul(x, w_gate)
    u = jnp.matmul(x, w_up)
    return jnp.matmul(g * (1.0 / (1.0 + jnp.exp(-g))) * u, w_down)
