"""Causal attention as a Pallas kernel (prefill hot loop).

A single-pass softmax-attention kernel over one (batch, head) slice.  The
grid walks query blocks; for each query block the full K/V stripe is
resident in VMEM (sequence lengths in this repo are small enough — the
serving path buckets prefill at <= 256 tokens — that a [S, D] stripe fits
comfortably; a production TPU kernel would add an inner KV-block loop with
online softmax, which interpret mode would obscure without exercising any
additional HLO structure).

Hardware adaptation: the CUDA version of this loop (FlashAttention) tiles
over shared memory per threadblock; here BlockSpec expresses the same
HBM->VMEM schedule, and the MXU consumes the [bq, D] @ [D, S] score matmul.

interpret=True everywhere — see lora_matmul.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, causal, kv_len):
    """One query block against the full KV stripe.

    q_ref [bq, D]; k_ref [S, D]; v_ref [S, D]; o_ref [bq, D].
    """
    qi = pl.program_id(0)
    bq = q_ref.shape[0]
    q = q_ref[...]
    k = k_ref[...]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    if causal:
        # Global query index of each row in this block.
        row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        # Standard causal offset: query i attends keys j <= i + (Sk - Sq_total)
        # handled by the caller always passing aligned prefill (Sk == Sq).
        scores = jnp.where(col <= row, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    z = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot((p / z).astype(v_ref.dtype), v_ref[...])


def attention(q, k, v, *, causal=True, block_q=None):
    """Causal attention for one (batch, head) slice: [Sq,D],[Sk,D],[Sk,D]->[Sq,D].

    For causal masking Sq must equal Sk (prefill); decode (Sq=1) uses
    ``causal=False`` against the valid prefix, matching ref.attention_ref.
    """
    sq, d = q.shape
    sk = k.shape[0]
    if causal:
        assert sq == sk, "causal prefill kernel expects aligned Q/K lengths"
    bq = block_q or min(64, sq)
    while sq % bq:
        bq -= 1
    kernel = functools.partial(
        _attn_kernel, sm_scale=1.0 / (d**0.5), causal=causal, kv_len=sk
    )
    return pl.pallas_call(
        kernel,
        grid=(sq // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((sk, d), lambda i: (0, 0)),
            pl.BlockSpec((sk, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        interpret=True,
    )(q, k, v)


def attention_bh(q, k, v, *, causal=True):
    """Batched-heads wrapper: q [B, H, S, D], k/v [B, H, S, D] -> [B, H, S, D]."""
    fn = functools.partial(attention, causal=causal)
    return jax.vmap(jax.vmap(fn))(q, k, v)
