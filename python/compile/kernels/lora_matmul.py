"""Fused unmerged-LoRA projection as a Pallas kernel (L1 hot spot).

The paper (§4.4) keeps backbone and adapter computation *separate* so the
shared backbone weight stays read-only:

    y = x @ W  +  scale * (x @ A) @ B

The naive formulation launches three matmuls and reads the activation tile
``x`` from HBM twice.  This kernel fuses all three into one Pallas grid so
each ``x`` tile is loaded into VMEM once and reused for both the backbone
matmul (MXU-shaped tiles) and the low-rank adapter pair.  This is the
TPU-side analogue of Punica's SGMV trick on CUDA: the adapter matmuls are
tiny and memory-bound, so their cost disappears entirely once they ride on
the backbone tile schedule.

Hardware adaptation (DESIGN.md §2): CUDA threadblock tiling becomes a Pallas
grid over (M/bm, N/bn); the K-reduction runs as the innermost grid axis with
an accumulator held in the output ref (VMEM-resident across the K loop).
The LoRA rank r is small (8–64) so ``A``'s [bk, r] slice and ``B``'s [r, bn]
slice both fit beside the backbone tiles in VMEM.

All `pallas_call`s use ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers to plain HLO that any backend
executes (and that `aot.py` can export as HLO text for the Rust runtime).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_matmul_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scale, nsteps):
    """One (bm, bn) output tile; grid axis 2 walks the K reduction.

    x_ref [bm, bk] — activation tile, read ONCE per grid step and reused by
                     both the backbone and adapter products.
    w_ref [bk, bn] — backbone tile (shared, read-only).
    a_ref [bk, r]  — LoRA down-projection slice for this K step.
    b_ref [r, bn]  — LoRA up-projection slice for this N tile (K-invariant).
    o_ref [bm, bn] — accumulator, VMEM-resident across the K loop.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    # Backbone partial product: MXU-shaped [bm, bk] @ [bk, bn].
    acc = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # Adapter partial product over the same K slice: (x @ A_k) @ B.
    # Distributing the K-sum through the low-rank pair is exact:
    #   sum_k (x_k A_k) B == (x A) B.
    xa = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    acc = acc + scale * jnp.dot(xa, b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += acc.astype(o_ref.dtype)


def lora_matmul(x, w, a, b, scale, *, block_m=None, block_n=None, block_k=None):
    """Fused y = x @ W + scale * (x @ A) @ B via a single Pallas kernel.

    Shapes: x [M, K], w [K, N], a [K, r], b [r, N] -> [M, N].
    Block sizes default to MXU-friendly tiles clamped to the problem size.
    Dimensions must be divisible by the chosen blocks (the AOT path always
    pads to multiples of 8; tests exercise ragged shapes via the clamping).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    r = a.shape[1]
    assert a.shape == (k, r) and b.shape == (r, n), (a.shape, b.shape)

    bm = block_m or min(128, m)
    bn = block_n or min(128, n)
    bk = block_k or min(128, k)
    # Clamp to divisors so ragged test shapes still work.
    while m % bm:
        bm -= 1
    while n % bn:
        bn -= 1
    while k % bk:
        bk -= 1
    nsteps = k // bk

    kernel = functools.partial(_lora_matmul_kernel, scale=scale, nsteps=nsteps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nsteps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),  # x: row tile walks K
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),  # w: K x N tile
            pl.BlockSpec((bk, r), lambda i, j, s: (s, 0)),   # a: K slice, full rank
            pl.BlockSpec((r, bn), lambda i, j, s: (0, j)),   # b: full rank, N tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, a, b)


def lora_matmul_batched(x, w, a, b, scale):
    """vmap-free batched wrapper: flattens [..., K] leading dims to M."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    y = lora_matmul(x.reshape(-1, k), w, a, b, scale)
    return y.reshape(*lead, w.shape[1])
