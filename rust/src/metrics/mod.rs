//! Request-level metrics and aggregation: TTFT, TPOT, E2E latency,
//! cold-start breakdown, SLO violation, throughput (paper §6.1 metrics).

use std::collections::BTreeMap;

use crate::artifact::Tier;
use crate::coldstart::ColdPath;
use crate::trace::Request;
use crate::util::stats::{self, Summary};

/// The cold-start / serving phases the paper's breakdown figures track
/// (Fig. 1, Fig. 8). Order matters: it is the loading precedence order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Phase {
    Queue,
    ContainerInit,
    LibraryLoad,
    BackboneLoad,
    AdapterLoad,
    KernelCompile,
    Prefill,
    Decode,
}

impl Phase {
    pub const ALL: [Phase; 8] = [
        Phase::Queue,
        Phase::ContainerInit,
        Phase::LibraryLoad,
        Phase::BackboneLoad,
        Phase::AdapterLoad,
        Phase::KernelCompile,
        Phase::Prefill,
        Phase::Decode,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Queue => "queue",
            Phase::ContainerInit => "container-init",
            Phase::LibraryLoad => "library-load",
            Phase::BackboneLoad => "backbone-load",
            Phase::AdapterLoad => "adapter-load",
            Phase::KernelCompile => "kernel-compile",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }

    pub fn is_cold_start(self) -> bool {
        matches!(
            self,
            Phase::ContainerInit
                | Phase::LibraryLoad
                | Phase::BackboneLoad
                | Phase::AdapterLoad
                | Phase::KernelCompile
        )
    }
}

/// Completed-request record.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: u64,
    pub function: usize,
    pub arrival_s: f64,
    /// Per-phase durations (seconds).
    pub phases: BTreeMap<Phase, f64>,
    /// Time to first token (arrival → first token emitted).
    pub ttft_s: f64,
    /// Average time per output token over the decode.
    pub tpot_s: f64,
    /// Arrival → last token.
    pub e2e_s: f64,
    pub output_tokens: usize,
    pub batch_size: usize,
    /// Tier the backbone checkpoint was sourced from on this request's
    /// cold load (tiered store only; `None` = warm dispatch or flat
    /// fast path).
    pub backbone_tier: Option<Tier>,
    /// Which cold-start path this request's batch took (warm / tiered /
    /// snapshot-restore / pipelined) — the cold-start subsystem's
    /// per-request tag, exported by the trace sink.
    pub cold_path: ColdPath,
}

impl RequestOutcome {
    pub fn cold_start_s(&self) -> f64 {
        self.phases
            .iter()
            .filter(|(p, _)| p.is_cold_start())
            .map(|(_, d)| d)
            .sum()
    }
}

/// Extra run statistics beyond per-request metrics: counters the engine
/// and the policy layer (coordinator::policy) both write to.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub offload_events: usize,
    pub offloaded_gb: f64,
    pub preload_decisions: usize,
    pub blocked_dispatches: usize,
    /// Memory-blocked functions re-tried after memory was freed (a
    /// batch completion on a routing candidate, or a keep-alive
    /// eviction).
    pub blocked_retries: usize,
    pub cold_dispatches: usize,
    pub warm_dispatches: usize,
    /// Event-loop telemetry (fleet experiment / hygiene regressions).
    pub events_processed: u64,
    /// Peak number of *live* pending events (cancelled events leave the
    /// queue immediately, so this tracks real in-flight work).
    pub peak_event_queue: usize,
    /// `KeepaliveCheck` events actually processed — O(expiry windows),
    /// not O(completions), since exactly one is armed at a time.
    pub keepalive_checks: u64,
    /// Superseded events removed via `EventQueue::cancel` (the O(1)
    /// replacement for the old generation/version staleness skips).
    pub events_cancelled: u64,
    /// Aggregate billing samples handed to the `BillingModel` — exactly
    /// one per positive-width inter-event interval, independent of GPU
    /// count (the old path took one sample *per GPU* per interval).
    pub bill_samples: u64,
    /// Billing-class reclassifications (`Engine::reclassify_gpu` calls):
    /// O(1) each, O(GPUs touched) per event. The aggregate-verification
    /// counter `fleet --check` bounds per event.
    pub bill_reclass: u64,
    /// Wall-clock spent in the per-sample billing path — producing the
    /// aggregate sample, pricing it, and fanning it out to the opt-in
    /// series sampler / attached observers — measured only when
    /// `Engine::set_bill_timing(true)` (the fleet bench); zero
    /// otherwise. Nondeterministic — never rendered into report tables,
    /// only into BENCH_sim.json.
    pub bill_sample_wall_s: f64,
    /// Wall-clock spent in `Engine::reclassify_gpu` (billing-class
    /// maintenance, including the end-of-event dirty drain), under the
    /// same opt-in meter. Split from the sample meter so fleet profiles
    /// can attribute drain cost separately from sampling cost.
    pub bill_reclass_wall_s: f64,
    /// Backbone loads satisfied over the inter-zone fabric instead of
    /// remote storage (zone-sharded runs only; always 0 at zones = 1).
    pub cross_zone_fetches: u64,
    /// In-flight load completions re-scheduled because a flow joined or
    /// left a shared link (tiered store only; cancel + re-push pairs).
    pub load_retimes: u64,
    /// Tiered cold backbone loads resolved against the memory hierarchy.
    /// Conservation: `tier_hits_ram + tier_hits_ssd + tier_hits_remote
    /// == tiered_cold_loads` (checked by `Engine::check_indexes` and
    /// `fleet --check`).
    pub tiered_cold_loads: u64,
    /// Backbone sourced from the host-RAM checkpoint cache (or already
    /// staged host-side by the policy).
    pub tier_hits_ram: u64,
    /// Backbone read from node-local NVMe (cache miss, SSD-seeded store).
    pub tier_hits_ssd: u64,
    /// Backbone streamed from the remote object store over the NIC
    /// (cache miss, no local checkpoint).
    pub tier_hits_remote: u64,
    /// Checkpoints evicted from host caches by the cache policy.
    pub cache_evictions: u64,
    /// Fault injection: GPU crash events fired (0 with faults off).
    pub gpu_crashes: u64,
    /// Fault injection: GPU recoveries fired.
    pub gpu_recoveries: u64,
    /// Transient cold-load failures injected.
    pub load_failures: u64,
    /// Retry wakeups scheduled after transient load failures.
    pub retries: u64,
    /// Requests that failed permanently (deadline or retry exhaustion).
    pub requests_failed: u64,
    /// Requests re-enqueued because their in-flight batch's GPU crashed
    /// (no retry budget consumed; deadline still applies).
    pub redispatched: u64,
    /// Correlated-domain faults: whole-node outages fired / repaired.
    pub node_outages: u64,
    pub node_repairs: u64,
    /// Zone-wide outages fired / repaired (each engine is one zone).
    pub zone_outages: u64,
    pub zone_repairs: u64,
    /// Degraded-mode episodes begun / restored to full speed. The two
    /// differ only when a crash cut an episode short.
    pub degrades: u64,
    pub degrade_restores: u64,
    /// In-flight work re-timed by a degrade factor change: exec
    /// completion ticks and flat cold loads (cancel + re-push pairs),
    /// plus loads stretched at dispatch onto a degraded GPU.
    pub degrade_retimes: u64,
    /// Cold-start subsystem: snapshot builds started after a full
    /// tiered load. Conservation: `snapshot_builds == snapshots_built +
    /// snapshot_builds_cancelled + snapshot_builds_declined +
    /// in-flight builds` (checked by `Engine::check_indexes`).
    pub snapshot_builds: u64,
    /// Snapshot builds that completed and were admitted into the node's
    /// host cache.
    pub snapshots_built: u64,
    /// In-flight snapshot builds cancelled by a GPU/node failure.
    pub snapshot_builds_cancelled: u64,
    /// Completed builds the cache policy declined to admit (no room).
    pub snapshot_builds_declined: u64,
    /// Cold starts served by restoring a host-resident snapshot instead
    /// of the tiered walk.
    pub snapshot_restores: u64,
    /// Cold backbone loads split across K nodes (pipelined strategy).
    pub pipelined_loads: u64,
    /// Sibling shards created by pipelined loads (K-1 per load).
    pub pipelined_shards: u64,
    /// Consolidation transfers completed. End-of-run conservation:
    /// `pipeline_consolidations + pipeline_cancellations ==
    /// pipelined_loads` — every pipelined load either consolidates or
    /// is cancelled by a failure.
    pub pipeline_consolidations: u64,
    /// Pipelined loads cancelled (shards + consolidation torn down) by
    /// a GPU/node failure; the retry falls back to the tiered path.
    pub pipeline_cancellations: u64,
}

impl RunStats {
    /// Fold another zone's counters into this one (zone-sharded merge).
    /// Sums every additive counter; `peak_event_queue` takes the max —
    /// the zones' queues are disjoint, so the fleet-wide peak within one
    /// zone is the honest analogue of the single-engine statistic.
    pub fn merge(&mut self, o: &RunStats) {
        self.offload_events += o.offload_events;
        self.offloaded_gb += o.offloaded_gb;
        self.preload_decisions += o.preload_decisions;
        self.blocked_dispatches += o.blocked_dispatches;
        self.blocked_retries += o.blocked_retries;
        self.cold_dispatches += o.cold_dispatches;
        self.warm_dispatches += o.warm_dispatches;
        self.events_processed += o.events_processed;
        self.peak_event_queue = self.peak_event_queue.max(o.peak_event_queue);
        self.keepalive_checks += o.keepalive_checks;
        self.events_cancelled += o.events_cancelled;
        self.bill_samples += o.bill_samples;
        self.bill_reclass += o.bill_reclass;
        self.bill_sample_wall_s += o.bill_sample_wall_s;
        self.bill_reclass_wall_s += o.bill_reclass_wall_s;
        self.cross_zone_fetches += o.cross_zone_fetches;
        self.load_retimes += o.load_retimes;
        self.tiered_cold_loads += o.tiered_cold_loads;
        self.tier_hits_ram += o.tier_hits_ram;
        self.tier_hits_ssd += o.tier_hits_ssd;
        self.tier_hits_remote += o.tier_hits_remote;
        self.cache_evictions += o.cache_evictions;
        self.gpu_crashes += o.gpu_crashes;
        self.gpu_recoveries += o.gpu_recoveries;
        self.load_failures += o.load_failures;
        self.retries += o.retries;
        self.requests_failed += o.requests_failed;
        self.redispatched += o.redispatched;
        self.node_outages += o.node_outages;
        self.node_repairs += o.node_repairs;
        self.zone_outages += o.zone_outages;
        self.zone_repairs += o.zone_repairs;
        self.degrades += o.degrades;
        self.degrade_restores += o.degrade_restores;
        self.degrade_retimes += o.degrade_retimes;
        self.snapshot_builds += o.snapshot_builds;
        self.snapshots_built += o.snapshots_built;
        self.snapshot_builds_cancelled += o.snapshot_builds_cancelled;
        self.snapshot_builds_declined += o.snapshot_builds_declined;
        self.snapshot_restores += o.snapshot_restores;
        self.pipelined_loads += o.pipelined_loads;
        self.pipelined_shards += o.pipelined_shards;
        self.pipeline_consolidations += o.pipeline_consolidations;
        self.pipeline_cancellations += o.pipeline_cancellations;
    }
}

/// Aggregated metrics for one run of one system.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub outcomes: Vec<RequestOutcome>,
    pub duration_s: f64,
    /// Requests that failed permanently (fault injection: deadline or
    /// retry exhaustion). Failed requests do not appear in `outcomes`.
    pub failed: u64,
    /// Permanent failures broken down by function id — the denominator
    /// side of per-class SLO attainment (a failed request is an SLO
    /// miss, never a dropped sample).
    pub failed_by_function: BTreeMap<usize, u64>,
}

impl RunMetrics {
    pub fn record(&mut self, o: RequestOutcome) {
        self.outcomes.push(o);
    }

    /// Fraction of finished requests that completed successfully
    /// (1.0 when nothing failed — including the faultless fast path).
    pub fn goodput(&self) -> f64 {
        let done = self.outcomes.len() as f64 + self.failed as f64;
        if done <= 0.0 {
            return 1.0;
        }
        self.outcomes.len() as f64 / done
    }

    pub fn ttfts(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.ttft_s).collect()
    }

    pub fn e2es(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.e2e_s).collect()
    }

    pub fn tpots(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.tpot_s).collect()
    }

    pub fn ttft(&self) -> Summary {
        stats::summarize(&self.ttfts())
    }

    pub fn e2e(&self) -> Summary {
        stats::summarize(&self.e2es())
    }

    pub fn tpot(&self) -> Summary {
        stats::summarize(&self.tpots())
    }

    /// Mean seconds spent in each phase per request (Fig. 8a-style).
    pub fn phase_means(&self) -> BTreeMap<Phase, f64> {
        let mut sums: BTreeMap<Phase, f64> = BTreeMap::new();
        for o in &self.outcomes {
            for (&p, &d) in &o.phases {
                *sums.entry(p).or_insert(0.0) += d;
            }
        }
        let n = self.outcomes.len().max(1) as f64;
        sums.into_iter().map(|(p, s)| (p, s / n)).collect()
    }

    /// Cumulative seconds per phase over the whole workload (Fig. 8b-style).
    pub fn phase_totals(&self) -> BTreeMap<Phase, f64> {
        let mut sums: BTreeMap<Phase, f64> = BTreeMap::new();
        for o in &self.outcomes {
            for (&p, &d) in &o.phases {
                *sums.entry(p).or_insert(0.0) += d;
            }
        }
        sums
    }

    /// Fraction of requests whose TTFT exceeds the given per-function SLO.
    pub fn slo_violation_rate(&self, slo_of: impl Fn(usize) -> f64) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        let viol = self
            .outcomes
            .iter()
            .filter(|o| o.ttft_s > slo_of(o.function))
            .count();
        viol as f64 / self.outcomes.len() as f64
    }

    /// Fraction of *finished* requests (completed + permanently failed)
    /// whose TTFT met the per-function SLO. Failed requests count as
    /// misses, so the surface cannot be gamed by dropping work; an
    /// empty run is vacuously 1.0. Complement of `slo_violation_rate`
    /// only in fault-free runs, where the denominators coincide.
    pub fn slo_attainment(&self, slo_of: impl Fn(usize) -> f64) -> f64 {
        let total = self.outcomes.len() as f64 + self.failed as f64;
        if total <= 0.0 {
            return 1.0;
        }
        let hits = self
            .outcomes
            .iter()
            .filter(|o| o.ttft_s <= slo_of(o.function))
            .count();
        hits as f64 / total
    }

    /// Per-function-class SLO attainment (deadline hit-rate), keyed by
    /// function id. Functions with no finished requests are absent.
    pub fn slo_attainment_by_function(
        &self,
        slo_of: impl Fn(usize) -> f64,
    ) -> BTreeMap<usize, f64> {
        let mut hits: BTreeMap<usize, u64> = BTreeMap::new();
        let mut totals: BTreeMap<usize, u64> = BTreeMap::new();
        for o in &self.outcomes {
            *totals.entry(o.function).or_insert(0) += 1;
            if o.ttft_s <= slo_of(o.function) {
                *hits.entry(o.function).or_insert(0) += 1;
            }
        }
        for (&f, &n) in &self.failed_by_function {
            *totals.entry(f).or_insert(0) += n;
        }
        totals
            .into_iter()
            .map(|(f, n)| (f, hits.get(&f).copied().unwrap_or(0) as f64 / n as f64))
            .collect()
    }

    /// Output-token throughput over the run (tokens/s).
    pub fn token_throughput(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.outcomes
            .iter()
            .map(|o| o.output_tokens as f64)
            .sum::<f64>()
            / self.duration_s
    }

    /// Completed-request throughput (req/s).
    pub fn request_throughput(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.outcomes.len() as f64 / self.duration_s
    }

    /// Largest batch observed (Table 2 "peak batch size").
    pub fn peak_batch(&self) -> usize {
        self.outcomes.iter().map(|o| o.batch_size).max().unwrap_or(0)
    }

    /// TTFT CDF at thresholds (Fig. 12), restricted to one set of functions.
    pub fn ttft_cdf(&self, functions: &[usize], thresholds: &[f64]) -> Vec<f64> {
        let xs: Vec<f64> = self
            .outcomes
            .iter()
            .filter(|o| functions.contains(&o.function))
            .map(|o| o.ttft_s)
            .collect();
        stats::cdf_at(&xs, thresholds)
    }

    /// Filter outcomes (and failure counts) to a set of functions
    /// (e.g. "7B-series" rows).
    pub fn subset(&self, functions: &[usize]) -> RunMetrics {
        let failed_by_function: BTreeMap<usize, u64> = self
            .failed_by_function
            .iter()
            .filter(|(f, _)| functions.contains(f))
            .map(|(&f, &n)| (f, n))
            .collect();
        RunMetrics {
            outcomes: self
                .outcomes
                .iter()
                .filter(|o| functions.contains(&o.function))
                .cloned()
                .collect(),
            duration_s: self.duration_s,
            failed: failed_by_function.values().sum(),
            failed_by_function,
        }
    }
}

/// Helper to assemble an outcome from phase durations.
pub fn outcome_from_phases(
    req: &Request,
    phases: BTreeMap<Phase, f64>,
    tpot_s: f64,
    batch_size: usize,
) -> RequestOutcome {
    let before_first_token: f64 = phases
        .iter()
        .filter(|(p, _)| !matches!(p, Phase::Decode))
        .map(|(_, d)| d)
        .sum();
    let decode: f64 = phases.get(&Phase::Decode).copied().unwrap_or(0.0);
    RequestOutcome {
        id: req.id,
        function: req.function,
        arrival_s: req.arrival_s,
        ttft_s: before_first_token,
        tpot_s,
        e2e_s: before_first_token + decode,
        output_tokens: req.output_tokens,
        batch_size,
        phases,
        backbone_tier: None,
        cold_path: ColdPath::Warm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(f: usize, ttft: f64, e2e: f64) -> RequestOutcome {
        RequestOutcome {
            id: 0,
            function: f,
            arrival_s: 0.0,
            phases: BTreeMap::new(),
            ttft_s: ttft,
            tpot_s: 0.03,
            e2e_s: e2e,
            output_tokens: 100,
            batch_size: 4,
            backbone_tier: None,
            cold_path: ColdPath::Warm,
        }
    }

    #[test]
    fn slo_violation_rate_per_function() {
        let mut m = RunMetrics::default();
        m.record(outcome(0, 1.0, 3.0));
        m.record(outcome(0, 3.0, 5.0)); // violates 2.5
        m.record(outcome(1, 3.0, 5.0)); // within 4.0
        let rate = m.slo_violation_rate(|f| if f == 0 { 2.5 } else { 4.0 });
        assert!((rate - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn throughputs() {
        let mut m = RunMetrics::default();
        m.duration_s = 50.0;
        for _ in 0..10 {
            m.record(outcome(0, 1.0, 2.0));
        }
        assert!((m.token_throughput() - 20.0).abs() < 1e-9);
        assert!((m.request_throughput() - 0.2).abs() < 1e-9);
        assert_eq!(m.peak_batch(), 4);
    }

    #[test]
    fn phase_accounting() {
        let req = Request {
            id: 1,
            function: 0,
            arrival_s: 0.0,
            prompt_tokens: 60,
            output_tokens: 100,
        };
        let mut phases = BTreeMap::new();
        phases.insert(Phase::Queue, 0.2);
        phases.insert(Phase::BackboneLoad, 1.0);
        phases.insert(Phase::Prefill, 0.5);
        phases.insert(Phase::Decode, 3.0);
        let o = outcome_from_phases(&req, phases, 0.03, 2);
        assert!((o.ttft_s - 1.7).abs() < 1e-9);
        assert!((o.e2e_s - 4.7).abs() < 1e-9);
        assert!((o.cold_start_s() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn goodput_counts_permanent_failures() {
        let mut m = RunMetrics::default();
        assert_eq!(m.goodput(), 1.0, "empty run is vacuously good");
        m.record(outcome(0, 1.0, 2.0));
        m.record(outcome(0, 1.0, 2.0));
        assert_eq!(m.goodput(), 1.0);
        m.failed = 2;
        assert!((m.goodput() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fault_counters_merge_additively() {
        let mut a = RunStats { gpu_crashes: 2, redispatched: 5, ..RunStats::default() };
        let b = RunStats {
            gpu_crashes: 1,
            gpu_recoveries: 1,
            load_failures: 4,
            retries: 3,
            requests_failed: 2,
            redispatched: 1,
            ..RunStats::default()
        };
        a.merge(&b);
        assert_eq!(a.gpu_crashes, 3);
        assert_eq!(a.gpu_recoveries, 1);
        assert_eq!(a.load_failures, 4);
        assert_eq!(a.retries, 3);
        assert_eq!(a.requests_failed, 2);
        assert_eq!(a.redispatched, 6);
    }

    #[test]
    fn subset_filters() {
        let mut m = RunMetrics::default();
        m.record(outcome(0, 1.0, 2.0));
        m.record(outcome(5, 9.0, 9.5));
        m.failed = 3;
        m.failed_by_function.insert(0, 2);
        m.failed_by_function.insert(5, 1);
        let s = m.subset(&[5]);
        assert_eq!(s.outcomes.len(), 1);
        assert_eq!(s.outcomes[0].function, 5);
        assert_eq!(s.failed, 1, "subset carries its functions' failures");
        assert_eq!(s.failed_by_function.get(&5), Some(&1));
        assert_eq!(s.failed_by_function.get(&0), None);
    }

    #[test]
    fn slo_attainment_counts_failures_as_misses() {
        let mut m = RunMetrics::default();
        assert_eq!(m.slo_attainment(|_| 1.0), 1.0, "empty run is vacuously attained");
        m.record(outcome(0, 1.0, 3.0)); // hit (≤ 2.5)
        m.record(outcome(0, 3.0, 5.0)); // miss
        m.record(outcome(1, 3.0, 5.0)); // hit (≤ 4.0)
        let slo = |f: usize| if f == 0 { 2.5 } else { 4.0 };
        assert!((m.slo_attainment(slo) - 2.0 / 3.0).abs() < 1e-9);
        // Two permanent failures on function 1: misses, not dropped.
        m.failed = 2;
        m.failed_by_function.insert(1, 2);
        assert!((m.slo_attainment(slo) - 2.0 / 5.0).abs() < 1e-9);
        let per = m.slo_attainment_by_function(slo);
        assert!((per[&0] - 0.5).abs() < 1e-9);
        assert!((per[&1] - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn domain_and_degrade_counters_merge_additively() {
        let mut a = RunStats { node_outages: 1, degrades: 2, ..RunStats::default() };
        let b = RunStats {
            node_outages: 2,
            node_repairs: 3,
            zone_outages: 1,
            zone_repairs: 1,
            degrades: 1,
            degrade_restores: 2,
            degrade_retimes: 7,
            ..RunStats::default()
        };
        a.merge(&b);
        assert_eq!(a.node_outages, 3);
        assert_eq!(a.node_repairs, 3);
        assert_eq!(a.zone_outages, 1);
        assert_eq!(a.zone_repairs, 1);
        assert_eq!(a.degrades, 3);
        assert_eq!(a.degrade_restores, 2);
        assert_eq!(a.degrade_retimes, 7);
    }

    #[test]
    fn coldstart_counters_merge_additively() {
        let mut a = RunStats {
            snapshot_builds: 2,
            snapshot_restores: 1,
            pipelined_loads: 1,
            ..RunStats::default()
        };
        let b = RunStats {
            snapshot_builds: 1,
            snapshots_built: 1,
            snapshot_builds_cancelled: 1,
            snapshot_builds_declined: 1,
            snapshot_restores: 4,
            pipelined_loads: 2,
            pipelined_shards: 6,
            pipeline_consolidations: 1,
            pipeline_cancellations: 1,
            ..RunStats::default()
        };
        a.merge(&b);
        assert_eq!(a.snapshot_builds, 3);
        assert_eq!(a.snapshots_built, 1);
        assert_eq!(a.snapshot_builds_cancelled, 1);
        assert_eq!(a.snapshot_builds_declined, 1);
        assert_eq!(a.snapshot_restores, 5);
        assert_eq!(a.pipelined_loads, 3);
        assert_eq!(a.pipelined_shards, 6);
        assert_eq!(a.pipeline_consolidations, 1);
        assert_eq!(a.pipeline_cancellations, 1);
    }

    #[test]
    fn cdf_shape() {
        let mut m = RunMetrics::default();
        for t in [0.5, 1.0, 1.5, 2.0] {
            m.record(outcome(0, t, t + 1.0));
        }
        let c = m.ttft_cdf(&[0], &[1.0, 2.0]);
        assert_eq!(c, vec![0.5, 1.0]);
    }
}
