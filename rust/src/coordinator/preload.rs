//! Pre-Loading Scheduler (paper §4.1): which artifacts of which functions
//! to pre-load into which idle container / GPU.
//!
//! Formulated as a Precedence-Constrained Knapsack Problem (PCKP):
//! maximise Σ v_i^f x_i^{f,target} subject to
//!   * capacity of each container and GPU,
//!   * placement rules (libraries → container only; CUDA kernels → GPU
//!     only; backbones/adapters → either),
//!   * precedence (models need libraries; kernels need the model on GPU),
//!   * backbone–adapter GPU coupling.
//!
//! PCKP is NP-hard; exact DP is O(2^(|F|·(|C|+|G|))) — infeasible at
//! serverless scheduling latencies.  We implement the paper's greedy by
//! *value density* ρ = v/w (O(|F|²·(|C|+|G|)) worst case), plus an exact
//! brute-force oracle (`exact_plan`) used by tests to verify the greedy is
//! near-optimal on small instances.

use std::collections::BTreeMap;

use crate::artifact::{ArtifactKind, FunctionSpec, Tier};
use crate::cluster::{Cluster, ContainerId, GpuId};
use crate::sharing::BackboneRegistry;

/// GPU memory the planner refuses to fill with pre-loaded artifacts, so
/// serving always has KV-cache headroom (≈ a 20-request 7B batch).
pub const KV_PRELOAD_RESERVE_GB: f64 = 10.0;

/// Where one artifact is pre-loaded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Container(ContainerId),
    Gpu(GpuId),
}

/// One pre-loading decision.
#[derive(Debug, Clone)]
pub struct Decision {
    pub function: usize,
    pub kind: ArtifactKind,
    pub placement: Placement,
    pub size_gb: f64,
    /// Benefit v = (latency saved) × (arrival rate), §4.1.
    pub value: f64,
}

#[derive(Debug, Clone, Default)]
pub struct PreloadPlan {
    pub decisions: Vec<Decision>,
}

impl PreloadPlan {
    pub fn total_value(&self) -> f64 {
        self.decisions.iter().map(|d| d.value).sum()
    }

    pub fn has(&self, function: usize, kind: ArtifactKind) -> bool {
        self.decisions
            .iter()
            .any(|d| d.function == function && d.kind == kind)
    }

    pub fn placement_of(&self, function: usize, kind: ArtifactKind) -> Option<Placement> {
        self.decisions
            .iter()
            .find(|d| d.function == function && d.kind == kind)
            .map(|d| d.placement)
    }
}

/// Scheduler inputs per function: its spec and the estimated arrival rate
/// (req/s) from the controller's sliding-window history.
#[derive(Debug, Clone)]
pub struct FunctionDemand {
    pub spec: FunctionSpec,
    pub rate: f64,
}

/// Candidate (artifact, target) with value/weight, before capacity checks.
#[derive(Debug, Clone)]
struct Candidate {
    function: usize,
    kind: ArtifactKind,
    placement: Placement,
    size_gb: f64,
    value: f64,
    density: f64,
}

pub struct PreloadScheduler {
    /// Cold-start source tier for non-preloaded artifacts (Remote for a
    /// fresh deployment, Ssd once checkpoints are cached node-locally).
    pub cold_tier: Tier,
}

impl Default for PreloadScheduler {
    fn default() -> Self {
        PreloadScheduler { cold_tier: Tier::Ssd }
    }
}

impl PreloadScheduler {
    pub fn new(cold_tier: Tier) -> Self {
        PreloadScheduler { cold_tier }
    }

    fn cold_load_s(&self, a: &crate::artifact::ArtifactSpec) -> f64 {
        // Uncontended default-bandwidth view: planning values predate any
        // link contention the load will actually see.
        a.load_s(self.cold_tier)
    }

    /// Enumerate placement candidates with §4.1 values:
    /// * GPU placement of X saves the full cold load of X;
    /// * container placement of a model saves (cold − PCIe-up) time;
    /// * libraries are only container-placeable, kernels only GPU-placeable.
    fn candidates(
        &self,
        demands: &[FunctionDemand],
        cluster: &Cluster,
        registry: &BackboneRegistry,
    ) -> Vec<Candidate> {
        let mut out = Vec::new();
        for d in demands {
            let arts = d.spec.artifacts();
            for a in &arts {
                let cold = self.cold_load_s(a);
                // Value of having it GPU-resident: full cold load avoided.
                let v_gpu = cold * d.rate;
                // Value of container residency: cold load reduced to the
                // RAM→GPU hop.
                let v_ram = (cold - a.load_s(Tier::ContainerRam)).max(0.0) * d.rate;
                if a.kind.container_placeable() && v_ram > 0.0 {
                    for cid in cluster.container_ids() {
                        out.push(Candidate {
                            function: d.spec.id,
                            kind: a.kind,
                            placement: Placement::Container(cid),
                            size_gb: a.size_gb,
                            value: v_ram,
                            density: v_ram / a.size_gb.max(1e-6),
                        });
                    }
                }
                if a.kind.gpu_placeable() && v_gpu > 0.0 {
                    // Backbone GPU placement is *shared*: skip if some GPU
                    // already hosts it (value collapses to attach ≈ 0).
                    if a.kind == ArtifactKind::Backbone
                        && !registry.hosts(d.spec.model.name).is_empty()
                    {
                        continue;
                    }
                    for gid in cluster.gpu_ids() {
                        out.push(Candidate {
                            function: d.spec.id,
                            kind: a.kind,
                            placement: Placement::Gpu(gid),
                            size_gb: a.size_gb,
                            value: v_gpu,
                            density: v_gpu / a.size_gb.max(1e-6),
                        });
                    }
                }
            }
        }
        out
    }

    /// The §4.1 greedy: sort all candidates by value density, place in
    /// order while respecting capacity + precedence + coupling. Runs in
    /// multiple passes so a high-density kernel skipped for a missing
    /// prerequisite is retried once its backbone lands.
    ///
    /// Target selection within a placement class is *least-loaded first*:
    /// every per-GPU (per-container) duplicate of a candidate has the same
    /// density, so the tie is broken toward the target with the most
    /// remaining planning capacity — spreading models across the cluster
    /// instead of packing one GPU solid.
    pub fn plan(
        &self,
        demands: &[FunctionDemand],
        cluster: &Cluster,
        registry: &BackboneRegistry,
    ) -> PreloadPlan {
        let mut cands = self.candidates(demands, cluster, registry);
        cands.sort_by(|a, b| b.density.total_cmp(&a.density));

        let model_of: BTreeMap<usize, &FunctionSpec> =
            demands.iter().map(|d| (d.spec.id, &d.spec)).collect();

        // Remaining capacities (planning view — nothing is mutated yet).
        // Each GPU keeps `KV_PRELOAD_RESERVE_GB` un-planned: pre-loaded
        // artifacts must never starve serving of KV-cache room (§4.3's
        // offloader is the *emergency* path, not the steady state).
        let mut gpu_free: BTreeMap<GpuId, f64> = cluster
            .gpu_ids()
            .iter()
            .map(|&g| {
                (g, (cluster.gpu(g).free_gb() - KV_PRELOAD_RESERVE_GB).max(0.0))
            })
            .collect();
        let mut ctr_free: BTreeMap<ContainerId, f64> = cluster
            .container_ids()
            .iter()
            .map(|&c| (c, cluster.container(c).free_gb()))
            .collect();

        let mut plan = PreloadPlan::default();
        // (function,kind) placed once at most (first = highest density).
        let mut placed: BTreeMap<(usize, ArtifactKind), Placement> = BTreeMap::new();
        // model-name → GPU chosen for the shared backbone in this plan.
        let mut planned_backbone_gpu: BTreeMap<&str, GpuId> = BTreeMap::new();

        let max_passes = 4;
        for _ in 0..max_passes {
            let mut progressed = false;
            for c in &cands {
                // A GPU placement strictly dominates a container placement
                // of the same artifact (it saves the PCIe hop too): when a
                // GPU candidate becomes admissible after its backbone
                // landed in a later pass, upgrade the earlier container
                // decision instead of skipping.
                if let Some(Placement::Container(prev)) =
                    placed.get(&(c.function, c.kind)).copied()
                {
                    if matches!(c.placement, Placement::Gpu(_))
                        && self.admissible(
                            c,
                            model_of[&c.function],
                            &placed,
                            &planned_backbone_gpu,
                            registry,
                            cluster,
                        )
                    {
                        let fits = match c.placement {
                            Placement::Gpu(g) => gpu_free[&g] + 1e-9 >= c.size_gb,
                            _ => false,
                        };
                        if fits {
                            // Refund the container bytes, drop the old
                            // decision, and fall through to place on GPU.
                            *ctr_free.get_mut(&prev).unwrap() += c.size_gb;
                            placed.remove(&(c.function, c.kind));
                            plan.decisions.retain(|d| {
                                !(d.function == c.function && d.kind == c.kind)
                            });
                        }
                    }
                }
                if placed.contains_key(&(c.function, c.kind)) {
                    continue;
                }
                let spec = model_of[&c.function];
                let model = spec.model.name;
                if !self.admissible(
                    c, spec, &placed, &planned_backbone_gpu, registry, cluster,
                ) {
                    continue;
                }
                match c.placement {
                    Placement::Gpu(_) => {
                        // Shared backbone: if another function already
                        // planned this model's backbone, ride that GPU —
                        // free of charge (no extra bytes).
                        if c.kind == ArtifactKind::Backbone {
                            if let Some(&pg) = planned_backbone_gpu.get(model) {
                                placed
                                    .insert((c.function, c.kind), Placement::Gpu(pg));
                                plan.decisions.push(Decision {
                                    function: c.function,
                                    kind: c.kind,
                                    placement: Placement::Gpu(pg),
                                    size_gb: 0.0, // shared, already paid
                                    value: c.value,
                                });
                                progressed = true;
                                continue;
                            }
                        }
                        // Least-loaded admissible GPU that fits. Under
                        // failure-aware routing, planned free space is
                        // discounted by the GPU's failure-history
                        // penalty — staging avoids crash-prone or
                        // degraded hardware. Off (default) the penalty
                        // is exactly 0.0 and `x - 0.0` keeps the
                        // comparison bit-identical.
                        let best = gpu_free
                            .iter()
                            .filter(|(&g, &free)| {
                                free + 1e-9 >= c.size_gb
                                    && self.admissible(
                                        &Candidate {
                                            placement: Placement::Gpu(g),
                                            ..c.clone()
                                        },
                                        spec,
                                        &placed,
                                        &planned_backbone_gpu,
                                        registry,
                                        cluster,
                                    )
                            })
                            .max_by(|a, b| {
                                (*a.1 - cluster.failure_penalty(*a.0))
                                    .total_cmp(&(*b.1 - cluster.failure_penalty(*b.0)))
                            })
                            .map(|(&g, _)| g);
                        let Some(g) = best else { continue };
                        *gpu_free.get_mut(&g).unwrap() -= c.size_gb;
                        if c.kind == ArtifactKind::Backbone {
                            planned_backbone_gpu.insert(model, g);
                        }
                        placed.insert((c.function, c.kind), Placement::Gpu(g));
                        plan.decisions.push(Decision {
                            function: c.function,
                            kind: c.kind,
                            placement: Placement::Gpu(g),
                            size_gb: c.size_gb,
                            value: c.value,
                        });
                        progressed = true;
                    }
                    Placement::Container(_) => {
                        let best = ctr_free
                            .iter()
                            .filter(|(&cid, &free)| {
                                free + 1e-9 >= c.size_gb
                                    && self.admissible(
                                        &Candidate {
                                            placement: Placement::Container(cid),
                                            ..c.clone()
                                        },
                                        spec,
                                        &placed,
                                        &planned_backbone_gpu,
                                        registry,
                                        cluster,
                                    )
                            })
                            .max_by(|a, b| a.1.total_cmp(b.1))
                            .map(|(&cid, _)| cid);
                        let Some(cid) = best else { continue };
                        *ctr_free.get_mut(&cid).unwrap() -= c.size_gb;
                        placed.insert((c.function, c.kind), Placement::Container(cid));
                        plan.decisions.push(Decision {
                            function: c.function,
                            kind: c.kind,
                            placement: Placement::Container(cid),
                            size_gb: c.size_gb,
                            value: c.value,
                        });
                        progressed = true;
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        plan
    }

    /// Precedence + coupling checks for one candidate against the current
    /// partial plan.
    fn admissible(
        &self,
        c: &Candidate,
        spec: &FunctionSpec,
        placed: &BTreeMap<(usize, ArtifactKind), Placement>,
        planned_backbone_gpu: &BTreeMap<&str, GpuId>,
        registry: &BackboneRegistry,
        _cluster: &Cluster,
    ) -> bool {
        let model = spec.model.name;
        let backbone_gpu = |g: GpuId| -> bool {
            planned_backbone_gpu.get(model).copied() == Some(g)
                || registry.is_hosted_on(model, g)
        };
        match (c.kind, c.placement) {
            // Libraries: container only, no prerequisites.
            (ArtifactKind::Library, Placement::Container(_)) => true,
            (ArtifactKind::Library, Placement::Gpu(_)) => false,
            // Models on GPU require libraries placed (any container) —
            // §4.1 "models require libraries first".
            (ArtifactKind::Backbone, Placement::Gpu(_)) => placed
                .contains_key(&(c.function, ArtifactKind::Library)),
            (ArtifactKind::Backbone, Placement::Container(_)) => true,
            // Adapter GPU placement must ride a GPU with (a plan for) its
            // backbone — §4.1 backbone–adapter coupling.
            (ArtifactKind::Adapter, Placement::Gpu(g)) => backbone_gpu(g),
            // Adapter in container: coupled to the node of the backbone's
            // GPU when one exists; otherwise free (it is host RAM).
            (ArtifactKind::Adapter, Placement::Container(cid)) => {
                match planned_backbone_gpu.get(model) {
                    Some(g) => g.node == cid.node,
                    None => registry.hosts(model).is_empty()
                        || registry.hosts(model).iter().any(|h| h.node == cid.node),
                }
            }
            // Kernels: GPU only, and only where the model is resident —
            // §4.1 "CUDA kernels require models on GPU first".
            (ArtifactKind::CudaKernel, Placement::Gpu(g)) => backbone_gpu(g),
            (ArtifactKind::CudaKernel, Placement::Container(_)) => false,
            (ArtifactKind::Container, _) => false,
        }
    }

    /// Apply a plan to the cluster ledgers (Pre-Loading Agent, step 3).
    pub fn apply(
        &self,
        plan: &PreloadPlan,
        demands: &[FunctionDemand],
        cluster: &mut Cluster,
        registry: &mut BackboneRegistry,
    ) {
        let spec_of: BTreeMap<usize, &FunctionSpec> =
            demands.iter().map(|d| (d.spec.id, &d.spec)).collect();
        for d in &plan.decisions {
            let spec = spec_of[&d.function];
            match (d.kind, d.placement) {
                (ArtifactKind::Backbone, Placement::Gpu(g)) => {
                    registry
                        .load(cluster, spec.model.name, spec.model.weights_gb, g)
                        .expect("planned backbone placement must fit");
                }
                (k, Placement::Gpu(g)) => {
                    cluster
                        .gpu_mut(g)
                        .place_artifact(d.function, k, d.size_gb)
                        .expect("planned GPU placement must fit");
                }
                (k, Placement::Container(cid)) => {
                    cluster
                        .container_mut(cid)
                        .place(d.function, k, d.size_gb)
                        .expect("planned container placement must fit");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Exact oracle for tests: brute-force over candidate subsets (tiny inputs).

/// Exact PCKP optimum by exhaustive search. Only usable for instances with
/// ≤ ~14 candidate decisions; tests use it to bound the greedy's gap.
pub fn exact_plan(
    sched: &PreloadScheduler,
    demands: &[FunctionDemand],
    cluster: &Cluster,
    registry: &BackboneRegistry,
) -> f64 {
    let cands = sched.candidates(demands, cluster, registry);
    // Deduplicate to one candidate per (function, kind, placement).
    assert!(cands.len() <= 20, "exact oracle is exponential; {} too many", cands.len());

    let model_of: BTreeMap<usize, &FunctionSpec> =
        demands.iter().map(|d| (d.spec.id, &d.spec)).collect();

    let mut best = 0.0f64;
    let n = cands.len();
    'subset: for mask in 0u32..(1 << n) {
        let chosen: Vec<&Candidate> =
            (0..n).filter(|i| mask & (1 << i) != 0).map(|i| &cands[i]).collect();
        // At most one placement per (function, kind).
        let mut seen = std::collections::BTreeSet::new();
        for c in &chosen {
            if !seen.insert((c.function, c.kind)) {
                continue 'subset;
            }
        }
        // Capacity (with backbone sharing: one model's backbone bytes are
        // paid once per GPU).
        let mut gpu_used: BTreeMap<GpuId, f64> = BTreeMap::new();
        let mut ctr_used: BTreeMap<ContainerId, f64> = BTreeMap::new();
        let mut backbone_on: BTreeMap<(&str, GpuId), bool> = BTreeMap::new();
        for c in &chosen {
            let model = model_of[&c.function].model.name;
            match c.placement {
                Placement::Gpu(g) => {
                    let pay = if c.kind == ArtifactKind::Backbone {
                        !backbone_on.insert((model, g), true).unwrap_or(false)
                    } else {
                        true
                    };
                    if pay {
                        *gpu_used.entry(g).or_insert(0.0) += c.size_gb;
                    }
                }
                Placement::Container(cid) => {
                    *ctr_used.entry(cid).or_insert(0.0) += c.size_gb;
                }
            }
        }
        for (g, used) in &gpu_used {
            if *used > cluster.gpu(*g).free_gb() + 1e-9 {
                continue 'subset;
            }
        }
        for (cid, used) in &ctr_used {
            if *used > cluster.container(*cid).free_gb() + 1e-9 {
                continue 'subset;
            }
        }
        // Precedence & coupling.
        let placed: BTreeMap<(usize, ArtifactKind), Placement> = chosen
            .iter()
            .map(|c| ((c.function, c.kind), c.placement))
            .collect();
        let mut planned_backbone: BTreeMap<&str, GpuId> = BTreeMap::new();
        for c in &chosen {
            if c.kind == ArtifactKind::Backbone {
                if let Placement::Gpu(g) = c.placement {
                    let model = model_of[&c.function].model.name;
                    if let Some(&pg) = planned_backbone.get(model) {
                        if pg != g {
                            continue 'subset; // split backbone placement
                        }
                    }
                    planned_backbone.insert(model, g);
                }
            }
        }
        for c in &chosen {
            if !sched.admissible(
                c, model_of[&c.function], &placed, &planned_backbone, registry, cluster,
            ) {
                continue 'subset;
            }
        }
        let value: f64 = chosen.iter().map(|c| c.value).sum();
        best = best.max(value);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::ModelProfile;

    fn demand(id: usize, rate: f64) -> FunctionDemand {
        FunctionDemand {
            spec: FunctionSpec::new(id, ModelProfile::llama2_7b(), id),
            rate,
        }
    }

    fn setup(n_fns: usize) -> (Vec<FunctionDemand>, Cluster, BackboneRegistry) {
        let demands = (0..n_fns).map(|i| demand(i, 0.5)).collect();
        (demands, Cluster::new(1, 2, 2), BackboneRegistry::new())
    }

    #[test]
    fn respects_placement_rules() {
        let (d, c, r) = setup(2);
        let plan = PreloadScheduler::default().plan(&d, &c, &r);
        for dec in &plan.decisions {
            match dec.kind {
                ArtifactKind::Library => {
                    assert!(matches!(dec.placement, Placement::Container(_)))
                }
                ArtifactKind::CudaKernel => {
                    assert!(matches!(dec.placement, Placement::Gpu(_)))
                }
                _ => {}
            }
        }
    }

    #[test]
    fn kernels_only_where_backbone_planned() {
        let (d, c, r) = setup(4);
        let plan = PreloadScheduler::default().plan(&d, &c, &r);
        for dec in &plan.decisions {
            if dec.kind == ArtifactKind::CudaKernel {
                let Placement::Gpu(g) = dec.placement else { panic!() };
                // Some function of the same model placed its backbone there.
                let ok = plan.decisions.iter().any(|b| {
                    b.kind == ArtifactKind::Backbone && b.placement == Placement::Gpu(g)
                });
                assert!(ok, "kernel without backbone on {g}");
            }
        }
    }

    #[test]
    fn backbone_shared_single_copy() {
        // Four 7B functions: only ONE decision pays backbone bytes; the
        // rest ride the shared copy (size_gb == 0).
        let (d, c, r) = setup(4);
        let plan = PreloadScheduler::default().plan(&d, &c, &r);
        let paid: Vec<&Decision> = plan
            .decisions
            .iter()
            .filter(|x| x.kind == ArtifactKind::Backbone && x.size_gb > 0.0)
            .collect();
        let free: Vec<&Decision> = plan
            .decisions
            .iter()
            .filter(|x| x.kind == ArtifactKind::Backbone && x.size_gb == 0.0)
            .collect();
        assert_eq!(paid.len(), 1, "exactly one paid backbone copy");
        assert_eq!(free.len(), 3, "other functions share it");
    }

    #[test]
    fn capacity_never_exceeded() {
        // Tiny GPU: backbones don't fit; plan must not overcommit.
        let (d, _, r) = setup(6);
        let mut c = Cluster::new(1, 1, 1);
        // Shrink the GPU to 10 GB (7B backbone is 13.5).
        let gid = GpuId { node: 0, index: 0 };
        c.replace_gpu(gid, crate::cluster::Gpu::with_capacity(gid, 10.0));
        let plan = PreloadScheduler::default().plan(&d, &c, &r);
        let gpu_bytes: f64 = plan
            .decisions
            .iter()
            .filter(|x| matches!(x.placement, Placement::Gpu(_)))
            .map(|x| x.size_gb)
            .sum();
        assert!(gpu_bytes <= c.gpu(c.gpu_ids()[0]).free_gb() + 1e-9);
        assert!(!plan.has(0, ArtifactKind::Backbone) || gpu_bytes < 10.0);
    }

    #[test]
    fn apply_writes_ledgers() {
        let (d, mut c, mut r) = setup(2);
        let sched = PreloadScheduler::default();
        let plan = sched.plan(&d, &c, &r);
        sched.apply(&plan, &d, &mut c, &mut r);
        assert_eq!(r.hosts("llama2-7b").len(), 1);
        // Every applied artifact is findable.
        for dec in &plan.decisions {
            match (dec.kind, dec.placement) {
                (ArtifactKind::Backbone, Placement::Gpu(g)) => {
                    assert!(c.gpu(g).has_shared_backbone("llama2-7b"))
                }
                (k, Placement::Gpu(g)) => assert!(c.gpu(g).has_artifact(dec.function, k)),
                (k, Placement::Container(id)) => {
                    assert!(c.container(id).has(dec.function, k))
                }
            }
        }
    }

    #[test]
    fn higher_rate_functions_preferred() {
        // One GPU that fits one backbone; the hot function should win it.
        let demands = vec![demand(0, 0.05), demand(1, 5.0)];
        let mut c = Cluster::new(1, 1, 2);
        let gid = GpuId { node: 0, index: 0 };
        c.replace_gpu(gid, crate::cluster::Gpu::with_capacity(gid, 18.0));
        let r = BackboneRegistry::new();
        let plan = PreloadScheduler::default().plan(&demands, &c, &r);
        // Both share one backbone (same model) — but kernels/adapters are
        // per-function; fn 1 must be at least as preloaded as fn 0.
        let v1: f64 = plan
            .decisions
            .iter()
            .filter(|d| d.function == 1)
            .map(|d| d.value)
            .sum();
        let v0: f64 = plan
            .decisions
            .iter()
            .filter(|d| d.function == 0)
            .map(|d| d.value)
            .sum();
        assert!(v1 >= v0, "hot function value {v1} < cold {v0}");
    }

    #[test]
    fn greedy_close_to_exact_on_small_instances() {
        // Small instance the oracle can enumerate: 1 function, 1 GPU,
        // 1 container.
        let demands = vec![demand(0, 1.0)];
        let c = Cluster::new(1, 1, 1);
        let r = BackboneRegistry::new();
        let sched = PreloadScheduler::default();
        let g = sched.plan(&demands, &c, &r).total_value();
        let opt = exact_plan(&sched, &demands, &c, &r);
        assert!(g >= 0.75 * opt, "greedy {g} vs exact {opt}");
    }

    #[test]
    fn scheduling_latency_under_1ms() {
        // §6.9: "The Pre-Loading Scheduler ... 1 ms additional latency".
        let (d, c, r) = setup(8);
        let sched = PreloadScheduler::default();
        let t0 = std::time::Instant::now();
        let _ = sched.plan(&d, &c, &r);
        let el = t0.elapsed();
        assert!(el.as_millis() < 50, "plan took {el:?}"); // debug-build slack
    }
}
