//! Dynamic GPU Offloader (paper §4.3).
//!
//! When a GPU needs Q_g additional memory (KV cache for an arriving
//! batch), evict *unrelated* pre-loaded artifacts — per-function models
//! (x_Mg) and CUDA kernels (x_Kg), Eq. 6 — minimising the total future
//! value lost (Eq. 7).  NP-hard like the pre-loading problem; solved with
//! the same value-density greedy (lowest ρ = v/w evicted first), which
//! "executes within microseconds".
//!
//! Eviction destinations: per-function artifacts fall back to container
//! RAM (cheap reload over PCIe) or are dropped entirely; shared backbones
//! are only evictable at refcount 0 and only when no protected function
//! needs them.

use crate::artifact::ArtifactKind;
use crate::cluster::{Cluster, GpuId};
use crate::sharing::BackboneRegistry;

/// One evictable item on a GPU, with its §4.1-style value.
#[derive(Debug, Clone, PartialEq)]
pub struct Evictable {
    pub function: Option<usize>, // None = shared backbone
    pub model: Option<String>,   // Some for shared backbones
    pub kind: ArtifactKind,
    pub size_gb: f64,
    /// Future-acceleration value v (loading delay × arrival rate).
    pub value: f64,
}

impl Evictable {
    pub fn density(&self) -> f64 {
        self.value / self.size_gb.max(1e-9)
    }
}

/// The eviction plan for one request for Q_g GB.
#[derive(Debug, Clone, Default)]
pub struct OffloadPlan {
    pub evictions: Vec<Evictable>,
    pub freed_gb: f64,
    /// True iff freed_gb ≥ requested Q_g.
    pub satisfied: bool,
}

impl OffloadPlan {
    pub fn value_lost(&self) -> f64 {
        self.evictions.iter().map(|e| e.value).sum()
    }
}

pub struct DynamicOffloader;

impl DynamicOffloader {
    /// Enumerate evictable items on `gpu`, excluding `protected` functions
    /// (the ones the incoming batch belongs to) and any backbone still
    /// referenced by live instances.
    pub fn evictable(
        cluster: &Cluster,
        registry: &BackboneRegistry,
        gpu: GpuId,
        protected: &[usize],
        value_of: impl Fn(Option<usize>, ArtifactKind) -> f64,
    ) -> Vec<Evictable> {
        let g = cluster.gpu(gpu);
        let mut out = Vec::new();
        // Allocation-free residency walk (no BTreeSet snapshot) — this
        // runs on every memory-blocked dispatch at fleet scale.
        cluster.for_each_resident(gpu, |f| {
            if protected.contains(&f) {
                return;
            }
            if let Some(res) = g.function_residency(f) {
                for (&kind, &gb) in &res.kinds {
                    // Eq. 6/7 variables: models (x_Mg) and kernels (x_Kg).
                    if matches!(
                        kind,
                        ArtifactKind::Backbone
                            | ArtifactKind::Adapter
                            | ArtifactKind::CudaKernel
                    ) {
                        out.push(Evictable {
                            function: Some(f),
                            model: None,
                            kind,
                            size_gb: gb,
                            value: value_of(Some(f), kind),
                        });
                    }
                }
            }
        });
        // Shared backbones: evictable only with zero attached readers.
        for (model, seg) in g.shared_models() {
            if seg.refcount == 0 && registry.is_hosted_on(model, gpu) {
                out.push(Evictable {
                    function: None,
                    model: Some(model.clone()),
                    kind: ArtifactKind::Backbone,
                    size_gb: seg.size_gb,
                    value: value_of(None, ArtifactKind::Backbone),
                });
            }
        }
        out
    }

    /// Value-density greedy (Eq. 7): evict lowest-ρ first until Q_g is
    /// freed (or nothing evictable remains).
    pub fn plan(mut evictable: Vec<Evictable>, need_gb: f64) -> OffloadPlan {
        evictable.sort_by(|a, b| a.density().total_cmp(&b.density()));
        let mut plan = OffloadPlan::default();
        for e in evictable {
            if plan.freed_gb >= need_gb {
                break;
            }
            plan.freed_gb += e.size_gb;
            plan.evictions.push(e);
        }
        plan.satisfied = plan.freed_gb >= need_gb;
        plan
    }

    /// Execute a plan against the ledgers. Per-function artifacts move to
    /// container RAM when `spill_to` is given (and has room), else drop.
    pub fn apply(
        plan: &OffloadPlan,
        cluster: &mut Cluster,
        registry: &mut BackboneRegistry,
        gpu: GpuId,
        spill_to: Option<crate::cluster::ContainerId>,
    ) {
        for e in &plan.evictions {
            match (e.function, &e.model) {
                (Some(f), _) => {
                    if cluster.gpu_mut(gpu).evict_artifact(f, e.kind).is_ok() {
                        if let Some(cid) = spill_to {
                            if e.kind.container_placeable() {
                                // Best-effort spill; dropping is also legal.
                                let _ = cluster
                                    .container_mut(cid)
                                    .place(f, e.kind, e.size_gb);
                            }
                        }
                    }
                }
                (None, Some(model)) => {
                    let _ = registry.unload(cluster, model, gpu);
                }
                _ => unreachable!(),
            }
        }
    }

    /// Convenience: free `need_gb` on `gpu` end-to-end. Returns the plan.
    pub fn free(
        cluster: &mut Cluster,
        registry: &mut BackboneRegistry,
        gpu: GpuId,
        need_gb: f64,
        protected: &[usize],
        value_of: impl Fn(Option<usize>, ArtifactKind) -> f64,
        spill_to: Option<crate::cluster::ContainerId>,
    ) -> OffloadPlan {
        let already = cluster.gpu(gpu).free_gb();
        if already >= need_gb {
            return OffloadPlan { evictions: vec![], freed_gb: 0.0, satisfied: true };
        }
        let evictable =
            Self::evictable(cluster, registry, gpu, protected, value_of);
        let plan = Self::plan(evictable, need_gb - already);
        Self::apply(&plan, cluster, registry, gpu, spill_to);
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuId;

    fn gid() -> GpuId {
        GpuId { node: 0, index: 0 }
    }

    fn setup() -> (Cluster, BackboneRegistry) {
        let mut c = Cluster::new(1, 1, 1);
        let mut r = BackboneRegistry::new();
        // Resident: fn0 adapter+kernel, fn1 adapter+kernel, idle shared 13B.
        r.load(&mut c, "llama2-13b", 26.0, gid()).unwrap();
        let g = c.gpu_mut(gid());
        g.place_artifact(0, ArtifactKind::Adapter, 0.2).unwrap();
        g.place_artifact(0, ArtifactKind::CudaKernel, 0.5).unwrap();
        g.place_artifact(1, ArtifactKind::Adapter, 0.2).unwrap();
        g.place_artifact(1, ArtifactKind::CudaKernel, 0.5).unwrap();
        (c, r)
    }

    fn values(f: Option<usize>, k: ArtifactKind) -> f64 {
        // fn0 is hot (high future value), fn1 cold, idle backbone coldest
        // per GB.
        match (f, k) {
            (Some(0), _) => 10.0,
            (Some(1), _) => 1.0,
            (None, _) => 5.0,
            _ => 1.0,
        }
    }

    #[test]
    fn evicts_lowest_density_first() {
        let (c, r) = setup();
        let ev = DynamicOffloader::evictable(&c, &r, gid(), &[], values);
        let plan = DynamicOffloader::plan(ev, 0.3);
        assert!(plan.satisfied);
        // fn1's artifacts (ρ=1/0.2, 1/0.5) and the idle backbone
        // (ρ=5/26≈0.19) are cheapest per GB ⇒ backbone goes first.
        assert_eq!(plan.evictions[0].model.as_deref(), Some("llama2-13b"));
    }

    #[test]
    fn frees_at_least_q(/* Eq. 6 */) {
        let (mut c, mut r) = setup();
        let before = c.gpu(gid()).free_gb();
        let plan = DynamicOffloader::free(
            &mut c, &mut r, gid(), before + 2.0, &[], values, None,
        );
        assert!(plan.satisfied);
        assert!(c.gpu(gid()).free_gb() >= before + 2.0 - 1e-9);
    }

    #[test]
    fn protected_functions_untouched() {
        let (c, r) = setup();
        let ev = DynamicOffloader::evictable(&c, &r, gid(), &[0], values);
        assert!(ev.iter().all(|e| e.function != Some(0)));
    }

    #[test]
    fn live_backbone_not_evictable() {
        let (mut c, mut r) = setup();
        r.attach(&mut c, "llama2-13b", gid(), 0).unwrap();
        let ev = DynamicOffloader::evictable(&c, &r, gid(), &[], values);
        assert!(ev.iter().all(|e| e.model.is_none()));
    }

    #[test]
    fn unsatisfiable_reported_not_panicked() {
        let (c, r) = setup();
        let ev = DynamicOffloader::evictable(&c, &r, gid(), &[], values);
        let plan = DynamicOffloader::plan(ev, 1e9);
        assert!(!plan.satisfied);
        assert!(plan.freed_gb > 0.0); // evicted everything it could
    }

    #[test]
    fn spills_to_container_ram() {
        let (mut c, mut r) = setup();
        let cid = c.container_ids()[0];
        // Need more than fn1's kernel alone (0.5 GB) so its adapter —
        // the container-placeable artifact — must also be evicted.
        let need = c.gpu(gid()).free_gb() + 0.6;
        // Value function that makes the idle backbone precious, so the
        // greedy reaches for fn1's per-function artifacts instead.
        let v = |f: Option<usize>, k: ArtifactKind| match (f, k) {
            (None, _) => 1e6,
            (Some(0), _) => 10.0,
            _ => 0.1,
        };
        DynamicOffloader::free(&mut c, &mut r, gid(), need, &[], v, Some(cid));
        // The evicted adapter (container-placeable) landed in host RAM.
        let spilled = c.container(cid).used_gb();
        assert!(spilled > 0.0, "expected spill, container empty");
        assert!(c.container(cid).has(1, ArtifactKind::Adapter));
    }

    #[test]
    fn noop_when_memory_already_free() {
        let (mut c, mut r) = setup();
        let plan = DynamicOffloader::free(
            &mut c, &mut r, gid(), 1.0, &[], values, None,
        );
        assert!(plan.satisfied);
        assert!(plan.evictions.is_empty());
    }

    #[test]
    fn minimises_value_lost_vs_alternative() {
        // Greedy by density must not evict the hot fn0 artifacts while
        // cold fn1 artifacts suffice.
        let (c, r) = setup();
        let ev = DynamicOffloader::evictable(&c, &r, gid(), &[], values);
        let plan = DynamicOffloader::plan(ev, 0.6);
        assert!(plan
            .evictions
            .iter()
            .all(|e| e.function != Some(0)), "evicted hot artifacts: {plan:?}");
    }
}
