//! Pluggable scheduling-policy layer.
//!
//! The paper's contribution is a set of *policies* — PCKP pre-loading
//! (§4.1), two-layer adaptive batching (§4.2), dynamic offloading (§4.3),
//! event-integrated billing (§6.1/§6.4) — layered over a serving
//! substrate. This module turns each of those into a trait so that every
//! system under test (ServerlessLoRA, the baselines, the NBS/NPL/NDO/NAB
//! ablations, and new systems like the predictive pre-loader) is a
//! *policy bundle* constructed by `sim::config::SystemConfig::bundle`,
//! and the discrete-event engine core contains no per-system branches.
//!
//! Layering: policies sit between the coordinator algorithms they wrap
//! (`PreloadScheduler`, `BatchQueue`, `DynamicOffloader`) and the engine
//! that consults them. They mutate the substrate only through
//! [`PolicyEnv`], never through the event loop. See DESIGN.md §3.

use std::collections::{BTreeMap, BTreeSet};

use crate::artifact::{
    params, ArtifactKind, FunctionSpec, LinkKind, ModelProfile, PhaseCost, Term,
};
use crate::cluster::{Cluster, ContainerId, GpuId, HostCache};
use crate::coldstart::{ColdStartKind, ColdStartSpec, PipelineParams, SnapshotParams};
use crate::coordinator::batching::BatchQueue;
use crate::coordinator::offload::{DynamicOffloader, OffloadPlan};
use crate::coordinator::preload::{FunctionDemand, Placement, PreloadScheduler};
use crate::coordinator::router::{Readiness, Router};
use crate::cost::CostTracker;
use crate::metrics::{Phase, RunStats};
use crate::sharing::BackboneRegistry;
use crate::util::rng::Pcg64;

// ---------------------------------------------------------------- contexts

/// Mutable view over the substrate for deployment-time and runtime policy
/// hooks. Policies stage artifacts and record stats through this; the
/// engine's event loop never appears in a policy signature.
pub struct PolicyEnv<'a> {
    pub cluster: &'a mut Cluster,
    pub registry: &'a mut BackboneRegistry,
    pub functions: &'a [FunctionSpec],
    /// Mean arrival rate per function (the §4.1 benefit input).
    pub rates: &'a [f64],
    /// §4.4 backbone sharing — a substrate property (how memory is
    /// accounted), not a per-event decision, hence carried here.
    pub sharing: bool,
    /// Serverful function → dedicated GPU map (filled by resident
    /// deployment policies; consulted by the router).
    pub dedicated: &'a mut BTreeMap<usize, GpuId>,
    pub stats: &'a mut RunStats,
}

/// Everything a pre-load policy may consult when pricing one cold start.
/// All fields are plain values — the dispatch layer snapshots the ledger
/// state so policies stay side-effect-free here.
pub struct LoadQuery<'a> {
    pub function: usize,
    pub model: &'a ModelProfile,
    pub ready: Readiness,
    /// Instance is warm: keep-alive-warm with a live CUDA context, or
    /// pre-warmed by the policy (see [`PreloadPolicy::prewarmed`]).
    pub warm_instance: bool,
    /// Some container holds this function's libraries.
    pub container_has_library: bool,
    /// Some container holds this function's adapter.
    pub container_has_adapter: bool,
    /// Some container holds this function's *own* backbone copy
    /// (InstaInfer-style per-slot staging).
    pub container_has_own_backbone: bool,
    /// Some container holds a backbone copy of this *model* (staging
    /// copies are per-model: any same-model function can read them).
    pub container_has_model_backbone: bool,
}

// ------------------------------------------------------------------ traits

/// §4.1 artifact staging: what is resident before an invocation arrives,
/// and what latency each remaining cold-start phase costs.
pub trait PreloadPolicy: Send {
    fn name(&self) -> &'static str;

    /// Deployment-time staging, before the first arrival.
    fn deploy(&mut self, env: &mut PolicyEnv);

    /// Runtime hook on every request arrival (forecast updates for
    /// predictive policies). Default: nothing.
    fn on_arrival(&mut self, _function: usize, _now_s: f64, _env: &mut PolicyEnv) {}

    /// Do this function's artifacts survive the keep-alive teardown of
    /// its instance? True when they belong to the provider-side agent
    /// (§2.4), not to the user instance.
    fn retains_artifacts(&self, _function: usize) -> bool {
        false
    }

    /// A fully pre-staged process runs at warm speed — the §6.3 claim
    /// that a pre-loaded cold start matches a warm start.
    fn prewarmed(&self, _ready: Readiness) -> bool {
        false
    }

    /// Kernel-state latency a scale-out instance pays (a dispatch while
    /// the function already has in-flight batches starts a new process:
    /// fresh CUDA context, fresh per-context kernel handles).
    fn scaleout_kernel_s(&self, _function: usize, m: &ModelProfile) -> f64 {
        m.kernel_jit_s
    }

    /// Cold-start phase → cost terms for one dispatch.  Each phase is an
    /// ordered list of fixed overheads and per-link transfers; the tiered
    /// engine turns the transfers into contended flows, while the flat
    /// engine folds them to scalars via [`PreloadPolicy::load_phases`].
    /// Ledger mutation (making artifacts resident) is done by the
    /// dispatch layer from the same `Readiness`; this prices it.
    fn load_plan(&mut self, q: &LoadQuery) -> BTreeMap<Phase, PhaseCost>;

    /// Scalar view of [`PreloadPolicy::load_plan`] at default link
    /// bandwidths: phase → seconds, each phase folded in term order —
    /// bit-identical to the flat latencies this trait used to return.
    fn load_phases(&mut self, q: &LoadQuery) -> BTreeMap<Phase, f64> {
        self.load_plan(q)
            .into_iter()
            .map(|(p, c)| (p, c.total_default()))
            .collect()
    }
}

/// §4.2 batching: when a queue fires and how large a batch it wants.
/// Policies are stateless deciders over the engine-owned [`BatchQueue`]s.
pub trait BatchingPolicy: Send {
    fn name(&self) -> &'static str;

    /// Fire-now decision for one queue. `target_idle` lazily reports
    /// whether the GPU this function routes to has a free prefill slot.
    fn should_dispatch(&self, q: &BatchQueue, now_s: f64, target_idle: &dyn Fn() -> bool) -> bool;

    /// Earliest future instant at which the queue would time out (event
    /// wakeup scheduling).
    fn expiry_time(&self, q: &BatchQueue) -> Option<f64>;

    /// Desired batch size before the memory cap.
    fn desired_batch(&self, q: &BatchQueue) -> usize;

    /// Eq. 5 deadline-margin prioritisation (adaptive) vs plain FIFO.
    fn prioritise_by_margin(&self) -> bool;
}

/// §4.3 memory-pressure resolution at dispatch time.
pub trait OffloadPolicy: Send {
    fn name(&self) -> &'static str;

    /// Try to free `need_gb` on `gpu` without touching `protect`.
    /// `None` ⇒ this policy never evicts; the caller blocks until
    /// completions free memory (the NDO ablation / baselines).
    #[allow(clippy::too_many_arguments)]
    fn try_free(
        &mut self,
        cluster: &mut Cluster,
        registry: &mut BackboneRegistry,
        gpu: GpuId,
        need_gb: f64,
        protect: &[usize],
        functions: &[FunctionSpec],
        rates: &[f64],
        spill: Option<ContainerId>,
    ) -> Option<OffloadPlan>;
}

/// One billing class's aggregate footprint over an inter-event interval.
/// Both §6.1 pricing rules are linear within a class, so summing before
/// pricing is exact — the engine maintains these sums by delta and never
/// walks the GPUs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClassBillSample {
    /// GPUs currently in this class (CPU/host-mem surcharges are
    /// per-instance, so the count still matters).
    pub gpus: usize,
    /// Σ resident GB above the runtime reserve across the class.
    pub used_gb: f64,
    /// Σ device capacity across the class (unshared billing charges
    /// whole GPUs).
    pub total_gb: f64,
}

/// The cluster's billable state over an inter-event interval, one
/// [`ClassBillSample`] per billing class. GPUs with no billable bytes
/// (the empty class) are omitted — no pricing rule charges them.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AggregateBillSample {
    /// GPUs with at least one executing batch.
    pub active: ClassBillSample,
    /// GPUs with an in-flight artifact load but nothing executing —
    /// loading bills like execution (the instance is allocated and
    /// working), kept separate for observability.
    pub loading: ClassBillSample,
    /// Idle GPUs hosting at least one keep-alive-warm function.
    pub idle_warm: ClassBillSample,
    /// Idle GPUs whose residency is entirely agent-staged (§2.4:
    /// "pre-loading without extra wastage" — not billed to users).
    pub idle_cold: ClassBillSample,
}

/// How resource-time turns into dollars (§6.1 pricing rules).
pub trait BillingModel: Send {
    fn name(&self) -> &'static str;

    /// Whether per-interval sampling is needed at all (serverful
    /// billing is flat and skips the event-integrated path).
    fn needs_interval(&self) -> bool {
        true
    }

    /// Integrate the cluster's cost over a `dt_s`-second interval from
    /// one aggregate sample — O(1) per interval regardless of fleet
    /// size.
    fn bill(&self, s: &AggregateBillSample, dt_s: f64, cost: &mut CostTracker);

    /// End-of-run settlement (serverful: dedicated GPU-hours).
    fn finalize(&self, dedicated_gpus: usize, end_s: f64, cost: &mut CostTracker);
}

/// The fifth policy axis: host-RAM checkpoint-cache admission/eviction —
/// the tiered store's RAM tier (`cluster/cache.rs`).  The dispatch layer
/// consults it on every tiered cold load: `on_hit` when the node's cache
/// already holds the model, `admit` after a miss streamed the checkpoint
/// through the node.  Policies make room by evicting through the ledger;
/// the eviction count is reported back for `RunStats`.
pub trait CachePolicy: Send {
    fn name(&self) -> &'static str;

    /// The node's cache holds `model` and a load is about to read it.
    fn on_hit(&mut self, cache: &mut HostCache, model: &'static str, now_s: f64) {
        cache.touch(model, now_s);
    }

    /// A miss just streamed `model` (`size_gb`) through the node.  Admit
    /// it (possibly evicting) or decline; returns evictions performed.
    fn admit(
        &mut self,
        cache: &mut HostCache,
        model: &'static str,
        size_gb: f64,
        now_s: f64,
    ) -> u64;
}

/// The sixth policy axis: the cold-start *strategy* — which plan brings
/// a cold function up (`coldstart` module, mechanism in
/// `sim::coldstart`). The dispatch layer asks for the per-function
/// strategy class at every cold load; the snapshot/pipeline parameter
/// blocks configure the two non-default paths. The default
/// [`TieredColdStart`] answers `Tiered` for everything and the engine
/// then takes the historical segmented path bit-for-bit.
pub trait ColdStartPolicy: Send {
    fn name(&self) -> &'static str;

    /// Strategy class of one function id (head vs tail mixing).
    fn strategy(&self, function: usize) -> ColdStartKind;

    /// SnapStart parameters (build / restore / storage surcharge).
    fn snapshot(&self) -> &SnapshotParams;

    /// Pipelined-load parameters (width K, consolidation trigger).
    fn pipeline(&self) -> &PipelineParams;
}

/// The full policy complement one engine run is driven by.
pub struct PolicyBundle {
    pub preload: Box<dyn PreloadPolicy>,
    pub batching: Box<dyn BatchingPolicy>,
    pub offload: Box<dyn OffloadPolicy>,
    pub billing: Box<dyn BillingModel>,
    pub cache: Box<dyn CachePolicy>,
    pub cold_start: Box<dyn ColdStartPolicy>,
}

// ------------------------------------------------- shared phase helpers

/// Container + process (CUDA context) initialisation phase. Policies that
/// keep warm containers (`container_cold = false`) pay only the context.
fn init_phase(q: &LoadQuery, container_cold: bool, plan: &mut BTreeMap<Phase, PhaseCost>) {
    if !q.warm_instance && !q.ready.cuda_context {
        let mut c = PhaseCost::fixed(params::CUDA_CONTEXT_INIT_S);
        if container_cold {
            c.push(Term::Fixed(params::CONTAINER_INIT_S));
        }
        plan.insert(Phase::ContainerInit, c);
    }
}

/// Adapter load phase — identical across policies: PCIe from a container
/// copy, NVMe otherwise, plus the PEFT-style attach cost.
fn adapter_phase(q: &LoadQuery, plan: &mut BTreeMap<Phase, PhaseCost>) {
    if !q.ready.adapter_on_gpu {
        let link = if q.container_has_adapter {
            LinkKind::Pcie
        } else {
            LinkKind::Nvme
        };
        plan.insert(
            Phase::AdapterLoad,
            PhaseCost(vec![
                Term::Xfer { link, gb: q.model.adapter_gb },
                Term::Fixed(params::ADAPTER_ATTACH_S),
            ]),
        );
    }
}

/// Cold library load: NVMe read + cold import.
fn library_cold(m: &ModelProfile) -> PhaseCost {
    PhaseCost(vec![
        Term::Xfer { link: LinkKind::Nvme, gb: m.library_gb },
        Term::Fixed(params::LIBRARY_IMPORT_S),
    ])
}

// ------------------------------------------------------ preload policies

/// No pre-loading at all (the NPL ablation): every cold start walks the
/// full path — container, libraries, backbone from SSD (PCIe when a
/// staging copy exists), adapter, JIT.
pub struct NoPreload;

impl PreloadPolicy for NoPreload {
    fn name(&self) -> &'static str {
        "none"
    }

    fn deploy(&mut self, _env: &mut PolicyEnv) {}

    fn load_plan(&mut self, q: &LoadQuery) -> BTreeMap<Phase, PhaseCost> {
        let m = q.model;
        let mut plan = BTreeMap::new();
        init_phase(q, true, &mut plan);
        if !q.warm_instance {
            plan.insert(Phase::LibraryLoad, library_cold(m));
        }
        if !q.ready.backbone_on_gpu {
            let link = if q.container_has_model_backbone {
                LinkKind::Pcie
            } else {
                LinkKind::Nvme
            };
            plan.insert(Phase::BackboneLoad, PhaseCost::xfer(link, m.weights_gb));
        }
        adapter_phase(q, &mut plan);
        if !q.ready.kernel_on_gpu && !q.warm_instance {
            plan.insert(Phase::KernelCompile, PhaseCost::fixed(m.kernel_jit_s));
        }
        plan
    }
}

/// ServerlessLLM: no artifact pre-loading, but the multi-tier checkpoint
/// store makes backbone loads run at PCIe speed.
pub struct FastCheckpointPreload;

impl PreloadPolicy for FastCheckpointPreload {
    fn name(&self) -> &'static str {
        "fast-checkpoint"
    }

    fn deploy(&mut self, _env: &mut PolicyEnv) {}

    fn load_plan(&mut self, q: &LoadQuery) -> BTreeMap<Phase, PhaseCost> {
        let m = q.model;
        let mut plan = BTreeMap::new();
        init_phase(q, true, &mut plan);
        if !q.warm_instance {
            plan.insert(Phase::LibraryLoad, library_cold(m));
        }
        if !q.ready.backbone_on_gpu {
            plan.insert(
                Phase::BackboneLoad,
                PhaseCost::xfer(LinkKind::Pcie, m.weights_gb),
            );
        }
        adapter_phase(q, &mut plan);
        if !q.ready.kernel_on_gpu && !q.warm_instance {
            plan.insert(Phase::KernelCompile, PhaseCost::fixed(m.kernel_jit_s));
        }
        plan
    }
}

/// InstaInfer: opportunistically pre-loads libraries + models into idle
/// containers' RAM. Its time-series predictor churns: a mispredicted cold
/// start first waits out the in-flight preload of *another* function.
pub struct OpportunisticPreload {
    pub hit_rate: f64,
    rng: Pcg64,
}

impl OpportunisticPreload {
    /// The rng stream constant matches the engine's historical insta-churn
    /// stream, preserving bit-exact metrics across the policy refactor.
    pub fn new(hit_rate: f64, seed: u64) -> Self {
        OpportunisticPreload { hit_rate, rng: Pcg64::with_stream(seed, 0x51f7) }
    }
}

impl PreloadPolicy for OpportunisticPreload {
    fn name(&self) -> &'static str {
        "container-opportunistic"
    }

    /// Libraries + backbone + adapter into idle containers' RAM (one
    /// function per container slot, round-robin).
    fn deploy(&mut self, env: &mut PolicyEnv) {
        let cids = env.cluster.container_ids();
        for (i, spec) in env.functions.iter().enumerate() {
            let cid = cids[i % cids.len()];
            let c = env.cluster.container_mut(cid);
            let _ = c.place(spec.id, ArtifactKind::Library, spec.model.library_gb);
            let _ = c.place(spec.id, ArtifactKind::Backbone, spec.model.weights_gb);
            let _ = c.place(spec.id, ArtifactKind::Adapter, spec.model.adapter_gb);
        }
    }

    fn load_plan(&mut self, q: &LoadQuery) -> BTreeMap<Phase, PhaseCost> {
        let m = q.model;
        let mut plan: BTreeMap<Phase, PhaseCost> = BTreeMap::new();
        // Predictor outcome for this cold start (one draw per cold start,
        // in dispatch order — the determinism contract).
        let mut insta_hit = true;
        if !q.warm_instance {
            insta_hit = self.rng.f64() < self.hit_rate;
            if !insta_hit {
                // Churn wait: the slot is busy finishing another
                // function's in-flight NVMe staging read.
                plan.entry(Phase::Queue)
                    .or_default()
                    .push(Term::Xfer { link: LinkKind::Nvme, gb: m.weights_gb });
            }
        }
        init_phase(q, false, &mut plan);
        if !q.warm_instance {
            let c = if insta_hit && q.container_has_library {
                PhaseCost::fixed(params::LIBRARY_WARM_IMPORT_S)
            } else {
                library_cold(m)
            };
            plan.insert(Phase::LibraryLoad, c);
        }
        if !q.ready.backbone_on_gpu {
            let c = if insta_hit && q.container_has_own_backbone {
                PhaseCost::xfer(LinkKind::Pcie, m.weights_gb)
            } else {
                // Two hops: NVMe into host RAM, then PCIe up.
                PhaseCost(vec![
                    Term::Xfer { link: LinkKind::Nvme, gb: m.weights_gb },
                    Term::Xfer { link: LinkKind::Pcie, gb: m.weights_gb },
                ])
            };
            plan.insert(Phase::BackboneLoad, c);
        }
        adapter_phase(q, &mut plan);
        if !q.ready.kernel_on_gpu && !q.warm_instance {
            // InstaInfer never pre-compiles kernels.
            plan.insert(Phase::KernelCompile, PhaseCost::fixed(m.kernel_jit_s));
        }
        plan
    }
}

/// ServerlessLoRA §4.1: full PCKP pre-loading at deployment time —
/// libraries into containers, backbone + adapter + kernels onto GPUs,
/// CUDA contexts pre-warmed by the Pre-Loading Agent.
pub struct FullPreload;

impl FullPreload {
    /// Stage one container copy of each model's backbone so on-demand
    /// *replicas* (contention relief) load over PCIe rather than SSD.
    fn stage_backbone_copies(env: &mut PolicyEnv) {
        let mut staged: BTreeSet<&str> = BTreeSet::new();
        let cids = env.cluster.container_ids();
        for (i, spec) in env.functions.iter().enumerate() {
            if staged.insert(spec.model.name) {
                let cid = cids[i % cids.len()];
                let _ = env.cluster.container_mut(cid).place(
                    spec.id,
                    ArtifactKind::Backbone,
                    spec.model.weights_gb,
                );
            }
        }
    }
}

impl PreloadPolicy for FullPreload {
    fn name(&self) -> &'static str {
        "full-pckp"
    }

    fn deploy(&mut self, env: &mut PolicyEnv) {
        let demands: Vec<FunctionDemand> = env
            .functions
            .iter()
            .zip(env.rates)
            .map(|(spec, &rate)| FunctionDemand { spec: spec.clone(), rate })
            .collect();
        let sched = PreloadScheduler::default();
        let plan = sched.plan(&demands, env.cluster, env.registry);
        if env.sharing {
            sched.apply(&plan, &demands, env.cluster, env.registry);
        } else {
            // NBS ablation: the same plan, but every function pays for a
            // *private* backbone copy (best-effort under memory).
            for d in &plan.decisions {
                let spec = &env.functions[d.function];
                match (d.kind, d.placement) {
                    (ArtifactKind::Backbone, Placement::Gpu(g)) => {
                        let _ = env.cluster.gpu_mut(g).place_artifact(
                            d.function,
                            ArtifactKind::Backbone,
                            spec.model.weights_gb,
                        );
                    }
                    (k, Placement::Gpu(g)) => {
                        let _ = env.cluster.gpu_mut(g).place_artifact(d.function, k, d.size_gb);
                    }
                    (k, Placement::Container(cid)) => {
                        let _ = env.cluster.container_mut(cid).place(d.function, k, d.size_gb);
                    }
                }
            }
        }
        env.stats.preload_decisions = plan.decisions.len();
        Self::stage_backbone_copies(env);
        // Pre-warm the process (CUDA context) where each kernel landed.
        for d in &plan.decisions {
            if let (ArtifactKind::CudaKernel, Placement::Gpu(g)) = (d.kind, d.placement) {
                let _ = env.cluster.gpu_mut(g).create_cuda_context(d.function);
            }
        }
    }

    /// Artifacts belong to the Pre-Loading Agent and survive instance
    /// keep-alive expiry (§2.4 "pre-loading without extra wastage").
    fn retains_artifacts(&self, _function: usize) -> bool {
        true
    }

    /// Kernels compiled + context created ⇒ warm-start speed (§6.3).
    fn prewarmed(&self, ready: Readiness) -> bool {
        ready.cuda_context && ready.kernel_on_gpu
    }

    /// Full pre-loading keeps a warm kernel cache even for a scale-out
    /// process instance.
    fn scaleout_kernel_s(&self, _function: usize, m: &ModelProfile) -> f64 {
        m.kernel_cache_load_s
    }

    fn load_plan(&mut self, q: &LoadQuery) -> BTreeMap<Phase, PhaseCost> {
        let m = q.model;
        let mut plan = BTreeMap::new();
        init_phase(q, false, &mut plan);
        if !q.warm_instance {
            plan.insert(
                Phase::LibraryLoad,
                PhaseCost::fixed(params::LIBRARY_WARM_IMPORT_S),
            );
        }
        if !q.ready.backbone_on_gpu {
            // Replica loads come from the staged host-RAM copy when one
            // exists (PCIe), else from NVMe.
            let link = if q.container_has_model_backbone {
                LinkKind::Pcie
            } else {
                LinkKind::Nvme
            };
            plan.insert(Phase::BackboneLoad, PhaseCost::xfer(link, m.weights_gb));
        }
        adapter_phase(q, &mut plan);
        if !q.ready.kernel_on_gpu && !q.warm_instance {
            plan.insert(Phase::KernelCompile, PhaseCost::fixed(m.kernel_cache_load_s));
        }
        plan
    }
}

/// Serverful deployment (vLLM / dLoRA): dedicate GPUs and make everything
/// resident up-front. vLLM: one deployment per function. dLoRA: one per
/// backbone model (its adapters share the backbone in-process).
pub struct ServerfulResident;

impl PreloadPolicy for ServerfulResident {
    fn name(&self) -> &'static str {
        "serverful-resident"
    }

    fn deploy(&mut self, env: &mut PolicyEnv) {
        let gpu_ids = env.cluster.gpu_ids();
        if env.sharing {
            // dLoRA: GPU per distinct model.
            let mut model_gpu: BTreeMap<&str, GpuId> = BTreeMap::new();
            let mut next = 0;
            for spec in env.functions {
                let m = &spec.model;
                let g = *model_gpu.entry(m.name).or_insert_with(|| {
                    let g = gpu_ids[next % gpu_ids.len()];
                    next += 1;
                    g
                });
                env.registry.load(env.cluster, m.name, m.weights_gb, g).unwrap();
                let gpu = env.cluster.gpu_mut(g);
                gpu.place_artifact(spec.id, ArtifactKind::Adapter, m.adapter_gb).unwrap();
                gpu.place_artifact(spec.id, ArtifactKind::CudaKernel, m.kernel_gb).unwrap();
                gpu.create_cuda_context(spec.id).unwrap();
                env.dedicated.insert(spec.id, g);
            }
        } else {
            // vLLM: GPU per function, private backbone.
            for (i, spec) in env.functions.iter().enumerate() {
                let m = &spec.model;
                let g = gpu_ids[i % gpu_ids.len()];
                let gpu = env.cluster.gpu_mut(g);
                gpu.place_artifact(spec.id, ArtifactKind::Backbone, m.weights_gb).unwrap();
                gpu.place_artifact(spec.id, ArtifactKind::Adapter, m.adapter_gb).unwrap();
                gpu.place_artifact(spec.id, ArtifactKind::CudaKernel, m.kernel_gb).unwrap();
                gpu.create_cuda_context(spec.id).unwrap();
                env.dedicated.insert(spec.id, g);
            }
        }
    }

    fn retains_artifacts(&self, _function: usize) -> bool {
        true // moot: serverful instances never expire
    }

    /// Everything is resident; dispatch never pays a load phase.
    fn load_plan(&mut self, _q: &LoadQuery) -> BTreeMap<Phase, PhaseCost> {
        BTreeMap::new()
    }
}

/// Predictive pre-loading — the plug-in proof of the policy API, in the
/// spirit of Predictive-LoRA: a per-function EWMA arrival-rate forecast;
/// functions whose forecast crosses a threshold are pre-staged (backbone,
/// adapter, kernels, CUDA context) ahead of the predicted burst, and fall
/// back to the ordinary keep-alive lifecycle when demand fades.
pub struct PredictivePreload {
    /// EWMA smoothing factor for instantaneous-rate samples.
    pub alpha: f64,
    /// Forecast rate (req/s) above which a function is pre-staged.
    pub threshold: f64,
    ewma: BTreeMap<usize, f64>,
    last_arrival: BTreeMap<usize, f64>,
    staged: BTreeSet<usize>,
}

impl Default for PredictivePreload {
    fn default() -> Self {
        // Threshold sits between the 2nd and 3rd RATE_TIERS of the paper
        // workload (1/90 ≈ 0.011 and 1/180 ≈ 0.0056 req/s): the hot half
        // of a deployment is staged, the cold tail is not.
        PredictivePreload {
            alpha: 0.3,
            threshold: 0.008,
            ewma: BTreeMap::new(),
            last_arrival: BTreeMap::new(),
            staged: BTreeSet::new(),
        }
    }
}

impl PredictivePreload {
    pub fn forecast(&self, function: usize) -> f64 {
        self.ewma.get(&function).copied().unwrap_or(0.0)
    }

    pub fn is_staged(&self, function: usize) -> bool {
        self.staged.contains(&function)
    }

    /// Best-effort staging of one function's artifacts on its best GPU.
    fn stage(&mut self, f: usize, env: &mut PolicyEnv) {
        let spec = env.functions[f].clone();
        let m = &spec.model;
        // Per-model host-RAM staging copy: replica/backbone reloads go
        // over PCIe instead of SSD. The residency index answers "does
        // any container hold a peer's backbone" without a container scan.
        let has_copy = env
            .functions
            .iter()
            .filter(|s| s.model.name == m.name)
            .any(|s| env.cluster.container_has(s.id, ArtifactKind::Backbone));
        if !has_copy {
            let cids = env.cluster.container_ids();
            if let Some(&cid) = cids.get(f % cids.len().max(1)) {
                let _ = env.cluster.container_mut(cid).place(
                    f,
                    ArtifactKind::Backbone,
                    m.weights_gb,
                );
            }
        }
        let Some(route) = Router::route(env.cluster, env.registry, &spec, 1) else {
            return;
        };
        let g = route.gpu;
        let ready = route.readiness;
        if !ready.backbone_on_gpu {
            let placed = if env.sharing {
                env.registry.load(env.cluster, m.name, m.weights_gb, g).is_ok()
            } else {
                env.cluster
                    .gpu_mut(g)
                    .place_artifact(f, ArtifactKind::Backbone, m.weights_gb)
                    .is_ok()
            };
            if !placed {
                return; // no room: stay unstaged, retry on a later arrival
            }
        }
        let gpu = env.cluster.gpu_mut(g);
        if !ready.adapter_on_gpu {
            let _ = gpu.place_artifact(f, ArtifactKind::Adapter, m.adapter_gb);
        }
        if !ready.kernel_on_gpu {
            let _ = gpu.place_artifact(f, ArtifactKind::CudaKernel, m.kernel_gb);
        }
        if !ready.cuda_context {
            let _ = gpu.create_cuda_context(f);
        }
        self.staged.insert(f);
        env.stats.preload_decisions += 1;
    }
}

impl PreloadPolicy for PredictivePreload {
    fn name(&self) -> &'static str {
        "predictive-ewma"
    }

    /// Seed forecasts from the controller's deployment-time rate
    /// estimates and stage everything already above threshold.
    fn deploy(&mut self, env: &mut PolicyEnv) {
        for (i, &r) in env.rates.iter().enumerate() {
            self.ewma.insert(i, r);
        }
        for f in 0..env.functions.len() {
            if self.forecast(f) >= self.threshold {
                self.stage(f, env);
            }
        }
    }

    /// EWMA update on every arrival; stage on upward crossings, release
    /// (back to the keep-alive lifecycle) when the forecast halves.
    fn on_arrival(&mut self, f: usize, now_s: f64, env: &mut PolicyEnv) {
        if let Some(prev) = self.last_arrival.insert(f, now_s) {
            let inst = 1.0 / (now_s - prev).max(1e-3);
            let e = self.ewma.entry(f).or_insert(0.0);
            *e = self.alpha * inst + (1.0 - self.alpha) * *e;
        }
        let fc = self.forecast(f);
        if fc >= self.threshold && !self.staged.contains(&f) {
            self.stage(f, env);
        } else if fc < self.threshold / 2.0 {
            self.staged.remove(&f);
        }
    }

    /// Staged artifacts belong to the agent; unstaged functions tear down
    /// with their instance like any serverless function.
    fn retains_artifacts(&self, function: usize) -> bool {
        self.staged.contains(&function)
    }

    fn prewarmed(&self, ready: Readiness) -> bool {
        ready.cuda_context && ready.kernel_on_gpu
    }

    fn scaleout_kernel_s(&self, function: usize, m: &ModelProfile) -> f64 {
        if self.staged.contains(&function) {
            m.kernel_cache_load_s
        } else {
            m.kernel_jit_s
        }
    }

    fn load_plan(&mut self, q: &LoadQuery) -> BTreeMap<Phase, PhaseCost> {
        let m = q.model;
        let hot = self.staged.contains(&q.function);
        let mut plan = BTreeMap::new();
        init_phase(q, !hot, &mut plan);
        if !q.warm_instance {
            let c = if hot {
                PhaseCost::fixed(params::LIBRARY_WARM_IMPORT_S)
            } else {
                library_cold(m)
            };
            plan.insert(Phase::LibraryLoad, c);
        }
        if !q.ready.backbone_on_gpu {
            let link = if q.container_has_model_backbone {
                LinkKind::Pcie
            } else {
                LinkKind::Nvme
            };
            plan.insert(Phase::BackboneLoad, PhaseCost::xfer(link, m.weights_gb));
        }
        adapter_phase(q, &mut plan);
        if !q.ready.kernel_on_gpu && !q.warm_instance {
            let c = if hot {
                PhaseCost::fixed(m.kernel_cache_load_s)
            } else {
                PhaseCost::fixed(m.kernel_jit_s)
            };
            plan.insert(Phase::KernelCompile, c);
        }
        plan
    }
}

// ----------------------------------------------------- batching policies

/// Two-layer adaptive batching (Eq. 2–5): fill-or-expire locally, and
/// fire early when the arrival stream settles and the target GPU has a
/// free prefill slot.
pub struct AdaptiveBatching;

impl BatchingPolicy for AdaptiveBatching {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn should_dispatch(&self, q: &BatchQueue, now_s: f64, target_idle: &dyn Fn() -> bool) -> bool {
        if q.is_empty() {
            return false;
        }
        q.should_dispatch(now_s) || (q.settled(now_s) && target_idle())
    }

    fn expiry_time(&self, q: &BatchQueue) -> Option<f64> {
        q.expiry_time()
    }

    fn desired_batch(&self, q: &BatchQueue) -> usize {
        q.len().min(q.max_batch).max(1)
    }

    fn prioritise_by_margin(&self) -> bool {
        true
    }
}

/// Fixed batch size + fixed delay (the NAB ablations and the baselines'
/// static batchers) — FixedBatchQueue semantics over the engine's queues.
pub struct FixedBatching {
    pub size: usize,
    pub delay_s: f64,
}

impl BatchingPolicy for FixedBatching {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn should_dispatch(&self, q: &BatchQueue, now_s: f64, _target_idle: &dyn Fn() -> bool) -> bool {
        if q.is_empty() {
            return false;
        }
        q.len() >= self.size || now_s - q.oldest_arrival().unwrap() >= self.delay_s - 1e-9
    }

    fn expiry_time(&self, q: &BatchQueue) -> Option<f64> {
        q.oldest_arrival().map(|a| a + self.delay_s)
    }

    fn desired_batch(&self, q: &BatchQueue) -> usize {
        q.len().min(self.size).max(1)
    }

    fn prioritise_by_margin(&self) -> bool {
        false
    }
}

// ------------------------------------------------------ offload policies

/// §4.3 dynamic offloading: free Q_g by evicting the least-valuable
/// unrelated artifacts, value = reload latency × arrival rate.
pub struct DynamicOffload;

impl OffloadPolicy for DynamicOffload {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    #[allow(clippy::too_many_arguments)]
    fn try_free(
        &mut self,
        cluster: &mut Cluster,
        registry: &mut BackboneRegistry,
        gpu: GpuId,
        need_gb: f64,
        protect: &[usize],
        functions: &[FunctionSpec],
        rates: &[f64],
        spill: Option<ContainerId>,
    ) -> Option<OffloadPlan> {
        let plan = DynamicOffloader::free(
            cluster,
            registry,
            gpu,
            need_gb,
            protect,
            |of, kind| {
                let rate = of.map(|x| rates[x]).unwrap_or(0.05);
                let reload = match kind {
                    ArtifactKind::Backbone => of
                        .map(|x| functions[x].model.weights_gb / params::BW_SSD_GBPS)
                        .unwrap_or(3.0),
                    ArtifactKind::Adapter => 0.3,
                    ArtifactKind::CudaKernel => 2.5,
                    _ => 0.5,
                };
                reload * rate
            },
            spill,
        );
        Some(plan)
    }
}

/// Block until completions free memory (NDO ablation / baselines).
pub struct NoOffload;

impl OffloadPolicy for NoOffload {
    fn name(&self) -> &'static str {
        "block"
    }

    #[allow(clippy::too_many_arguments)]
    fn try_free(
        &mut self,
        _cluster: &mut Cluster,
        _registry: &mut BackboneRegistry,
        _gpu: GpuId,
        _need_gb: f64,
        _protect: &[usize],
        _functions: &[FunctionSpec],
        _rates: &[f64],
        _spill: Option<ContainerId>,
    ) -> Option<OffloadPlan> {
        None
    }
}

// -------------------------------------------------------- cache policies

/// Plain LRU: always admit, evicting least-recently-used checkpoints
/// until the new one fits (ties break by model name — deterministic).
pub struct LruCache;

impl CachePolicy for LruCache {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn admit(
        &mut self,
        cache: &mut HostCache,
        model: &'static str,
        size_gb: f64,
        now_s: f64,
    ) -> u64 {
        if !cache.enabled() || size_gb > cache.capacity_gb {
            return 0;
        }
        let mut evicted = 0;
        while cache.free_gb() + 1e-9 < size_gb {
            let Some(v) = cache.lru_victim() else { return evicted };
            cache.remove(v);
            evicted += 1;
        }
        cache.insert(model, size_gb, now_s);
        evicted
    }
}

/// Size-aware LRU: evict the *largest* entries first (ties toward the
/// older, then by name).  Frees the most bytes per eviction and biases
/// the cache toward keeping many small checkpoints over one giant one.
pub struct SizeAwareLruCache;

impl SizeAwareLruCache {
    fn victim(cache: &HostCache) -> Option<&'static str> {
        cache
            .entries()
            .max_by(|a, b| {
                a.1.size_gb
                    .total_cmp(&b.1.size_gb)
                    .then(b.1.last_use_s.total_cmp(&a.1.last_use_s))
                    .then(b.0.cmp(a.0))
            })
            .map(|(k, _)| k)
    }
}

impl CachePolicy for SizeAwareLruCache {
    fn name(&self) -> &'static str {
        "size-aware-lru"
    }

    fn admit(
        &mut self,
        cache: &mut HostCache,
        model: &'static str,
        size_gb: f64,
        now_s: f64,
    ) -> u64 {
        if !cache.enabled() || size_gb > cache.capacity_gb {
            return 0;
        }
        let mut evicted = 0;
        while cache.free_gb() + 1e-9 < size_gb {
            let Some(v) = Self::victim(cache) else { return evicted };
            cache.remove(v);
            evicted += 1;
        }
        cache.insert(model, size_gb, now_s);
        evicted
    }
}

/// Pin-hot: entries with `pin_uses`+ hits are pinned and never evicted;
/// admission is *declined* (no partial eviction) when the unpinned set
/// cannot make room.  Protects hot checkpoints from burst-driven churn.
pub struct PinHotCache {
    /// Use count at which an entry becomes pinned.
    pub pin_uses: u64,
}

impl Default for PinHotCache {
    fn default() -> Self {
        PinHotCache { pin_uses: 3 }
    }
}

impl PinHotCache {
    fn unpinned_victim(&self, cache: &HostCache) -> Option<&'static str> {
        cache
            .entries()
            .filter(|(_, e)| e.uses < self.pin_uses)
            .min_by(|a, b| a.1.last_use_s.total_cmp(&b.1.last_use_s).then(a.0.cmp(b.0)))
            .map(|(k, _)| k)
    }
}

impl CachePolicy for PinHotCache {
    fn name(&self) -> &'static str {
        "pin-hot"
    }

    fn admit(
        &mut self,
        cache: &mut HostCache,
        model: &'static str,
        size_gb: f64,
        now_s: f64,
    ) -> u64 {
        if !cache.enabled() || size_gb > cache.capacity_gb {
            return 0;
        }
        // Feasibility first: free space + every unpinned byte must cover
        // the admission, otherwise decline without touching the ledger.
        let reclaimable: f64 = cache
            .entries()
            .filter(|(_, e)| e.uses < self.pin_uses)
            .map(|(_, e)| e.size_gb)
            .sum();
        if cache.free_gb() + reclaimable + 1e-9 < size_gb {
            return 0;
        }
        let mut evicted = 0;
        while cache.free_gb() + 1e-9 < size_gb {
            let Some(v) = self.unpinned_victim(cache) else { break };
            cache.remove(v);
            evicted += 1;
        }
        if cache.free_gb() + 1e-9 >= size_gb {
            cache.insert(model, size_gb, now_s);
        }
        evicted
    }
}

// --------------------------------------------------- cold-start policies

/// The default cold-start policy: every function takes the segmented
/// tiered load. `cold_start: None` selects this and the engine performs
/// zero additional work — the dormant fast path.
pub struct TieredColdStart {
    snapshot: SnapshotParams,
    pipeline: PipelineParams,
}

impl Default for TieredColdStart {
    fn default() -> Self {
        TieredColdStart {
            snapshot: SnapshotParams::default(),
            pipeline: PipelineParams::default(),
        }
    }
}

impl ColdStartPolicy for TieredColdStart {
    fn name(&self) -> &'static str {
        "tiered"
    }

    fn strategy(&self, _function: usize) -> ColdStartKind {
        ColdStartKind::Tiered
    }

    fn snapshot(&self) -> &SnapshotParams {
        &self.snapshot
    }

    fn pipeline(&self) -> &PipelineParams {
        &self.pipeline
    }
}

/// Spec-driven cold-start policy: per-function-class strategy mixing
/// (head vs tail) with the spec's snapshot/pipeline parameter blocks.
pub struct SpecColdStart {
    spec: ColdStartSpec,
}

impl SpecColdStart {
    pub fn new(spec: ColdStartSpec) -> Self {
        SpecColdStart { spec }
    }
}

impl ColdStartPolicy for SpecColdStart {
    fn name(&self) -> &'static str {
        match self.spec.head {
            Some(_) => "mixed",
            None => self.spec.strategy.id(),
        }
    }

    fn strategy(&self, function: usize) -> ColdStartKind {
        self.spec.strategy_for(function)
    }

    fn snapshot(&self) -> &SnapshotParams {
        &self.spec.snapshot
    }

    fn pipeline(&self) -> &PipelineParams {
        &self.spec.pipeline
    }
}

// ------------------------------------------------------- billing models

/// Serverless event-integrated billing: active (executing or loading)
/// GPUs bill their resident GB at the active rate, idle GPUs at the
/// keep-alive idle rate — and only while a keep-alive-warm function
/// resides there (§2.4: agent-staged artifacts are not billed to users).
/// Both rules are linear in GB within a class, so the aggregate sums
/// price exactly what the historical per-GPU walk priced.
pub struct ServerlessBilling {
    /// Without backbone sharing a function occupies its GPU *exclusively*
    /// (§1): the platform bills the whole allocated GPU, not the bytes
    /// touched. Sharing enables fractional allocation — the cost win.
    pub sharing: bool,
}

impl BillingModel for ServerlessBilling {
    fn name(&self) -> &'static str {
        "serverless"
    }

    fn bill(&self, s: &AggregateBillSample, dt_s: f64, cost: &mut CostTracker) {
        let active_gpus = s.active.gpus + s.loading.gpus;
        if active_gpus > 0 {
            let billed = if self.sharing {
                s.active.used_gb + s.loading.used_gb
            } else {
                s.active.total_gb + s.loading.total_gb
            };
            // CPU/host-mem of the functions actively working there, per
            // allocated instance.
            cost.add_active(billed, dt_s, 4.0 * active_gpus as f64, 16.0 * active_gpus as f64);
        }
        if s.idle_warm.gpus > 0 {
            let billed = if self.sharing {
                s.idle_warm.used_gb
            } else {
                s.idle_warm.total_gb
            };
            cost.add_idle(billed, dt_s, 4.0 * s.idle_warm.gpus as f64);
        }
        // idle_cold: agent-staged residency only — never billed.
    }

    fn finalize(&self, _dedicated_gpus: usize, _end_s: f64, _cost: &mut CostTracker) {}
}

/// Serverful flat billing: dedicated GPUs bill wall-clock regardless of
/// utilisation; nothing accrues per-interval.
pub struct ServerfulBilling;

impl BillingModel for ServerfulBilling {
    fn name(&self) -> &'static str {
        "serverful"
    }

    fn needs_interval(&self) -> bool {
        false
    }

    fn bill(&self, _s: &AggregateBillSample, _dt_s: f64, _cost: &mut CostTracker) {}

    fn finalize(&self, dedicated_gpus: usize, end_s: f64, cost: &mut CostTracker) {
        cost.add_serverful(dedicated_gpus as f64, end_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::ModelProfile;
    use crate::coordinator::batching::Queued;

    fn queue_with(n: usize, t: f64) -> BatchQueue {
        let mut q = BatchQueue::new(0, &ModelProfile::llama2_7b());
        for i in 0..n as u64 {
            q.push(Queued { request: i, arrival_s: t });
        }
        q
    }

    fn env_fixture() -> (Cluster, BackboneRegistry, Vec<FunctionSpec>, Vec<f64>) {
        let cluster = Cluster::new(1, 2, 4);
        let registry = BackboneRegistry::new();
        let functions: Vec<FunctionSpec> = (0..4)
            .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
            .collect();
        let rates = vec![0.02, 0.02, 0.002, 0.002];
        (cluster, registry, functions, rates)
    }

    fn query<'a>(m: &'a ModelProfile, warm: bool, ready: Readiness) -> LoadQuery<'a> {
        LoadQuery {
            function: 0,
            model: m,
            ready,
            warm_instance: warm,
            container_has_library: false,
            container_has_adapter: false,
            container_has_own_backbone: false,
            container_has_model_backbone: false,
        }
    }

    const COLD: Readiness = Readiness {
        backbone_on_gpu: false,
        adapter_on_gpu: false,
        kernel_on_gpu: false,
        cuda_context: false,
    };

    #[test]
    fn adaptive_matches_batch_queue_semantics() {
        let p = AdaptiveBatching;
        let q = queue_with(1, 0.0);
        let never = || false;
        let always = || true;
        // Not expired, not settled ⇒ no dispatch even with an idle GPU.
        assert!(!p.should_dispatch(&q, 0.05, &always));
        // Settled + idle GPU ⇒ dispatch before expiry.
        assert!(p.should_dispatch(&q, 0.2, &always));
        assert!(!p.should_dispatch(&q, 0.2, &never));
        // Expiry fires regardless of the GPU.
        let t = p.expiry_time(&q).unwrap();
        assert!(p.should_dispatch(&q, t + 1e-3, &never));
        assert!(p.prioritise_by_margin());
    }

    #[test]
    fn fixed_matches_fixed_queue_semantics() {
        let p = FixedBatching { size: 10, delay_s: 0.5 };
        let idle = || true;
        let q1 = queue_with(1, 0.0);
        assert!(!p.should_dispatch(&q1, 0.4, &idle));
        assert!(p.should_dispatch(&q1, 0.51, &idle));
        let q10 = queue_with(10, 0.0);
        assert!(p.should_dispatch(&q10, 0.0, &idle));
        assert_eq!(p.desired_batch(&q10), 10);
        assert_eq!(p.expiry_time(&q1), Some(0.5));
        assert!(!p.prioritise_by_margin());
    }

    #[test]
    fn empty_queue_never_fires() {
        let q = BatchQueue::new(0, &ModelProfile::llama2_7b());
        let idle = || true;
        assert!(!AdaptiveBatching.should_dispatch(&q, 1e9, &idle));
        assert!(!FixedBatching { size: 1, delay_s: 0.0 }.should_dispatch(&q, 1e9, &idle));
    }

    #[test]
    fn no_offload_blocks_dynamic_frees() {
        let (mut c, mut r, functions, rates) = env_fixture();
        let g = c.gpu_ids()[0];
        c.gpu_mut(g).place_artifact(1, ArtifactKind::Adapter, 0.2).unwrap();
        let need = c.gpu(g).free_gb() + 0.1;
        assert!(NoOffload
            .try_free(&mut c, &mut r, g, need, &[0], &functions, &rates, None)
            .is_none());
        let plan = DynamicOffload
            .try_free(&mut c, &mut r, g, need, &[0], &functions, &rates, None)
            .unwrap();
        assert!(plan.freed_gb > 0.0);
    }

    #[test]
    fn billing_models_split_active_idle_flat() {
        // One executing GPU (20/48 GB), one loading GPU (10/48 GB), two
        // idle-warm GPUs (8/96 GB), one idle-cold GPU (5/48 GB).
        let sample = AggregateBillSample {
            active: ClassBillSample { gpus: 1, used_gb: 20.0, total_gb: 48.0 },
            loading: ClassBillSample { gpus: 1, used_gb: 10.0, total_gb: 48.0 },
            idle_warm: ClassBillSample { gpus: 2, used_gb: 8.0, total_gb: 96.0 },
            idle_cold: ClassBillSample { gpus: 1, used_gb: 5.0, total_gb: 48.0 },
        };
        let mut c = CostTracker::default();
        ServerlessBilling { sharing: true }.bill(&sample, 2.0, &mut c);
        // Loading bills like execution; idle-cold bills nothing.
        assert!((c.gpu_active_gb_s - 60.0).abs() < 1e-9);
        assert!((c.gpu_idle_gb_s - 16.0).abs() < 1e-9);
        // CPU/host-mem surcharges are per active instance (2 of them).
        assert!((c.cpu_core_s - 16.0).abs() < 1e-9);
        // Unshared bills whole GPUs: (48 + 48) GB active, 96 GB idle.
        let mut c2 = CostTracker::default();
        ServerlessBilling { sharing: false }.bill(&sample, 2.0, &mut c2);
        assert!((c2.gpu_active_gb_s - 192.0).abs() < 1e-9);
        assert!((c2.gpu_idle_gb_s - 192.0).abs() < 1e-9);
        // An all-empty sample accrues nothing at all.
        let mut c3 = CostTracker::default();
        ServerlessBilling { sharing: true }.bill(&AggregateBillSample::default(), 2.0, &mut c3);
        assert_eq!(c3.total_usd(), 0.0);
        // Serverful: nothing per-interval, flat at finalize.
        let mut c4 = CostTracker::default();
        let sf = ServerfulBilling;
        assert!(!sf.needs_interval());
        sf.bill(&sample, 2.0, &mut c4);
        assert_eq!(c4.total_usd(), 0.0);
        sf.finalize(2, 3600.0, &mut c4);
        assert!((c4.serverful_gpu_s - 7200.0).abs() < 1e-9);
    }

    #[test]
    fn full_preload_prices_warm_start() {
        let m = ModelProfile::llama2_7b();
        let mut p = FullPreload;
        let ready = Readiness {
            backbone_on_gpu: true,
            adapter_on_gpu: true,
            kernel_on_gpu: true,
            cuda_context: true,
        };
        // Pre-warmed ⇒ warm-instance ⇒ zero load phases (§6.3).
        assert!(p.prewarmed(ready));
        let phases = p.load_phases(&query(&m, true, ready));
        assert!(phases.is_empty());
        // Cold replica with a staged host copy loads backbone over PCIe.
        let q = LoadQuery {
            container_has_model_backbone: true,
            ..query(&m, false, COLD)
        };
        let phases = p.load_phases(&q);
        let bb = phases[&Phase::BackboneLoad];
        assert!((bb - m.weights_gb / params::BW_PCIE_GBPS).abs() < 1e-9);
        assert!((phases[&Phase::KernelCompile] - m.kernel_cache_load_s).abs() < 1e-9);
    }

    #[test]
    fn opportunistic_miss_adds_churn_wait() {
        let m = ModelProfile::llama2_7b();
        // hit_rate 0 forces a miss deterministically.
        let mut p = OpportunisticPreload::new(0.0, 1);
        let phases = p.load_phases(&query(&m, false, COLD));
        let churn = phases[&Phase::Queue];
        assert!((churn - m.weights_gb / params::BW_SSD_GBPS).abs() < 1e-9);
        // A miss pays SSD + PCIe for the backbone.
        let bb = phases[&Phase::BackboneLoad];
        let expect = m.weights_gb / params::BW_SSD_GBPS + m.weights_gb / params::BW_PCIE_GBPS;
        assert!((bb - expect).abs() < 1e-9);
    }

    #[test]
    fn predictive_stages_hot_functions_at_deploy() {
        let (mut cluster, mut registry, functions, rates) = env_fixture();
        let mut dedicated = BTreeMap::new();
        let mut stats = RunStats::default();
        let mut p = PredictivePreload::default();
        {
            let mut env = PolicyEnv {
                cluster: &mut cluster,
                registry: &mut registry,
                functions: &functions,
                rates: &rates,
                sharing: true,
                dedicated: &mut dedicated,
                stats: &mut stats,
            };
            p.deploy(&mut env);
        }
        // The two hot functions (0.02 req/s) staged; cold tail not.
        assert!(p.is_staged(0) && p.is_staged(1));
        assert!(!p.is_staged(2) && !p.is_staged(3));
        assert_eq!(stats.preload_decisions, 2);
        assert!(p.retains_artifacts(0));
        assert!(!p.retains_artifacts(2));
        // Staged artifacts are actually resident somewhere.
        let resident = cluster.gpu_ids().iter().any(|&g| {
            cluster.gpu(g).has_artifact(0, ArtifactKind::Adapter)
                && cluster.gpu(g).has_cuda_context(0)
        });
        assert!(resident, "staging left no residue on any GPU");
    }

    #[test]
    fn predictive_ewma_reacts_to_bursts() {
        let (mut cluster, mut registry, functions, rates) = env_fixture();
        let mut dedicated = BTreeMap::new();
        let mut stats = RunStats::default();
        let mut p = PredictivePreload::default();
        let mut env = PolicyEnv {
            cluster: &mut cluster,
            registry: &mut registry,
            functions: &functions,
            rates: &rates,
            sharing: true,
            dedicated: &mut dedicated,
            stats: &mut stats,
        };
        // Cold function 3 gets a burst: 1 req/s for 20 arrivals.
        for i in 0..20 {
            p.on_arrival(3, i as f64, &mut env);
        }
        assert!(p.forecast(3) > p.threshold, "forecast {}", p.forecast(3));
        assert!(p.is_staged(3));
    }

    #[test]
    fn load_plan_folds_to_the_flat_latencies_bitwise() {
        // The term plans must fold (left, in term order, at default
        // bandwidths) to the exact pre-refactor scalar latencies.
        let m = ModelProfile::llama2_7b();
        let bits = |x: f64| x.to_bits();
        let phases = NoPreload.load_phases(&query(&m, false, COLD));
        assert_eq!(
            bits(phases[&Phase::ContainerInit]),
            bits(params::CUDA_CONTEXT_INIT_S + params::CONTAINER_INIT_S)
        );
        assert_eq!(
            bits(phases[&Phase::LibraryLoad]),
            bits(m.library_gb / params::BW_SSD_GBPS + params::LIBRARY_IMPORT_S)
        );
        assert_eq!(
            bits(phases[&Phase::BackboneLoad]),
            bits(m.weights_gb / params::BW_SSD_GBPS)
        );
        assert_eq!(
            bits(phases[&Phase::AdapterLoad]),
            bits(m.adapter_gb / params::BW_SSD_GBPS + params::ADAPTER_ATTACH_S)
        );
        assert_eq!(bits(phases[&Phase::KernelCompile]), bits(m.kernel_jit_s));
        // Deterministic InstaInfer miss: churn + two-hop backbone.
        let phases = OpportunisticPreload::new(0.0, 1).load_phases(&query(&m, false, COLD));
        assert_eq!(bits(phases[&Phase::Queue]), bits(m.weights_gb / params::BW_SSD_GBPS));
        assert_eq!(
            bits(phases[&Phase::BackboneLoad]),
            bits(m.weights_gb / params::BW_SSD_GBPS + m.weights_gb / params::BW_PCIE_GBPS)
        );
        // ServerlessLLM: PCIe-speed backbone.
        let phases = FastCheckpointPreload.load_phases(&query(&m, false, COLD));
        assert_eq!(
            bits(phases[&Phase::BackboneLoad]),
            bits(m.weights_gb / params::BW_PCIE_GBPS)
        );
        // Serverful: no phases at all.
        assert!(ServerfulResident.load_plan(&query(&m, false, COLD)).is_empty());
    }

    #[test]
    fn lru_cache_evicts_oldest_to_admit() {
        let mut cache = HostCache::new(40.0);
        let mut p = LruCache;
        assert_eq!(p.admit(&mut cache, "a", 13.5, 1.0), 0);
        assert_eq!(p.admit(&mut cache, "b", 26.0, 2.0), 0);
        // "c" needs room: the oldest ("a") goes.
        assert_eq!(p.admit(&mut cache, "c", 13.5, 3.0), 1);
        assert!(!cache.contains("a") && cache.contains("b") && cache.contains("c"));
        // A hit refreshes recency: now "c" is the LRU victim.
        p.on_hit(&mut cache, "b", 4.0);
        assert_eq!(p.admit(&mut cache, "d", 13.5, 5.0), 1);
        assert!(cache.contains("b") && !cache.contains("c"));
        // Oversized checkpoints are never admitted (and evict nothing).
        assert_eq!(p.admit(&mut cache, "huge", 100.0, 6.0), 0);
        assert!(!cache.contains("huge"));
        // Disabled tier: no-op.
        let mut off = HostCache::new(0.0);
        assert_eq!(p.admit(&mut off, "a", 1.0, 0.0), 0);
        assert!(off.is_empty());
    }

    #[test]
    fn size_aware_lru_evicts_largest_first() {
        let mut cache = HostCache::new(41.0);
        let mut p = SizeAwareLruCache;
        p.admit(&mut cache, "small-old", 13.5, 1.0);
        p.admit(&mut cache, "big", 26.0, 2.0);
        // Plain LRU would evict "small-old"; size-aware drops "big"
        // (one eviction frees enough).
        assert_eq!(p.admit(&mut cache, "incoming", 14.0, 3.0), 1);
        assert!(cache.contains("small-old") && !cache.contains("big"));
        assert!(cache.contains("incoming"));
    }

    #[test]
    fn pin_hot_declines_rather_than_evict_pinned() {
        let mut cache = HostCache::new(30.0);
        let mut p = PinHotCache { pin_uses: 3 };
        p.admit(&mut cache, "hot", 26.0, 1.0); // uses = 1
        p.on_hit(&mut cache, "hot", 2.0); // 2
        p.on_hit(&mut cache, "hot", 3.0); // 3 → pinned
        // The incoming checkpoint cannot fit without evicting the pinned
        // entry: declined, ledger untouched.
        assert_eq!(p.admit(&mut cache, "newcomer", 13.5, 4.0), 0);
        assert!(cache.contains("hot") && !cache.contains("newcomer"));
        // A small one that fits beside the pin is admitted normally.
        assert_eq!(p.admit(&mut cache, "tiny", 2.0, 5.0), 0);
        assert!(cache.contains("tiny"));
        // "tiny" (1 use) is evictable; a just-fitting load takes its slot.
        assert_eq!(p.admit(&mut cache, "mid", 4.0, 6.0), 1);
        assert!(!cache.contains("tiny") && cache.contains("mid"));
    }

    /// A GPU crash takes the whole node's worker process down, so
    /// `Engine::invalidate_gpu` clears the host cache *around* the
    /// policy: even pin-hot-pinned entries go. The policy must survive
    /// that external invalidation — the pin state lives in the evicted
    /// entries, so a re-admitted checkpoint starts cold (unpinned).
    #[test]
    fn pin_hot_survives_crash_invalidation() {
        let mut cache = HostCache::new(30.0);
        let mut p = PinHotCache { pin_uses: 3 };
        p.admit(&mut cache, "hot", 26.0, 1.0);
        p.on_hit(&mut cache, "hot", 2.0);
        p.on_hit(&mut cache, "hot", 3.0); // pinned
        p.admit(&mut cache, "cold", 2.0, 4.0);

        // Crash: the engine evicts every entry directly through the
        // ledger, pinned or not (exactly what invalidate_gpu does).
        let staged: Vec<&'static str> = cache.entries().map(|(m, _)| m).collect();
        for m in staged {
            assert!(cache.remove(m), "entry listed but not removable");
        }
        assert!(cache.is_empty(), "invalidation must clear the node cache");
        assert_eq!(cache.used_gb(), 0.0, "capacity accounting must return to zero");
        assert_eq!(cache.free_gb(), cache.capacity_gb);

        // Admit-after-invalidate: the tier works again immediately, and
        // the re-admitted former pin is back to one use — evictable.
        assert_eq!(p.admit(&mut cache, "hot", 26.0, 5.0), 0);
        assert_eq!(cache.get("hot").unwrap().uses, 1, "pin state must not survive");
        assert_eq!(p.admit(&mut cache, "newcomer", 13.5, 6.0), 1);
        assert!(!cache.contains("hot"), "an unpinned re-admission is a valid victim");
        assert!(cache.contains("newcomer"));
        assert!(
            cache.used_gb() <= cache.capacity_gb + 1e-9,
            "occupancy must stay within capacity across invalidate + re-admit"
        );
    }

    /// Capacity accounting is conserved through interleaved admissions,
    /// policy evictions, and external (crash-style) removals: occupancy
    /// always equals the sum of the surviving entries and never exceeds
    /// capacity.
    #[test]
    fn cache_capacity_conserved_under_mixed_eviction() {
        let mut cache = HostCache::new(40.0);
        let mut p = PinHotCache { pin_uses: 2 };
        let check = |cache: &HostCache| {
            let sum: f64 = cache.entries().map(|(_, e)| e.size_gb).sum();
            assert!((cache.used_gb() - sum).abs() < 1e-12, "ledger drifted");
            assert!(cache.used_gb() <= cache.capacity_gb + 1e-9, "over capacity");
            assert!((cache.free_gb() - (cache.capacity_gb - sum).max(0.0)).abs() < 1e-12);
        };
        p.admit(&mut cache, "a", 13.5, 1.0);
        p.on_hit(&mut cache, "a", 2.0); // pinned at 2 uses
        p.admit(&mut cache, "b", 13.5, 3.0);
        p.admit(&mut cache, "c", 13.0, 4.0);
        check(&cache);
        // Policy eviction to make room ("b"/"c" unpinned, "a" safe).
        let evicted = p.admit(&mut cache, "d", 20.0, 5.0);
        assert!(evicted > 0 && cache.contains("a"));
        check(&cache);
        // External removal mid-stream (a crash on the node).
        assert!(cache.remove("a"));
        check(&cache);
        // The freed pinned bytes are immediately admittable.
        let just_fits = cache.free_gb() - 0.5;
        assert_eq!(p.admit(&mut cache, "e", just_fits, 6.0), 0);
        check(&cache);
    }
}
