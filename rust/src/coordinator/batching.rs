//! Adaptive Batching Scheduler (paper §4.2): two-layer batching.
//!
//! Local layer — per-function *fill-or-expire*: with the linear prefill
//! model T_i(b) = T0 + α(b−1) (Eq. 2), offline profiling bounds the max
//! batch B_i within the SLO; the batch delay adapts to the current fill,
//! d_i = SLO_i − T_i(N_i) (Eq. 3): small batches wait longer to collect
//! future requests, full batches fire immediately.
//!
//! Global layer — contention-aware dispatch: M concurrent batches on one
//! GPU stretch every batch to M·T_i(b) (Eq. 4); batches are prioritised by
//! *deadline margin* Δ_i = SLO_i − (w_i + M·T_i(b)) (Eq. 5): the tightest
//! margin dispatches first, loose margins keep collecting.

use crate::artifact::ModelProfile;

/// One queued request (the batcher only needs ids and arrival times).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Queued {
    pub request: u64,
    pub arrival_s: f64,
}

/// Debounce window for idle-GPU dispatch: a queue is "settled" once no
/// new request arrived for this long. Near-concurrent burst members
/// (tens of ms apart) coalesce into one batch instead of splitting into
/// instance-churning waves; a lone request pays only +150 ms — which is
/// also what puts warm TTFT at T0 + ~0.15 s, the paper's ~576 ms regime.
pub const DEBOUNCE_S: f64 = 0.15;

/// Per-function batch queue with the Eq. 2/3 policy.
#[derive(Debug, Clone)]
pub struct BatchQueue {
    pub function: usize,
    /// SLO for TTFT, seconds.
    pub slo_s: f64,
    /// Eq. 2 coefficients.
    pub t0_s: f64,
    pub alpha_s: f64,
    /// Offline-profiled max batch within SLO (then clamped by memory).
    pub max_batch: usize,
    /// Arrival time of the most recent request (debounce input).
    pub last_arrival_s: f64,
    queue: Vec<Queued>,
}

impl BatchQueue {
    pub fn new(function: usize, profile: &ModelProfile) -> Self {
        BatchQueue {
            function,
            slo_s: profile.slo_ttft_s(),
            t0_s: profile.t0_prefill_s,
            alpha_s: profile.alpha_prefill_s,
            max_batch: profile.slo_max_batch(),
            last_arrival_s: f64::NEG_INFINITY,
            queue: Vec::new(),
        }
    }

    /// Has the arrival stream paused long enough that dispatching now
    /// would not split an in-flight burst?
    pub fn settled(&self, now_s: f64) -> bool {
        now_s - self.last_arrival_s >= DEBOUNCE_S
    }

    /// Fixed-size variant for the NAB ablation / baseline systems.
    pub fn fixed(function: usize, profile: &ModelProfile, batch: usize, delay_s: f64) -> FixedBatchQueue {
        FixedBatchQueue {
            inner: BatchQueue::new(function, profile),
            batch_size: batch.max(1),
            delay_s,
        }
    }

    pub fn push(&mut self, q: Queued) {
        self.last_arrival_s = self.last_arrival_s.max(q.arrival_s);
        self.queue.push(q);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Eq. 2: predicted prefill latency at batch size b.
    pub fn predicted_ttft(&self, b: usize) -> f64 {
        self.t0_s + self.alpha_s * (b.max(1) - 1) as f64
    }

    /// Eq. 3: adaptive batch delay at the current fill — how much longer
    /// the *oldest* queued request can afford to wait.
    pub fn batch_delay(&self, now_s: f64) -> f64 {
        let n = self.queue.len();
        if n == 0 {
            return f64::INFINITY;
        }
        let waited = now_s - self.oldest_arrival().unwrap();
        (self.slo_s - self.predicted_ttft(n) - waited).max(0.0)
    }

    pub fn oldest_arrival(&self) -> Option<f64> {
        self.queue
            .iter()
            .map(|q| q.arrival_s)
            .min_by(f64::total_cmp)
    }

    /// Eq. 5 deadline margin under M-way contention.
    pub fn deadline_margin(&self, now_s: f64, contention_m: usize) -> f64 {
        let n = self.queue.len().min(self.max_batch).max(1);
        let waited = now_s - self.oldest_arrival().unwrap_or(now_s);
        self.slo_s - (waited + contention_m.max(1) as f64 * self.predicted_ttft(n))
    }

    /// Fill-or-expire: should this queue dispatch now?
    /// Fires when full (N ≥ B_i) or when the adaptive delay has expired.
    pub fn should_dispatch(&self, now_s: f64) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queue.len() >= self.max_batch || self.batch_delay(now_s) <= 0.0
    }

    /// Earliest future time at which this queue would time out (for the
    /// event-driven simulator to schedule a wakeup).
    pub fn expiry_time(&self) -> Option<f64> {
        let n = self.queue.len();
        if n == 0 {
            return None;
        }
        Some(self.oldest_arrival().unwrap() + self.slo_s - self.predicted_ttft(n))
    }

    /// Take up to `memory_cap` requests as one batch (FIFO).
    pub fn take_batch(&mut self, memory_cap: usize) -> Vec<Queued> {
        let take = self.queue.len().min(self.max_batch).min(memory_cap.max(1));
        self.queue.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        self.queue.drain(..take).collect()
    }
}

/// Fixed batching for the NAB ablation (#1 b=1; #2 b=10,d=500ms;
/// #3 b=20,d=1000ms) and the baselines' static batchers.
#[derive(Debug, Clone)]
pub struct FixedBatchQueue {
    inner: BatchQueue,
    pub batch_size: usize,
    pub delay_s: f64,
}

impl FixedBatchQueue {
    pub fn push(&mut self, q: Queued) {
        self.inner.push(q);
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn should_dispatch(&self, now_s: f64) -> bool {
        if self.inner.is_empty() {
            return false;
        }
        if self.inner.len() >= self.batch_size {
            return true;
        }
        now_s - self.inner.oldest_arrival().unwrap() >= self.delay_s
    }

    pub fn expiry_time(&self) -> Option<f64> {
        self.inner.oldest_arrival().map(|t| t + self.delay_s)
    }

    pub fn take_batch(&mut self, memory_cap: usize) -> Vec<Queued> {
        let take = self.inner.queue.len().min(self.batch_size).min(memory_cap.max(1));
        self.inner.queue.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        self.inner.queue.drain(..take).collect()
    }
}

/// Global contention-aware selector (Eq. 4/5): among dispatchable queues,
/// pick the one with the smallest deadline margin.
pub fn select_by_deadline_margin<'a>(
    queues: impl Iterator<Item = &'a BatchQueue>,
    now_s: f64,
    contention_m: usize,
) -> Option<usize> {
    queues
        .filter(|q| !q.is_empty())
        .map(|q| (q.function, q.deadline_margin(now_s, contention_m)))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(f, _)| f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::ModelProfile;

    fn queue() -> BatchQueue {
        BatchQueue::new(0, &ModelProfile::llama2_7b())
    }

    #[test]
    fn max_batch_bounded_by_slo() {
        let q = queue();
        assert!(q.predicted_ttft(q.max_batch) <= q.slo_s + 1e-9);
        assert!(q.predicted_ttft(q.max_batch + 1) > q.slo_s);
    }

    #[test]
    fn eq3_delay_shrinks_as_batch_fills() {
        let mut q = queue();
        q.push(Queued { request: 1, arrival_s: 0.0 });
        let d1 = q.batch_delay(0.0);
        for i in 2..=10 {
            q.push(Queued { request: i, arrival_s: 0.0 });
        }
        let d10 = q.batch_delay(0.0);
        // d = SLO − T(N) − waited; T grows with N ⇒ delay shrinks.
        assert!(d10 < d1);
        assert!((d1 - d10 - 9.0 * q.alpha_s).abs() < 1e-9);
    }

    #[test]
    fn dispatches_when_full() {
        let mut q = queue();
        for i in 0..q.max_batch as u64 {
            q.push(Queued { request: i, arrival_s: 0.0 });
        }
        assert!(q.should_dispatch(0.0));
    }

    #[test]
    fn dispatches_on_expiry_never_violating_slo() {
        let mut q = queue();
        q.push(Queued { request: 1, arrival_s: 0.0 });
        let expiry = q.expiry_time().unwrap();
        assert!(!q.should_dispatch(expiry - 0.01));
        assert!(q.should_dispatch(expiry + 0.001));
        // Dispatching exactly at expiry still meets the SLO prediction:
        // waited + T(N) == SLO.
        let waited = expiry;
        assert!((waited + q.predicted_ttft(1) - q.slo_s).abs() < 1e-9);
    }

    #[test]
    fn small_batches_wait_longer() {
        // §4.2: "the Batch Scheduler tends to wait longer when the batch
        // size is small".
        let mut q1 = queue();
        q1.push(Queued { request: 1, arrival_s: 0.0 });
        let mut q5 = queue();
        for i in 0..20 {
            q5.push(Queued { request: i, arrival_s: 0.0 });
        }
        assert!(q1.batch_delay(0.0) > q5.batch_delay(0.0));
    }

    #[test]
    fn take_batch_fifo_and_capped() {
        let mut q = queue();
        for i in 0..50u64 {
            q.push(Queued { request: i, arrival_s: i as f64 * 0.01 });
        }
        let b = q.take_batch(8);
        assert_eq!(b.len(), 8); // memory cap binds before max_batch
        assert_eq!(b[0].request, 0);
        assert_eq!(b[7].request, 7);
        assert_eq!(q.len(), 42);
    }

    #[test]
    fn margin_shrinks_under_contention() {
        let mut q = queue();
        q.push(Queued { request: 1, arrival_s: 0.0 });
        let m1 = q.deadline_margin(0.1, 1);
        let m4 = q.deadline_margin(0.1, 4);
        assert!(m4 < m1);
        // Eq. 5 exactly: Δ = SLO − (w + M·T(b)).
        assert!((m4 - (q.slo_s - (0.1 + 4.0 * q.predicted_ttft(1)))).abs() < 1e-9);
    }

    #[test]
    fn tightest_margin_selected() {
        let mut a = BatchQueue::new(0, &ModelProfile::llama2_7b());
        let mut b = BatchQueue::new(1, &ModelProfile::llama2_7b());
        a.push(Queued { request: 1, arrival_s: 0.0 });
        b.push(Queued { request: 2, arrival_s: 1.5 }); // waited less
        let sel = select_by_deadline_margin([&a, &b].into_iter(), 2.0, 1);
        assert_eq!(sel, Some(0)); // a has waited longer ⇒ smaller margin
    }

    #[test]
    fn fixed_queue_matches_nab_variants() {
        let m = ModelProfile::llama2_7b();
        // NAB #2: batch 10, delay 500 ms.
        let mut q = BatchQueue::fixed(0, &m, 10, 0.5);
        q.push(Queued { request: 1, arrival_s: 0.0 });
        assert!(!q.should_dispatch(0.4));
        assert!(q.should_dispatch(0.51));
        for i in 2..=10 {
            q.push(Queued { request: i, arrival_s: 0.1 });
        }
        assert!(q.should_dispatch(0.11)); // full fires immediately
        assert_eq!(q.take_batch(usize::MAX).len(), 10);
    }

    #[test]
    fn empty_queue_never_dispatches() {
        let q = queue();
        assert!(!q.should_dispatch(1e9));
        assert_eq!(q.expiry_time(), None);
        assert_eq!(
            select_by_deadline_margin([&q].into_iter(), 0.0, 1),
            None
        );
    }
}
