//! Keep-alive policy (§2.2): serverless platforms keep an invoked
//! function's instance (and its artifacts) for a fixed window after
//! execution. Keep-alive is what makes baseline LoRA serving expensive
//! (idle full backbones bill GPU GB-seconds) and, for ServerlessLoRA,
//! what creates the idle capacity the pre-loader exploits (§2.4).
//!
//! Expiries are kept in a time-ordered index alongside the per-function
//! map, so `next_expiry` is O(log n) and `expired` pops a prefix — the
//! engine re-arms its single `KeepaliveCheck` on every completion, which
//! would otherwise re-scan every warm function at fleet scale.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::f64_key;

/// Default industry keep-alive window (Azure Functions: 10 min; we use
/// the common 5-minute setting the serverless-inference literature uses).
pub const DEFAULT_KEEPALIVE_S: f64 = 300.0;

/// Tracks the keep-alive expiry of warm function instances.
#[derive(Debug, Clone)]
pub struct KeepAlive {
    pub window_s: f64,
    /// function → expiry time.
    expiry: BTreeMap<usize, f64>,
    /// (total-order key of expiry time, function): the time-ordered view.
    order: BTreeSet<(u64, usize)>,
}

impl Default for KeepAlive {
    fn default() -> Self {
        Self::new(DEFAULT_KEEPALIVE_S)
    }
}

impl KeepAlive {
    pub fn new(window_s: f64) -> Self {
        KeepAlive { window_s, expiry: BTreeMap::new(), order: BTreeSet::new() }
    }

    /// A function finished serving at `now` — (re)arm its window.
    pub fn touch(&mut self, function: usize, now_s: f64) {
        let e = now_s + self.window_s;
        if let Some(old) = self.expiry.insert(function, e) {
            self.order.remove(&(f64_key(old), function));
        }
        self.order.insert((f64_key(e), function));
    }

    pub fn is_warm(&self, function: usize, now_s: f64) -> bool {
        self.expiry.get(&function).map(|&e| e > now_s).unwrap_or(false)
    }

    /// Raw membership: does an (unexpired-or-not-yet-swept) window exist
    /// for `function`? The engine's warm-set mirror for the billing
    /// aggregates is defined against this — windows leave it exactly when
    /// the keep-alive sweep pops them, so both sides flip within the same
    /// zero-width event instant.
    pub fn contains(&self, function: usize) -> bool {
        self.expiry.contains_key(&function)
    }

    /// Iterate every tracked function (billing-oracle rebuilds).
    pub fn tracked(&self) -> impl Iterator<Item = usize> + '_ {
        self.expiry.keys().copied()
    }

    /// Functions whose window expired by `now` (to be torn down + billed
    /// until their expiry instant). Pops a prefix of the time order.
    pub fn expired(&mut self, now_s: f64) -> Vec<(usize, f64)> {
        let cut = f64_key(now_s);
        let mut out = Vec::new();
        while let Some(&(k, f)) = self.order.first() {
            if k > cut {
                break;
            }
            self.order.pop_first();
            let e = self.expiry.remove(&f).expect("order entry without expiry");
            out.push((f, e));
        }
        out
    }

    /// Next expiry instant (simulator wakeup), O(log n). The engine arms
    /// exactly one `KeepaliveCheck` here and re-arms (cancelling the old
    /// event) whenever this minimum moves.
    pub fn next_expiry(&self) -> Option<f64> {
        self.order.first().map(|&(_, f)| self.expiry[&f])
    }

    pub fn warm_functions(&self, now_s: f64) -> Vec<usize> {
        self.expiry
            .iter()
            .filter(|(_, &e)| e > now_s)
            .map(|(&f, _)| f)
            .collect()
    }

    pub fn drop(&mut self, function: usize) {
        if let Some(e) = self.expiry.remove(&function) {
            self.order.remove(&(f64_key(e), function));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_arms_and_expires() {
        let mut k = KeepAlive::new(300.0);
        k.touch(1, 100.0);
        assert!(k.is_warm(1, 350.0));
        assert!(!k.is_warm(1, 400.01));
        let ex = k.expired(401.0);
        assert_eq!(ex, vec![(1, 400.0)]);
        assert!(!k.is_warm(1, 100.0)); // removed
    }

    #[test]
    fn touch_extends() {
        let mut k = KeepAlive::new(300.0);
        k.touch(1, 0.0);
        k.touch(1, 200.0);
        assert!(k.is_warm(1, 450.0));
        assert_eq!(k.next_expiry(), Some(500.0));
    }

    #[test]
    fn warm_set_and_drop() {
        let mut k = KeepAlive::new(10.0);
        k.touch(1, 0.0);
        k.touch(2, 5.0);
        let mut warm = k.warm_functions(7.0);
        warm.sort_unstable();
        assert_eq!(warm, vec![1, 2]);
        k.drop(1);
        assert_eq!(k.warm_functions(7.0), vec![2]);
    }

    #[test]
    fn unknown_function_is_cold() {
        let k = KeepAlive::default();
        assert!(!k.is_warm(9, 0.0));
    }

    #[test]
    fn next_expiry_never_decreases_under_touch() {
        // The lazy-rearm contract the engine's single armed
        // KeepaliveCheck relies on: touches only move the minimum later.
        let mut k = KeepAlive::new(100.0);
        k.touch(1, 0.0);
        let mut armed = k.next_expiry().unwrap();
        for (f, t) in [(2usize, 10.0), (1, 50.0), (3, 60.0), (2, 99.0)] {
            k.touch(f, t);
            let e = k.next_expiry().unwrap();
            assert!(e >= armed, "min expiry moved earlier: {armed} -> {e}");
            armed = e;
        }
    }

    #[test]
    fn order_index_matches_map_under_churn() {
        // The ordered view must stay a faithful index of the map under
        // arbitrary touch/drop/expire interleavings.
        use crate::util::rng::Pcg64;
        let mut k = KeepAlive::new(50.0);
        let mut rng = Pcg64::new(17);
        let mut now = 0.0;
        for _ in 0..2000 {
            now += rng.f64() * 5.0;
            match rng.below(4) {
                0 | 1 => k.touch(rng.below(16), now),
                2 => k.drop(rng.below(16)),
                _ => {
                    let ex = k.expired(now);
                    for (_, e) in ex {
                        assert!(e <= now);
                    }
                }
            }
            // Index/map agreement.
            assert_eq!(k.order.len(), k.expiry.len());
            let brute = k
                .expiry
                .iter()
                .map(|(_, &e)| e)
                .min_by(f64::total_cmp);
            assert_eq!(
                k.next_expiry().map(f64::to_bits),
                brute.map(f64::to_bits),
                "min expiry diverged from brute force"
            );
        }
    }
}
