//! Keep-alive policy (§2.2): serverless platforms keep an invoked
//! function's instance (and its artifacts) for a fixed window after
//! execution. Keep-alive is what makes baseline LoRA serving expensive
//! (idle full backbones bill GPU GB-seconds) and, for ServerlessLoRA,
//! what creates the idle capacity the pre-loader exploits (§2.4).

use std::collections::BTreeMap;

/// Default industry keep-alive window (Azure Functions: 10 min; we use
/// the common 5-minute setting the serverless-inference literature uses).
pub const DEFAULT_KEEPALIVE_S: f64 = 300.0;

/// Tracks the keep-alive expiry of warm function instances.
#[derive(Debug, Clone)]
pub struct KeepAlive {
    pub window_s: f64,
    /// function → expiry time.
    expiry: BTreeMap<usize, f64>,
}

impl Default for KeepAlive {
    fn default() -> Self {
        Self::new(DEFAULT_KEEPALIVE_S)
    }
}

impl KeepAlive {
    pub fn new(window_s: f64) -> Self {
        KeepAlive { window_s, expiry: BTreeMap::new() }
    }

    /// A function finished serving at `now` — (re)arm its window.
    pub fn touch(&mut self, function: usize, now_s: f64) {
        self.expiry.insert(function, now_s + self.window_s);
    }

    pub fn is_warm(&self, function: usize, now_s: f64) -> bool {
        self.expiry.get(&function).map(|&e| e > now_s).unwrap_or(false)
    }

    /// Functions whose window expired by `now` (to be torn down + billed
    /// until their expiry instant).
    pub fn expired(&mut self, now_s: f64) -> Vec<(usize, f64)> {
        let out: Vec<(usize, f64)> = self
            .expiry
            .iter()
            .filter(|(_, &e)| e <= now_s)
            .map(|(&f, &e)| (f, e))
            .collect();
        for (f, _) in &out {
            self.expiry.remove(f);
        }
        out
    }

    /// Next expiry instant (simulator wakeup). The engine arms exactly
    /// one `KeepaliveCheck` at this instant; because every expiry is
    /// `touch_time + window` with `touch_time ≤ now`, a later touch can
    /// never move the minimum below an already-armed instant, so lazy
    /// re-arming on fire preserves exact teardown times.
    pub fn next_expiry(&self) -> Option<f64> {
        self.expiry.values().cloned().fold(None, |acc, e| {
            Some(acc.map_or(e, |a: f64| a.min(e)))
        })
    }

    pub fn warm_functions(&self, now_s: f64) -> Vec<usize> {
        self.expiry
            .iter()
            .filter(|(_, &e)| e > now_s)
            .map(|(&f, _)| f)
            .collect()
    }

    pub fn drop(&mut self, function: usize) {
        self.expiry.remove(&function);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_arms_and_expires() {
        let mut k = KeepAlive::new(300.0);
        k.touch(1, 100.0);
        assert!(k.is_warm(1, 350.0));
        assert!(!k.is_warm(1, 400.01));
        let ex = k.expired(401.0);
        assert_eq!(ex, vec![(1, 400.0)]);
        assert!(!k.is_warm(1, 100.0)); // removed
    }

    #[test]
    fn touch_extends() {
        let mut k = KeepAlive::new(300.0);
        k.touch(1, 0.0);
        k.touch(1, 200.0);
        assert!(k.is_warm(1, 450.0));
        assert_eq!(k.next_expiry(), Some(500.0));
    }

    #[test]
    fn warm_set_and_drop() {
        let mut k = KeepAlive::new(10.0);
        k.touch(1, 0.0);
        k.touch(2, 5.0);
        let mut warm = k.warm_functions(7.0);
        warm.sort_unstable();
        assert_eq!(warm, vec![1, 2]);
        k.drop(1);
        assert_eq!(k.warm_functions(7.0), vec![2]);
    }

    #[test]
    fn unknown_function_is_cold() {
        let k = KeepAlive::default();
        assert!(!k.is_warm(9, 0.0));
    }

    #[test]
    fn next_expiry_never_decreases_under_touch() {
        // The lazy-rearm contract the engine's single armed
        // KeepaliveCheck relies on: touches only move the minimum later.
        let mut k = KeepAlive::new(100.0);
        k.touch(1, 0.0);
        let mut armed = k.next_expiry().unwrap();
        for (f, t) in [(2usize, 10.0), (1, 50.0), (3, 60.0), (2, 99.0)] {
            k.touch(f, t);
            let e = k.next_expiry().unwrap();
            assert!(e >= armed, "min expiry moved earlier: {armed} -> {e}");
            armed = e;
        }
    }
}
