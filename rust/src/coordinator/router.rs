//! Request router / instance selection (workflow step 4, §3.3): pick the
//! function instance / GPU with the best pre-loaded state for an arriving
//! batch, locality-aware (§3.1 challenge 3: "function instances should
//! reside on GPUs that have already loaded corresponding backbone LLMs").
//!
//! Routing is sub-linear in cluster size: when the model has shared
//! backbone hosts, only that host set is scored (it is the per-model
//! shard of the candidate space); otherwise the candidates are the GPUs
//! where the function already has private residency (the cluster's
//! per-function index) plus the top of the cluster's free-memory
//! ordering — never a fresh `Vec` over every GPU. Selection is the
//! argmax of `(score, GpuId)`, which reproduces the historical full
//! scan's last-max-wins tie behavior exactly.

use crate::artifact::{ArtifactKind, FunctionSpec};
use crate::cluster::{Cluster, GpuId};
use crate::sharing::BackboneRegistry;
use crate::util::f64_key;

/// What the chosen GPU already has for this function — determines which
/// cold-start phases remain (the router's score and the simulator's
/// latency both derive from this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Readiness {
    pub backbone_on_gpu: bool,
    pub adapter_on_gpu: bool,
    pub kernel_on_gpu: bool,
    pub cuda_context: bool,
}

impl Readiness {
    pub fn fully_warm(&self) -> bool {
        self.backbone_on_gpu && self.adapter_on_gpu && self.kernel_on_gpu && self.cuda_context
    }
}

/// Router decision for one batch.
#[derive(Debug, Clone, Copy)]
pub struct Route {
    pub gpu: GpuId,
    pub readiness: Readiness,
    /// Estimated KV headroom (in requests) at the chosen GPU.
    pub kv_headroom: usize,
}

pub struct Router;

impl Router {
    pub fn readiness(cluster: &Cluster, spec: &FunctionSpec, gpu: GpuId) -> Readiness {
        let g = cluster.gpu(gpu);
        Readiness {
            backbone_on_gpu: g.has_shared_backbone(spec.model.name)
                || g.has_artifact(spec.id, ArtifactKind::Backbone),
            adapter_on_gpu: g.has_artifact(spec.id, ArtifactKind::Adapter),
            kernel_on_gpu: g.has_artifact(spec.id, ArtifactKind::CudaKernel),
            cuda_context: g.has_cuda_context(spec.id),
        }
    }

    /// Score a GPU for this function: prefer warm artifacts (locality),
    /// then KV headroom, minus the failure-history penalty (GB-units of
    /// decayed crash count and active slowdown) when failure-aware
    /// routing is enabled. With the knob off the penalty is exactly 0.0,
    /// and `x - 0.0` is an IEEE identity — scores are bit-identical to
    /// the failure-blind router. Higher is better.
    fn score(cluster: &Cluster, spec: &FunctionSpec, gpu: GpuId) -> f64 {
        let r = Self::readiness(cluster, spec, gpu);
        let g = cluster.gpu(gpu);
        // Weights mirror relative load costs: backbone ≫ kernel > adapter.
        let warm = (r.backbone_on_gpu as u32 as f64) * spec.model.weights_gb
            + (r.kernel_on_gpu as u32 as f64) * 3.0
            + (r.adapter_on_gpu as u32 as f64) * 1.0
            + (r.cuda_context as u32 as f64) * 0.5;
        warm + g.free_gb() / 1000.0 - cluster.failure_penalty(gpu)
    }

    /// Penalised selection key: GPUs that cannot even fit the KV after
    /// full offload score 1e6 lower (the offloader handles partial
    /// shortfalls). Mapped through [`f64_key`] so keys order with plain
    /// tuple `Ord`; ties on the exact score resolve by `GpuId`.
    fn key(cluster: &Cluster, spec: &FunctionSpec, kv_need: f64, g: GpuId) -> (u64, GpuId) {
        let s = Self::score(cluster, spec, g)
            - if cluster.gpu(g).total_gb < kv_need { 1e6 } else { 0.0 };
        (f64_key(s), g)
    }

    /// Pick the best GPU for a batch of `batch` requests of `spec`.
    /// `registry` narrows the search to backbone hosts when any exist.
    pub fn route(
        cluster: &Cluster,
        registry: &BackboneRegistry,
        spec: &FunctionSpec,
        batch: usize,
    ) -> Option<Route> {
        let kv_need = spec.model.kv_per_request_gb * batch as f64;
        let hosts = registry.hosts(spec.model.name);
        let best = if hosts.is_empty() {
            Self::route_cold(cluster, spec, kv_need)
        } else {
            // Per-model shard: score only the host set. Ties keep the
            // historical full scan's last-max-wins in host-list order
            // (hosts are in registry insertion order, not id order).
            // Down GPUs (fault injection) are skipped; with faults off
            // the filter passes everything and the fold is unchanged.
            hosts
                .iter()
                .filter(|&&g| cluster.gpu_is_up(g))
                .fold(None::<(u64, GpuId)>, |acc, &g| {
                    let s = Self::key(cluster, spec, kv_need, g).0;
                    match acc {
                        Some((best_s, _)) if best_s > s => acc,
                        _ => Some((s, g)),
                    }
                })
                .map(|(_, g)| g)
                // Every host down: fall back to the cold path rather
                // than declaring the model unroutable until repair.
                .or_else(|| Self::route_cold(cluster, spec, kv_need))
        }?;
        let readiness = Self::readiness(cluster, spec, best);
        let headroom = (cluster.gpu(best).free_gb()
            / spec.model.kv_per_request_gb.max(1e-9))
            .floor()
            .max(0.0) as usize;
        Some(Route { gpu: best, readiness, kv_headroom: headroom })
    }

    /// No shared-backbone host yet: candidates are the GPUs where this
    /// function already has residency (warm score) plus the free-memory
    /// frontier (zero-warmth score is `free/1000`, so the frontier GPU is
    /// the argmax of the rest — O(resident + log G), not O(G)).
    fn route_cold(cluster: &Cluster, spec: &FunctionSpec, kv_need: f64) -> Option<GpuId> {
        let resident = cluster.gpus_with_function(spec.id);
        let mut best: Option<(u64, GpuId)> = None;
        for &g in &resident {
            if !cluster.gpu_is_up(g) {
                continue; // down GPUs are not candidates
            }
            best = best.max(Some(Self::key(cluster, spec, kv_need, g)));
        }
        let mut cold: Option<(u64, GpuId)> = None;
        // Failure-aware routing breaks the "descending free order ⇒
        // descending score" shortcut: a crash-prone GPU's penalty can
        // demote it below a less-free candidate, so the scan must see
        // every GPU. With tracking off (the default) the shortcut — and
        // its exact historical tie behavior — is untouched.
        let tracking = cluster.failure_tracking_enabled();
        cluster.scan_free_desc(|g, free| {
            if !cluster.gpu_is_up(g) {
                return false; // down GPUs are not candidates
            }
            if resident.contains(&g) {
                return false; // already scored with its warmth
            }
            let s = free / 1000.0 - cluster.failure_penalty(g);
            if cluster.gpu(g).total_gb < kv_need {
                // Penalised fallback: the first one seen is the argmax
                // (descending free order ⇒ descending penalised score).
                if tracking {
                    cold = cold.max(Some((f64_key(s - 1e6), g)));
                } else if cold.is_none() {
                    cold = Some((f64_key(s - 1e6), g));
                }
                false
            } else if tracking {
                cold = cold.max(Some((f64_key(s), g)));
                false // keep scanning: later GPUs may out-score penalties
            } else {
                // First KV-fitting GPU on the frontier: argmax of every
                // remaining zero-warmth candidate. Stop the scan.
                cold = Some((f64_key(s), g));
                true
            }
        });
        best.max(cold).map(|(_, g)| g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::ModelProfile;

    fn spec(id: usize) -> FunctionSpec {
        FunctionSpec::new(id, ModelProfile::llama2_7b(), id)
    }

    #[test]
    fn prefers_backbone_host() {
        let mut c = Cluster::new(1, 4, 2);
        let mut r = BackboneRegistry::new();
        let target = c.gpu_ids()[2];
        r.load(&mut c, "llama2-7b", 13.5, target).unwrap();
        let route = Router::route(&c, &r, &spec(0), 4).unwrap();
        assert_eq!(route.gpu, target);
        assert!(route.readiness.backbone_on_gpu);
    }

    #[test]
    fn prefers_fully_warm_over_backbone_only() {
        let mut c = Cluster::new(1, 2, 2);
        let mut r = BackboneRegistry::new();
        let [g0, g1] = [c.gpu_ids()[0], c.gpu_ids()[1]];
        r.load(&mut c, "llama2-7b", 13.5, g0).unwrap();
        r.load(&mut c, "llama2-7b", 13.5, g1).unwrap();
        c.gpu_mut(g1).place_artifact(0, ArtifactKind::Adapter, 0.16).unwrap();
        c.gpu_mut(g1).place_artifact(0, ArtifactKind::CudaKernel, 0.5).unwrap();
        c.gpu_mut(g1).create_cuda_context(0).unwrap();
        let route = Router::route(&c, &r, &spec(0), 4).unwrap();
        assert_eq!(route.gpu, g1);
        assert!(route.readiness.fully_warm());
    }

    #[test]
    fn cold_cluster_routes_somewhere() {
        let c = Cluster::new(2, 2, 2);
        let r = BackboneRegistry::new();
        let route = Router::route(&c, &r, &spec(0), 1).unwrap();
        assert!(!route.readiness.backbone_on_gpu);
        assert!(route.kv_headroom > 0);
    }

    #[test]
    fn cold_ties_resolve_to_highest_id() {
        // Historical full-scan semantics: equal scores pick the last GPU
        // in id order — the sub-linear path must match.
        let c = Cluster::new(2, 2, 2);
        let r = BackboneRegistry::new();
        let route = Router::route(&c, &r, &spec(0), 1).unwrap();
        assert_eq!(route.gpu, *c.gpu_ids().last().unwrap());
    }

    #[test]
    fn private_residency_found_without_backbone_host() {
        // The per-function residency index must surface warm GPUs even
        // when the registry has no host for the model (no-sharing mode).
        let mut c = Cluster::new(1, 4, 2);
        let r = BackboneRegistry::new();
        let warm = c.gpu_ids()[1];
        c.gpu_mut(warm).place_artifact(0, ArtifactKind::Adapter, 0.16).unwrap();
        c.gpu_mut(warm).place_artifact(0, ArtifactKind::CudaKernel, 0.5).unwrap();
        c.gpu_mut(warm).create_cuda_context(0).unwrap();
        let route = Router::route(&c, &r, &spec(0), 1).unwrap();
        assert_eq!(route.gpu, warm, "warm artifacts beat a colder, freer GPU");
        assert!(route.readiness.adapter_on_gpu && route.readiness.kernel_on_gpu);
    }

    #[test]
    fn down_gpus_are_never_routed_to() {
        let mut c = Cluster::new(1, 2, 2);
        let mut r = BackboneRegistry::new();
        let [g0, g1] = [c.gpu_ids()[0], c.gpu_ids()[1]];
        // A warm backbone host would normally win; take it down.
        r.load(&mut c, "llama2-7b", 13.5, g1).unwrap();
        c.set_gpu_health(g1, false);
        let route = Router::route(&c, &r, &spec(0), 1).unwrap();
        assert_eq!(route.gpu, g0, "host down: cold fallback routes elsewhere");
        // Whole cluster down: nothing is routable.
        c.set_gpu_health(g0, false);
        assert!(Router::route(&c, &r, &spec(0), 1).is_none());
        // Recovery restores candidacy (and the warm host wins again).
        c.set_gpu_health(g1, true);
        assert_eq!(Router::route(&c, &r, &spec(0), 1).unwrap().gpu, g1);
    }

    #[test]
    fn failure_penalty_diverts_routing_when_enabled() {
        let mut c = Cluster::new(1, 2, 2);
        let r = BackboneRegistry::new();
        let [g0, g1] = [c.gpu_ids()[0], c.gpu_ids()[1]];
        // Cold ties resolve to the highest id — g1 — by default.
        assert_eq!(Router::route(&c, &r, &spec(0), 1).unwrap().gpu, g1);
        c.enable_failure_tracking(600.0, 4.0);
        assert_eq!(
            Router::route(&c, &r, &spec(0), 1).unwrap().gpu,
            g1,
            "tracking with no history changes nothing"
        );
        c.note_crash(g1, 0.0);
        assert_eq!(
            Router::route(&c, &r, &spec(0), 1).unwrap().gpu,
            g0,
            "crash history must penalize g1 below the clean twin"
        );
        // An active 3× degrade on g0 (penalty 8.0) now outweighs g1's
        // single crash (penalty 4.0).
        c.note_degrade(g0, 3.0);
        assert_eq!(Router::route(&c, &r, &spec(0), 1).unwrap().gpu, g1);
        c.note_degrade(g0, 1.0);
        assert_eq!(Router::route(&c, &r, &spec(0), 1).unwrap().gpu, g0, "restore clears it");
    }

    #[test]
    fn headroom_reflects_free_memory() {
        let mut c = Cluster::new(1, 1, 1);
        let r = BackboneRegistry::new();
        let g = c.gpu_ids()[0];
        let free_before = c.gpu(g).free_gb();
        let route = Router::route(&c, &r, &spec(0), 1).unwrap();
        let expect = (free_before / 0.45).floor() as usize;
        assert_eq!(route.kv_headroom, expect);
        c.gpu_mut(g).reserve_kv(1, 20.0).unwrap();
        let route2 = Router::route(&c, &r, &spec(0), 1).unwrap();
        assert!(route2.kv_headroom < route.kv_headroom);
    }
}
