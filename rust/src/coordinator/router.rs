//! Request router / instance selection (workflow step 4, §3.3): pick the
//! function instance / GPU with the best pre-loaded state for an arriving
//! batch, locality-aware (§3.1 challenge 3: "function instances should
//! reside on GPUs that have already loaded corresponding backbone LLMs").

use crate::artifact::{ArtifactKind, FunctionSpec};
use crate::cluster::{Cluster, GpuId};
use crate::sharing::BackboneRegistry;

/// What the chosen GPU already has for this function — determines which
/// cold-start phases remain (the router's score and the simulator's
/// latency both derive from this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Readiness {
    pub backbone_on_gpu: bool,
    pub adapter_on_gpu: bool,
    pub kernel_on_gpu: bool,
    pub cuda_context: bool,
}

impl Readiness {
    pub fn fully_warm(&self) -> bool {
        self.backbone_on_gpu && self.adapter_on_gpu && self.kernel_on_gpu && self.cuda_context
    }
}

/// Router decision for one batch.
#[derive(Debug, Clone, Copy)]
pub struct Route {
    pub gpu: GpuId,
    pub readiness: Readiness,
    /// Estimated KV headroom (in requests) at the chosen GPU.
    pub kv_headroom: usize,
}

pub struct Router;

impl Router {
    pub fn readiness(cluster: &Cluster, spec: &FunctionSpec, gpu: GpuId) -> Readiness {
        let g = cluster.gpu(gpu);
        Readiness {
            backbone_on_gpu: g.has_shared_backbone(spec.model.name)
                || g.has_artifact(spec.id, ArtifactKind::Backbone),
            adapter_on_gpu: g.has_artifact(spec.id, ArtifactKind::Adapter),
            kernel_on_gpu: g.has_artifact(spec.id, ArtifactKind::CudaKernel),
            cuda_context: g.has_cuda_context(spec.id),
        }
    }

    /// Score a GPU for this function: prefer warm artifacts (locality),
    /// then KV headroom. Higher is better.
    fn score(cluster: &Cluster, spec: &FunctionSpec, gpu: GpuId) -> f64 {
        let r = Self::readiness(cluster, spec, gpu);
        let g = cluster.gpu(gpu);
        // Weights mirror relative load costs: backbone ≫ kernel > adapter.
        let warm = (r.backbone_on_gpu as u32 as f64) * spec.model.weights_gb
            + (r.kernel_on_gpu as u32 as f64) * 3.0
            + (r.adapter_on_gpu as u32 as f64) * 1.0
            + (r.cuda_context as u32 as f64) * 0.5;
        warm + g.free_gb() / 1000.0 // free memory as tie-break
    }

    /// Pick the best GPU for a batch of `batch` requests of `spec`.
    /// `registry` narrows the search to backbone hosts when any exist.
    pub fn route(
        cluster: &Cluster,
        registry: &BackboneRegistry,
        spec: &FunctionSpec,
        batch: usize,
    ) -> Option<Route> {
        let hosts = registry.hosts(spec.model.name);
        let candidates: Vec<GpuId> = if hosts.is_empty() {
            cluster.gpu_ids()
        } else {
            hosts.to_vec()
        };
        let kv_need = spec.model.kv_per_request_gb * batch as f64;
        let best = candidates
            .into_iter()
            .max_by(|&a, &b| {
                let sa = Self::score(cluster, spec, a)
                    // Penalise GPUs that cannot even fit the KV after full
                    // offload (offloader handles partial shortfalls).
                    - if cluster.gpu(a).total_gb < kv_need { 1e6 } else { 0.0 };
                let sb = Self::score(cluster, spec, b)
                    - if cluster.gpu(b).total_gb < kv_need { 1e6 } else { 0.0 };
                sa.total_cmp(&sb)
            })?;
        let readiness = Self::readiness(cluster, spec, best);
        let headroom = (cluster.gpu(best).free_gb()
            / spec.model.kv_per_request_gb.max(1e-9))
            .floor()
            .max(0.0) as usize;
        Some(Route { gpu: best, readiness, kv_headroom: headroom })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::ModelProfile;

    fn spec(id: usize) -> FunctionSpec {
        FunctionSpec::new(id, ModelProfile::llama2_7b(), id)
    }

    #[test]
    fn prefers_backbone_host() {
        let mut c = Cluster::new(1, 4, 2);
        let mut r = BackboneRegistry::new();
        let target = c.gpu_ids()[2];
        r.load(&mut c, "llama2-7b", 13.5, target).unwrap();
        let route = Router::route(&c, &r, &spec(0), 4).unwrap();
        assert_eq!(route.gpu, target);
        assert!(route.readiness.backbone_on_gpu);
    }

    #[test]
    fn prefers_fully_warm_over_backbone_only() {
        let mut c = Cluster::new(1, 2, 2);
        let mut r = BackboneRegistry::new();
        let [g0, g1] = [c.gpu_ids()[0], c.gpu_ids()[1]];
        r.load(&mut c, "llama2-7b", 13.5, g0).unwrap();
        r.load(&mut c, "llama2-7b", 13.5, g1).unwrap();
        c.gpu_mut(g1).place_artifact(0, ArtifactKind::Adapter, 0.16).unwrap();
        c.gpu_mut(g1).place_artifact(0, ArtifactKind::CudaKernel, 0.5).unwrap();
        c.gpu_mut(g1).create_cuda_context(0).unwrap();
        let route = Router::route(&c, &r, &spec(0), 4).unwrap();
        assert_eq!(route.gpu, g1);
        assert!(route.readiness.fully_warm());
    }

    #[test]
    fn cold_cluster_routes_somewhere() {
        let c = Cluster::new(2, 2, 2);
        let r = BackboneRegistry::new();
        let route = Router::route(&c, &r, &spec(0), 1).unwrap();
        assert!(!route.readiness.backbone_on_gpu);
        assert!(route.kv_headroom > 0);
    }

    #[test]
    fn headroom_reflects_free_memory() {
        let mut c = Cluster::new(1, 1, 1);
        let r = BackboneRegistry::new();
        let g = c.gpu_ids()[0];
        let free_before = c.gpu(g).free_gb();
        let route = Router::route(&c, &r, &spec(0), 1).unwrap();
        let expect = (free_before / 0.45).floor() as usize;
        assert_eq!(route.kv_headroom, expect);
        c.gpu_mut(g).reserve_kv(1, 20.0).unwrap();
        let route2 = Router::route(&c, &r, &spec(0), 1).unwrap();
        assert!(route2.kv_headroom < route.kv_headroom);
    }
}
