//! The paper's L3 coordination contribution: the Pre-Loading Scheduler
//! (§4.1, PCKP greedy), the two-layer Adaptive Batching Scheduler (§4.2),
//! the Dynamic Offloader (§4.3), and the locality-aware request router
//! (§3.3 step 4). The backbone-sharing registry they coordinate over
//! lives in `crate::sharing`; the ledgers in `crate::cluster`.

pub mod batching;
pub mod keepalive;
pub mod offload;
pub mod policy;
pub mod preload;
pub mod router;

pub use batching::{BatchQueue, FixedBatchQueue, Queued};
pub use keepalive::KeepAlive;
pub use offload::{DynamicOffloader, OffloadPlan};
pub use policy::{
    AdaptiveBatching, AggregateBillSample, BatchingPolicy, BillingModel, ClassBillSample,
    DynamicOffload, FastCheckpointPreload, FixedBatching, FullPreload, LoadQuery, NoOffload,
    NoPreload, OffloadPolicy, OpportunisticPreload, PolicyBundle, PolicyEnv, PredictivePreload,
    PreloadPolicy, ServerfulBilling, ServerfulResident, ServerlessBilling,
};
pub use preload::{
    exact_plan, Decision, FunctionDemand, Placement, PreloadPlan, PreloadScheduler,
};
pub use router::{Readiness, Route, Router};
