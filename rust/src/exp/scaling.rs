//! §6.7 scalability: Fig. 11a strong scaling (fixed workload, more GPUs)
//! and Fig. 11b weak scaling (workload and GPUs grow proportionally) —
//! `ScenarioSpec` grids through `scenario::run_grid`.

use crate::scenario::{ClusterSpec, WorkloadSpec};
use crate::trace::Pattern;
use crate::util::table::{ms, Table};

pub fn fig11(quick: bool) -> String {
    let dur = if quick { 1800.0 } else { 3600.0 };
    let mut out = String::new();

    // (a) strong scaling: all 8 functions, 2 → 16 GPUs.
    let mut t = Table::new(
        "Fig 11a — Strong scaling (8 fns, fixed workload)",
        &["GPUs", "system", "E2E (ms)", "TTFT (ms)"],
    );
    let keyed: Vec<(usize, crate::scenario::ScenarioSpec)> = [2usize, 4, 8, 16]
        .into_iter()
        .flat_map(|n_gpus| {
            ["serverless-lora", "serverless-llm", "instainfer"].into_iter().map(move |id| {
                let spec = super::cell(
                    format!("fig11a-{n_gpus}g-{id}"),
                    id,
                    ClusterSpec::Uniform {
                        nodes: 1,
                        gpus_per_node: n_gpus,
                        containers_per_node: 2 * n_gpus,
                        trim_gpus: None,
                        zones: 1,
                    },
                    WorkloadSpec::Paper { pattern: Pattern::Normal, seed: 11 },
                    dur,
                    1,
                );
                (n_gpus, spec)
            })
        })
        .collect();
    let (gpus, specs): (Vec<_>, Vec<_>) = keyed.into_iter().unzip();
    for (n_gpus, r) in gpus.into_iter().zip(super::run_cells(specs)) {
        let (system, run) = r.into_only();
        t.row(vec![
            n_gpus.to_string(),
            system,
            ms(run.metrics.e2e().mean),
            ms(run.metrics.ttft().mean),
        ]);
    }
    out.push_str(&t.render());

    // (b) weak scaling: workload ∝ GPUs (scale× 8 fns on scale× 4 GPUs).
    let mut t = Table::new(
        "Fig 11b — Weak scaling (workload ∝ GPUs)",
        &["scale", "GPUs", "fns", "system", "E2E (ms)"],
    );
    let keyed: Vec<(usize, crate::scenario::ScenarioSpec)> = [1usize, 2, 4]
        .into_iter()
        .flat_map(|scale| {
            ["serverless-lora", "instainfer"].into_iter().map(move |id| {
                let spec = super::cell(
                    format!("fig11b-x{scale}-{id}"),
                    id,
                    ClusterSpec::Uniform {
                        nodes: scale,
                        gpus_per_node: 4,
                        containers_per_node: 8,
                        trim_gpus: None,
                        zones: 1,
                    },
                    WorkloadSpec::Scaled { pattern: Pattern::Normal, scale, seed: 13 },
                    dur,
                    1,
                );
                (scale, spec)
            })
        })
        .collect();
    let (scales, specs): (Vec<_>, Vec<_>) = keyed.into_iter().unzip();
    for (scale, r) in scales.into_iter().zip(super::run_cells(specs)) {
        let (system, run) = r.into_only();
        t.row(vec![
            scale.to_string(),
            (scale * 4).to_string(),
            (scale * 8).to_string(),
            system,
            ms(run.metrics.e2e().mean),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::sim::workloads::{paper_workload, scaled_workload};
    use crate::sim::{Engine, SystemConfig};

    /// Fig. 11a: ServerlessLoRA converts added GPU memory into lower (or
    /// equal) latency, and outperforms baselines at every cluster size.
    #[test]
    fn strong_scaling_monotone_and_winning() {
        let w = paper_workload(Pattern::Normal, 1200.0, 3);
        let e2e = |cfg: SystemConfig, n: usize| {
            let cluster = Cluster::new(1, n, 2 * n);
            let (m, _, _) = Engine::new(cfg, cluster, w.clone(), 1).run();
            m.e2e().mean
        };
        let lora2 = e2e(SystemConfig::serverless_lora(), 2);
        let lora16 = e2e(SystemConfig::serverless_lora(), 16);
        assert!(lora16 <= lora2 * 1.1, "more GPUs slower: {lora2} -> {lora16}");
        let sllm16 = e2e(SystemConfig::serverless_llm(), 16);
        assert!(lora16 < sllm16, "lora {lora16} vs sllm {sllm16}");
    }

    /// Fig. 11b: under weak scaling ServerlessLoRA's E2E stays stable
    /// (within 25% across 1×→4×).
    #[test]
    fn weak_scaling_stable_e2e() {
        let e2e = |scale: usize| {
            let w = scaled_workload(Pattern::Normal, 1200.0, scale, 13);
            let cluster = Cluster::new(scale, 4, 8);
            let (m, _, _) =
                Engine::new(SystemConfig::serverless_lora(), cluster, w, 1).run();
            m.e2e().mean
        };
        let s1 = e2e(1);
        let s4 = e2e(4);
        assert!(
            (s4 - s1).abs() / s1 < 0.25,
            "weak scaling drift: {s1} -> {s4}"
        );
    }
}
