//! Experiment registry: one entry per table and figure in the paper's
//! evaluation (§6). Each function regenerates the corresponding rows /
//! series on the simulated testbed and returns them as rendered tables.
//!
//! Every engine-driven experiment builds a grid of
//! [`crate::scenario::ScenarioSpec`] cells and runs it through
//! `scenario::run_grid` — the same entry point the `run --scenario` CLI
//! uses — so a table cell and a JSON-driven run are one code path.
//! (`fig5` and `overhead` run no simulations — trace-generator stats and
//! scheduler micro-benchmarks respectively — and stay outside the
//! scenario surface.)
//!
//! Invoked by `cargo bench` (rust/benches/paper_eval.rs) and by the CLI
//! (`serverless-lora simulate --exp <id>`). See DESIGN.md §4 for the
//! experiment ↔ module index and EXPERIMENTS.md for recorded results.

pub mod ablation;
pub mod breakdown;
pub mod coldstarts;
pub mod cost_eff;
pub mod faults;
pub mod fleet;
pub mod latency;
pub mod overhead;
pub mod runner;
pub mod scaling;
pub mod throughput;
pub mod tiers;
pub mod traces;

use crate::cluster::Cluster;
use crate::cost::CostTracker;
use crate::metrics::RunMetrics;
use crate::scenario::{self, ClusterSpec, ScenarioReport, ScenarioSpec, WorkloadSpec};
use crate::sim::{Engine, RunStats, SystemConfig, Workload};
use crate::util::json::{num, obj, Json};

/// Simulated horizon. The paper runs 4-hour traces; `quick` mode runs one
/// hour, which preserves every ordering at a quarter of the wall time.
pub fn horizon(quick: bool) -> f64 {
    if quick {
        3600.0
    } else {
        4.0 * 3600.0
    }
}

/// The paper's 16-GPU evaluation cluster (4 × g6e.24xlarge).
pub fn paper_cluster() -> Cluster {
    Cluster::paper_multinode()
}

/// Run one system over one workload on a fresh paper cluster (unit-test
/// shorthand; the table-rendering paths go through [`run_cells`]).
pub fn run_system(
    cfg: SystemConfig,
    workload: Workload,
    seed: u64,
) -> (RunMetrics, CostTracker, RunStats) {
    Engine::new(cfg, paper_cluster(), workload, seed).run()
}

/// Build one grid cell: a single-engine-seed `ScenarioSpec`. Experiment
/// grids are static and valid by construction, so a validation failure
/// here is a bug — it panics rather than propagating.
pub fn cell(
    name: String,
    system: &str,
    cluster: ClusterSpec,
    workload: WorkloadSpec,
    horizon_s: f64,
    seed: u64,
) -> ScenarioSpec {
    ScenarioSpec::builder(&name)
        .system(system)
        .cluster(cluster)
        .workload(workload)
        .horizon_s(horizon_s)
        .seed(seed)
        .build()
        .unwrap_or_else(|e| panic!("experiment cell '{name}' failed validation: {e}"))
}

/// Run a grid of experiment cells through the scenario entry point
/// (order-preserving `--jobs` fan-out over every `(spec, seed)` pair).
pub fn run_cells(specs: Vec<ScenarioSpec>) -> Vec<ScenarioReport> {
    scenario::run_grid(&specs).expect("experiment-built scenarios validate")
}

/// Headline metrics for the machine-readable bench record
/// (BENCH_sim.json): a short Normal-pattern run of the flagship vs the
/// strongest serverless baseline, tracked across PRs.
pub fn headline_json() -> Json {
    let w = crate::sim::workloads::paper_workload(crate::trace::Pattern::Normal, 900.0, 11);
    let (lm, lc, _) = run_system(SystemConfig::serverless_lora(), w.clone(), 1);
    let (sm, sc, _) = run_system(SystemConfig::serverless_llm(), w, 1);
    obj(vec![
        ("lora_ttft_ms", num(lm.ttft().mean * 1000.0)),
        ("sllm_ttft_ms", num(sm.ttft().mean * 1000.0)),
        ("ttft_speedup", num(sm.ttft().mean / lm.ttft().mean.max(1e-12))),
        ("lora_cost_usd", num(lc.total_usd())),
        ("sllm_cost_usd", num(sc.total_usd())),
        ("cost_ratio", num(sc.total_usd() / lc.total_usd().max(1e-12))),
    ])
}

/// All experiment ids: the paper artifacts in paper order, then the
/// engine-health experiments (`fleet`: cluster-size scaling sweep;
/// `tiers`: host-cache capacity × burstiness sweep over the tiered
/// artifact store; `faults`: MTBF × MTTR fault-injection sweep;
/// `coldstarts`: cold-start strategy × keep-alive sweep).
pub const ALL_EXPERIMENTS: [&str; 18] = [
    "fig1", "fig2", "fig5", "fig6", "fig7", "fig8", "fig9", "tab1", "tab2",
    "fig10", "tab3", "fig11", "fig12", "overhead", "fleet", "tiers", "faults",
    "coldstarts",
];

/// Dispatch an experiment by id. Returns the rendered report.
pub fn run_experiment(id: &str, quick: bool) -> String {
    match id {
        "fig1" => breakdown::fig1(quick),
        "fig2" => cost_eff::fig2(quick),
        "fig5" => traces::fig5(quick),
        "fig6" => latency::fig6(quick),
        "fig7" => latency::fig7(quick),
        "fig8" => breakdown::fig8(quick),
        "fig9" => cost_eff::fig9(quick),
        "tab1" => cost_eff::tab1(quick),
        "tab2" => throughput::tab2(quick),
        "fig10" => {
            let mut s = throughput::fig10a(quick);
            s.push_str(&ablation::fig10b(quick));
            s
        }
        "tab3" => ablation::tab3(quick),
        "fig11" => scaling::fig11(quick),
        "fig12" => latency::fig12(quick),
        "overhead" => overhead::report(),
        "fleet" => fleet::fleet(quick),
        "tiers" => tiers::tiers(quick),
        "faults" => faults::faults(quick),
        "coldstarts" => coldstarts::coldstarts(quick),
        other => format!("unknown experiment '{other}'; known: {ALL_EXPERIMENTS:?}\n"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_reports_cleanly() {
        assert!(run_experiment("nope", true).contains("unknown experiment"));
    }

    #[test]
    fn registry_lists_every_paper_artifact() {
        // Tables 1–3 and data Figures 1, 2, 5–12 (Figs 3/4 are
        // architecture diagrams with no data series).
        for id in ["tab1", "tab2", "tab3"] {
            assert!(ALL_EXPERIMENTS.contains(&id));
        }
        for f in [1, 2, 5, 6, 7, 8, 9, 10, 11, 12] {
            assert!(ALL_EXPERIMENTS.contains(&format!("fig{f}").as_str()));
        }
        // Engine-health experiments ride the same registry.
        assert!(ALL_EXPERIMENTS.contains(&"fleet"));
        assert!(ALL_EXPERIMENTS.contains(&"tiers"));
        assert!(ALL_EXPERIMENTS.contains(&"faults"));
        assert!(ALL_EXPERIMENTS.contains(&"coldstarts"));
    }
}
