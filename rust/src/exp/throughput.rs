//! §6.5 throughput: Table 2 (peak token/request throughput + peak batch)
//! and Fig. 10a (completion time at max batch under contention) —
//! `ScenarioSpec` grids through `scenario::run_grid`.
//!
//! Setup per the paper: four Llama2-7B LoRA functions on TWO GPUs (each
//! GPU can hold two full 7B models *or* one shared backbone + KV room).

use crate::scenario::{ClusterSpec, ScenarioSpec, WorkloadSpec};
use crate::util::table::{f, Table};

/// The saturating contenders. The Throughput workload's stream is
/// Predictable, so InstaInfer resolves to its best-case predictor.
const SATURATING_IDS: [&str; 3] = ["serverless-lora", "serverless-llm", "instainfer"];

/// One cell per system on the 2-GPU cluster, shared by both tables.
fn saturating_cells(tag: &str, dur: f64) -> Vec<ScenarioSpec> {
    SATURATING_IDS
        .into_iter()
        .map(|id| {
            super::cell(
                format!("{tag}-{id}"),
                id,
                ClusterSpec::Uniform {
                    nodes: 1,
                    gpus_per_node: 2,
                    containers_per_node: 8,
                    trim_gpus: None,
                    zones: 1,
                },
                WorkloadSpec::Throughput { seed: 21 },
                dur,
                2,
            )
        })
        .collect()
}

pub fn tab2(quick: bool) -> String {
    let dur = if quick { 300.0 } else { 900.0 };
    let mut t = Table::new(
        "Table 2 — Peak throughput, 4× Llama2-7B fns on 2 GPUs",
        &["system", "tokens/s", "peak batch", "requests/s"],
    );
    for r in super::run_cells(saturating_cells("tab2", dur)) {
        let (system, run) = r.into_only();
        let m = run.metrics;
        t.row(vec![
            system,
            f(m.token_throughput()),
            m.peak_batch().to_string(),
            f(m.request_throughput()),
        ]);
    }
    t.render()
}

pub fn fig10a(quick: bool) -> String {
    let dur = if quick { 300.0 } else { 900.0 };
    let mut t = Table::new(
        "Fig 10a — Completion time at max batch (same saturating workload)",
        &["system", "mean E2E (s)", "p99 E2E (s)", "completed"],
    );
    for r in super::run_cells(saturating_cells("fig10a", dur)) {
        let (system, run) = r.into_only();
        let m = run.metrics;
        t.row(vec![
            system,
            f(m.e2e().mean),
            f(m.e2e().p99),
            m.outcomes.len().to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::sim::workloads::throughput_workload;
    use crate::sim::{Engine, SystemConfig};

    fn two_gpu_cluster() -> Cluster {
        Cluster::new(1, 2, 8)
    }

    fn run_throughput(cfg: SystemConfig, dur: f64) -> (f64, usize, f64) {
        let w = throughput_workload(dur, 21);
        let (m, _, _) = Engine::new(cfg, two_gpu_cluster(), w, 2).run();
        (m.token_throughput(), m.peak_batch(), m.request_throughput())
    }

    /// Table 2 headline: backbone sharing frees KV memory ⇒ larger peak
    /// batches and higher token/request throughput than both baselines.
    #[test]
    fn sharing_lifts_peak_batch_and_throughput() {
        let (tok_l, batch_l, req_l) =
            run_throughput(SystemConfig::serverless_lora(), 240.0);
        let (tok_s, batch_s, req_s) =
            run_throughput(SystemConfig::serverless_llm(), 240.0);
        assert!(
            batch_l > batch_s,
            "peak batch: lora {batch_l} vs sllm {batch_s}"
        );
        assert!(tok_l > tok_s, "tokens/s: lora {tok_l} vs sllm {tok_s}");
        assert!(req_l > req_s, "req/s: lora {req_l} vs sllm {req_s}");
    }

    /// Fig. 10a: even at its larger peak batch (max contention),
    /// ServerlessLoRA completes the same workload sooner.
    #[test]
    fn completion_time_shortest_despite_contention() {
        let w = throughput_workload(240.0, 21);
        let (ml, _, _) = Engine::new(
            SystemConfig::serverless_lora(),
            two_gpu_cluster(),
            w.clone(),
            2,
        )
        .run();
        let (ms_, _, _) = Engine::new(
            SystemConfig::serverless_llm(),
            two_gpu_cluster(),
            w,
            2,
        )
        .run();
        // Same offered load; compare completions and mean E2E.
        assert!(
            ml.outcomes.len() >= ms_.outcomes.len(),
            "completions: {} vs {}",
            ml.outcomes.len(),
            ms_.outcomes.len()
        );
        assert!(
            ml.e2e().mean < ms_.e2e().mean,
            "E2E: {} vs {}",
            ml.e2e().mean,
            ms_.e2e().mean
        );
    }
}
