//! §6.5 throughput: Table 2 (peak token/request throughput + peak batch)
//! and Fig. 10a (completion time at max batch under contention).
//!
//! Setup per the paper: four Llama2-7B LoRA functions on TWO GPUs (each
//! GPU can hold two full 7B models *or* one shared backbone + KV room).

use crate::cluster::Cluster;
use crate::sim::workloads::throughput_workload;
use crate::sim::{Engine, SystemConfig};
use crate::trace::Pattern;
use crate::util::table::{f, Table};

fn two_gpu_cluster() -> Cluster {
    Cluster::new(1, 2, 8)
}

fn run_throughput(cfg: SystemConfig, dur: f64) -> (f64, usize, f64) {
    let w = throughput_workload(dur, 21);
    let (m, _, _) = Engine::new(cfg, two_gpu_cluster(), w, 2).run();
    (m.token_throughput(), m.peak_batch(), m.request_throughput())
}

fn saturating_systems() -> Vec<SystemConfig> {
    vec![
        SystemConfig::serverless_lora(),
        SystemConfig::serverless_llm(),
        SystemConfig::instainfer(Pattern::Predictable),
    ]
}

pub fn tab2(quick: bool) -> String {
    let dur = if quick { 300.0 } else { 900.0 };
    let mut t = Table::new(
        "Table 2 — Peak throughput, 4× Llama2-7B fns on 2 GPUs",
        &["system", "tokens/s", "peak batch", "requests/s"],
    );
    let rows = super::runner::parallel_map(saturating_systems(), move |cfg| {
        let name = cfg.name;
        let (tok, batch, req) = run_throughput(cfg, dur);
        (name, tok, batch, req)
    });
    for (name, tok, batch, req) in rows {
        t.row(vec![name.into(), f(tok), batch.to_string(), f(req)]);
    }
    t.render()
}

pub fn fig10a(quick: bool) -> String {
    let dur = if quick { 300.0 } else { 900.0 };
    let mut t = Table::new(
        "Fig 10a — Completion time at max batch (same saturating workload)",
        &["system", "mean E2E (s)", "p99 E2E (s)", "completed"],
    );
    let rows = super::runner::parallel_map(saturating_systems(), move |cfg| {
        let name = cfg.name;
        let w = throughput_workload(dur, 21);
        let (m, _, _) = Engine::new(cfg, two_gpu_cluster(), w, 2).run();
        (name, m)
    });
    for (name, m) in rows {
        t.row(vec![
            name.into(),
            f(m.e2e().mean),
            f(m.e2e().p99),
            m.outcomes.len().to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 2 headline: backbone sharing frees KV memory ⇒ larger peak
    /// batches and higher token/request throughput than both baselines.
    #[test]
    fn sharing_lifts_peak_batch_and_throughput() {
        let (tok_l, batch_l, req_l) =
            run_throughput(SystemConfig::serverless_lora(), 240.0);
        let (tok_s, batch_s, req_s) =
            run_throughput(SystemConfig::serverless_llm(), 240.0);
        assert!(
            batch_l > batch_s,
            "peak batch: lora {batch_l} vs sllm {batch_s}"
        );
        assert!(tok_l > tok_s, "tokens/s: lora {tok_l} vs sllm {tok_s}");
        assert!(req_l > req_s, "req/s: lora {req_l} vs sllm {req_s}");
    }

    /// Fig. 10a: even at its larger peak batch (max contention),
    /// ServerlessLoRA completes the same workload sooner.
    #[test]
    fn completion_time_shortest_despite_contention() {
        let w = throughput_workload(240.0, 21);
        let (ml, _, _) = Engine::new(
            SystemConfig::serverless_lora(),
            two_gpu_cluster(),
            w.clone(),
            2,
        )
        .run();
        let (ms_, _, _) = Engine::new(
            SystemConfig::serverless_llm(),
            two_gpu_cluster(),
            w,
            2,
        )
        .run();
        // Same offered load; compare completions and mean E2E.
        assert!(
            ml.outcomes.len() >= ms_.outcomes.len(),
            "completions: {} vs {}",
            ml.outcomes.len(),
            ms_.outcomes.len()
        );
        assert!(
            ml.e2e().mean < ms_.e2e().mean,
            "E2E: {} vs {}",
            ml.e2e().mean,
            ms_.e2e().mean
        );
    }
}
