//! §6.9 overhead: scheduler decision latency (paper: ~1 ms per scheduler,
//! < 6 ms total under the heaviest load) and the backbone-sharing memory
//! overhead (paper: 473 MB of per-process CUDA context vs 14–80 GB saved).
//!
//! (Wall-clock micro-benchmarks of the schedulers themselves — no
//! simulator runs, so this experiment has no `ScenarioSpec` form; see
//! `exp` module docs.)

use std::time::Instant;

use crate::artifact::{params, FunctionSpec, ModelProfile};
use crate::coordinator::{
    BatchQueue, DynamicOffloader, FunctionDemand, PreloadScheduler, Queued,
};
use crate::sharing::BackboneRegistry;
use crate::util::table::{f, Table};

fn bench_us(mut op: impl FnMut(), iters: usize) -> f64 {
    // Warm up, then measure.
    for _ in 0..3 {
        op();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        op();
    }
    t0.elapsed().as_secs_f64() * 1e6 / iters as f64
}

pub fn report() -> String {
    let mut t = Table::new(
        "§6.9 — Scheduler overhead (µs per decision) and sharing overhead",
        &["component", "value", "unit"],
    );

    // Pre-Loading Scheduler over the full 8-fn / 16-GPU deployment.
    let demands: Vec<FunctionDemand> = (0..8)
        .map(|i| FunctionDemand {
            spec: FunctionSpec::new(
                i,
                if i < 4 {
                    ModelProfile::llama2_7b()
                } else {
                    ModelProfile::llama2_13b()
                },
                i % 4,
            ),
            rate: 0.05,
        })
        .collect();
    let cluster = crate::cluster::Cluster::paper_multinode();
    let registry = BackboneRegistry::new();
    let sched = PreloadScheduler::default();
    let us = bench_us(
        || {
            let _ = sched.plan(&demands, &cluster, &registry);
        },
        50,
    );
    t.row(vec!["preload scheduler plan".into(), f(us), "µs".into()]);

    // Batching decision: margin + dispatch check over 8 queues.
    let mut queues: Vec<BatchQueue> = demands
        .iter()
        .map(|d| BatchQueue::new(d.spec.id, &d.spec.model))
        .collect();
    for (i, q) in queues.iter_mut().enumerate() {
        for j in 0..10u64 {
            q.push(Queued { request: j, arrival_s: i as f64 * 0.01 });
        }
    }
    let us = bench_us(
        || {
            let _ = crate::coordinator::batching::select_by_deadline_margin(
                queues.iter(),
                1.0,
                2,
            );
        },
        10_000,
    );
    t.row(vec!["batching scheduler decision".into(), f(us), "µs".into()]);

    // Offloader plan over a loaded GPU (paper: "executes within µs").
    let mut cluster2 = crate::cluster::Cluster::new(1, 1, 1);
    let mut reg2 = BackboneRegistry::new();
    let g = cluster2.gpu_ids()[0];
    reg2.load(&mut cluster2, "llama2-13b", 26.0, g).unwrap();
    for fid in 0..8 {
        let gpu = cluster2.gpu_mut(g);
        let _ = gpu.place_artifact(fid, crate::artifact::ArtifactKind::Adapter, 0.2);
        let _ =
            gpu.place_artifact(fid, crate::artifact::ArtifactKind::CudaKernel, 0.5);
    }
    let us = bench_us(
        || {
            let ev = DynamicOffloader::evictable(&cluster2, &reg2, g, &[0], |_, _| 1.0);
            let _ = DynamicOffloader::plan(ev, 2.0);
        },
        10_000,
    );
    t.row(vec!["dynamic offloader plan".into(), f(us), "µs".into()]);

    // Sharing memory overhead: per-process CUDA context (the §6.9 473 MB)
    // against the saved backbone bytes for 4 functions.
    let ctx_gb = params::CUDA_CONTEXT_GB;
    let saved_7b = 3.0 * ModelProfile::llama2_7b().weights_gb;
    let saved_13b = 3.0 * ModelProfile::llama2_13b().weights_gb;
    t.row(vec!["CUDA-context overhead / fn".into(), f(ctx_gb * 1000.0), "MB".into()]);
    t.row(vec!["backbone GB saved (4×7B)".into(), f(saved_7b), "GB".into()]);
    t.row(vec!["backbone GB saved (4×13B)".into(), f(saved_13b), "GB".into()]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §6.9: scheduling decisions must stay in the paper's regime —
    /// pre-loading plan ≈ 1 ms; batching/offload decisions are micro-ops.
    #[test]
    fn scheduler_decisions_fast() {
        let demands: Vec<FunctionDemand> = (0..8)
            .map(|i| FunctionDemand {
                spec: FunctionSpec::new(i, ModelProfile::llama2_7b(), i % 4),
                rate: 0.05,
            })
            .collect();
        let cluster = crate::cluster::Cluster::paper_multinode();
        let registry = BackboneRegistry::new();
        let sched = PreloadScheduler::default();
        let t0 = Instant::now();
        let _ = sched.plan(&demands, &cluster, &registry);
        // 50 ms budget leaves room for debug builds; release is ≤ ~1 ms.
        assert!(t0.elapsed().as_millis() < 50);
    }

    #[test]
    fn sharing_overhead_negligible_vs_savings() {
        // 473 MB context vs ≥ 40 GB saved for 4× 7B functions.
        assert!(params::CUDA_CONTEXT_GB < 0.05 * 3.0 * ModelProfile::llama2_7b().weights_gb);
    }

    #[test]
    fn report_renders() {
        let r = report();
        assert!(r.contains("preload scheduler"));
        assert!(r.contains("offloader"));
    }
}
