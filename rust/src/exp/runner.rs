//! Parallel experiment runner: order-preserving scoped-thread fan-out for
//! independent simulator runs.
//!
//! Every `Engine` run is independent and seed-deterministic, so the
//! experiment suites fan their (config, workload) grids out across
//! threads and still render bit-identical tables in the same order as a
//! sequential run. The job count is a process-wide setting (`--jobs N` on
//! the bench harness and the `simulate` CLI); `jobs() == 1` (the default)
//! runs inline with zero threading overhead.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

static JOBS: AtomicUsize = AtomicUsize::new(1);

/// Set the process-wide worker count for experiment fan-out.
pub fn set_jobs(n: usize) {
    JOBS.store(n.max(1), Ordering::SeqCst);
}

pub fn jobs() -> usize {
    JOBS.load(Ordering::SeqCst)
}

/// Map `f` over `items` with the process-wide job count, preserving input
/// order in the output.
pub fn parallel_map<I, T, F>(items: Vec<I>, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(I) -> T + Sync,
{
    parallel_map_with(jobs(), items, f)
}

/// Same, with an explicit worker count (used by tests to compare the
/// parallel and sequential paths without touching the global setting).
pub fn parallel_map_with<I, T, F>(n_jobs: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(I) -> T + Sync,
{
    let n = items.len();
    let workers = n_jobs.max(1).min(n.max(1));
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    // Work-stealing by atomic index: each worker claims the next
    // unclaimed item, computes, and writes into its dedicated slot —
    // output order equals input order no matter the interleaving.
    let tasks: Vec<Mutex<Option<I>>> =
        items.into_iter().map(|i| Mutex::new(Some(i))).collect();
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let item = tasks[i]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("task claimed twice");
                let r = f(item);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker died before writing"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let ys = parallel_map_with(8, xs.clone(), |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path_equals_parallel_path() {
        let xs: Vec<u64> = (0..37).collect();
        let seq = parallel_map_with(1, xs.clone(), |x| x.wrapping_mul(0x9E37).rotate_left(7));
        let par = parallel_map_with(4, xs, |x| x.wrapping_mul(0x9E37).rotate_left(7));
        assert_eq!(seq, par);
    }

    #[test]
    fn handles_empty_and_single() {
        let e: Vec<u32> = parallel_map_with(4, Vec::<u32>::new(), |x| x);
        assert!(e.is_empty());
        assert_eq!(parallel_map_with(4, vec![9], |x| x + 1), vec![10]);
    }

    #[test]
    fn jobs_setting_clamps_to_one() {
        let before = jobs();
        set_jobs(0);
        assert_eq!(jobs(), 1);
        set_jobs(before);
    }
}
