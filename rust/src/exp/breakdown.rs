//! Fig. 1 (motivation: time breakdown of LoRA invocations) and
//! Fig. 8 (single-invocation cold-start breakdown + whole-workload
//! cumulative breakdown) — `ScenarioSpec` grids through
//! `scenario::run_grid`.

use crate::artifact::ModelProfile;
use crate::metrics::Phase;
use crate::scenario::{ClusterSpec, ScenarioSpec, SystemSpec, WorkloadSpec};
use crate::trace::Pattern;
use crate::util::table::{ms, Table};

fn phase_row(m: &crate::metrics::RunMetrics, per_request: bool) -> Vec<String> {
    let map = if per_request { m.phase_means() } else { m.phase_totals() };
    Phase::ALL
        .iter()
        .map(|p| ms(map.get(p).copied().unwrap_or(0.0)))
        .collect()
}

fn header() -> Vec<&'static str> {
    let mut h = vec!["system"];
    h.extend(Phase::ALL.iter().map(|p| p.name()));
    h
}

pub fn fig1(quick: bool) -> String {
    let dur = super::horizon(quick);
    let mut t = Table::new(
        "Fig 1 — Mean per-request time breakdown (ms), 3× Llama2-13B LoRA fns",
        &header(),
    );
    let specs: Vec<ScenarioSpec> = ["instainfer", "serverless-llm", "serverless-lora"]
        .into_iter()
        .map(|id| {
            super::cell(
                format!("fig1-{id}"),
                id,
                ClusterSpec::Paper,
                WorkloadSpec::Breakdown13b { seed: 7 },
                dur,
                1,
            )
        })
        .collect();
    for r in super::run_cells(specs) {
        let (system, run) = r.into_only();
        let mut row = vec![system];
        row.extend(phase_row(&run.metrics, true));
        t.row(row);
    }
    t.render()
}

pub fn fig8(quick: bool) -> String {
    let mut out = String::new();

    // (a) single fully-pre-warmed invocation per model: best-case
    // cold-start mitigation of each system. Best case per §6.3 means
    // InstaInfer's predictor is pinned to a hit (`hit_rate` override);
    // the paper cluster trivially gives the one function its own GPU.
    for model in [ModelProfile::llama2_7b(), ModelProfile::llama2_13b()] {
        let mut t = Table::new(
            &format!(
                "Fig 8a — Single-invocation breakdown (ms), {} (best case)",
                model.name
            ),
            &header(),
        );
        let workload = WorkloadSpec::SingleInvocation { model: model.name.to_string() };
        let mut insta = SystemSpec::new("instainfer");
        insta.hit_rate = Some(1.0);
        let specs = vec![
            ScenarioSpec::builder(&format!("fig8a-{}-instainfer", model.name))
                .system_spec(insta)
                .workload(workload.clone())
                .horizon_s(30.0)
                .seed(1)
                .build()
                .expect("fig8a cell validates"),
            super::cell(
                format!("fig8a-{}-serverless-llm", model.name),
                "serverless-llm",
                ClusterSpec::Paper,
                workload.clone(),
                30.0,
                1,
            ),
            super::cell(
                format!("fig8a-{}-serverless-lora", model.name),
                "serverless-lora",
                ClusterSpec::Paper,
                workload,
                30.0,
                1,
            ),
        ];
        for r in super::run_cells(specs) {
            let (system, run) = r.into_only();
            let mut row = vec![system];
            row.extend(phase_row(&run.metrics, true));
            t.row(row);
        }
        out.push_str(&t.render());
    }

    // (b) cumulative over the whole Normal workload.
    let dur = super::horizon(quick);
    let mut t = Table::new(
        "Fig 8b — Cumulative time breakdown (ms) over the Normal workload",
        &header(),
    );
    let specs: Vec<ScenarioSpec> = ["instainfer", "serverless-llm", "serverless-lora"]
        .into_iter()
        .map(|id| {
            super::cell(
                format!("fig8b-{id}"),
                id,
                ClusterSpec::Paper,
                WorkloadSpec::Paper { pattern: Pattern::Normal, seed: 11 },
                dur,
                1,
            )
        })
        .collect();
    for r in super::run_cells(specs) {
        let (system, run) = r.into_only();
        let mut row = vec![system];
        row.extend(phase_row(&run.metrics, false));
        t.row(row);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workloads::{breakdown_13b_workload, single_invocation};
    use crate::sim::SystemConfig;

    /// §2.3: artifact loading dominates cold-start time (>90% of startup)
    /// for non-preloading systems.
    #[test]
    fn artifact_loading_dominates_cold_start() {
        let w = single_invocation(ModelProfile::llama2_13b());
        let (m, _, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        let phases = m.phase_means();
        let container = phases.get(&Phase::ContainerInit).copied().unwrap_or(0.0);
        let artifacts: f64 = [
            Phase::LibraryLoad,
            Phase::BackboneLoad,
            Phase::AdapterLoad,
            Phase::KernelCompile,
        ]
        .iter()
        .map(|p| phases.get(p).copied().unwrap_or(0.0))
        .sum();
        assert!(
            artifacts / (artifacts + container) > 0.7,
            "artifacts {artifacts} vs container {container}"
        );
    }

    /// Fig. 8a: only ServerlessLoRA fully eliminates cold start (a fully
    /// pre-warmed invocation is as fast as a warm start).
    #[test]
    fn serverless_lora_eliminates_cold_start() {
        let w = single_invocation(ModelProfile::llama2_7b());
        let (m, _, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w, 1);
        assert_eq!(m.outcomes.len(), 1);
        assert!(
            m.outcomes[0].cold_start_s() < 0.2,
            "cold start {}",
            m.outcomes[0].cold_start_s()
        );
    }

    /// Fig. 8a: InstaInfer retains the kernel-compile slice (it never
    /// pre-compiles kernels); ServerlessLLM retains library + kernel cost.
    #[test]
    fn baselines_retain_cold_start_slices() {
        let w = single_invocation(ModelProfile::llama2_7b());
        let (mi, _, _) = super::super::run_system(
            SystemConfig::instainfer(Pattern::Predictable),
            w.clone(),
            1,
        );
        let pi = mi.phase_means();
        assert!(pi.get(&Phase::KernelCompile).copied().unwrap_or(0.0) > 1.0);
        let (ms_, _, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        let ps = ms_.phase_means();
        assert!(ps.get(&Phase::LibraryLoad).copied().unwrap_or(0.0) > 1.0);
        assert!(ps.get(&Phase::KernelCompile).copied().unwrap_or(0.0) > 1.0);
    }

    /// Fig. 1 ordering: ServerlessLoRA's mean cold-start share is the
    /// smallest of the three serverless systems.
    #[test]
    fn fig1_cold_start_ordering() {
        let w = breakdown_13b_workload(1800.0, 7);
        let cold = |cfg: SystemConfig| {
            let (m, _, _) = super::super::run_system(cfg, w.clone(), 1);
            m.outcomes.iter().map(|o| o.cold_start_s()).sum::<f64>()
                / m.outcomes.len().max(1) as f64
        };
        let lora = cold(SystemConfig::serverless_lora());
        let sllm = cold(SystemConfig::serverless_llm());
        let insta = cold(SystemConfig::instainfer(Pattern::Normal));
        assert!(lora < sllm && lora < insta, "lora {lora} sllm {sllm} insta {insta}");
    }
}
