//! Fig. 1 (motivation: time breakdown of LoRA invocations) and
//! Fig. 8 (single-invocation cold-start breakdown + whole-workload
//! cumulative breakdown).

use crate::artifact::{FunctionSpec, ModelProfile};
use crate::metrics::Phase;
use crate::sim::workloads::{paper_workload, single_invocation};
use crate::sim::{SystemConfig, Workload};
use crate::trace::{merge, Pattern, TraceSpec};
use crate::util::table::{ms, Table};

fn phase_row(m: &crate::metrics::RunMetrics, per_request: bool) -> Vec<String> {
    let map = if per_request { m.phase_means() } else { m.phase_totals() };
    Phase::ALL
        .iter()
        .map(|p| ms(map.get(p).copied().unwrap_or(0.0)))
        .collect()
}

fn header() -> Vec<&'static str> {
    let mut h = vec!["system"];
    h.extend(Phase::ALL.iter().map(|p| p.name()));
    h
}

/// Fig. 1 workload: three Llama2-13B LoRA functions on the Azure-like
/// Normal trace.
fn fig1_workload(duration_s: f64) -> Workload {
    let functions: Vec<FunctionSpec> = (0..3)
        .map(|i| FunctionSpec::new(i, ModelProfile::llama2_13b(), i))
        .collect();
    let rates = vec![1.0 / 120.0, 1.0 / 300.0, 1.0 / 600.0];
    let traces = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, Pattern::Normal, rates[f.id], 7 + f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

pub fn fig1(quick: bool) -> String {
    let dur = super::horizon(quick);
    let mut t = Table::new(
        "Fig 1 — Mean per-request time breakdown (ms), 3× Llama2-13B LoRA fns",
        &header(),
    );
    let systems = vec![
        SystemConfig::instainfer(Pattern::Normal),
        SystemConfig::serverless_llm(),
        SystemConfig::serverless_lora(),
    ];
    let rows = super::runner::parallel_map(systems, move |cfg| {
        let name = cfg.name;
        let (m, _, _) = super::run_system(cfg, fig1_workload(dur), 1);
        (name, m)
    });
    for (name, m) in rows {
        let mut row = vec![name.to_string()];
        row.extend(phase_row(&m, true));
        t.row(row);
    }
    t.render()
}

pub fn fig8(quick: bool) -> String {
    let mut out = String::new();

    // (a) single fully-pre-warmed invocation per model: best-case
    // cold-start mitigation of each system.
    for model in [ModelProfile::llama2_7b(), ModelProfile::llama2_13b()] {
        let mut t = Table::new(
            &format!(
                "Fig 8a — Single-invocation breakdown (ms), {} (best case)",
                model.name
            ),
            &header(),
        );
        for cfg in [
            // Best case per §6.3: each system fully pre-warmed by its own
            // mitigation — InstaInfer's predictor is forced to a hit.
            SystemConfig {
                preload: crate::sim::PreloadMode::ContainerOpportunistic {
                    hit_rate: 1.0,
                },
                ..SystemConfig::instainfer(Pattern::Normal)
            },
            SystemConfig::serverless_llm(),
            SystemConfig::serverless_lora(),
        ] {
            let name = cfg.name;
            let w = single_invocation(model.clone());
            // Dedicated GPU per function (the §6.3 setup) — the paper
            // cluster trivially satisfies this with one function.
            let (m, _, _) = super::run_system(cfg, w, 1);
            let mut row = vec![name.to_string()];
            row.extend(phase_row(&m, true));
            t.row(row);
        }
        out.push_str(&t.render());
    }

    // (b) cumulative over the whole Normal workload.
    let dur = super::horizon(quick);
    let mut t = Table::new(
        "Fig 8b — Cumulative time breakdown (ms) over the Normal workload",
        &header(),
    );
    let systems = vec![
        SystemConfig::instainfer(Pattern::Normal),
        SystemConfig::serverless_llm(),
        SystemConfig::serverless_lora(),
    ];
    let rows = super::runner::parallel_map(systems, move |cfg| {
        let name = cfg.name;
        let (m, _, _) = super::run_system(cfg, paper_workload(Pattern::Normal, dur, 11), 1);
        (name, m)
    });
    for (name, m) in rows {
        let mut row = vec![name.to_string()];
        row.extend(phase_row(&m, false));
        t.row(row);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §2.3: artifact loading dominates cold-start time (>90% of startup)
    /// for non-preloading systems.
    #[test]
    fn artifact_loading_dominates_cold_start() {
        let w = single_invocation(ModelProfile::llama2_13b());
        let (m, _, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        let phases = m.phase_means();
        let container = phases.get(&Phase::ContainerInit).copied().unwrap_or(0.0);
        let artifacts: f64 = [
            Phase::LibraryLoad,
            Phase::BackboneLoad,
            Phase::AdapterLoad,
            Phase::KernelCompile,
        ]
        .iter()
        .map(|p| phases.get(p).copied().unwrap_or(0.0))
        .sum();
        assert!(
            artifacts / (artifacts + container) > 0.7,
            "artifacts {artifacts} vs container {container}"
        );
    }

    /// Fig. 8a: only ServerlessLoRA fully eliminates cold start (a fully
    /// pre-warmed invocation is as fast as a warm start).
    #[test]
    fn serverless_lora_eliminates_cold_start() {
        let w = single_invocation(ModelProfile::llama2_7b());
        let (m, _, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w, 1);
        assert_eq!(m.outcomes.len(), 1);
        assert!(
            m.outcomes[0].cold_start_s() < 0.2,
            "cold start {}",
            m.outcomes[0].cold_start_s()
        );
    }

    /// Fig. 8a: InstaInfer retains the kernel-compile slice (it never
    /// pre-compiles kernels); ServerlessLLM retains library + kernel cost.
    #[test]
    fn baselines_retain_cold_start_slices() {
        let w = single_invocation(ModelProfile::llama2_7b());
        let (mi, _, _) = super::super::run_system(
            SystemConfig::instainfer(Pattern::Predictable),
            w.clone(),
            1,
        );
        let pi = mi.phase_means();
        assert!(pi.get(&Phase::KernelCompile).copied().unwrap_or(0.0) > 1.0);
        let (ms_, _, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        let ps = ms_.phase_means();
        assert!(ps.get(&Phase::LibraryLoad).copied().unwrap_or(0.0) > 1.0);
        assert!(ps.get(&Phase::KernelCompile).copied().unwrap_or(0.0) > 1.0);
    }

    /// Fig. 1 ordering: ServerlessLoRA's mean cold-start share is the
    /// smallest of the three serverless systems.
    #[test]
    fn fig1_cold_start_ordering() {
        let w = fig1_workload(1800.0);
        let cold = |cfg: SystemConfig| {
            let (m, _, _) = super::super::run_system(cfg, w.clone(), 1);
            m.outcomes.iter().map(|o| o.cold_start_s()).sum::<f64>()
                / m.outcomes.len().max(1) as f64
        };
        let lora = cold(SystemConfig::serverless_lora());
        let sllm = cold(SystemConfig::serverless_llm());
        let insta = cold(SystemConfig::instainfer(Pattern::Normal));
        assert!(lora < sllm && lora < insta, "lora {lora} sllm {sllm} insta {insta}");
    }
}
