//! Cold-start strategy experiment (`coldstarts`): sweep the three
//! cold-start strategies × keep-alive (the cold-start-rate knob) on the
//! no-preload baseline and report where each strategy earns its keep —
//! snapshot-restore on *repeat* colds (the snapshot exists by then),
//! pipelined on *first-touch* colds (no snapshot can exist yet, but K
//! idle GPUs can each pull a slice), and the snapshot storage surcharge
//! the restores are bought with.
//!
//! The sweep runs `npl` with the tiered store on a multi-node cluster:
//! nothing is pre-staged, so every cold start takes the strategy under
//! test, and sibling nodes exist for the pipelined splits. Shorter
//! keep-alive ⇒ more colds ⇒ more strategy exposure; the tiered column
//! at each keep-alive is the baseline the other two are judged against.

use std::sync::Mutex;

use crate::coldstart::{ColdPath, ColdStartKind, ColdStartSpec};
use crate::scenario::{ClusterSpec, ScenarioSpec, SeedRun, WorkloadSpec};
use crate::sim::TierSpec;
use crate::trace::Pattern;
use crate::util::json::{num, obj, Json};
use crate::util::table::{ms, Table};

/// Most recent snapshot-restore and pipelined reference cells (shortest
/// keep-alive), reused by `coldstarts_json` when the sweep already ran.
static LAST_REFERENCE: Mutex<Option<(ColdPoint, ColdPoint, ColdPoint)>> = Mutex::new(None);

/// One measured grid cell.
#[derive(Clone)]
pub struct ColdPoint {
    pub strategy: ColdStartKind,
    pub keepalive_s: f64,
    pub requests: usize,
    /// Cold outcomes (any non-warm path) / all outcomes.
    pub cold: usize,
    /// Mean TTFT over each function's *first* cold outcome.
    pub first_ttft_s: f64,
    /// Mean TTFT over every later cold outcome (repeat colds).
    pub repeat_ttft_s: f64,
    pub restores: u64,
    pub pipelined: u64,
    pub total_usd: f64,
    pub snapshot_usd: f64,
}

/// Keep-alive values swept (seconds) — the cold-start-rate axis.
pub fn keepalives(quick: bool) -> Vec<f64> {
    if quick {
        vec![20.0, 120.0]
    } else {
        vec![20.0, 120.0, 600.0]
    }
}

/// The three strategies, tiered (the baseline) first.
pub const STRATEGIES: [ColdStartKind; 3] = [
    ColdStartKind::Tiered,
    ColdStartKind::SnapshotRestore,
    ColdStartKind::Pipelined,
];

fn horizon(quick: bool) -> f64 {
    if quick {
        600.0
    } else {
        1800.0
    }
}

/// Build one grid cell: no-preload system, tiered store, the strategy
/// under test, a 4-node cluster (sibling nodes for the pipelined
/// splits), paper workload at bursty arrivals.
fn cell(strategy: ColdStartKind, keepalive_s: f64, horizon_s: f64, seed: u64) -> ScenarioSpec {
    ScenarioSpec::builder(&format!("coldstarts-{}-ka{keepalive_s}", strategy.id()))
        .system("npl")
        .keepalive_s(keepalive_s)
        .tiers(TierSpec::default())
        .cold_start(ColdStartSpec::uniform(strategy))
        .cluster(ClusterSpec::Uniform {
            nodes: 4,
            gpus_per_node: 2,
            containers_per_node: 8,
            trim_gpus: None,
            zones: 1,
        })
        .workload(WorkloadSpec::Paper { pattern: Pattern::Bursty, seed })
        .horizon_s(horizon_s)
        .seed(seed)
        .build()
        .expect("coldstarts cell validates")
}

/// Split the run's cold outcomes into per-function first touch vs
/// repeats and average each side's TTFT.
fn fold(strategy: ColdStartKind, keepalive_s: f64, run: &SeedRun) -> ColdPoint {
    let mut outcomes: Vec<_> = run
        .metrics
        .outcomes
        .iter()
        .filter(|o| o.cold_path != ColdPath::Warm)
        .collect();
    outcomes.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
    let mut seen = std::collections::BTreeSet::new();
    let (mut first, mut repeat) = (Vec::new(), Vec::new());
    for o in &outcomes {
        if seen.insert(o.function) {
            first.push(o.ttft_s);
        } else {
            repeat.push(o.ttft_s);
        }
    }
    let mean = |xs: &[f64]| {
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    };
    ColdPoint {
        strategy,
        keepalive_s,
        requests: run.requests,
        cold: outcomes.len(),
        first_ttft_s: mean(&first),
        repeat_ttft_s: mean(&repeat),
        restores: run.stats.snapshot_restores,
        pipelined: run.stats.pipelined_loads,
        total_usd: run.cost.total_usd(),
        snapshot_usd: run.cost.snapshot_usd,
    }
}

/// Run one cell and fold it into a [`ColdPoint`].
pub fn run_point(
    strategy: ColdStartKind,
    keepalive_s: f64,
    horizon_s: f64,
    seed: u64,
) -> ColdPoint {
    let spec = cell(strategy, keepalive_s, horizon_s, seed);
    let report = crate::scenario::run(&spec).expect("coldstarts cell runs");
    let (_, run) = report.into_only();
    assert_eq!(
        run.metrics.outcomes.len(),
        run.requests,
        "coldstarts cell lost requests"
    );
    let st = &run.stats;
    assert_eq!(
        st.pipeline_consolidations + st.pipeline_cancellations,
        st.pipelined_loads,
        "pipelined loads do not conserve (fault-free run)"
    );
    match strategy {
        ColdStartKind::Tiered => assert_eq!(
            st.snapshot_restores + st.pipelined_loads,
            0,
            "tiered cells must not touch the other strategies' machinery"
        ),
        ColdStartKind::SnapshotRestore => {
            assert_eq!(st.pipelined_loads, 0);
        }
        ColdStartKind::Pipelined => {
            assert_eq!(st.snapshot_restores, 0);
        }
    }
    fold(strategy, keepalive_s, &run)
}

/// The rendered sweep (experiment id `coldstarts`).
pub fn coldstarts(quick: bool) -> String {
    let mut t = Table::new(
        "Cold-start strategies — strategy × keep-alive sweep (no-preload baseline)",
        &[
            "strategy",
            "keepalive s",
            "requests",
            "cold",
            "first-TTFT(ms)",
            "repeat-TTFT(ms)",
            "restores",
            "pipelined",
            "cost $",
            "snapshot $",
        ],
    );
    let dur = horizon(quick);
    let shortest = keepalives(quick)[0];
    let mut reference: (Option<ColdPoint>, Option<ColdPoint>, Option<ColdPoint>) =
        (None, None, None);
    for keepalive_s in keepalives(quick) {
        for strategy in STRATEGIES {
            let p = run_point(strategy, keepalive_s, dur, 11);
            if keepalive_s == shortest {
                match strategy {
                    ColdStartKind::Tiered => reference.0 = Some(p.clone()),
                    ColdStartKind::SnapshotRestore => reference.1 = Some(p.clone()),
                    ColdStartKind::Pipelined => reference.2 = Some(p.clone()),
                }
            }
            t.row(vec![
                strategy.id().to_string(),
                format!("{keepalive_s}"),
                p.requests.to_string(),
                p.cold.to_string(),
                ms(p.first_ttft_s),
                ms(p.repeat_ttft_s),
                p.restores.to_string(),
                p.pipelined.to_string(),
                format!("{:.4}", p.total_usd),
                format!("{:.6}", p.snapshot_usd),
            ]);
        }
    }
    if let (Some(a), Some(b), Some(c)) = reference {
        *LAST_REFERENCE.lock().unwrap() = Some((a, b, c));
    }
    t.render()
}

/// Machine-readable record of the shortest-keep-alive column (all three
/// strategies) for cross-PR tracking in `BENCH_sim.json`. Reuses the
/// sweep's measurements when a `coldstarts()` run covered them.
pub fn coldstarts_json(quick: bool) -> Json {
    let cached = LAST_REFERENCE.lock().unwrap().clone();
    let (tiered, snap, pipe) = match cached {
        Some(t) => t,
        None => {
            let ka = keepalives(quick)[0];
            let dur = horizon(quick);
            (
                run_point(ColdStartKind::Tiered, ka, dur, 11),
                run_point(ColdStartKind::SnapshotRestore, ka, dur, 11),
                run_point(ColdStartKind::Pipelined, ka, dur, 11),
            )
        }
    };
    obj(vec![
        ("keepalive_s", num(tiered.keepalive_s)),
        ("tiered_first_ttft_ms", num(tiered.first_ttft_s * 1000.0)),
        ("tiered_repeat_ttft_ms", num(tiered.repeat_ttft_s * 1000.0)),
        ("snapshot_repeat_ttft_ms", num(snap.repeat_ttft_s * 1000.0)),
        (
            "snapshot_repeat_speedup",
            num(tiered.repeat_ttft_s / snap.repeat_ttft_s.max(1e-12)),
        ),
        ("snapshot_restores", num(snap.restores as f64)),
        ("snapshot_usd", num(snap.snapshot_usd)),
        ("pipelined_first_ttft_ms", num(pipe.first_ttft_s * 1000.0)),
        (
            "pipelined_first_speedup",
            num(tiered.first_ttft_s / pipe.first_ttft_s.max(1e-12)),
        ),
        ("pipelined_loads", num(pipe.pipelined as f64)),
        ("tiered_cost_usd", num(tiered.total_usd)),
        ("snapshot_cost_usd", num(snap.total_usd)),
        ("pipelined_cost_usd", num(pipe.total_usd)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_grow_with_full_mode() {
        assert!(keepalives(true).len() < keepalives(false).len());
        assert_eq!(STRATEGIES[0], ColdStartKind::Tiered, "the baseline leads");
    }

    #[test]
    fn snapshot_restore_beats_tiered_on_repeat_colds() {
        // The acceptance cell: same workload, same keep-alive — repeat
        // colds restore from the host-resident snapshot instead of
        // re-walking the tiers, and the storage surcharge shows up in
        // the cost split.
        let tiered = run_point(ColdStartKind::Tiered, 20.0, 600.0, 11);
        let snap = run_point(ColdStartKind::SnapshotRestore, 20.0, 600.0, 11);
        assert!(snap.restores > 0, "short keep-alive must trigger restores");
        assert!(tiered.repeat_ttft_s > 0.0, "baseline must see repeat colds");
        assert!(
            snap.repeat_ttft_s < tiered.repeat_ttft_s,
            "restores must beat tiered repeat colds: {} vs {}",
            snap.repeat_ttft_s,
            tiered.repeat_ttft_s
        );
        assert!(snap.snapshot_usd > 0.0, "the surcharge must be visible");
        assert_eq!(tiered.snapshot_usd, 0.0, "tiered pays no surcharge");
    }

    #[test]
    fn pipelined_beats_tiered_on_first_touch() {
        let tiered = run_point(ColdStartKind::Tiered, 20.0, 600.0, 11);
        let pipe = run_point(ColdStartKind::Pipelined, 20.0, 600.0, 11);
        assert!(pipe.pipelined > 0, "first touches must pipeline");
        assert!(
            pipe.first_ttft_s < tiered.first_ttft_s,
            "K-way splits must beat solo first-touch loads: {} vs {}",
            pipe.first_ttft_s,
            tiered.first_ttft_s
        );
    }

    #[test]
    fn json_record_names_the_tracked_counters() {
        let j = coldstarts_json(true);
        for key in [
            "snapshot_repeat_speedup",
            "snapshot_restores",
            "snapshot_usd",
            "pipelined_first_speedup",
            "pipelined_loads",
            "tiered_cost_usd",
        ] {
            assert!(j.get(key).is_some(), "BENCH record missing '{key}'");
        }
    }
}
