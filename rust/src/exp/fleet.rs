//! Fleet-scale engine benchmark: not a paper artifact but the
//! engine-health experiment behind the ROADMAP north star ("heavy
//! traffic, as fast as the hardware allows"). Sweeps cluster size and
//! function count (8→256 GPUs, 64→4096 functions in full mode) and
//! reports wall-clock, events processed per second, peak live
//! event-queue length, and cancellations, so the timing-wheel /
//! routing-index work is tracked across PRs via `BENCH_sim.json`.
//!
//! `--skew S` drives the sweep with the Zipf(S) function-popularity
//! workload instead of the uniform-tiers one (Azure-style head-heavy
//! traffic; stresses keep-alive + preload); adding `--cov-head H` /
//! `--cov-tail T` classes the head and tail of the Zipf ranking into
//! different CoV burstiness patterns (Azure: hot functions are also the
//! burstiest). `--check` re-runs the quick grid and fails on counter
//! blowups against the committed structural bounds (`QUICK_BOUNDS`) —
//! the CI regression guard, which since the billing-aggregate work also
//! bounds billing samples and reclassifications per event.

use std::sync::Mutex;

use crate::scenario::{ClusterSpec, WorkloadSpec};
use crate::trace::Pattern;
use crate::util::json::{num, obj, Json};
use crate::util::table::Table;

/// Largest point measured by the most recent unskewed `fleet()` sweep,
/// so `fleet_json` (the BENCH_sim.json record) reuses it instead of
/// re-simulating the single most expensive configuration.
static LAST_LARGEST: Mutex<Option<FleetPoint>> = Mutex::new(None);

/// One measured grid point.
#[derive(Clone)]
pub struct FleetPoint {
    pub gpus: usize,
    pub fns: usize,
    /// Engine zones the cluster was sharded into (1 = plain engine).
    pub zones: usize,
    /// Worker threads driving the engines (= zones).
    pub threads: usize,
    pub requests: usize,
    pub completed: usize,
    pub wall_s: f64,
    pub events: u64,
    pub events_per_s: f64,
    /// Events per wall-second *per engine thread* — the per-core
    /// throughput the sharding must preserve (nondeterministic;
    /// JSON/check only).
    pub events_per_s_per_core: f64,
    pub peak_queue: usize,
    pub keepalive_checks: u64,
    pub events_cancelled: u64,
    /// Aggregate billing samples (one per positive-width interval —
    /// must stay ≤ events + 1 regardless of GPU count).
    pub bill_samples: u64,
    /// Billing-class reclassifications (O(GPUs touched) per event).
    pub bill_reclass: u64,
    /// Wall-clock inside billing sampling (nondeterministic; JSON-only).
    pub bill_sample_wall_s: f64,
    /// Wall-clock inside billing-class reclassification (the drain
    /// cost), split from the sample meter (nondeterministic; JSON-only).
    pub bill_reclass_wall_s: f64,
}

/// The (GPUs, functions) sweep. Quick mode stays CI-sized; full mode
/// climbs to the λScale-style fleet regime.
pub fn grid(quick: bool) -> Vec<(usize, usize)> {
    if quick {
        vec![(8, 64), (16, 256), (32, 1024)]
    } else {
        vec![
            (8, 64),
            (16, 256),
            (32, 1024),
            (64, 2048),
            (128, 3072),
            (256, 4096),
            (1024, 16384),
            (4096, 65536),
        ]
    }
}

fn horizon(quick: bool) -> f64 {
    if quick {
        600.0
    } else {
        1800.0
    }
}

/// Fleet clusters follow the paper's node shape: 8 GPUs per node with
/// two warm container slots per GPU, trimming the last node so the
/// cluster has exactly the requested GPU count. With `zones > 1` the
/// node count is rounded up to a zone multiple so the shard split is
/// exact (`gpus` itself must divide evenly — asserted in `run_point`).
fn fleet_cluster_spec(gpus: usize, zones: usize) -> ClusterSpec {
    ClusterSpec::Uniform {
        nodes: gpus.div_ceil(8).max(1).next_multiple_of(zones),
        gpus_per_node: 8,
        containers_per_node: 16,
        trim_gpus: Some(gpus),
        zones,
    }
}

/// Same shape, materialized (shape unit tests).
#[cfg(test)]
fn cluster_of(gpus: usize) -> crate::cluster::Cluster {
    fleet_cluster_spec(gpus, 1).materialize()
}

/// Run the flagship system at one grid point — as a `ScenarioSpec`
/// through `scenario::run` — and measure the engine. `skew` switches
/// the workload to Zipf(skew) function popularity; `cov` additionally
/// classes the Zipf head/tail into different burstiness patterns (only
/// meaningful with `skew`, ignored without). `zones > 1` shards the
/// cluster across that many engine threads (`sim::sharded`).
pub fn run_point(
    gpus: usize,
    fns: usize,
    duration_s: f64,
    seed: u64,
    skew: Option<f64>,
    cov: Option<(Pattern, Pattern)>,
    zones: usize,
) -> FleetPoint {
    assert!(zones >= 1, "zones must be >= 1");
    assert_eq!(gpus % zones, 0, "zones must divide the GPU count evenly");
    let workload = match (skew, cov) {
        (Some(s), Some((head, tail))) => {
            WorkloadSpec::ZipfFleetCov { fns, skew: s, head, tail, seed }
        }
        (Some(s), None) => WorkloadSpec::ZipfFleet { fns, skew: s, seed },
        (None, _) => WorkloadSpec::Fleet { fns, seed },
    };
    let spec = crate::scenario::ScenarioSpec::builder(&format!("fleet-{gpus}g-{fns}f"))
        .system("serverless-lora")
        .cluster(fleet_cluster_spec(gpus, zones))
        .workload(workload)
        .horizon_s(duration_s)
        .seed(seed)
        .bill_timing(true)
        .build()
        .expect("fleet point validates");
    let report = crate::scenario::run(&spec).expect("fleet point runs");
    let (_, run) = report.into_only();
    let (stats, wall_s) = (&run.stats, run.wall_s);
    let events_per_s = stats.events_processed as f64 / wall_s.max(1e-9);
    FleetPoint {
        gpus,
        fns,
        zones,
        threads: zones,
        requests: run.requests,
        completed: run.metrics.outcomes.len(),
        wall_s,
        events: stats.events_processed,
        events_per_s,
        events_per_s_per_core: events_per_s / zones as f64,
        peak_queue: stats.peak_event_queue,
        keepalive_checks: stats.keepalive_checks,
        events_cancelled: stats.events_cancelled,
        bill_samples: stats.bill_samples,
        bill_reclass: stats.bill_reclass,
        bill_sample_wall_s: stats.bill_sample_wall_s,
        bill_reclass_wall_s: stats.bill_reclass_wall_s,
    }
}

/// The rendered sweep (experiment id `fleet`). The table shows only
/// deterministic engine counters so the report digest in
/// `BENCH_sim.json` stays stable run-to-run; wall-clock and events/sec
/// (nondeterministic by nature) are recorded by `fleet_json` and the
/// bench harness's per-experiment `wall_s`.
pub fn fleet(quick: bool) -> String {
    fleet_with(quick, None, None)
}

pub fn fleet_with(quick: bool, skew: Option<f64>, cov: Option<(Pattern, Pattern)>) -> String {
    let dur = horizon(quick);
    let cols = [
        "GPUs",
        "fns",
        "zones",
        "threads",
        "requests",
        "events",
        "peak queue",
        "KA checks",
        "cancelled",
        "bill samples",
    ];
    let title = match (skew, cov) {
        (Some(s), Some((h, t))) => format!(
            "Fleet — engine scaling sweep, Zipf({s}) popularity, \
             {}-head/{}-tail CoV (ServerlessLoRA flagship)",
            h.name(),
            t.name()
        ),
        (Some(s), None) => format!(
            "Fleet — engine scaling sweep, Zipf({s}) popularity (ServerlessLoRA flagship)"
        ),
        (None, _) => "Fleet — engine scaling sweep (ServerlessLoRA flagship)".to_string(),
    };
    let mut t = Table::new(&title, &cols);
    let points = grid(quick);
    let largest = *points.last().expect("grid non-empty");
    for (gpus, fns) in points {
        let p = run_point(gpus, fns, dur, 11, skew, cov, 1);
        assert_eq!(p.completed, p.requests, "fleet run lost requests");
        if skew.is_none() && (gpus, fns) == largest {
            *LAST_LARGEST.lock().unwrap() = Some(p.clone());
        }
        t.row(fleet_row(&p));
    }
    t.render()
}

fn fleet_row(p: &FleetPoint) -> Vec<String> {
    vec![
        p.gpus.to_string(),
        p.fns.to_string(),
        p.zones.to_string(),
        p.threads.to_string(),
        p.requests.to_string(),
        p.events.to_string(),
        p.peak_queue.to_string(),
        p.keepalive_checks.to_string(),
        p.events_cancelled.to_string(),
        p.bill_samples.to_string(),
    ]
}

/// The zone-sharding CI smoke (`serverless-lora fleet --zones N`): one
/// λScale-sized point — 1024 GPUs / 16384 functions — run through the
/// sharded engine. The table keeps only deterministic counters; the
/// per-core throughput lands in `BENCH_sim.json` via `fleet_json`.
pub fn fleet_zones(zones: usize) -> String {
    let (gpus, fns) = (1024, 16384);
    let title = format!("Fleet — zone-sharded point, {zones} zone(s) (ServerlessLoRA flagship)");
    let mut t = Table::new(
        &title,
        &[
            "GPUs",
            "fns",
            "zones",
            "threads",
            "requests",
            "events",
            "peak queue",
            "KA checks",
            "cancelled",
            "bill samples",
        ],
    );
    let p = run_point(gpus, fns, 120.0, 11, None, None, zones);
    assert_eq!(p.completed, p.requests, "sharded fleet run lost requests");
    t.row(fleet_row(&p));
    t.render()
}

/// Machine-readable record of the sweep's largest configuration, for
/// cross-PR tracking in `BENCH_sim.json`. Reuses the measurement from a
/// `fleet()` sweep in this process when one ran (the bench harness runs
/// the experiment first), re-simulating only if it did not.
pub fn fleet_json(quick: bool) -> Json {
    let &(gpus, fns) = grid(quick).last().expect("grid non-empty");
    let cached = LAST_LARGEST.lock().unwrap().clone();
    let p = match cached {
        Some(p) if (p.gpus, p.fns) == (gpus, fns) => p,
        _ => run_point(gpus, fns, horizon(quick), 11, None, None, 1),
    };
    obj(vec![
        ("gpus", num(p.gpus as f64)),
        ("fns", num(p.fns as f64)),
        ("zones", num(p.zones as f64)),
        ("threads", num(p.threads as f64)),
        ("requests", num(p.requests as f64)),
        ("completed", num(p.completed as f64)),
        ("wall_s", num(p.wall_s)),
        ("events", num(p.events as f64)),
        ("events_per_s", num(p.events_per_s)),
        ("events_per_s_per_core", num(p.events_per_s_per_core)),
        ("peak_event_queue", num(p.peak_queue as f64)),
        ("keepalive_checks", num(p.keepalive_checks as f64)),
        ("events_cancelled", num(p.events_cancelled as f64)),
        ("bill_samples", num(p.bill_samples as f64)),
        ("bill_reclass", num(p.bill_reclass as f64)),
        // The split billing meter (ROADMAP follow-on): sampling cost vs
        // reclassification/drain cost, plus their sum for continuity
        // with the historical single `bill_wall_s` record.
        ("bill_sample_wall_s", num(p.bill_sample_wall_s)),
        ("bill_reclass_wall_s", num(p.bill_reclass_wall_s)),
        ("bill_wall_s", num(p.bill_sample_wall_s + p.bill_reclass_wall_s)),
        // Billing's share of engine wall-clock — the perf-win trajectory
        // for the O(1) aggregate sampling (was O(G) per event).
        (
            "bill_wall_share",
            num((p.bill_sample_wall_s + p.bill_reclass_wall_s) / p.wall_s.max(1e-9)),
        ),
    ])
}

// --------------------------------------------------- regression guard

/// Committed regression bounds for one quick-grid point. The engine's
/// counters are deterministic for a fixed seed; the bounds are
/// *structural* envelopes (derived below), deliberately loose so only a
/// real event-hygiene regression trips them:
///
/// * fired events amortize to a handful per request — 1 arrival, ≤3 exec
///   events per batch (LoadDone + one retiring tick per job), ≤2 queue
///   checks, a sliver of keep-alive sweeps — well under
///   `max_events_per_request`;
/// * the live queue holds 1 streamed arrival + ≤2 wakeups per function +
///   ≤1 tick per GPU + one LoadDone per in-flight batch + 1 keep-alive
///   sweep, bounded by `max_peak_queue` (cancelled events leave the
///   queue immediately, so stale entries cannot inflate it);
/// * billing takes exactly one aggregate sample per positive-width
///   interval — `bill_samples ≤ events + 1` structurally, so the bound
///   is 1.01 samples/event at any GPU count (the old per-GPU path would
///   sit at ~G× that);
/// * reclassifications are O(GPUs touched) per event — a handful per
///   batch lifecycle plus the one-off init scan — far under
///   `max_bill_reclass_per_event`.
pub struct FleetBound {
    pub gpus: usize,
    pub fns: usize,
    pub max_events_per_request: f64,
    pub max_peak_queue: usize,
    pub max_bill_samples_per_event: f64,
    pub max_bill_reclass_per_event: f64,
    /// Throughput floor: events per wall-second per engine thread. The
    /// only wall-clock-based bound — set an order of magnitude under
    /// what a release build sustains on weak CI hardware, so it only
    /// trips on an asymptotic regression (a hot loop going O(GPUs) or
    /// O(fns)), not on machine noise.
    pub min_events_per_s_per_core: f64,
}

/// Bounds for `grid(true)`, in order. `max_peak_queue` is
/// `2·fns + 64·gpus + 16` (the 64/GPU term covers ticks + in-flight
/// loading batches, which GPU memory caps far below that).
pub const QUICK_BOUNDS: &[FleetBound] = &[
    FleetBound {
        gpus: 8,
        fns: 64,
        max_events_per_request: 16.0,
        max_peak_queue: 656,
        max_bill_samples_per_event: 1.01,
        max_bill_reclass_per_event: 12.0,
        min_events_per_s_per_core: 10_000.0,
    },
    FleetBound {
        gpus: 16,
        fns: 256,
        max_events_per_request: 16.0,
        max_peak_queue: 1552,
        max_bill_samples_per_event: 1.01,
        max_bill_reclass_per_event: 12.0,
        min_events_per_s_per_core: 10_000.0,
    },
    FleetBound {
        gpus: 32,
        fns: 1024,
        max_events_per_request: 16.0,
        max_peak_queue: 4112,
        max_bill_samples_per_event: 1.01,
        max_bill_reclass_per_event: 12.0,
        min_events_per_s_per_core: 10_000.0,
    },
];

/// Run one point against its bound; `Ok` is the report line.
fn check_point(b: &FleetBound, dur: f64) -> Result<String, String> {
    let p = run_point(b.gpus, b.fns, dur, 11, None, None, 1);
    let per_req = p.events as f64 / p.requests.max(1) as f64;
    let samples_per_ev = p.bill_samples as f64 / p.events.max(1) as f64;
    let reclass_per_ev = p.bill_reclass as f64 / p.events.max(1) as f64;
    let line = format!(
        "fleet-check {}g/{}f: {} requests, {:.2} events/request (bound {}), \
         peak queue {} (bound {}), {} cancelled, \
         {:.3} bill samples/event (bound {}), {:.2} reclass/event (bound {}), \
         {:.0} events/s/core (floor {})",
        b.gpus,
        b.fns,
        p.requests,
        per_req,
        b.max_events_per_request,
        p.peak_queue,
        b.max_peak_queue,
        p.events_cancelled,
        samples_per_ev,
        b.max_bill_samples_per_event,
        reclass_per_ev,
        b.max_bill_reclass_per_event,
        p.events_per_s_per_core,
        b.min_events_per_s_per_core,
    );
    if p.completed != p.requests {
        return Err(format!("{line}\n  FAIL: lost {} requests", p.requests - p.completed));
    }
    if per_req > b.max_events_per_request {
        return Err(format!("{line}\n  FAIL: event-count blowup ({per_req:.2}/request)"));
    }
    if p.peak_queue > b.max_peak_queue {
        return Err(format!("{line}\n  FAIL: live event queue grew past its envelope"));
    }
    if p.events_cancelled == 0 {
        return Err(format!("{line}\n  FAIL: no cancellations — supersession is broken"));
    }
    if samples_per_ev > b.max_bill_samples_per_event {
        return Err(format!(
            "{line}\n  FAIL: billing is no longer O(1) per event ({samples_per_ev:.3})"
        ));
    }
    if p.bill_samples == 0 {
        return Err(format!("{line}\n  FAIL: no billing samples — aggregation is broken"));
    }
    if reclass_per_ev > b.max_bill_reclass_per_event {
        return Err(format!(
            "{line}\n  FAIL: reclassification blowup ({reclass_per_ev:.2}/event)"
        ));
    }
    if p.events_per_s_per_core < b.min_events_per_s_per_core {
        return Err(format!(
            "{line}\n  FAIL: per-core throughput below the committed floor \
             ({:.0} events/s/core)",
            p.events_per_s_per_core
        ));
    }
    Ok(line)
}

/// Tiered-store leg of the regression guard: run the `tiers`
/// experiment's bursty reference cell and bound the hierarchy counters.
/// Structural envelopes:
///
/// * conservation — every tiered cold load resolves to exactly one tier
///   (`ram + ssd + remote == cold_loads`, also engine-asserted);
/// * a load joining a link with `n` flows in flight re-times at most
///   those `n` flows, and leaves re-time at most `n - 1` more; with GPU
///   memory capping in-flight loads per node far below the container
///   count, `max_load_retimes_per_cold_load` bounds the cancel+re-push
///   traffic — a blowup means re-timing went quadratic or a flow leaked;
/// * bursty arrivals on one node *must* contend (`retimes > 0`) — zero
///   means the fair-share path silently stopped engaging.
fn check_tiers() -> Result<String, String> {
    const MAX_LOAD_RETIMES_PER_COLD_LOAD: f64 = 64.0;
    let p = super::tiers::run_point(
        crate::sim::TierSpec::default().host_cache_gb,
        Pattern::Bursty,
        600.0,
        11,
    );
    let retimes_per_load = p.retimes as f64 / (p.cold_loads as f64).max(1.0);
    let line = format!(
        "tiers-check {}gb/bursty: {} requests, {} cold loads \
         (ram {} / ssd {} / remote {}), {} evictions, \
         {:.2} retimes/cold-load (bound {MAX_LOAD_RETIMES_PER_COLD_LOAD})",
        p.cache_gb,
        p.requests,
        p.cold_loads,
        p.hits_ram,
        p.hits_ssd,
        p.hits_remote,
        p.evictions,
        retimes_per_load,
    );
    if p.hits_ram + p.hits_ssd + p.hits_remote != p.cold_loads {
        return Err(format!("{line}\n  FAIL: tier-hit conservation violated"));
    }
    if p.cold_loads == 0 {
        return Err(format!(
            "{line}\n  FAIL: no tiered cold loads — the hierarchy is not engaged"
        ));
    }
    if p.retimes == 0 {
        return Err(format!(
            "{line}\n  FAIL: no link re-timings under bursty arrivals — \
             fair-share contention is broken"
        ));
    }
    if retimes_per_load > MAX_LOAD_RETIMES_PER_COLD_LOAD {
        return Err(format!(
            "{line}\n  FAIL: re-timing blowup ({retimes_per_load:.2}/cold-load)"
        ));
    }
    Ok(line)
}

/// Fault-injection leg of the regression guard: run a fast-failure /
/// quick-repair cell of the `faults` experiment and bound the recovery
/// counters. Structural envelopes:
///
/// * conservation — every offered request completes or fails by the end
///   (asserted per seed inside `faults::run_point`), and every crash
///   repairs before the queue drains (`recoveries == crashes`);
/// * crashes *must* catch work in flight (`redispatched > 0`) — zero
///   means the crash-kill path silently stopped re-enqueuing victims;
/// * a transient load failure retries at most once per affected request
///   per attempt, so `retries / load_failures` is bounded by the largest
///   batch a single cold load can carry — a blowup means the retry loop
///   stopped converging.
fn check_faults() -> Result<String, String> {
    const MAX_RETRIES_PER_LOAD_FAILURE: f64 = 64.0;
    let p = super::faults::run_point(150.0, 30.0, true);
    let retries_per_failure = p.retries as f64 / (p.load_failures as f64).max(1.0);
    let line = format!(
        "faults-check mtbf{}/mttr{}: {} requests, {} crashes / {} recoveries, \
         {} redispatched, {} load failures, {:.2} retries/load-failure \
         (bound {MAX_RETRIES_PER_LOAD_FAILURE}), goodput {:.3}",
        p.mtbf_s,
        p.mttr_s,
        p.requests,
        p.crashes,
        p.recoveries,
        p.redispatched,
        p.load_failures,
        retries_per_failure,
        p.goodput.mean,
    );
    if p.crashes == 0 {
        return Err(format!(
            "{line}\n  FAIL: no GPU crashes at a 150 s MTBF — the injector is not firing"
        ));
    }
    if p.recoveries != p.crashes {
        return Err(format!(
            "{line}\n  FAIL: {} crashes but {} recoveries — a GPU stayed down",
            p.crashes, p.recoveries
        ));
    }
    if p.redispatched == 0 {
        return Err(format!(
            "{line}\n  FAIL: crashes never re-dispatched in-flight work"
        ));
    }
    if p.load_failures == 0 || p.retries == 0 {
        return Err(format!(
            "{line}\n  FAIL: the transient-failure retry path is not engaged"
        ));
    }
    if retries_per_failure > MAX_RETRIES_PER_LOAD_FAILURE {
        return Err(format!(
            "{line}\n  FAIL: retry blowup ({retries_per_failure:.2}/load-failure)"
        ));
    }
    if !(p.goodput.mean > 0.0 && p.goodput.mean <= 1.0) {
        return Err(format!("{line}\n  FAIL: goodput {} out of range", p.goodput.mean));
    }
    Ok(line)
}

/// Correlated-faults leg of the regression guard: run the domains +
/// degrade cell (`faults::run_correlated`, failure-aware routing so the
/// penalty path is exercised end to end) and check the structural
/// envelopes that a refactor is most likely to silently break:
///
/// * node outages *must* fire at a 450 s MTBF over the quick horizon,
///   and every one must repair before the queue drains
///   (`node_repairs == node_outages` — a miscount means a repair chain
///   was dropped or double-armed);
/// * the zone chain must fire and drain back to all-nodes-up
///   (`zone_repairs == zone_outages`);
/// * degrade episodes must fire *and* re-time in-flight work
///   (`degrade_retimes > 0`) — zero retimes with nonzero episodes means
///   the slowdown never reached the execution model;
/// * SLO attainment stays a hit-rate: in (0, 1].
fn check_correlated() -> Result<String, String> {
    let p = super::faults::run_correlated(true, true);
    let line = format!(
        "correlated-check failure-aware: {} requests, node {}/{} out/rep, \
         zone {}/{} out/rep, {} degrades / {} retimes, SLO-att {:.3}, goodput {:.3}",
        p.requests,
        p.node_outages,
        p.node_repairs,
        p.zone_outages,
        p.zone_repairs,
        p.degrades,
        p.degrade_retimes,
        p.slo.mean,
        p.goodput.mean,
    );
    if p.node_outages == 0 {
        return Err(format!(
            "{line}\n  FAIL: no node outages at a 450 s MTBF — the domain injector is not firing"
        ));
    }
    if p.node_repairs != p.node_outages {
        return Err(format!(
            "{line}\n  FAIL: {} node outages but {} repairs — a node stayed down",
            p.node_outages, p.node_repairs
        ));
    }
    if p.zone_outages == 0 {
        return Err(format!(
            "{line}\n  FAIL: no zone outages at a 180 s MTBF — the zone chain is not firing"
        ));
    }
    if p.zone_repairs != p.zone_outages {
        return Err(format!(
            "{line}\n  FAIL: {} zone outages but {} repairs — the zone never drained",
            p.zone_outages, p.zone_repairs
        ));
    }
    if p.degrades == 0 || p.degrade_retimes == 0 {
        return Err(format!(
            "{line}\n  FAIL: degraded mode is not re-timing work \
             ({} episodes, {} retimes)",
            p.degrades, p.degrade_retimes
        ));
    }
    if !(p.slo.mean > 0.0 && p.slo.mean <= 1.0) {
        return Err(format!("{line}\n  FAIL: SLO attainment {} out of range", p.slo.mean));
    }
    Ok(line)
}

/// Cold-start leg of the regression guard: run the `coldstarts`
/// experiment's short-keep-alive cells (fault-free) and check the
/// strategy invariants a refactor is most likely to silently break:
///
/// * snapshots *must* fire at a 20 s keep-alive (`restores > 0`) — zero
///   means build/admit/restore stopped chaining;
/// * repeat colds under snapshot-restore must come in at or under the
///   tiered baseline's repeat colds (the whole point of the snapshot);
/// * first touches *must* pipeline (`pipelined > 0`) on a multi-node
///   cluster with idle siblings;
/// * pipelined loads conserve on a fault-free run: every split either
///   consolidated or was cancelled, and here nothing crashes, so
///   `consolidations == pipelined` exactly (checked inside
///   `coldstarts::run_point`, which also checks
///   `consolidations + cancellations == pipelined`).
fn check_coldstarts() -> Result<String, String> {
    use crate::coldstart::ColdStartKind;
    let tiered = super::coldstarts::run_point(ColdStartKind::Tiered, 20.0, 600.0, 11);
    let snap = super::coldstarts::run_point(ColdStartKind::SnapshotRestore, 20.0, 600.0, 11);
    let pipe = super::coldstarts::run_point(ColdStartKind::Pipelined, 20.0, 600.0, 11);
    let line = format!(
        "coldstarts-check ka20: {} requests, {} colds; snapshot {} restores, \
         repeat-TTFT {:.1} ms vs tiered {:.1} ms, surcharge ${:.6}; \
         pipelined {} loads, first-TTFT {:.1} ms vs tiered {:.1} ms",
        tiered.requests,
        tiered.cold,
        snap.restores,
        snap.repeat_ttft_s * 1000.0,
        tiered.repeat_ttft_s * 1000.0,
        snap.snapshot_usd,
        pipe.pipelined,
        pipe.first_ttft_s * 1000.0,
        tiered.first_ttft_s * 1000.0,
    );
    if snap.restores == 0 {
        return Err(format!(
            "{line}\n  FAIL: no snapshot restores at a 20 s keep-alive — \
             the build/restore chain is not engaged"
        ));
    }
    if snap.repeat_ttft_s > tiered.repeat_ttft_s {
        return Err(format!(
            "{line}\n  FAIL: snapshot-restore repeat colds slower than tiered \
             ({:.1} ms vs {:.1} ms)",
            snap.repeat_ttft_s * 1000.0,
            tiered.repeat_ttft_s * 1000.0
        ));
    }
    if snap.snapshot_usd <= 0.0 {
        return Err(format!(
            "{line}\n  FAIL: restores fired but the storage surcharge is zero"
        ));
    }
    if pipe.pipelined == 0 {
        return Err(format!(
            "{line}\n  FAIL: no pipelined loads with idle sibling nodes — \
             the K-way split is not engaged"
        ));
    }
    Ok(line)
}

/// CI regression guard (`serverless-lora fleet --check`): run the quick
/// grid and compare the deterministic counters against `QUICK_BOUNDS`,
/// then bound the tiered-store counters on the `tiers` reference cell,
/// the recovery counters on a fast-failure `faults` cell, the
/// domain/degrade counters on the correlated-faults cell, and the
/// cold-start strategy invariants on the `coldstarts` reference cells.
pub fn check() -> Result<String, String> {
    let mut out = String::new();
    for b in QUICK_BOUNDS {
        let line = check_point(b, horizon(true))?;
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str(&check_tiers()?);
    out.push('\n');
    out.push_str(&check_faults()?);
    out.push('\n');
    out.push_str(&check_correlated()?);
    out.push('\n');
    out.push_str(&check_coldstarts()?);
    out.push('\n');
    out.push_str("fleet-check: all counters within committed bounds\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_point_conserves_and_measures() {
        let p = run_point(8, 16, 120.0, 3, None, None, 1);
        assert_eq!(p.completed, p.requests, "lost requests");
        assert!(p.requests > 0);
        assert!(p.events >= p.requests as u64, "every request is ≥1 event");
        assert!(p.peak_queue > 0);
        assert!(p.events_per_s > 0.0);
        // Billing telemetry rides every point: O(1) samples per event,
        // wall-clock metered (run_point turns timing on).
        assert!(p.bill_samples > 0);
        assert!(p.bill_samples <= p.events + 1, "billing not O(1)/event");
        assert!(p.bill_reclass > 0);
        assert!(p.bill_sample_wall_s > 0.0);
        assert!(p.bill_reclass_wall_s > 0.0);
    }

    #[test]
    fn skewed_point_conserves_and_cancels() {
        let p = run_point(8, 16, 300.0, 3, Some(1.2), None, 1);
        assert_eq!(p.completed, p.requests, "lost requests");
        assert!(p.requests > 0);
        assert!(
            p.events_cancelled > 0,
            "supersession should cancel events under real traffic"
        );
    }

    #[test]
    fn cov_classed_point_conserves() {
        let p = run_point(
            8,
            16,
            300.0,
            3,
            Some(1.2),
            Some((Pattern::Bursty, Pattern::Predictable)),
            1,
        );
        assert_eq!(p.completed, p.requests, "lost requests");
        assert!(p.requests > 0);
        assert!(p.bill_samples <= p.events + 1);
    }

    #[test]
    fn grid_grows_and_caps_match_modes() {
        let q = grid(true);
        let f = grid(false);
        assert!(q.len() < f.len());
        assert_eq!(f.last(), Some(&(4096, 65536)), "λScale-regime cap");
        for w in f.windows(2) {
            assert!(w[1].0 > w[0].0 && w[1].1 > w[0].1);
        }
    }

    #[test]
    fn cluster_shape_has_requested_gpus() {
        // Exact counts, including non-multiples of the 8-per-node shape.
        for gpus in [1, 3, 8, 16, 20, 32, 64, 100, 128, 256] {
            assert_eq!(cluster_of(gpus).n_gpus(), gpus, "gpus={gpus}");
        }
    }

    #[test]
    fn bounds_cover_the_quick_grid() {
        let g = grid(true);
        assert_eq!(g.len(), QUICK_BOUNDS.len());
        for (point, b) in g.iter().zip(QUICK_BOUNDS) {
            assert_eq!(*point, (b.gpus, b.fns), "bounds out of sync with the grid");
            assert_eq!(b.max_peak_queue, 2 * b.fns + 64 * b.gpus + 16);
            // One aggregate sample per event is structural; only a
            // per-GPU regression could breach it.
            assert!(b.max_bill_samples_per_event < 1.5);
            assert!(b.max_bill_reclass_per_event >= 4.0);
            assert!(b.min_events_per_s_per_core > 0.0);
        }
    }

    #[test]
    fn check_point_passes_at_small_scale() {
        // A miniature bound with the same structural envelope: the guard
        // itself must pass on a healthy engine.
        let b = FleetBound {
            gpus: 8,
            fns: 16,
            max_events_per_request: 16.0,
            max_peak_queue: 2 * 16 + 64 * 8 + 16,
            max_bill_samples_per_event: 1.01,
            max_bill_reclass_per_event: 12.0,
            // Debug builds are ~50× slower than release; keep the
            // in-test floor nominal so only the plumbing is exercised.
            min_events_per_s_per_core: 10.0,
        };
        let line = check_point(&b, 120.0).expect("healthy engine trips the guard");
        assert!(line.contains("events/request"));
        assert!(line.contains("bill samples/event"));
        assert!(line.contains("events/s/core"));
    }

    #[test]
    fn tiers_leg_of_the_guard_passes() {
        // The tiered-store bounds must hold on a healthy engine: loads
        // resolved, conservation intact, contention engaged but bounded.
        let line = check_tiers().expect("healthy tiered engine trips the guard");
        assert!(line.contains("retimes/cold-load"));
        assert!(line.contains("cold loads"));
    }

    #[test]
    fn faults_leg_of_the_guard_passes() {
        // The recovery bounds must hold on a healthy engine: crashes
        // fired and repaired, victims re-dispatched, retries bounded.
        let line = check_faults().expect("healthy faulty engine trips the guard");
        assert!(line.contains("retries/load-failure"));
        assert!(line.contains("redispatched"));
    }

    #[test]
    fn correlated_leg_of_the_guard_passes() {
        // The domain/degrade bounds must hold on a healthy engine: node
        // and zone chains fired and drained, degrade re-timed work, SLO
        // attainment a hit-rate.
        let line = check_correlated().expect("healthy correlated-faults engine trips the guard");
        assert!(line.contains("out/rep"));
        assert!(line.contains("SLO-att"));
    }

    #[test]
    fn coldstarts_leg_of_the_guard_passes() {
        // The cold-start strategy invariants must hold on a healthy
        // engine: restores fired and beat tiered repeat colds, first
        // touches pipelined, surcharge visible.
        let line = check_coldstarts().expect("healthy cold-start engine trips the guard");
        assert!(line.contains("restores"));
        assert!(line.contains("pipelined"));
    }

    #[test]
    fn sharded_point_conserves_and_records_zones() {
        // 16 GPUs over 2 zones: 2 nodes → 1 node/zone, trim 8 GPUs each.
        let p = run_point(16, 32, 120.0, 3, None, None, 2);
        assert_eq!(p.completed, p.requests, "sharded run lost requests");
        assert_eq!((p.zones, p.threads), (2, 2));
        assert!(p.requests > 0);
        assert!(p.events_per_s_per_core > 0.0);
        assert!(
            (p.events_per_s_per_core - p.events_per_s / 2.0).abs() < 1e-9,
            "per-core throughput must divide by the thread count"
        );
    }

    #[test]
    fn fleet_scale_indexes_match_bruteforce_mid_run_multi_seed() {
        // The arena/SoA hot state (dense busy/loading/exec/billing
        // arrays, the two-key warm-pair index) must agree with its
        // brute-force recomputation *mid-run* at four-digit GPU counts,
        // not just on the toy clusters of the engine unit tests.
        use crate::sim::{workloads, Engine, SystemConfig};
        for seed in [3u64, 17] {
            let w = workloads::fleet_workload(2048, 120.0, seed);
            let n = w.requests.len();
            assert!(n > 500, "fleet workload too small to stress the arenas: {n}");
            let mut e =
                Engine::new(SystemConfig::serverless_lora(), cluster_of(1024), w, seed);
            let mut steps: u64 = 0;
            while e.step() {
                steps += 1;
                // Sparse: the brute-force check is O(GPUs·residents).
                if steps % 4096 == 0 {
                    e.check_indexes();
                }
            }
            e.check_indexes();
            let (m, _, _) = e.finish();
            assert_eq!(m.outcomes.len(), n, "seed {seed} lost requests");
        }
    }
}
