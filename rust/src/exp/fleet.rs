//! Fleet-scale engine benchmark: not a paper artifact but the
//! engine-health experiment behind the ROADMAP north star ("heavy
//! traffic, as fast as the hardware allows"). Sweeps cluster size and
//! function count (8→256 GPUs, 64→4096 functions in full mode) and
//! reports wall-clock, events processed per second, and peak
//! event-queue length, so the dispatch-index / event-hygiene work is
//! tracked across PRs via `BENCH_sim.json`.

use std::sync::Mutex;
use std::time::Instant;

use crate::cluster::Cluster;
use crate::sim::workloads::fleet_workload;
use crate::sim::{Engine, SystemConfig};
use crate::util::json::{num, obj, Json};
use crate::util::table::Table;

/// Largest point measured by the most recent `fleet()` sweep, so
/// `fleet_json` (the BENCH_sim.json record) reuses it instead of
/// re-simulating the single most expensive configuration.
static LAST_LARGEST: Mutex<Option<FleetPoint>> = Mutex::new(None);

/// One measured grid point.
#[derive(Clone)]
pub struct FleetPoint {
    pub gpus: usize,
    pub fns: usize,
    pub requests: usize,
    pub completed: usize,
    pub wall_s: f64,
    pub events: u64,
    pub events_per_s: f64,
    pub peak_queue: usize,
    pub keepalive_checks: u64,
    pub stale_queue_checks: u64,
}

/// The (GPUs, functions) sweep. Quick mode stays CI-sized; full mode
/// climbs to the λScale-style fleet regime.
pub fn grid(quick: bool) -> Vec<(usize, usize)> {
    if quick {
        vec![(8, 64), (16, 256), (32, 1024)]
    } else {
        vec![(8, 64), (16, 256), (32, 1024), (64, 2048), (128, 3072), (256, 4096)]
    }
}

fn horizon(quick: bool) -> f64 {
    if quick {
        600.0
    } else {
        1800.0
    }
}

/// Fleet clusters follow the paper's node shape: 8 GPUs per node with
/// two warm container slots per GPU, trimming the last node so the
/// cluster has exactly the requested GPU count.
fn cluster_of(gpus: usize) -> Cluster {
    let nodes = gpus.div_ceil(8).max(1);
    let mut c = Cluster::new(nodes, 8, 16);
    while c.n_gpus() > gpus.max(1) {
        let last = c.nodes.last_mut().expect("at least one node");
        last.gpus.pop();
    }
    c
}

/// Run the flagship system at one grid point and measure the engine.
pub fn run_point(gpus: usize, fns: usize, duration_s: f64, seed: u64) -> FleetPoint {
    let w = fleet_workload(fns, duration_s, seed);
    let requests = w.requests.len();
    let t0 = Instant::now();
    let engine = Engine::new(SystemConfig::serverless_lora(), cluster_of(gpus), w, seed);
    let (m, _, stats) = engine.run();
    let wall_s = t0.elapsed().as_secs_f64();
    FleetPoint {
        gpus,
        fns,
        requests,
        completed: m.outcomes.len(),
        wall_s,
        events: stats.events_processed,
        events_per_s: stats.events_processed as f64 / wall_s.max(1e-9),
        peak_queue: stats.peak_event_queue,
        keepalive_checks: stats.keepalive_checks,
        stale_queue_checks: stats.stale_queue_checks,
    }
}

/// The rendered sweep (experiment id `fleet`). The table shows only
/// deterministic engine counters so the report digest in
/// `BENCH_sim.json` stays stable run-to-run; wall-clock and events/sec
/// (nondeterministic by nature) are recorded by `fleet_json` and the
/// bench harness's per-experiment `wall_s`.
pub fn fleet(quick: bool) -> String {
    let dur = horizon(quick);
    let cols = [
        "GPUs",
        "fns",
        "requests",
        "events",
        "peak queue",
        "KA checks",
        "stale QC",
    ];
    let mut t = Table::new("Fleet — engine scaling sweep (ServerlessLoRA flagship)", &cols);
    let points = grid(quick);
    let largest = *points.last().expect("grid non-empty");
    for (gpus, fns) in points {
        let p = run_point(gpus, fns, dur, 11);
        assert_eq!(p.completed, p.requests, "fleet run lost requests");
        if (gpus, fns) == largest {
            *LAST_LARGEST.lock().unwrap() = Some(p.clone());
        }
        t.row(vec![
            p.gpus.to_string(),
            p.fns.to_string(),
            p.requests.to_string(),
            p.events.to_string(),
            p.peak_queue.to_string(),
            p.keepalive_checks.to_string(),
            p.stale_queue_checks.to_string(),
        ]);
    }
    t.render()
}

/// Machine-readable record of the sweep's largest configuration, for
/// cross-PR tracking in `BENCH_sim.json`. Reuses the measurement from a
/// `fleet()` sweep in this process when one ran (the bench harness runs
/// the experiment first), re-simulating only if it did not.
pub fn fleet_json(quick: bool) -> Json {
    let &(gpus, fns) = grid(quick).last().expect("grid non-empty");
    let cached = LAST_LARGEST.lock().unwrap().clone();
    let p = match cached {
        Some(p) if (p.gpus, p.fns) == (gpus, fns) => p,
        _ => run_point(gpus, fns, horizon(quick), 11),
    };
    obj(vec![
        ("gpus", num(p.gpus as f64)),
        ("fns", num(p.fns as f64)),
        ("requests", num(p.requests as f64)),
        ("completed", num(p.completed as f64)),
        ("wall_s", num(p.wall_s)),
        ("events", num(p.events as f64)),
        ("events_per_s", num(p.events_per_s)),
        ("peak_event_queue", num(p.peak_queue as f64)),
        ("keepalive_checks", num(p.keepalive_checks as f64)),
        ("stale_queue_checks", num(p.stale_queue_checks as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_point_conserves_and_measures() {
        let p = run_point(8, 16, 120.0, 3);
        assert_eq!(p.completed, p.requests, "lost requests");
        assert!(p.requests > 0);
        assert!(p.events >= p.requests as u64, "every request is ≥1 event");
        assert!(p.peak_queue > 0);
        assert!(p.events_per_s > 0.0);
    }

    #[test]
    fn grid_grows_and_caps_match_modes() {
        let q = grid(true);
        let f = grid(false);
        assert!(q.len() < f.len());
        assert_eq!(f.last(), Some(&(256, 4096)));
        for w in f.windows(2) {
            assert!(w[1].0 > w[0].0 && w[1].1 > w[0].1);
        }
    }

    #[test]
    fn cluster_shape_has_requested_gpus() {
        // Exact counts, including non-multiples of the 8-per-node shape.
        for gpus in [1, 3, 8, 16, 20, 32, 64, 100, 128, 256] {
            assert_eq!(cluster_of(gpus).n_gpus(), gpus, "gpus={gpus}");
        }
    }
}
