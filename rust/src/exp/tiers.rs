//! Tiered-store experiment (`tiers`): sweep host-cache capacity ×
//! arrival burstiness and report how the dynamic memory hierarchy
//! resolves cold backbone loads — RAM/NVMe/remote hit mix, cache
//! evictions, fair-share link re-timings, and the resulting TTFT.
//!
//! The sweep runs the no-preload baseline (`npl`): with nothing staged
//! ahead of time every cold start exercises the hierarchy, so the cache
//! policy and link contention — not the preload planner — dominate the
//! numbers. Burstier arrivals pile concurrent cold loads onto the same
//! NVMe/PCIe links (visible as `retimes`), and larger host caches turn
//! repeat cold starts into RAM hits; the table shows both effects in
//! one grid. All reported columns are deterministic for a fixed seed,
//! so the report digest in `BENCH_sim.json` stays stable run-to-run.

use std::sync::Mutex;

use crate::scenario::{ClusterSpec, ScenarioSpec, WorkloadSpec};
use crate::sim::TierSpec;
use crate::trace::Pattern;
use crate::util::json::{num, obj, Json};
use crate::util::table::{ms, Table};

/// Most recent measurement of the reference cell (default cache,
/// bursty arrivals), reused by `tiers_json` (the BENCH_sim.json
/// record) when the sweep already ran in this process.
static LAST_REFERENCE: Mutex<Option<TierPoint>> = Mutex::new(None);

/// One measured grid cell.
#[derive(Clone)]
pub struct TierPoint {
    pub cache_gb: f64,
    pub pattern: Pattern,
    pub requests: usize,
    pub ttft_mean_s: f64,
    pub ttft_p99_s: f64,
    pub cold_loads: u64,
    pub hits_ram: u64,
    pub hits_ssd: u64,
    pub hits_remote: u64,
    pub evictions: u64,
    pub retimes: u64,
}

/// Host-cache capacities swept (GB). 0 keeps contention modelling with
/// no cache tier — the hierarchy's floor.
pub fn cache_sizes(quick: bool) -> Vec<f64> {
    if quick {
        vec![0.0, 16.0, 64.0]
    } else {
        vec![0.0, 16.0, 64.0, 128.0]
    }
}

/// Arrival burstiness classes swept (the paper's CoV bands).
pub fn patterns(quick: bool) -> Vec<Pattern> {
    if quick {
        vec![Pattern::Predictable, Pattern::Bursty]
    } else {
        vec![Pattern::Predictable, Pattern::Normal, Pattern::Bursty]
    }
}

fn horizon(quick: bool) -> f64 {
    if quick {
        600.0
    } else {
        1800.0
    }
}

/// Build one grid cell: no-preload system with the tiered store at the
/// given cache capacity, a one-node cluster (all cold loads share one
/// node's links — contention is the point), paper workload at the given
/// burstiness.
fn cell(cache_gb: f64, pattern: Pattern, horizon_s: f64, seed: u64) -> ScenarioSpec {
    ScenarioSpec::builder(&format!("tiers-{cache_gb}gb-{}", pattern.name()))
        .system("npl")
        .tiers(TierSpec { host_cache_gb: cache_gb, ..TierSpec::default() })
        .cluster(ClusterSpec::Uniform {
            nodes: 1,
            gpus_per_node: 4,
            containers_per_node: 8,
            trim_gpus: None,
            zones: 1,
        })
        .workload(WorkloadSpec::Paper { pattern, seed })
        .horizon_s(horizon_s)
        .seed(seed)
        .build()
        .expect("tiers cell validates")
}

/// Run one cell and fold its run into a [`TierPoint`].
pub fn run_point(cache_gb: f64, pattern: Pattern, horizon_s: f64, seed: u64) -> TierPoint {
    let spec = cell(cache_gb, pattern, horizon_s, seed);
    let report = crate::scenario::run(&spec).expect("tiers cell runs");
    let (_, run) = report.into_only();
    assert_eq!(
        run.metrics.outcomes.len(),
        run.requests,
        "tiers cell lost requests"
    );
    let st = &run.stats;
    assert_eq!(
        st.tier_hits_ram + st.tier_hits_ssd + st.tier_hits_remote,
        st.tiered_cold_loads,
        "tier-hit conservation violated"
    );
    TierPoint {
        cache_gb,
        pattern,
        requests: run.requests,
        ttft_mean_s: run.metrics.ttft().mean,
        ttft_p99_s: run.metrics.ttft().p99,
        cold_loads: st.tiered_cold_loads,
        hits_ram: st.tier_hits_ram,
        hits_ssd: st.tier_hits_ssd,
        hits_remote: st.tier_hits_remote,
        evictions: st.cache_evictions,
        retimes: st.load_retimes,
    }
}

/// The rendered sweep (experiment id `tiers`).
pub fn tiers(quick: bool) -> String {
    let mut t = Table::new(
        "Tiered store — cache capacity × burstiness sweep (no-preload baseline)",
        &[
            "cache GB",
            "pattern",
            "requests",
            "TTFT(ms)",
            "TTFT-p99(ms)",
            "cold loads",
            "ram",
            "ssd",
            "remote",
            "evictions",
            "retimes",
        ],
    );
    let dur = horizon(quick);
    for cache_gb in cache_sizes(quick) {
        for pattern in patterns(quick) {
            let p = run_point(cache_gb, pattern, dur, 11);
            if cache_gb == TierSpec::default().host_cache_gb && pattern == Pattern::Bursty {
                *LAST_REFERENCE.lock().unwrap() = Some(p.clone());
            }
            t.row(vec![
                format!("{cache_gb}"),
                pattern.name().to_string(),
                p.requests.to_string(),
                ms(p.ttft_mean_s),
                ms(p.ttft_p99_s),
                p.cold_loads.to_string(),
                p.hits_ram.to_string(),
                p.hits_ssd.to_string(),
                p.hits_remote.to_string(),
                p.evictions.to_string(),
                p.retimes.to_string(),
            ]);
        }
    }
    t.render()
}

/// Machine-readable record of the reference cell (default 64 GB cache,
/// bursty arrivals) for cross-PR tracking in `BENCH_sim.json`: the tier
/// hit mix and re-time counts. Reuses the sweep's measurement when a
/// `tiers()` run in this process covered the cell.
pub fn tiers_json(quick: bool) -> Json {
    let cached = LAST_REFERENCE.lock().unwrap().clone();
    let p = match cached {
        Some(p) => p,
        None => run_point(
            TierSpec::default().host_cache_gb,
            Pattern::Bursty,
            horizon(quick),
            11,
        ),
    };
    obj(vec![
        ("cache_gb", num(p.cache_gb)),
        ("requests", num(p.requests as f64)),
        ("ttft_ms", num(p.ttft_mean_s * 1000.0)),
        ("ttft_p99_ms", num(p.ttft_p99_s * 1000.0)),
        ("tiered_cold_loads", num(p.cold_loads as f64)),
        ("tier_hits_ram", num(p.hits_ram as f64)),
        ("tier_hits_ssd", num(p.hits_ssd as f64)),
        ("tier_hits_remote", num(p.hits_remote as f64)),
        (
            "ram_hit_rate",
            num(p.hits_ram as f64 / (p.cold_loads as f64).max(1.0)),
        ),
        ("cache_evictions", num(p.evictions as f64)),
        ("load_retimes", num(p.retimes as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_grow_with_full_mode() {
        assert!(cache_sizes(true).len() < cache_sizes(false).len());
        assert!(patterns(true).len() < patterns(false).len());
        assert_eq!(cache_sizes(true)[0], 0.0, "the no-cache floor stays in CI");
    }

    #[test]
    fn point_resolves_loads_and_conserves() {
        // Short horizon, smallest cache: the conservation asserts inside
        // run_point are the test; beyond them, the hierarchy must have
        // actually been exercised.
        let p = run_point(0.0, Pattern::Bursty, 300.0, 11);
        assert!(p.requests > 0);
        assert!(p.cold_loads > 0, "no-preload run must cold-load");
        assert_eq!(p.hits_ram + p.hits_ssd + p.hits_remote, p.cold_loads);
        assert_eq!(p.hits_ram, 0, "0 GB cache cannot produce RAM hits");
        assert_eq!(p.evictions, 0, "0 GB cache cannot evict");
    }

    #[test]
    fn cache_capacity_creates_ram_hits() {
        let cold = run_point(0.0, Pattern::Bursty, 600.0, 11);
        let cached = run_point(64.0, Pattern::Bursty, 600.0, 11);
        assert_eq!(cold.hits_ram, 0);
        assert!(
            cached.hits_ram > 0,
            "a 64 GB cache must convert repeat cold loads into RAM hits"
        );
        assert!(
            cached.ttft_mean_s <= cold.ttft_mean_s,
            "RAM hits cannot make mean TTFT worse: {} vs {}",
            cached.ttft_mean_s,
            cold.ttft_mean_s
        );
    }

    #[test]
    fn json_record_names_the_tracked_counters() {
        let j = tiers_json(true);
        for key in [
            "ram_hit_rate",
            "tier_hits_ram",
            "tier_hits_ssd",
            "tier_hits_remote",
            "load_retimes",
            "cache_evictions",
        ] {
            assert!(j.get(key).is_some(), "BENCH record missing '{key}'");
        }
    }
}
