//! Fig. 5 — the three arrival patterns: verify the generator lands each
//! pattern in its CoV band and report the burstiness profile.
//!
//! (Generator statistics only — no engine runs, so this experiment has
//! no `ScenarioSpec` form; see `exp` module docs.)

use crate::trace::{stream_cov, Pattern, TraceSpec};
use crate::util::table::{f, Table};

pub fn fig5(quick: bool) -> String {
    let dur = if quick { 3600.0 } else { 4.0 * 3600.0 };
    let mut t = Table::new(
        "Fig 5 — Arrival patterns by inter-arrival CoV",
        &["pattern", "band", "measured CoV", "requests", "peak/valley (per-min)"],
    );
    for p in Pattern::ALL {
        let reqs = TraceSpec::new(0, p, 1.0 / 30.0, 42).generate(dur);
        let cov = stream_cov(&reqs);
        let (lo, hi) = p.cov_band();
        // Per-minute counts for the peak/valley ratio (the Azure LLM
        // trace shows up to 34.6×).
        let mut counts = vec![0usize; (dur / 60.0).ceil() as usize];
        for r in &reqs {
            counts[(r.arrival_s / 60.0) as usize] += 1;
        }
        let peak = *counts.iter().max().unwrap() as f64;
        let valley = counts
            .iter()
            .filter(|&&c| c > 0)
            .min()
            .copied()
            .unwrap_or(1) as f64;
        t.row(vec![
            p.name().into(),
            if hi.is_finite() {
                format!("({lo}, {hi}]")
            } else {
                format!("> {lo}")
            },
            f(cov),
            reqs.len().to_string(),
            f(peak / valley),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_includes_all_patterns() {
        let r = fig5(true);
        for p in Pattern::ALL {
            assert!(r.contains(p.name()), "{r}");
        }
    }

    #[test]
    fn bursty_has_big_peak_valley_ratio() {
        let reqs = TraceSpec::new(0, Pattern::Bursty, 1.0 / 30.0, 42)
            .generate(4.0 * 3600.0);
        let mut counts = vec![0usize; 240];
        for r in &reqs {
            counts[(r.arrival_s / 60.0) as usize] += 1;
        }
        let peak = *counts.iter().max().unwrap() as f64;
        let mean = reqs.len() as f64 / 240.0;
        assert!(peak / mean > 4.0, "peak {peak} vs mean {mean}");
    }
}
