//! Fault-injection experiment (`faults`): sweep GPU failure rate (MTBF)
//! × repair time (MTTR) and report goodput, permanent failures, and the
//! TTFT degradation against a fault-free reference run of the same
//! workload.
//!
//! Each grid cell runs the flagship system with the deterministic fault
//! injector enabled (`sim::fault`) over several engine seeds; the
//! multi-seed runs are collapsed to mean ± 95% CI via
//! `scenario::summarize`, so the table shows how tight the fault model's
//! effect is across seeds, not just a single draw. Crashes kill in-flight
//! batches (their requests re-dispatch), transient load failures burn
//! bounded-backoff retries, and requests that exhaust the retry budget
//! or their deadline fail permanently — goodput is the fraction that
//! still completed.

use std::sync::Mutex;

use crate::scenario::{self, ClusterSpec, MetricSummary, ScenarioSpec, WorkloadSpec};
use crate::sim::{FaultSpec, RetrySpec};
use crate::trace::Pattern;
use crate::util::json::{num, obj, Json};
use crate::util::table::Table;

/// Most recent measurement of the reference cell (fastest failure rate,
/// slowest repair), reused by `faults_json` (the BENCH_sim.json record)
/// when the sweep already ran in this process.
static LAST_REFERENCE: Mutex<Option<FaultPoint>> = Mutex::new(None);

/// One measured grid cell: a multi-seed summary plus the fault-path
/// counters summed across seeds.
#[derive(Clone)]
pub struct FaultPoint {
    pub mtbf_s: f64,
    pub mttr_s: f64,
    pub requests: usize,
    pub goodput: MetricSummary,
    pub failed: MetricSummary,
    pub ttft_ms: MetricSummary,
    /// Fault-free reference TTFT (same workload/cluster/seeds).
    pub ttft_ref_ms: MetricSummary,
    pub crashes: u64,
    pub recoveries: u64,
    pub redispatched: u64,
    pub load_failures: u64,
    pub retries: u64,
}

impl FaultPoint {
    /// Mean TTFT degradation factor vs the fault-free reference.
    pub fn ttft_degradation(&self) -> f64 {
        self.ttft_ms.mean / self.ttft_ref_ms.mean.max(1e-12)
    }
}

/// Mean-time-between-failures values swept (seconds per GPU).
pub fn mtbfs(quick: bool) -> Vec<f64> {
    if quick {
        vec![300.0, 1200.0]
    } else {
        vec![150.0, 300.0, 1200.0]
    }
}

/// Mean-time-to-repair values swept (seconds).
pub fn mttrs(quick: bool) -> Vec<f64> {
    if quick {
        vec![15.0, 60.0]
    } else {
        vec![15.0, 60.0, 180.0]
    }
}

fn seeds(quick: bool) -> Vec<u64> {
    if quick {
        vec![1, 7, 23]
    } else {
        vec![1, 7, 23, 42, 101]
    }
}

fn horizon(quick: bool) -> f64 {
    if quick {
        600.0
    } else {
        1800.0
    }
}

/// The sweep's fault shape at one (MTBF, MTTR) point: a 5% transient
/// load-failure rate rides along so the retry/backoff path is exercised
/// in every cell, with the default retry policy.
pub fn fault_spec(mtbf_s: f64, mttr_s: f64) -> FaultSpec {
    FaultSpec { mtbf_s, mttr_s, load_fail_prob: 0.05, retry: RetrySpec::default() }
}

/// Build one grid cell. Multi-node so a whole-node invalidation never
/// takes the only GPU; multi-seed so the summary carries a CI.
fn cell(faults: Option<FaultSpec>, name: &str, quick: bool) -> ScenarioSpec {
    let mut b = ScenarioSpec::builder(name)
        .cluster(ClusterSpec::Uniform {
            nodes: 2,
            gpus_per_node: 2,
            containers_per_node: 4,
            trim_gpus: None,
            zones: 1,
        })
        .workload(WorkloadSpec::Paper { pattern: Pattern::Bursty, seed: 11 })
        .horizon_s(horizon(quick))
        .seeds(seeds(quick));
    if let Some(f) = faults {
        b = b.faults(f);
    }
    b.build().expect("faults cell validates")
}

/// Run one (MTBF, MTTR) cell plus its fault-free reference and fold
/// both into a [`FaultPoint`]. Conservation is asserted per seed:
/// every offered request either completed or failed by the end.
pub fn run_point(mtbf_s: f64, mttr_s: f64, quick: bool) -> FaultPoint {
    let name = format!("faults-mtbf{mtbf_s}-mttr{mttr_s}");
    let faulty = scenario::run(&cell(Some(fault_spec(mtbf_s, mttr_s)), &name, quick))
        .expect("faults cell runs");
    let reference =
        scenario::run(&cell(None, "faults-reference", quick)).expect("reference cell runs");
    for run in &faulty.runs {
        assert_eq!(
            run.metrics.outcomes.len() + run.metrics.failed as usize,
            run.requests,
            "seed {}: requests must be conserved under faults",
            run.seed
        );
    }
    let sum = scenario::summarize(&faulty);
    let ref_sum = scenario::summarize(&reference);
    let tally = |f: fn(&crate::metrics::RunStats) -> u64| {
        faulty.runs.iter().map(|r| f(&r.stats)).sum::<u64>()
    };
    FaultPoint {
        mtbf_s,
        mttr_s,
        requests: sum.requests,
        goodput: sum.goodput,
        failed: sum.failed,
        ttft_ms: sum.ttft_ms,
        ttft_ref_ms: ref_sum.ttft_ms,
        crashes: tally(|s| s.gpu_crashes),
        recoveries: tally(|s| s.gpu_recoveries),
        redispatched: tally(|s| s.redispatched),
        load_failures: tally(|s| s.load_failures),
        retries: tally(|s| s.retries),
    }
}

/// The rendered sweep (experiment id `faults`).
pub fn faults(quick: bool) -> String {
    let mut t = Table::new(
        "Fault injection — MTBF × MTTR sweep (mean ± 95% CI across seeds)",
        &[
            "MTBF(s)",
            "MTTR(s)",
            "requests",
            "goodput",
            "failed",
            "TTFT(ms)",
            "TTFT ×ref",
            "crashes",
            "redisp",
            "load fails",
            "retries",
        ],
    );
    let mut reference: Option<FaultPoint> = None;
    for mtbf_s in mtbfs(quick) {
        for mttr_s in mttrs(quick) {
            let p = run_point(mtbf_s, mttr_s, quick);
            if reference.is_none() {
                // Fastest failure rate × fastest repair: first cell.
                reference = Some(p.clone());
            }
            t.row(vec![
                format!("{mtbf_s}"),
                format!("{mttr_s}"),
                p.requests.to_string(),
                p.goodput.cell(3),
                p.failed.cell(1),
                p.ttft_ms.cell(1),
                format!("{:.2}x", p.ttft_degradation()),
                p.crashes.to_string(),
                p.redispatched.to_string(),
                p.load_failures.to_string(),
                p.retries.to_string(),
            ]);
        }
    }
    *LAST_REFERENCE.lock().unwrap() = reference;
    t.render()
}

/// Machine-readable record of the reference cell (fastest swept failure
/// rate, fastest repair) for cross-PR tracking in `BENCH_sim.json`.
/// Reuses the sweep's measurement when a `faults()` run in this process
/// covered the cell.
pub fn faults_json(quick: bool) -> Json {
    let cached = LAST_REFERENCE.lock().unwrap().clone();
    let p = match cached {
        Some(p) => p,
        None => run_point(mtbfs(quick)[0], mttrs(quick)[0], quick),
    };
    obj(vec![
        ("mtbf_s", num(p.mtbf_s)),
        ("mttr_s", num(p.mttr_s)),
        ("requests", num(p.requests as f64)),
        ("goodput", num(p.goodput.mean)),
        ("failed_mean", num(p.failed.mean)),
        ("ttft_ms", num(p.ttft_ms.mean)),
        ("ttft_degradation", num(p.ttft_degradation())),
        ("gpu_crashes", num(p.crashes as f64)),
        ("gpu_recoveries", num(p.recoveries as f64)),
        ("redispatched", num(p.redispatched as f64)),
        ("load_failures", num(p.load_failures as f64)),
        ("retries", num(p.retries as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_grow_with_full_mode() {
        assert!(mtbfs(true).len() < mtbfs(false).len());
        assert!(mttrs(true).len() < mttrs(false).len());
        assert!(seeds(true).len() >= 3, "CIs need at least three seeds");
    }

    #[test]
    fn point_injects_faults_and_conserves() {
        // The conservation asserts inside run_point are the test; beyond
        // them, the fault machinery must have actually fired.
        let p = run_point(150.0, 30.0, true);
        assert!(p.requests > 0);
        assert!(p.crashes > 0, "a 150 s MTBF over 600 s must crash");
        assert_eq!(p.crashes, p.recoveries, "every crash must repair before the horizon drains");
        assert!(p.load_failures > 0, "5% load-failure rate must fire");
        assert!(p.retries > 0, "transient failures must be retried");
        assert!(
            p.goodput.mean > 0.0 && p.goodput.mean <= 1.0,
            "goodput {} out of range",
            p.goodput.mean
        );
        assert!(
            p.ttft_degradation() >= 0.95,
            "faults cannot meaningfully improve TTFT: {:.3}x",
            p.ttft_degradation()
        );
    }

    #[test]
    fn json_record_names_the_tracked_counters() {
        let j = faults_json(true);
        for key in [
            "goodput",
            "ttft_degradation",
            "gpu_crashes",
            "redispatched",
            "load_failures",
            "retries",
        ] {
            assert!(j.get(key).is_some(), "BENCH record missing '{key}'");
        }
    }
}
