//! Fault-injection experiment (`faults`): sweep GPU failure rate (MTBF)
//! × repair time (MTTR) and report goodput, permanent failures, and the
//! TTFT degradation against a fault-free reference run of the same
//! workload.
//!
//! Each grid cell runs the flagship system with the deterministic fault
//! injector enabled (`sim::fault`) over several engine seeds; the
//! multi-seed runs are collapsed to mean ± 95% CI via
//! `scenario::summarize`, so the table shows how tight the fault model's
//! effect is across seeds, not just a single draw. Crashes kill in-flight
//! batches (their requests re-dispatch), transient load failures burn
//! bounded-backoff retries, and requests that exhaust the retry budget
//! or their deadline fail permanently — goodput is the fraction that
//! still completed.
//!
//! A companion table runs the correlated-domains cell (node + zone
//! outages plus degrade episodes, `sim::fault::DomainSpec`/
//! `DegradeSpec`) once per routing mode — failure-blind vs
//! failure-aware — and reports SLO attainment (per-function deadline
//! hit-rate, failures counted as misses) next to goodput throughout.

use std::sync::Mutex;

use crate::scenario::{self, ClusterSpec, MetricSummary, ScenarioSpec, WorkloadSpec};
use crate::sim::{DegradeSpec, DomainLevel, DomainSpec, FaultSpec};
use crate::trace::Pattern;
use crate::util::json::{num, obj, Json};
use crate::util::table::Table;

/// Most recent measurement of the reference cell (fastest failure rate,
/// slowest repair), reused by `faults_json` (the BENCH_sim.json record)
/// when the sweep already ran in this process.
static LAST_REFERENCE: Mutex<Option<FaultPoint>> = Mutex::new(None);

/// Most recent correlated-domains measurements (failure-blind,
/// failure-aware), cached the same way for `faults_json`.
static LAST_CORRELATED: Mutex<Option<(CorrelatedPoint, CorrelatedPoint)>> = Mutex::new(None);

/// One measured grid cell: a multi-seed summary plus the fault-path
/// counters summed across seeds.
#[derive(Clone)]
pub struct FaultPoint {
    pub mtbf_s: f64,
    pub mttr_s: f64,
    pub requests: usize,
    pub goodput: MetricSummary,
    /// Deadline hit-rate (TTFT ≤ the per-function SLO; failures miss).
    pub slo: MetricSummary,
    pub failed: MetricSummary,
    pub ttft_ms: MetricSummary,
    /// Fault-free reference TTFT (same workload/cluster/seeds).
    pub ttft_ref_ms: MetricSummary,
    pub crashes: u64,
    pub recoveries: u64,
    pub redispatched: u64,
    pub load_failures: u64,
    pub retries: u64,
}

impl FaultPoint {
    /// Mean TTFT degradation factor vs the fault-free reference.
    pub fn ttft_degradation(&self) -> f64 {
        self.ttft_ms.mean / self.ttft_ref_ms.mean.max(1e-12)
    }
}

/// One measured correlated-domains cell (node + zone outages + degrade
/// episodes) under one routing mode.
#[derive(Clone)]
pub struct CorrelatedPoint {
    pub failure_aware: bool,
    pub requests: usize,
    pub goodput: MetricSummary,
    pub slo: MetricSummary,
    pub failed: MetricSummary,
    pub ttft_ms: MetricSummary,
    pub node_outages: u64,
    pub node_repairs: u64,
    pub zone_outages: u64,
    pub zone_repairs: u64,
    pub degrades: u64,
    pub degrade_retimes: u64,
}

/// Mean-time-between-failures values swept (seconds per GPU).
pub fn mtbfs(quick: bool) -> Vec<f64> {
    if quick {
        vec![300.0, 1200.0]
    } else {
        vec![150.0, 300.0, 1200.0]
    }
}

/// Mean-time-to-repair values swept (seconds).
pub fn mttrs(quick: bool) -> Vec<f64> {
    if quick {
        vec![15.0, 60.0]
    } else {
        vec![15.0, 60.0, 180.0]
    }
}

fn seeds(quick: bool) -> Vec<u64> {
    if quick {
        vec![1, 7, 23]
    } else {
        vec![1, 7, 23, 42, 101]
    }
}

fn horizon(quick: bool) -> f64 {
    if quick {
        600.0
    } else {
        1800.0
    }
}

/// The sweep's fault shape at one (MTBF, MTTR) point: a 5% transient
/// load-failure rate rides along so the retry/backoff path is exercised
/// in every cell, with the default retry policy.
pub fn fault_spec(mtbf_s: f64, mttr_s: f64) -> FaultSpec {
    FaultSpec { mtbf_s, mttr_s, load_fail_prob: 0.05, ..FaultSpec::default() }
}

/// The correlated-faults cell: GPU crashes plus node/zone outages and
/// degrade episodes, all aggressive enough to fire within the quick
/// horizon, with routing either failure-blind (the historical scorer)
/// or failure-aware (crash-history EWMA penalty).
pub fn correlated_spec(failure_aware: bool) -> FaultSpec {
    FaultSpec {
        mtbf_s: 600.0,
        mttr_s: 30.0,
        load_fail_prob: 0.02,
        domains: Some(DomainSpec {
            node: Some(DomainLevel { mtbf_s: 450.0, mttr_s: 40.0 }),
            // Aggressive enough that the zone chain fires within even
            // the quick 600 s horizon on every swept seed set.
            zone: Some(DomainLevel { mtbf_s: 180.0, mttr_s: 40.0 }),
        }),
        degrade: Some(DegradeSpec {
            mtbf_s: 400.0,
            duration_s: 60.0,
            factor_min: 2.0,
            factor_max: 4.0,
        }),
        failure_aware,
        ..FaultSpec::default()
    }
}

/// Build one grid cell. Multi-node so a whole-node invalidation never
/// takes the only GPU; multi-seed so the summary carries a CI.
fn cell(faults: Option<FaultSpec>, name: &str, quick: bool) -> ScenarioSpec {
    let mut b = ScenarioSpec::builder(name)
        .cluster(ClusterSpec::Uniform {
            nodes: 2,
            gpus_per_node: 2,
            containers_per_node: 4,
            trim_gpus: None,
            zones: 1,
        })
        .workload(WorkloadSpec::Paper { pattern: Pattern::Bursty, seed: 11 })
        .horizon_s(horizon(quick))
        .seeds(seeds(quick));
    if let Some(f) = faults {
        b = b.faults(f);
    }
    b.build().expect("faults cell validates")
}

/// Run one (MTBF, MTTR) cell plus its fault-free reference and fold
/// both into a [`FaultPoint`]. Conservation is asserted per seed:
/// every offered request either completed or failed by the end.
pub fn run_point(mtbf_s: f64, mttr_s: f64, quick: bool) -> FaultPoint {
    let name = format!("faults-mtbf{mtbf_s}-mttr{mttr_s}");
    let faulty = scenario::run(&cell(Some(fault_spec(mtbf_s, mttr_s)), &name, quick))
        .expect("faults cell runs");
    let reference =
        scenario::run(&cell(None, "faults-reference", quick)).expect("reference cell runs");
    for run in &faulty.runs {
        assert_eq!(
            run.metrics.outcomes.len() + run.metrics.failed as usize,
            run.requests,
            "seed {}: requests must be conserved under faults",
            run.seed
        );
    }
    let sum = scenario::summarize(&faulty);
    let ref_sum = scenario::summarize(&reference);
    let tally = |f: fn(&crate::metrics::RunStats) -> u64| {
        faulty.runs.iter().map(|r| f(&r.stats)).sum::<u64>()
    };
    FaultPoint {
        mtbf_s,
        mttr_s,
        requests: sum.requests,
        goodput: sum.goodput,
        slo: sum.slo_attainment,
        failed: sum.failed,
        ttft_ms: sum.ttft_ms,
        ttft_ref_ms: ref_sum.ttft_ms,
        crashes: tally(|s| s.gpu_crashes),
        recoveries: tally(|s| s.gpu_recoveries),
        redispatched: tally(|s| s.redispatched),
        load_failures: tally(|s| s.load_failures),
        retries: tally(|s| s.retries),
    }
}

/// Run the correlated-domains cell under one routing mode and fold it
/// into a [`CorrelatedPoint`]. Conservation is asserted per seed.
pub fn run_correlated(failure_aware: bool, quick: bool) -> CorrelatedPoint {
    let name =
        if failure_aware { "faults-correlated-aware" } else { "faults-correlated-blind" };
    let report = scenario::run(&cell(Some(correlated_spec(failure_aware)), name, quick))
        .expect("correlated cell runs");
    for run in &report.runs {
        assert_eq!(
            run.metrics.outcomes.len() + run.metrics.failed as usize,
            run.requests,
            "seed {}: requests must be conserved under domain faults",
            run.seed
        );
    }
    let sum = scenario::summarize(&report);
    let tally = |f: fn(&crate::metrics::RunStats) -> u64| {
        report.runs.iter().map(|r| f(&r.stats)).sum::<u64>()
    };
    CorrelatedPoint {
        failure_aware,
        requests: sum.requests,
        goodput: sum.goodput,
        slo: sum.slo_attainment,
        failed: sum.failed,
        ttft_ms: sum.ttft_ms,
        node_outages: tally(|s| s.node_outages),
        node_repairs: tally(|s| s.node_repairs),
        zone_outages: tally(|s| s.zone_outages),
        zone_repairs: tally(|s| s.zone_repairs),
        degrades: tally(|s| s.degrades),
        degrade_retimes: tally(|s| s.degrade_retimes),
    }
}

/// The rendered sweep (experiment id `faults`).
pub fn faults(quick: bool) -> String {
    let mut t = Table::new(
        "Fault injection — MTBF × MTTR sweep (mean ± 95% CI across seeds)",
        &[
            "MTBF(s)",
            "MTTR(s)",
            "requests",
            "goodput",
            "SLO-att",
            "failed",
            "TTFT(ms)",
            "TTFT ×ref",
            "crashes",
            "redisp",
            "load fails",
            "retries",
        ],
    );
    let mut reference: Option<FaultPoint> = None;
    for mtbf_s in mtbfs(quick) {
        for mttr_s in mttrs(quick) {
            let p = run_point(mtbf_s, mttr_s, quick);
            if reference.is_none() {
                // Fastest failure rate × fastest repair: first cell.
                reference = Some(p.clone());
            }
            t.row(vec![
                format!("{mtbf_s}"),
                format!("{mttr_s}"),
                p.requests.to_string(),
                p.goodput.cell(3),
                p.slo.cell(3),
                p.failed.cell(1),
                p.ttft_ms.cell(1),
                format!("{:.2}x", p.ttft_degradation()),
                p.crashes.to_string(),
                p.redispatched.to_string(),
                p.load_failures.to_string(),
                p.retries.to_string(),
            ]);
        }
    }
    *LAST_REFERENCE.lock().unwrap() = reference;
    let mut out = t.render();
    out.push_str(&correlated_table(quick));
    out
}

/// The correlated-domains companion table: node + zone outages and
/// degrade episodes, one row per routing mode so failure-blind vs
/// failure-aware routing read side by side.
fn correlated_table(quick: bool) -> String {
    let mut t = Table::new(
        "Correlated faults — node/zone outages + degrade (mean ± 95% CI across seeds)",
        &[
            "routing",
            "requests",
            "goodput",
            "SLO-att",
            "failed",
            "TTFT(ms)",
            "node out",
            "node rep",
            "zone out",
            "degrades",
            "retimes",
        ],
    );
    let blind = run_correlated(false, quick);
    let aware = run_correlated(true, quick);
    for p in [&blind, &aware] {
        t.row(vec![
            if p.failure_aware { "failure-aware" } else { "failure-blind" }.to_string(),
            p.requests.to_string(),
            p.goodput.cell(3),
            p.slo.cell(3),
            p.failed.cell(1),
            p.ttft_ms.cell(1),
            p.node_outages.to_string(),
            p.node_repairs.to_string(),
            p.zone_outages.to_string(),
            p.degrades.to_string(),
            p.degrade_retimes.to_string(),
        ]);
    }
    *LAST_CORRELATED.lock().unwrap() = Some((blind, aware));
    t.render()
}

/// Machine-readable record of the reference cell (fastest swept failure
/// rate, fastest repair) for cross-PR tracking in `BENCH_sim.json`.
/// Reuses the sweep's measurement when a `faults()` run in this process
/// covered the cell.
pub fn faults_json(quick: bool) -> Json {
    let cached = LAST_REFERENCE.lock().unwrap().clone();
    let p = match cached {
        Some(p) => p,
        None => run_point(mtbfs(quick)[0], mttrs(quick)[0], quick),
    };
    let correlated = LAST_CORRELATED.lock().unwrap().clone();
    let (blind, aware) = match correlated {
        Some(pair) => pair,
        None => (run_correlated(false, quick), run_correlated(true, quick)),
    };
    let corr = |p: &CorrelatedPoint| {
        obj(vec![
            ("goodput", num(p.goodput.mean)),
            ("slo_attainment", num(p.slo.mean)),
            ("failed_mean", num(p.failed.mean)),
            ("ttft_ms", num(p.ttft_ms.mean)),
            ("node_outages", num(p.node_outages as f64)),
            ("node_repairs", num(p.node_repairs as f64)),
            ("zone_outages", num(p.zone_outages as f64)),
            ("zone_repairs", num(p.zone_repairs as f64)),
            ("degrades", num(p.degrades as f64)),
            ("degrade_retimes", num(p.degrade_retimes as f64)),
        ])
    };
    obj(vec![
        ("mtbf_s", num(p.mtbf_s)),
        ("mttr_s", num(p.mttr_s)),
        ("requests", num(p.requests as f64)),
        ("goodput", num(p.goodput.mean)),
        ("slo_attainment", num(p.slo.mean)),
        ("failed_mean", num(p.failed.mean)),
        ("ttft_ms", num(p.ttft_ms.mean)),
        ("ttft_degradation", num(p.ttft_degradation())),
        ("gpu_crashes", num(p.crashes as f64)),
        ("gpu_recoveries", num(p.recoveries as f64)),
        ("redispatched", num(p.redispatched as f64)),
        ("load_failures", num(p.load_failures as f64)),
        ("retries", num(p.retries as f64)),
        ("correlated_blind", corr(&blind)),
        ("correlated_aware", corr(&aware)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_grow_with_full_mode() {
        assert!(mtbfs(true).len() < mtbfs(false).len());
        assert!(mttrs(true).len() < mttrs(false).len());
        assert!(seeds(true).len() >= 3, "CIs need at least three seeds");
    }

    #[test]
    fn point_injects_faults_and_conserves() {
        // The conservation asserts inside run_point are the test; beyond
        // them, the fault machinery must have actually fired.
        let p = run_point(150.0, 30.0, true);
        assert!(p.requests > 0);
        assert!(p.crashes > 0, "a 150 s MTBF over 600 s must crash");
        assert_eq!(p.crashes, p.recoveries, "every crash must repair before the horizon drains");
        assert!(p.load_failures > 0, "5% load-failure rate must fire");
        assert!(p.retries > 0, "transient failures must be retried");
        assert!(
            p.goodput.mean > 0.0 && p.goodput.mean <= 1.0,
            "goodput {} out of range",
            p.goodput.mean
        );
        assert!(
            p.ttft_degradation() >= 0.95,
            "faults cannot meaningfully improve TTFT: {:.3}x",
            p.ttft_degradation()
        );
        assert!(
            p.slo.mean > 0.0 && p.slo.mean <= 1.0,
            "SLO attainment {} out of range",
            p.slo.mean
        );
    }

    #[test]
    fn correlated_point_fires_domains_under_both_routing_modes() {
        for failure_aware in [false, true] {
            let p = run_correlated(failure_aware, true);
            assert!(p.requests > 0);
            assert!(p.node_outages > 0, "450 s node MTBF over 600 s × 2 nodes must fire");
            assert_eq!(
                p.node_outages, p.node_repairs,
                "every node outage must repair before the horizon drains"
            );
            assert!(p.zone_outages > 0, "180 s zone MTBF over 600 s must fire");
            assert_eq!(
                p.zone_outages, p.zone_repairs,
                "every zone outage must drain back to all-nodes-up"
            );
            assert!(p.degrades > 0, "400 s degrade MTBF over 600 s × 4 GPUs must fire");
            assert!(p.degrade_retimes > 0, "degrade episodes must re-time in-flight work");
            assert!(
                p.goodput.mean > 0.0 && p.goodput.mean <= 1.0,
                "goodput {} out of range",
                p.goodput.mean
            );
            assert!(
                p.slo.mean > 0.0 && p.slo.mean <= 1.0,
                "SLO attainment {} out of range",
                p.slo.mean
            );
        }
    }

    #[test]
    fn json_record_names_the_tracked_counters() {
        let j = faults_json(true);
        for key in [
            "goodput",
            "slo_attainment",
            "ttft_degradation",
            "gpu_crashes",
            "redispatched",
            "load_failures",
            "retries",
            "correlated_blind",
            "correlated_aware",
        ] {
            assert!(j.get(key).is_some(), "BENCH record missing '{key}'");
        }
        for mode in ["correlated_blind", "correlated_aware"] {
            let c = j.get(mode).unwrap();
            for key in ["slo_attainment", "node_outages", "zone_outages", "degrades"] {
                assert!(c.get(key).is_some(), "'{mode}' record missing '{key}'");
            }
        }
    }
}
