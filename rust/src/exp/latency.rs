//! Fig. 6 (average TTFT), Fig. 7 (average TPOT), Fig. 12 (TTFT CDF +
//! SLO violation) across Predictable / Normal / Bursty workloads for the
//! serverless systems (plus the Predictive-LoRA policy plug-in).
//!
//! Each figure's (pattern × system) grid is a `ScenarioSpec` grid run
//! through `scenario::run_grid`, so the cells fan out across `--jobs`
//! workers and render in grid order.

use crate::metrics::RunMetrics;
use crate::scenario::{ClusterSpec, WorkloadSpec};
use crate::sim::workloads::{series_13b, series_7b};
use crate::trace::Pattern;
use crate::util::table::{f, ms, Table};

/// The serverless contenders, by scenario system id (InstaInfer's
/// predictor hit rate resolves from each cell's workload pattern).
const SERVERLESS_IDS: [&str; 4] =
    ["serverless-lora", "predictive", "serverless-llm", "instainfer"];

/// Run the (pattern × serverless system) grid for one horizon as one
/// scenario grid, returning `(pattern, system name, metrics)` in grid
/// order.
fn pattern_grid(quick: bool) -> Vec<(Pattern, String, RunMetrics)> {
    let dur = super::horizon(quick);
    let keyed: Vec<(Pattern, crate::scenario::ScenarioSpec)> = Pattern::ALL
        .iter()
        .flat_map(|&p| {
            SERVERLESS_IDS.into_iter().map(move |id| {
                let spec = super::cell(
                    format!("latency-{}-{id}", p.name()),
                    id,
                    ClusterSpec::Paper,
                    WorkloadSpec::Paper { pattern: p, seed: 11 },
                    dur,
                    1,
                );
                (p, spec)
            })
        })
        .collect();
    let (patterns, specs): (Vec<_>, Vec<_>) = keyed.into_iter().unzip();
    let reports = super::run_cells(specs);
    patterns
        .into_iter()
        .zip(reports)
        .map(|(p, r)| {
            let (system, run) = r.into_only();
            (p, system, run.metrics)
        })
        .collect()
}

pub fn fig6(quick: bool) -> String {
    let mut t = Table::new(
        "Fig 6 — Average TTFT (ms), 8 LoRA functions on 16 GPUs",
        &["pattern", "system", "TTFT-7B", "TTFT-13B", "p99-7B", "p99-13B"],
    );
    for (pattern, name, m) in pattern_grid(quick) {
        let m7 = m.subset(&series_7b());
        let m13 = m.subset(&series_13b());
        t.row(vec![
            pattern.name().into(),
            name.into(),
            ms(m7.ttft().mean),
            ms(m13.ttft().mean),
            ms(m7.ttft().p99),
            ms(m13.ttft().p99),
        ]);
    }
    t.render()
}

pub fn fig7(quick: bool) -> String {
    let mut t = Table::new(
        "Fig 7 — Average TPOT (ms)",
        &["pattern", "system", "TPOT-7B", "TPOT-13B"],
    );
    for (pattern, name, m) in pattern_grid(quick) {
        t.row(vec![
            pattern.name().into(),
            name.into(),
            ms(m.subset(&series_7b()).tpot().mean),
            ms(m.subset(&series_13b()).tpot().mean),
        ]);
    }
    t.render()
}

pub fn fig12(quick: bool) -> String {
    // CDF thresholds in seconds; SLOs: 2.5 s (7B), 4.0 s (13B) — §6.8.
    let thresholds = [0.25, 0.5, 1.0, 2.0, 2.5, 4.0, 8.0, 16.0];
    // One run per (pattern, system), shared by both series tables.
    let grid = pattern_grid(quick);
    let mut out = String::new();
    for (series, label, slo) in
        [(series_7b(), "7B", 2.5), (series_13b(), "13B", 4.0)]
    {
        let mut t = Table::new(
            &format!("Fig 12 — TTFT CDF, Llama2-{label} series (SLO {slo} s)"),
            &[
                "pattern", "system", "<=0.25s", "<=0.5s", "<=1s", "<=2s",
                "<=2.5s", "<=4s", "<=8s", "<=16s", "SLO-viol%",
            ],
        );
        for (pattern, name, m) in &grid {
            let cdf = m.ttft_cdf(&series, &thresholds);
            let viol = m.subset(&series).slo_violation_rate(|_| slo);
            let mut row = vec![pattern.name().to_string(), name.clone()];
            row.extend(cdf.iter().map(|c| format!("{:.2}", c)));
            row.push(f(viol * 100.0));
            t.row(row);
        }
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workloads::paper_workload;
    use crate::sim::SystemConfig;

    /// The headline claim behind Fig. 6: ServerlessLoRA's TTFT beats both
    /// serverless baselines on every pattern.
    #[test]
    fn serverless_lora_wins_ttft_on_all_patterns() {
        for pattern in Pattern::ALL {
            let w = paper_workload(pattern, 1800.0, 3);
            let (lora, _, _) =
                super::super::run_system(SystemConfig::serverless_lora(), w.clone(), 1);
            let (sllm, _, _) =
                super::super::run_system(SystemConfig::serverless_llm(), w.clone(), 1);
            let (insta, _, _) =
                super::super::run_system(SystemConfig::instainfer(pattern), w, 1);
            assert!(
                lora.ttft().mean < sllm.ttft().mean,
                "{}: lora {} vs sllm {}",
                pattern.name(),
                lora.ttft().mean,
                sllm.ttft().mean
            );
            assert!(
                lora.ttft().mean < insta.ttft().mean,
                "{}: lora {} vs insta {}",
                pattern.name(),
                lora.ttft().mean,
                insta.ttft().mean
            );
        }
    }

    /// §6.2: ServerlessLoRA's TPOT is moderately higher (larger batches)
    /// but within ~25% of the fixed-batch baselines.
    #[test]
    fn tpot_penalty_is_moderate() {
        let w = paper_workload(Pattern::Bursty, 1800.0, 3);
        let (lora, _, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w.clone(), 1);
        let (sllm, _, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        let ratio = lora.tpot().mean / sllm.tpot().mean;
        assert!(ratio < 1.4, "TPOT ratio {ratio}");
    }

    /// §6.8: ServerlessLoRA has the lowest SLO violation rate.
    #[test]
    fn slo_violations_lowest_for_serverless_lora() {
        let w = paper_workload(Pattern::Bursty, 1800.0, 3);
        let slo = |f: usize| if f < 4 { 2.5 } else { 4.0 };
        let (lora, _, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w.clone(), 1);
        let (sllm, _, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        assert!(lora.slo_violation_rate(slo) <= sllm.slo_violation_rate(slo));
    }

    /// The predictive plug-in slots into the same grid: on the
    /// predictable pattern (EWMA's best case) it must land between the
    /// full pre-loader and the no-preload serverless baseline.
    #[test]
    fn predictive_between_full_and_baseline() {
        let w = paper_workload(Pattern::Predictable, 1800.0, 3);
        let (lora, _, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w.clone(), 1);
        let (pred, _, _) =
            super::super::run_system(SystemConfig::predictive(), w.clone(), 1);
        let (sllm, _, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        assert!(
            pred.ttft().mean <= sllm.ttft().mean * 1.02,
            "predictive {} vs sllm {}",
            pred.ttft().mean,
            sllm.ttft().mean
        );
        assert!(
            lora.ttft().mean <= pred.ttft().mean * 1.02,
            "full {} vs predictive {}",
            lora.ttft().mean,
            pred.ttft().mean
        );
    }
}
