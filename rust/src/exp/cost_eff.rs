//! Fig. 2 (motivation: serverless vs serverful cost-effectiveness),
//! Fig. 9 (cost-effectiveness vs all four baselines) and Table 1
//! (E2E latency / cost / relative cost-effectiveness, 7B & 13B series)
//! — `ScenarioSpec` grids through `scenario::run_grid`.

use crate::cost::relative_cost_effectiveness;
use crate::scenario::{ClusterSpec, ScenarioSpec, WorkloadSpec};
use crate::sim::workloads::{series_13b, series_7b};
use crate::trace::Pattern;
use crate::util::table::{f, ms, Table};

/// The five-system comparison, vLLM first (the figures normalise
/// against row 0).
const ALL_SYSTEM_IDS: [&str; 5] =
    ["vllm", "dlora", "instainfer", "serverless-llm", "serverless-lora"];

/// Run `ids` over the same deterministic workload as one scenario grid,
/// returning (system name, metrics, cost) in order. The first id must
/// be the vLLM baseline.
fn baseline_grid(
    tag: &str,
    ids: &[&str],
    workload: WorkloadSpec,
    dur: f64,
) -> Vec<(String, crate::metrics::RunMetrics, crate::cost::CostTracker)> {
    assert_eq!(ids[0], "vllm", "baseline must lead the system list");
    let specs: Vec<ScenarioSpec> = ids
        .iter()
        .map(|id| {
            super::cell(format!("{tag}-{id}"), id, ClusterSpec::Paper, workload.clone(), dur, 1)
        })
        .collect();
    super::run_cells(specs)
        .into_iter()
        .map(|r| {
            let (system, run) = r.into_only();
            (system, run.metrics, run.cost)
        })
        .collect()
}

pub fn fig2(quick: bool) -> String {
    let dur = super::horizon(quick);
    let mut out = String::new();
    for (n_fns, label) in [(1, "a: one Llama2-7B LLM"), (4, "b: four Llama2-7B LoRA fns")] {
        // Fig. 2a: ONE 7B function (general LLM serving) — serverless
        // wins on pay-per-use. Fig. 2b: the SAME demand split across
        // four LoRA functions — naive serverless loses its edge to
        // backbone redundancy (4 idle backbones, 4× the cold starts).
        let ids = ["vllm", "dlora", "serverless-llm", "instainfer", "serverless-lora"];
        let results = baseline_grid(
            &format!("fig2{}", if n_fns == 1 { 'a' } else { 'b' }),
            &ids,
            WorkloadSpec::SmallMulti { n_fns, seed: 5 },
            dur,
        );
        // vLLM is the first row: its run doubles as the baseline.
        let (base_e2e, base_cost) = (results[0].1.e2e().mean, results[0].2.total_usd());
        let mut t = Table::new(
            &format!("Fig 2{label} — cost-effectiveness (vLLM = 1)"),
            &["system", "E2E(ms)", "cost($)", "rel-cost-eff"],
        );
        for (name, m, c) in &results {
            t.row(vec![
                name.clone(),
                ms(m.e2e().mean),
                f(c.total_usd()),
                f(relative_cost_effectiveness(
                    m.e2e().mean,
                    c.total_usd(),
                    base_e2e,
                    base_cost,
                )),
            ]);
        }
        out.push_str(&t.render());
    }
    out
}

pub fn fig9(quick: bool) -> String {
    let dur = super::horizon(quick);
    let mut t = Table::new(
        "Fig 9 — Cost-effectiveness vs baselines (vLLM = 1), 8 fns / 16 GPUs",
        &["pattern", "system", "E2E(ms)", "cost($)", "rel-cost-eff"],
    );
    for pattern in Pattern::ALL {
        let results = baseline_grid(
            &format!("fig9-{}", pattern.name()),
            &ALL_SYSTEM_IDS,
            WorkloadSpec::Paper { pattern, seed: 11 },
            dur,
        );
        // vLLM leads the id list: its run doubles as the baseline.
        let (base_e2e, base_cost) = (results[0].1.e2e().mean, results[0].2.total_usd());
        for (name, m, c) in &results {
            t.row(vec![
                pattern.name().into(),
                name.clone(),
                ms(m.e2e().mean),
                f(c.total_usd()),
                f(relative_cost_effectiveness(
                    m.e2e().mean,
                    c.total_usd(),
                    base_e2e,
                    base_cost,
                )),
            ]);
        }
    }
    t.render()
}

pub fn tab1(quick: bool) -> String {
    // The paper's Table 1 splits 7B and 13B series; cost is attributed by
    // the series' share of GPU-time (approximated by its E2E×requests).
    let mut t = Table::new(
        "Table 1 — E2E (ms), cost ($) and relative cost-effectiveness, 7B (13B)",
        &["pattern", "system", "E2E 7B(13B)", "cost 7B(13B)", "rel-CE 7B(13B)"],
    );
    for pattern in Pattern::ALL {
        let dur = super::horizon(quick);
        let results = baseline_grid(
            &format!("tab1-{}", pattern.name()),
            &ALL_SYSTEM_IDS,
            WorkloadSpec::Paper { pattern, seed: 11 },
            dur,
        );
        // vLLM baseline per series (first row of the id list).
        let vm = &results[0].1;
        let (v7, v13) = (vm.subset(&series_7b()), vm.subset(&series_13b()));
        let (vc7, vc13) = split_cost(vm, results[0].2.total_usd());
        for (name, m, c) in &results {
            let (m7, m13) = (m.subset(&series_7b()), m.subset(&series_13b()));
            let (c7, c13) = split_cost(m, c.total_usd());
            t.row(vec![
                pattern.name().into(),
                name.clone(),
                format!("{} ({})", ms(m7.e2e().mean), ms(m13.e2e().mean)),
                format!("{} ({})", f(c7), f(c13)),
                format!(
                    "{} ({})",
                    f(relative_cost_effectiveness(
                        m7.e2e().mean, c7, v7.e2e().mean, vc7
                    )),
                    f(relative_cost_effectiveness(
                        m13.e2e().mean, c13, v13.e2e().mean, vc13
                    ))
                ),
            ]);
        }
    }
    t.render()
}

/// Attribute total run cost to the 7B/13B series by their share of
/// GPU-seconds (busy-time × memory-weight approximation).
fn split_cost(m: &crate::metrics::RunMetrics, total: f64) -> (f64, f64) {
    let busy = |fns: &[usize], weight: f64| -> f64 {
        m.subset(fns)
            .outcomes
            .iter()
            .map(|o| o.e2e_s * weight)
            .sum::<f64>()
    };
    let b7 = busy(&series_7b(), 14.0); // ~GB-weight of a 7B instance
    let b13 = busy(&series_13b(), 27.0);
    let tot = (b7 + b13).max(1e-9);
    (total * b7 / tot, total * b13 / tot)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workloads::{paper_workload, small_multi_workload};
    use crate::sim::SystemConfig;

    /// Fig. 2a: for ONE general LLM, serverless beats serverful
    /// cost-effectiveness (pay-per-use vs idle GPUs).
    #[test]
    fn fig2a_serverless_wins_single_llm() {
        let w = small_multi_workload(1, 3600.0, 5);
        let (vm, vc, _) = super::super::run_system(SystemConfig::vllm(), w.clone(), 1);
        let (sm, sc, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        let rel = relative_cost_effectiveness(
            sm.e2e().mean,
            sc.total_usd(),
            vm.e2e().mean,
            vc.total_usd(),
        );
        assert!(rel > 1.0, "serverless rel-CE {rel} <= 1");
    }

    /// Fig. 2b: with FOUR LoRA functions, the naive serverless baseline's
    /// advantage erodes (backbone redundancy: 4 idle backbones + per-fn
    /// cold starts), while ServerlessLoRA's sharing keeps its edge — the
    /// gap between them is what the paper's Fig. 2b motivates.
    ///
    /// NOTE: the paper's absolute "serverless < vLLM" in 2b depends on an
    /// unstated resource normalisation for the serverful baseline; we
    /// assert the normalisation-free ordering instead (see EXPERIMENTS.md).
    #[test]
    fn fig2b_sharing_beats_naive_serverless_on_multi_lora() {
        let w4 = small_multi_workload(4, 3600.0, 5);
        let (vm, vc, _) = super::super::run_system(SystemConfig::vllm(), w4.clone(), 1);
        let rel = |cfg: SystemConfig| {
            let (m, c, _) = super::super::run_system(cfg, w4.clone(), 1);
            relative_cost_effectiveness(
                m.e2e().mean,
                c.total_usd(),
                vm.e2e().mean,
                vc.total_usd(),
            )
        };
        let naive = rel(SystemConfig::serverless_llm());
        let lora = rel(SystemConfig::serverless_lora());
        assert!(
            lora > 1.5 * naive,
            "sharing should decisively beat naive serverless: {lora} vs {naive}"
        );
    }

    /// Fig. 9 / Table 1 headline: ServerlessLoRA has the best relative
    /// cost-effectiveness of all five systems.
    #[test]
    fn serverless_lora_best_cost_effectiveness() {
        let pattern = Pattern::Normal;
        let w = paper_workload(pattern, 1800.0, 3);
        let (vm, vc, _) = super::super::run_system(SystemConfig::vllm(), w.clone(), 1);
        let rel = |cfg: SystemConfig| {
            let (m, c, _) = super::super::run_system(cfg, w.clone(), 1);
            relative_cost_effectiveness(
                m.e2e().mean,
                c.total_usd(),
                vm.e2e().mean,
                vc.total_usd(),
            )
        };
        let lora = rel(SystemConfig::serverless_lora());
        for cfg in [
            SystemConfig::dlora(),
            SystemConfig::serverless_llm(),
            SystemConfig::instainfer(pattern),
        ] {
            let name = cfg.name;
            let other = rel(cfg);
            assert!(lora > other, "{name}: {other} >= lora {lora}");
        }
        assert!(lora > 1.0, "lora must beat vLLM: {lora}");
    }

    /// The paper's cost claim: ServerlessLoRA cuts monetary cost several
    /// times vs serverless baselines.
    #[test]
    fn serverless_lora_cheapest_serverless() {
        let w = paper_workload(Pattern::Normal, 1800.0, 3);
        let (_, lc, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w.clone(), 1);
        let (_, sc, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        assert!(
            lc.total_usd() < sc.total_usd(),
            "lora ${} vs sllm ${}",
            lc.total_usd(),
            sc.total_usd()
        );
    }
}
