//! Fig. 2 (motivation: serverless vs serverful cost-effectiveness),
//! Fig. 9 (cost-effectiveness vs all four baselines) and Table 1
//! (E2E latency / cost / relative cost-effectiveness, 7B & 13B series).

use crate::artifact::{FunctionSpec, ModelProfile};
use crate::cost::relative_cost_effectiveness;
use crate::sim::workloads::{paper_workload, series_13b, series_7b, RATE_TIERS};
use crate::sim::{SystemConfig, Workload};
use crate::trace::{merge, Pattern, TraceSpec};
use crate::util::table::{f, ms, Table};

fn all_systems(pattern: Pattern) -> Vec<SystemConfig> {
    vec![
        SystemConfig::vllm(),
        SystemConfig::dlora(),
        SystemConfig::instainfer(pattern),
        SystemConfig::serverless_llm(),
        SystemConfig::serverless_lora(),
    ]
}

/// Run `systems` over per-task copies of the same deterministic workload
/// in parallel, returning names + results in order. The first system must
/// be the vLLM baseline — the figures normalise against row 0.
fn baseline_grid(
    systems: Vec<SystemConfig>,
    make_workload: impl Fn() -> Workload,
) -> (
    Vec<&'static str>,
    Vec<(crate::metrics::RunMetrics, crate::cost::CostTracker, crate::sim::RunStats)>,
) {
    let tasks: Vec<(SystemConfig, Workload, u64)> = systems
        .into_iter()
        .map(|cfg| (cfg, make_workload(), 1))
        .collect();
    let names: Vec<&'static str> = tasks.iter().map(|(c, _, _)| c.name).collect();
    assert_eq!(names[0], "vLLM", "baseline must lead the system list");
    (names, super::run_systems(tasks))
}

/// Fig. 2a workload: ONE Llama2-7B function (general LLM serving) —
/// serverless wins on pay-per-use. Fig. 2b: the SAME total demand split
/// across four 7B LoRA functions — naive serverless loses its edge to
/// backbone redundancy (4 idle backbones, 4× the cold starts).
fn small_workload(n_fns: usize, duration_s: f64) -> Workload {
    let functions: Vec<FunctionSpec> = (0..n_fns)
        .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
        .collect();
    let total = RATE_TIERS[0];
    let rates: Vec<f64> = (0..n_fns).map(|_| total / n_fns as f64).collect();
    let traces = functions
        .iter()
        .map(|fx| {
            TraceSpec::new(fx.id, Pattern::Normal, rates[fx.id], 5 + fx.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

pub fn fig2(quick: bool) -> String {
    let dur = super::horizon(quick);
    let mut out = String::new();
    for (n_fns, label) in [(1, "a: one Llama2-7B LLM"), (4, "b: four Llama2-7B LoRA fns")] {
        let systems = vec![
            SystemConfig::vllm(),
            SystemConfig::dlora(),
            SystemConfig::serverless_llm(),
            SystemConfig::instainfer(Pattern::Normal),
            SystemConfig::serverless_lora(),
        ];
        let (names, results) = baseline_grid(systems, || small_workload(n_fns, dur));
        // vLLM is the first row: its run doubles as the baseline.
        let (base_e2e, base_cost) = (results[0].0.e2e().mean, results[0].1.total_usd());
        let mut t = Table::new(
            &format!("Fig 2{label} — cost-effectiveness (vLLM = 1)"),
            &["system", "E2E(ms)", "cost($)", "rel-cost-eff"],
        );
        for (name, (m, c, _)) in names.into_iter().zip(&results) {
            t.row(vec![
                name.into(),
                ms(m.e2e().mean),
                f(c.total_usd()),
                f(relative_cost_effectiveness(
                    m.e2e().mean,
                    c.total_usd(),
                    base_e2e,
                    base_cost,
                )),
            ]);
        }
        out.push_str(&t.render());
    }
    out
}

pub fn fig9(quick: bool) -> String {
    let dur = super::horizon(quick);
    let mut t = Table::new(
        "Fig 9 — Cost-effectiveness vs baselines (vLLM = 1), 8 fns / 16 GPUs",
        &["pattern", "system", "E2E(ms)", "cost($)", "rel-cost-eff"],
    );
    for pattern in Pattern::ALL {
        let (names, results) =
            baseline_grid(all_systems(pattern), || paper_workload(pattern, dur, 11));
        // vLLM leads `all_systems`: its run doubles as the baseline.
        let (base_e2e, base_cost) = (results[0].0.e2e().mean, results[0].1.total_usd());
        for (name, (m, c, _)) in names.into_iter().zip(&results) {
            t.row(vec![
                pattern.name().into(),
                name.into(),
                ms(m.e2e().mean),
                f(c.total_usd()),
                f(relative_cost_effectiveness(
                    m.e2e().mean,
                    c.total_usd(),
                    base_e2e,
                    base_cost,
                )),
            ]);
        }
    }
    t.render()
}

pub fn tab1(quick: bool) -> String {
    // The paper's Table 1 splits 7B and 13B series; cost is attributed by
    // the series' share of GPU-time (approximated by its E2E×requests).
    let mut t = Table::new(
        "Table 1 — E2E (ms), cost ($) and relative cost-effectiveness, 7B (13B)",
        &["pattern", "system", "E2E 7B(13B)", "cost 7B(13B)", "rel-CE 7B(13B)"],
    );
    for pattern in Pattern::ALL {
        let dur = super::horizon(quick);
        let (names, results) =
            baseline_grid(all_systems(pattern), || paper_workload(pattern, dur, 11));
        // vLLM baseline per series (first row of `all_systems`).
        let vm = &results[0].0;
        let (v7, v13) = (vm.subset(&series_7b()), vm.subset(&series_13b()));
        let (vc7, vc13) = split_cost(vm, results[0].1.total_usd());
        for (name, (m, c, _)) in names.into_iter().zip(&results) {
            let (m7, m13) = (m.subset(&series_7b()), m.subset(&series_13b()));
            let (c7, c13) = split_cost(m, c.total_usd());
            t.row(vec![
                pattern.name().into(),
                name.into(),
                format!("{} ({})", ms(m7.e2e().mean), ms(m13.e2e().mean)),
                format!("{} ({})", f(c7), f(c13)),
                format!(
                    "{} ({})",
                    f(relative_cost_effectiveness(
                        m7.e2e().mean, c7, v7.e2e().mean, vc7
                    )),
                    f(relative_cost_effectiveness(
                        m13.e2e().mean, c13, v13.e2e().mean, vc13
                    ))
                ),
            ]);
        }
    }
    t.render()
}

/// Attribute total run cost to the 7B/13B series by their share of
/// GPU-seconds (busy-time × memory-weight approximation).
fn split_cost(m: &crate::metrics::RunMetrics, total: f64) -> (f64, f64) {
    let busy = |fns: &[usize], weight: f64| -> f64 {
        m.subset(fns)
            .outcomes
            .iter()
            .map(|o| o.e2e_s * weight)
            .sum::<f64>()
    };
    let b7 = busy(&series_7b(), 14.0); // ~GB-weight of a 7B instance
    let b13 = busy(&series_13b(), 27.0);
    let tot = (b7 + b13).max(1e-9);
    (total * b7 / tot, total * b13 / tot)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 2a: for ONE general LLM, serverless beats serverful
    /// cost-effectiveness (pay-per-use vs idle GPUs).
    #[test]
    fn fig2a_serverless_wins_single_llm() {
        let w = small_workload(1, 3600.0);
        let (vm, vc, _) = super::super::run_system(SystemConfig::vllm(), w.clone(), 1);
        let (sm, sc, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        let rel = relative_cost_effectiveness(
            sm.e2e().mean,
            sc.total_usd(),
            vm.e2e().mean,
            vc.total_usd(),
        );
        assert!(rel > 1.0, "serverless rel-CE {rel} <= 1");
    }

    /// Fig. 2b: with FOUR LoRA functions, the naive serverless baseline's
    /// advantage erodes (backbone redundancy: 4 idle backbones + per-fn
    /// cold starts), while ServerlessLoRA's sharing keeps its edge — the
    /// gap between them is what the paper's Fig. 2b motivates.
    ///
    /// NOTE: the paper's absolute "serverless < vLLM" in 2b depends on an
    /// unstated resource normalisation for the serverful baseline; we
    /// assert the normalisation-free ordering instead (see EXPERIMENTS.md).
    #[test]
    fn fig2b_sharing_beats_naive_serverless_on_multi_lora() {
        let w4 = small_workload(4, 3600.0);
        let (vm, vc, _) = super::super::run_system(SystemConfig::vllm(), w4.clone(), 1);
        let rel = |cfg: SystemConfig| {
            let (m, c, _) = super::super::run_system(cfg, w4.clone(), 1);
            relative_cost_effectiveness(
                m.e2e().mean,
                c.total_usd(),
                vm.e2e().mean,
                vc.total_usd(),
            )
        };
        let naive = rel(SystemConfig::serverless_llm());
        let lora = rel(SystemConfig::serverless_lora());
        assert!(
            lora > 1.5 * naive,
            "sharing should decisively beat naive serverless: {lora} vs {naive}"
        );
    }

    /// Fig. 9 / Table 1 headline: ServerlessLoRA has the best relative
    /// cost-effectiveness of all five systems.
    #[test]
    fn serverless_lora_best_cost_effectiveness() {
        let pattern = Pattern::Normal;
        let w = paper_workload(pattern, 1800.0, 3);
        let (vm, vc, _) = super::super::run_system(SystemConfig::vllm(), w.clone(), 1);
        let rel = |cfg: SystemConfig| {
            let (m, c, _) = super::super::run_system(cfg, w.clone(), 1);
            relative_cost_effectiveness(
                m.e2e().mean,
                c.total_usd(),
                vm.e2e().mean,
                vc.total_usd(),
            )
        };
        let lora = rel(SystemConfig::serverless_lora());
        for cfg in [
            SystemConfig::dlora(),
            SystemConfig::serverless_llm(),
            SystemConfig::instainfer(pattern),
        ] {
            let name = cfg.name;
            let other = rel(cfg);
            assert!(lora > other, "{name}: {other} >= lora {lora}");
        }
        assert!(lora > 1.0, "lora must beat vLLM: {lora}");
    }

    /// The paper's cost claim: ServerlessLoRA cuts monetary cost several
    /// times vs serverless baselines.
    #[test]
    fn serverless_lora_cheapest_serverless() {
        let w = paper_workload(Pattern::Normal, 1800.0, 3);
        let (_, lc, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w.clone(), 1);
        let (_, sc, _) =
            super::super::run_system(SystemConfig::serverless_llm(), w, 1);
        assert!(
            lc.total_usd() < sc.total_usd(),
            "lora ${} vs sllm ${}",
            lc.total_usd(),
            sc.total_usd()
        );
    }
}
