//! §6.6 ablation study: Fig. 10b (cost-effectiveness of each variant) and
//! Table 3 (TTFT / E2E / monetary cost, including the NAB #1–#3 fixed
//! batching strategies and the Predictive-LoRA pre-loading plug-in) —
//! a `ScenarioSpec` grid through `scenario::run_grid`.

use crate::cost::cost_effectiveness;
use crate::scenario::{ClusterSpec, ScenarioSpec, WorkloadSpec};
use crate::trace::Pattern;
use crate::util::table::{f, ms, Table};

/// The §6.6 variant set, full system first (the baseline row). The
/// ablation runs on a TIGHT cluster (4 GPUs for 8 functions): the
/// paper's §6.6 setting where pre-loaded artifacts and KV demand
/// actually contend, so Dynamic Offloading and batching policy have
/// bite.
pub const VARIANT_IDS: [&str; 8] = [
    "serverless-lora",
    "predictive",
    "nbs",
    "npl",
    "ndo",
    "nab1",
    "nab2",
    "nab3",
];

/// One tight-cluster cell per variant, run as one scenario grid.
fn variant_grid(
    quick: bool,
) -> Vec<(String, crate::metrics::RunMetrics, crate::cost::CostTracker)> {
    let dur = super::horizon(quick);
    let specs: Vec<ScenarioSpec> = VARIANT_IDS
        .into_iter()
        .map(|id| {
            super::cell(
                format!("ablation-{id}"),
                id,
                ClusterSpec::Uniform {
                    nodes: 1,
                    gpus_per_node: 4,
                    containers_per_node: 8,
                    trim_gpus: None,
                    zones: 1,
                },
                WorkloadSpec::Paper { pattern: Pattern::Normal, seed: 11 },
                dur,
                1,
            )
        })
        .collect();
    super::run_cells(specs)
        .into_iter()
        .map(|r| {
            let (system, run) = r.into_only();
            (system, run.metrics, run.cost)
        })
        .collect()
}

pub fn fig10b(quick: bool) -> String {
    let mut t = Table::new(
        "Fig 10b — Ablation: cost-effectiveness (full ServerlessLoRA = 1)",
        &["variant", "rel-cost-eff"],
    );
    let grid = variant_grid(quick);
    // The first variant IS the full system — its run doubles as baseline.
    assert_eq!(grid[0].0, "ServerlessLoRA", "baseline must lead `VARIANT_IDS`");
    let (fm, fc) = (&grid[0].1, &grid[0].2);
    let base = cost_effectiveness(fm.e2e().mean, fc.total_usd());
    for (name, m, c) in &grid {
        let ce = cost_effectiveness(m.e2e().mean, c.total_usd());
        t.row(vec![name.clone(), f(ce / base)]);
    }
    t.render()
}

pub fn tab3(quick: bool) -> String {
    let mut t = Table::new(
        "Table 3 — Ablation study (Normal workload, 8 fns)",
        &["variant", "TTFT (ms)", "E2E (ms)", "cost ($)"],
    );
    for (name, m, c) in variant_grid(quick) {
        t.row(vec![
            name,
            ms(m.ttft().mean),
            ms(m.e2e().mean),
            f(c.total_usd()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::sim::workloads::paper_workload;
    use crate::sim::{Engine, SystemConfig};

    /// The tight-cluster run the rendered grid uses, for ordering tests.
    fn tight_run(
        cfg: SystemConfig,
        w: crate::sim::Workload,
    ) -> (crate::metrics::RunMetrics, crate::cost::CostTracker) {
        let (m, c, _) = Engine::new(cfg, Cluster::new(1, 4, 8), w, 1).run();
        (m, c)
    }

    fn measure(cfg: SystemConfig) -> (f64, f64, f64) {
        let w = paper_workload(Pattern::Normal, 1800.0, 3);
        let (m, c) = tight_run(cfg, w);
        (m.ttft().mean, m.e2e().mean, c.total_usd())
    }

    /// Table 3 ordering: the full system has the lowest TTFT of the
    /// structural ablations (NBS / NPL — sharing and pre-loading are the
    /// big levers).
    #[test]
    fn full_system_beats_structural_ablations_on_ttft() {
        let (full_ttft, _, _) = measure(SystemConfig::serverless_lora());
        for cfg in [SystemConfig::nbs(), SystemConfig::npl()] {
            let name = cfg.name;
            let (ttft, _, _) = measure(cfg);
            assert!(
                full_ttft <= ttft * 1.05,
                "{name}: full {full_ttft} vs variant {ttft}"
            );
        }
    }

    /// §4.2 / §6.6: no-batching (NAB#1) loses where batching matters —
    /// bursty traffic — by churning new instances per concurrent request
    /// (worse TTFT) and paying contention (worse E2E).
    #[test]
    fn nab1_loses_under_bursts() {
        let w = paper_workload(Pattern::Bursty, 1800.0, 3);
        let (full, _, _) =
            super::super::run_system(SystemConfig::serverless_lora(), w.clone(), 1);
        let (nab1, _, _) = super::super::run_system(SystemConfig::nab(1), w, 1);
        assert!(
            full.ttft().mean < nab1.ttft().mean,
            "full {} vs NAB#1 {}",
            full.ttft().mean,
            nab1.ttft().mean
        );
        assert!(
            full.e2e().mean < nab1.e2e().mean,
            "E2E full {} vs NAB#1 {}",
            full.e2e().mean,
            nab1.e2e().mean
        );
    }

    #[test]
    fn nbs_is_the_most_expensive_variant() {
        let (_, _, full) = measure(SystemConfig::serverless_lora());
        let (_, _, nbs) = measure(SystemConfig::nbs());
        let (_, _, npl) = measure(SystemConfig::npl());
        assert!(nbs > full, "NBS ${nbs} should exceed full ${full}");
        assert!(nbs > npl * 0.9, "NBS ${nbs} should be among the worst (NPL ${npl})");
    }

    /// NPL loses to the full system on TTFT (pre-loading matters).
    #[test]
    fn npl_slower_than_full() {
        let (full, _, _) = measure(SystemConfig::serverless_lora());
        let (npl, _, _) = measure(SystemConfig::npl());
        assert!(npl >= full, "npl {npl} vs full {full}");
    }

    /// The predictive plug-in is a sane ablation row: it conserves
    /// requests on the tight cluster and never loses to no-preloading.
    #[test]
    fn predictive_variant_sane_on_tight_cluster() {
        let w = paper_workload(Pattern::Normal, 1800.0, 3);
        let n = w.requests.len();
        let (pm, _) = tight_run(SystemConfig::predictive(), w);
        assert_eq!(pm.outcomes.len(), n, "predictive lost requests");
        let (pred, _, _) = measure(SystemConfig::predictive());
        let (npl, _, _) = measure(SystemConfig::npl());
        assert!(
            pred <= npl * 1.05,
            "predictive {pred} vs npl {npl}"
        );
    }
}
