//! Backbone-sharing registry (paper §4.4).
//!
//! Tracks, cluster-wide, which GPUs host which shared backbone segment and
//! mediates attach/detach of isolated function instances.  This is the
//! control-plane analogue of the paper's CUDA-IPC design: the *data*-plane
//! equivalent lives in `runtime::engine`, where one set of PJRT backbone
//! buffers is shared zero-copy (Arc) across per-function states while each
//! function keeps its own adapter buffers and KV cache — the same
//! "read-only shared weights, isolated dynamic state" split.

use std::collections::BTreeMap;

use crate::cluster::{Cluster, GpuError, GpuId};

/// An opaque capability to read a shared backbone segment — the analogue
/// of a CUDA IPC handle. Holding one pins the segment (refcounted).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IpcHandle {
    pub model: String,
    pub gpu: GpuId,
    pub function: usize,
}

/// Cluster-wide registry of shared backbone segments.
#[derive(Debug, Default, Clone)]
pub struct BackboneRegistry {
    /// model → GPUs currently hosting a shared copy.
    hosts: BTreeMap<String, Vec<GpuId>>,
}

impl BackboneRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// GPUs hosting this backbone (locality candidates for the router).
    pub fn hosts(&self, model: &str) -> &[GpuId] {
        self.hosts.get(model).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn is_hosted_on(&self, model: &str, gpu: GpuId) -> bool {
        self.hosts(model).contains(&gpu)
    }

    /// Load a shared copy onto `gpu` (first function pays the bytes once;
    /// later functions attach for free — Observation 1's fix).
    pub fn load(
        &mut self,
        cluster: &mut Cluster,
        model: &str,
        size_gb: f64,
        gpu: GpuId,
    ) -> Result<(), GpuError> {
        cluster.gpu_mut(gpu).load_shared_backbone(model, size_gb)?;
        let v = self.hosts.entry(model.to_string()).or_default();
        if !v.contains(&gpu) {
            v.push(gpu);
        }
        Ok(())
    }

    /// Attach an isolated function instance; returns its IPC handle.
    pub fn attach(
        &mut self,
        cluster: &mut Cluster,
        model: &str,
        gpu: GpuId,
        function: usize,
    ) -> Result<IpcHandle, GpuError> {
        if !self.is_hosted_on(model, gpu) {
            return Err(GpuError::BackboneMissing(model.to_string()));
        }
        cluster.gpu_mut(gpu).attach_backbone(model)?;
        Ok(IpcHandle { model: model.to_string(), gpu, function })
    }

    /// Release a handle.
    pub fn detach(
        &mut self,
        cluster: &mut Cluster,
        handle: &IpcHandle,
    ) -> Result<(), GpuError> {
        cluster.gpu_mut(handle.gpu).detach_backbone(&handle.model)
    }

    /// Unload the shared copy from one GPU (offloader path). Fails while
    /// any handle is open — memory is never yanked under a live reader.
    pub fn unload(
        &mut self,
        cluster: &mut Cluster,
        model: &str,
        gpu: GpuId,
    ) -> Result<f64, GpuError> {
        let freed = cluster.gpu_mut(gpu).unload_shared_backbone(model)?;
        if let Some(v) = self.hosts.get_mut(model) {
            v.retain(|&g| g != gpu);
        }
        Ok(freed)
    }

    /// Total GPU memory saved relative to per-function private copies:
    /// (attached_instances − hosted_copies) × size (Observation 1's 99%).
    pub fn savings_gb(&self, cluster: &Cluster, model: &str, size_gb: f64) -> f64 {
        let attached: usize = self
            .hosts(model)
            .iter()
            .map(|&g| cluster.gpu(g).backbone_refcount(model))
            .sum();
        let copies = self.hosts(model).len();
        (attached.saturating_sub(copies)) as f64 * size_gb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Cluster, BackboneRegistry) {
        (Cluster::new(1, 2, 2), BackboneRegistry::new())
    }

    #[test]
    fn attach_requires_hosted() {
        let (mut c, mut r) = setup();
        let g = c.gpu_ids()[0];
        assert!(r.attach(&mut c, "7b", g, 0).is_err());
        r.load(&mut c, "7b", 13.5, g).unwrap();
        let h = r.attach(&mut c, "7b", g, 0).unwrap();
        assert_eq!(c.gpu(g).backbone_refcount("7b"), 1);
        r.detach(&mut c, &h).unwrap();
        assert_eq!(c.gpu(g).backbone_refcount("7b"), 0);
    }

    #[test]
    fn hundreds_of_functions_one_copy() {
        // §4.4: "A GPU can hold hundreds of LoRA functions simultaneously
        // using one backbone LLM."
        let (mut c, mut r) = setup();
        let g = c.gpu_ids()[0];
        r.load(&mut c, "7b", 13.5, g).unwrap();
        let before = c.gpu(g).free_gb();
        for f in 0..200 {
            r.attach(&mut c, "7b", g, f).unwrap();
        }
        // Attaching costs zero backbone bytes.
        assert_eq!(c.gpu(g).free_gb(), before);
        assert!((r.savings_gb(&c, "7b", 13.5) - 199.0 * 13.5).abs() < 1e-6);
    }

    #[test]
    fn unload_blocked_by_open_handles() {
        let (mut c, mut r) = setup();
        let g = c.gpu_ids()[0];
        r.load(&mut c, "7b", 13.5, g).unwrap();
        let h = r.attach(&mut c, "7b", g, 0).unwrap();
        assert!(r.unload(&mut c, "7b", g).is_err());
        r.detach(&mut c, &h).unwrap();
        assert_eq!(r.unload(&mut c, "7b", g).unwrap(), 13.5);
        assert!(r.hosts("7b").is_empty());
    }

    #[test]
    fn multiple_hosts_tracked() {
        let (mut c, mut r) = setup();
        let ids = c.gpu_ids();
        r.load(&mut c, "7b", 13.5, ids[0]).unwrap();
        r.load(&mut c, "7b", 13.5, ids[1]).unwrap();
        assert_eq!(r.hosts("7b").len(), 2);
        r.unload(&mut c, "7b", ids[0]).unwrap();
        assert_eq!(r.hosts("7b"), &[ids[1]]);
    }

    #[test]
    fn load_idempotent_in_registry() {
        let (mut c, mut r) = setup();
        let g = c.gpu_ids()[0];
        r.load(&mut c, "7b", 13.5, g).unwrap();
        r.load(&mut c, "7b", 13.5, g).unwrap();
        assert_eq!(r.hosts("7b").len(), 1);
    }
}
