//! # ServerlessLoRA
//!
//! A reproduction of *ServerlessLoRA: Minimizing Latency and Cost in
//! Serverless Inference for LoRA-Based LLMs* as a three-layer
//! Rust + JAX + Pallas system (see DESIGN.md).
//!
//! * `coordinator` — the paper's contribution: PCKP pre-loading (§4.1),
//!   two-layer adaptive batching (§4.2), dynamic GPU offloading (§4.3),
//!   locality-aware routing.
//! * `coldstart` — pluggable cold-start strategies (tiered /
//!   snapshot-restore / pipelined multi-GPU) behind the sixth policy
//!   trait (`ColdStartPolicy`); mechanism in `sim::coldstart`.
//! * `sharing` — backbone-sharing registry (§4.4, CUDA-IPC analogue).
//! * `cluster` — simulated GPU/container substrate with strict ledgers.
//! * `trace`, `cost`, `metrics` — workload, pricing and measurement.
//! * `sim` — discrete-event simulator (engine core + events + dispatch +
//!   billing) and the system configs that build policy bundles.
//! * `runtime` — real PJRT data plane: loads the AOT HLO-text artifacts
//!   and serves the tiny-Llama model with genuinely shared backbone
//!   buffers and isolated per-function state. Behind the `pjrt` feature
//!   (needs the external `xla` crate).
//! * `scenario` — the declarative scenario API: a typed `ScenarioSpec`
//!   (system + overrides, cluster shape, workload, seeds, sinks) with
//!   JSON round-trip, validated and executed by `scenario::run` /
//!   `run_grid`. The experiment suites and the `run --scenario` CLI
//!   share this single entry point.
//! * `exp` — one entry per paper table/figure (the bench harness calls
//!   these), each building `ScenarioSpec` grids, plus the parallel
//!   experiment runner.
//!
//! The policy layer (`coordinator::policy`) is the extension point: a new
//! serving system is a policy bundle registered in `sim::config`, never
//! an engine edit. See DESIGN.md.

pub mod artifact;
pub mod cluster;
pub mod coldstart;
pub mod coordinator;
pub mod cost;
pub mod exp;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scenario;
pub mod sharing;
pub mod sim;
pub mod trace;
pub mod util;
