//! Monetary cost model (paper §6.1, Alibaba Cloud Function Compute GPU
//! pricing) and the paper's cost-effectiveness metric
//! `1 / (E2E_latency × Monetary_Cost)` (footnote 3 / §6.4).

use crate::artifact::params;

/// Accumulates billable resource-time for one simulated run.
#[derive(Debug, Clone, Default)]
pub struct CostTracker {
    /// Active GPU memory × time (GB·s) — execution + artifact loading.
    pub gpu_active_gb_s: f64,
    /// Idle (keep-alive) GPU memory × time (GB·s).
    pub gpu_idle_gb_s: f64,
    /// vCPU core seconds.
    pub cpu_core_s: f64,
    /// Host memory GB seconds.
    pub mem_gb_s: f64,
    /// Serverful: dedicated whole-GPU seconds (billed regardless of use).
    pub serverful_gpu_s: f64,
    /// Snapshot-storage surcharge, USD (already priced — the cold-start
    /// subsystem integrates resident snapshot GB × its storage rate).
    /// Identically 0.0 unless the snapshot-restore strategy is active.
    pub snapshot_usd: f64,
}

impl CostTracker {
    pub fn add_active(&mut self, gpu_gb: f64, dur_s: f64, cpu_cores: f64, mem_gb: f64) {
        debug_assert!(dur_s >= 0.0);
        self.gpu_active_gb_s += gpu_gb * dur_s;
        self.cpu_core_s += cpu_cores * dur_s;
        self.mem_gb_s += mem_gb * dur_s;
    }

    pub fn add_idle(&mut self, gpu_gb: f64, dur_s: f64, mem_gb: f64) {
        debug_assert!(dur_s >= 0.0);
        self.gpu_idle_gb_s += gpu_gb * dur_s;
        self.mem_gb_s += mem_gb * dur_s;
    }

    pub fn add_serverful(&mut self, n_gpus: f64, dur_s: f64) {
        self.serverful_gpu_s += n_gpus * dur_s;
    }

    /// Total monetary cost in dollars.  The snapshot surcharge is added
    /// last: `x + 0.0` is bit-exact for the non-negative sums here, so
    /// runs without snapshots price bit-identically to pre-subsystem
    /// builds.
    pub fn total_usd(&self) -> f64 {
        self.gpu_active_gb_s * params::PRICE_GPU_GB_S
            + self.gpu_idle_gb_s * params::PRICE_GPU_IDLE_GB_S
            + self.cpu_core_s * params::PRICE_CPU_CORE_S
            + self.mem_gb_s * params::PRICE_MEM_GB_S
            + self.serverful_gpu_s * params::PRICE_SERVERFUL_GPU_S
            + self.snapshot_usd
    }

    /// Share of the bill attributable to GPU resources — the paper states
    /// ~90% for LLM functions; exposed so tests can sanity-check the model.
    pub fn gpu_share(&self) -> f64 {
        let gpu = self.gpu_active_gb_s * params::PRICE_GPU_GB_S
            + self.gpu_idle_gb_s * params::PRICE_GPU_IDLE_GB_S
            + self.serverful_gpu_s * params::PRICE_SERVERFUL_GPU_S;
        let t = self.total_usd();
        if t == 0.0 {
            0.0
        } else {
            gpu / t
        }
    }

    pub fn merge(&mut self, other: &CostTracker) {
        self.gpu_active_gb_s += other.gpu_active_gb_s;
        self.gpu_idle_gb_s += other.gpu_idle_gb_s;
        self.cpu_core_s += other.cpu_core_s;
        self.mem_gb_s += other.mem_gb_s;
        self.serverful_gpu_s += other.serverful_gpu_s;
        self.snapshot_usd += other.snapshot_usd;
    }
}

/// Paper footnote 3: cost-effectiveness = 1/(E2E_latency × Monetary_Cost).
/// Reported *relative to a baseline* (vLLM = 1) in Figs. 2 & 9 / Table 1.
pub fn cost_effectiveness(mean_e2e_s: f64, cost_usd: f64) -> f64 {
    if mean_e2e_s <= 0.0 || cost_usd <= 0.0 {
        return 0.0;
    }
    1.0 / (mean_e2e_s * cost_usd)
}

pub fn relative_cost_effectiveness(
    mean_e2e_s: f64,
    cost_usd: f64,
    base_e2e_s: f64,
    base_cost_usd: f64,
) -> f64 {
    cost_effectiveness(mean_e2e_s, cost_usd)
        / cost_effectiveness(base_e2e_s, base_cost_usd)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invocation_cost_magnitude() {
        // One 7B invocation: ~20 GB for ~3 s ⇒ around a tenth of a cent.
        let mut c = CostTracker::default();
        c.add_active(20.0, 3.0, 4.0, 16.0);
        let usd = c.total_usd();
        assert!(usd > 2e-4 && usd < 3e-3, "usd={usd}");
    }

    #[test]
    fn gpu_dominates_invocation_cost() {
        // §2.2: "GPU costs constitute approximately 90% of an invocation's
        // total monetary expense".
        let mut c = CostTracker::default();
        c.add_active(20.0, 3.0, 4.0, 16.0);
        assert!(c.gpu_share() > 0.75, "share={}", c.gpu_share());
    }

    #[test]
    fn serverful_hour_is_dollars() {
        let mut c = CostTracker::default();
        c.add_serverful(1.0, 3600.0);
        let usd = c.total_usd();
        assert!((usd - 1.86).abs() < 0.1, "usd={usd}");
    }

    #[test]
    fn cost_effectiveness_ordering() {
        // Faster and cheaper ⇒ strictly better.
        let better = cost_effectiveness(2.0, 5.0);
        let worse = cost_effectiveness(4.0, 20.0);
        assert!(better > worse);
        assert_eq!(
            relative_cost_effectiveness(2.0, 5.0, 2.0, 5.0),
            1.0
        );
    }

    #[test]
    fn merge_adds() {
        let mut a = CostTracker::default();
        a.add_active(10.0, 1.0, 1.0, 1.0);
        let mut b = CostTracker::default();
        b.add_idle(5.0, 2.0, 1.0);
        let ta = a.total_usd();
        let tb = b.total_usd();
        a.merge(&b);
        assert!((a.total_usd() - ta - tb).abs() < 1e-12);
    }

    #[test]
    fn snapshot_surcharge_prices_into_total_not_gpu_share() {
        let mut c = CostTracker::default();
        c.add_active(20.0, 3.0, 4.0, 16.0);
        let base = c.total_usd();
        let share = c.gpu_share();
        c.snapshot_usd = 5e-4;
        assert!((c.total_usd() - base - 5e-4).abs() < 1e-15);
        assert!(c.gpu_share() < share, "surcharge dilutes the GPU share");
        let mut other = CostTracker::default();
        other.snapshot_usd = 1e-4;
        c.merge(&other);
        assert!((c.snapshot_usd - 6e-4).abs() < 1e-15);
    }

    #[test]
    fn degenerate_inputs_are_zero() {
        assert_eq!(cost_effectiveness(0.0, 1.0), 0.0);
        assert_eq!(cost_effectiveness(1.0, 0.0), 0.0);
    }
}
