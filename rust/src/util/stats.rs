//! Streaming/summary statistics used by the metrics layer and the
//! trace-generator calibration tests: mean, variance, CoV, percentiles, CDF.

/// Summary statistics over a sample.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Percentile by linear interpolation on the sorted sample (inclusive).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    Summary {
        count: xs.len(),
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: percentile(&sorted, 0.50),
        p90: percentile(&sorted, 0.90),
        p95: percentile(&sorted, 0.95),
        p99: percentile(&sorted, 0.99),
    }
}

/// Coefficient of variation (std/mean) — the statistic the paper uses to
/// classify Azure traces into Predictable / Normal / Bursty.
pub fn cov(xs: &[f64]) -> f64 {
    let s = summarize(xs);
    if s.mean == 0.0 {
        0.0
    } else {
        s.std / s.mean
    }
}

/// Empirical CDF evaluated at the given thresholds: fraction of samples <= t.
pub fn cdf_at(xs: &[f64], thresholds: &[f64]) -> Vec<f64> {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    thresholds
        .iter()
        .map(|t| {
            let k = sorted.partition_point(|x| x <= t);
            k as f64 / sorted.len().max(1) as f64
        })
        .collect()
}

/// Fraction of samples strictly above the threshold (e.g. SLO violations).
pub fn frac_above(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x > threshold).count() as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert!((percentile(&xs, 0.25) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn summary_constant_series() {
        let s = summarize(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 2.0);
    }

    #[test]
    fn cov_exponential_is_one() {
        use crate::util::rng::Pcg64;
        let mut r = Pcg64::new(1);
        let xs: Vec<f64> = (0..30_000).map(|_| r.exp(3.0)).collect();
        assert!((cov(&xs) - 1.0).abs() < 0.03, "cov={}", cov(&xs));
    }

    #[test]
    fn cdf_monotone() {
        let xs = [1.0, 2.0, 3.0];
        let c = cdf_at(&xs, &[0.5, 1.5, 2.5, 3.5]);
        assert_eq!(c, vec![0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0]);
    }

    #[test]
    fn frac_above_works() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(frac_above(&xs, 2.5), 0.5);
        assert_eq!(frac_above(&xs, 10.0), 0.0);
        assert_eq!(frac_above(&[], 1.0), 0.0);
    }

    #[test]
    fn empty_summary_is_default() {
        assert_eq!(summarize(&[]).count, 0);
    }
}
