//! Deterministic PRNG (PCG-XSH-RR 64/32) plus the distributions the
//! simulator needs (uniform, exponential, gamma, normal, zipf).
//!
//! The `rand` crate is not vendored in this environment; this is a small,
//! well-known generator with reproducible streams so every experiment is
//! seed-stable across runs and platforms.

/// PCG-XSH-RR 64/32 (O'Neill 2014). 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53-bit precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire-style rejection-free for our needs.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Exponential with the given rate (mean 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        mean + std * (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Gamma(shape k, scale θ) via Marsaglia-Tsang (k >= 1) with the
    /// standard boost for k < 1. Used for CoV-controlled inter-arrivals:
    /// a Gamma renewal process with shape 1/CoV² has exactly that CoV.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal(0.0, 1.0);
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * scale;
            }
        }
    }

    /// Log-normal with the given median and sigma (of the underlying normal).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (self.normal(median.ln(), sigma)).exp()
    }

    /// Zipf-like rank selection over n items with exponent s (cheap inverse
    /// CDF by linear scan; n is small in all our uses).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        let norm: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * norm;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Precomputed Zipf(s) CDF over `n` ranks: O(n) to build, O(log n) per
/// draw. The linear-scan [`Pcg64::zipf`] recomputes the normalizer on
/// every call, which is fine for one-off draws over small `n` but not
/// for labelling a whole fleet-scale arrival stream (4096 functions ×
/// tens of thousands of requests).
#[derive(Debug, Clone)]
pub struct ZipfCdf {
    cdf: Vec<f64>,
}

impl ZipfCdf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf over zero ranks");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        ZipfCdf { cdf }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// P(rank) — rank 0 is the most popular.
    pub fn pmf(&self, rank: usize) -> f64 {
        let lo = if rank == 0 { 0.0 } else { self.cdf[rank - 1] };
        self.cdf[rank] - lo
    }

    /// Draw a rank in `[0, n)` by inverse CDF (binary search).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Pcg64::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_moments() {
        // Gamma(k, θ): mean kθ, var kθ².
        let mut r = Pcg64::new(5);
        let (k, th) = (4.0, 0.5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, th)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Pcg64::new(6);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| r.gamma(0.25, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.08, "mean={mean}");
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let mut r = Pcg64::new(8);
        let mut counts = [0usize; 5];
        for _ in 0..5000 {
            counts[r.zipf(5, 1.2)] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[3]);
    }

    #[test]
    fn zipf_cdf_matches_linear_scan_distribution() {
        // The precomputed CDF and the linear scan draw from the same
        // law: their empirical head frequencies agree within noise.
        let (n, s) = (16, 1.1);
        let table = ZipfCdf::new(n, s);
        let mut pmf_sum = 0.0;
        for r in 0..n {
            pmf_sum += table.pmf(r);
            if r > 0 {
                assert!(table.pmf(r) < table.pmf(r - 1), "pmf not decreasing");
            }
        }
        assert!((pmf_sum - 1.0).abs() < 1e-12);
        let mut rng_a = Pcg64::new(12);
        let mut rng_b = Pcg64::new(13);
        let trials = 40_000;
        let (mut head_a, mut head_b) = (0usize, 0usize);
        for _ in 0..trials {
            if table.sample(&mut rng_a) == 0 {
                head_a += 1;
            }
            if rng_b.zipf(n, s) == 0 {
                head_b += 1;
            }
        }
        let (fa, fb) = (head_a as f64 / trials as f64, head_b as f64 / trials as f64);
        assert!((fa - fb).abs() < 0.02, "head freq {fa} vs {fb}");
        assert!((fa - table.pmf(0)).abs() < 0.02, "head freq {fa} vs pmf {}", table.pmf(0));
    }

    #[test]
    fn zipf_cdf_sample_in_range_even_at_u_extremes() {
        let table = ZipfCdf::new(3, 2.0);
        let mut rng = Pcg64::new(5);
        for _ in 0..1000 {
            assert!(table.sample(&mut rng) < 3);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
