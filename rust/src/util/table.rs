//! Aligned plain-text table printer for the benchmark harness — every
//! paper table/figure is rendered as rows of this.

pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with sensible precision for report tables.
pub fn f(x: f64) -> String {
    if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

/// Format milliseconds from seconds.
pub fn ms(secs: f64) -> String {
    format!("{:.0}", secs * 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row(vec!["xxx".into(), "1".into()]);
        let out = t.render();
        assert!(out.contains("== T =="));
        assert!(out.contains("long-header"));
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new("T", &["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn float_formats() {
        assert_eq!(f(12345.6), "12346");
        assert_eq!(f(42.34), "42.3");
        assert_eq!(f(1.234), "1.23");
        assert_eq!(ms(0.5761), "576");
    }
}
