//! Minimal JSON parser + writer (serde is not vendored in this build
//! environment). Parses the artifact `manifest.json` / `golden.json`
//! emitted by `python/compile/aot.py` and serializes experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Non-negative integral number → u64 (seeds, counts). `None` for
    /// negatives, fractions, or values past exact f64 integer range.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64()
            .filter(|v| v.fract() == 0.0 && (0.0..=9.007e15).contains(v))
            .map(|v| v as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(xs: I) -> Json {
    Json::Arr(xs.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || b"+-.eE".contains(&c))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("bad \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bool_and_u64_accessors() {
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"x":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/llama-tiny/manifest.json"
        );
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert_eq!(m.get("model").unwrap().as_str(), Some("llama-tiny"));
            assert!(m.get("artifacts").unwrap().as_arr().unwrap().len() >= 4);
        }
    }
}
