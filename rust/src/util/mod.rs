//! Dependency-free utilities: deterministic RNG, statistics, JSON, tables.
//! (The sandbox vendors only the `xla` crate tree, so the usual helpers —
//! `rand`, `serde`, `criterion` — are reimplemented here at the scale this
//! project needs.)

pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
