//! Dependency-free utilities: deterministic RNG, statistics, JSON, tables.
//! (The sandbox vendors only the `xla` crate tree, so the usual helpers —
//! `rand`, `serde`, `criterion` — are reimplemented here at the scale this
//! project needs.)

pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Total-order key for an `f64`: a monotone bijection onto `u64` whose
/// `Ord` matches `f64::total_cmp`. Backs the ordered indexes that need
/// floats as B-tree keys (the cluster's free-memory index, keep-alive's
/// expiry order).
pub fn f64_key(x: f64) -> u64 {
    let b = x.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

#[cfg(test)]
mod tests {
    use super::f64_key;

    #[test]
    fn f64_key_matches_total_cmp() {
        let xs = [
            f64::NEG_INFINITY,
            -1e300,
            -2.0,
            -1.0,
            -1e-300,
            -0.0,
            0.0,
            1e-300,
            1.0,
            2.0,
            1e300,
            f64::INFINITY,
        ];
        for a in xs {
            for b in xs {
                assert_eq!(
                    f64_key(a).cmp(&f64_key(b)),
                    a.total_cmp(&b),
                    "key order diverged for {a} vs {b}"
                );
            }
        }
    }
}
