//! FNV-1a 64-bit — the shared digest behind the bench harness's
//! `BENCH_sim.json` output fingerprints and the integration tests' golden
//! metric fingerprints. One implementation so the two can never drift.

pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
pub const FNV_PRIME: u64 = 0x100000001b3;

pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Incremental variant for streaming mixed values without allocating.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a(FNV_OFFSET)
    }
}

impl Fnv1a {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let mut h = Fnv1a::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
        let mut h2 = Fnv1a::new();
        h2.write_u64(0x0102030405060708);
        assert_eq!(
            h2.finish(),
            fnv1a(&[0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01])
        );
    }
}
