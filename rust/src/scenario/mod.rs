//! Declarative scenario API — **the** way to run the simulator.
//!
//! The paper's evaluation (§6) is a grid of (system × workload × cluster
//! × seed) cells. A [`ScenarioSpec`] describes one cell declaratively
//! (typed builder in Rust, JSON on disk — `serverless-lora run
//! --scenario file.json`); [`run`] executes it and [`run_grid`] executes
//! a whole grid, fanning every `(spec, seed)` pair out through the
//! parallel experiment runner (`--jobs`) while preserving grid order.
//! Every experiment suite in `exp/` builds its tables through this entry
//! point, so a table cell and a JSON-driven CLI run are the *same* code
//! path — bit-identical by construction.
//!
//! Output sinks are selected in the spec ([`SinkSpec`]): billing
//! wall-clock metering, the opt-in per-billing-class time series
//! (`sim::observe::BillSeriesSampler`), and the opt-in per-request
//! trace file (`sim::observe::TraceExport`, CSV or JSON, with a
//! `{seed}` path placeholder for multi-seed scenarios) — all off by
//! default.

pub mod spec;

use std::time::Instant;

pub use spec::{
    BatchingOverride, ClusterSpec, ScenarioBuilder, ScenarioError, ScenarioSpec, SinkSpec,
    SystemSpec, TraceFormat, TraceSinkSpec, WorkloadSpec, SYSTEM_IDS,
};

use crate::cost::CostTracker;
use crate::exp::runner;
use crate::metrics::{RunMetrics, RunStats};
use crate::sim::{sharded, BillSeries, Engine};
use crate::trace::Pattern;
use crate::util::json::Json;
use crate::util::table::{f, ms, Table};

/// One seed's complete result.
pub struct SeedRun {
    pub seed: u64,
    /// Offered requests (the workload's trace length) — completions are
    /// `metrics.outcomes.len()`.
    pub requests: usize,
    /// Wall-clock for engine construction + run (workload generation
    /// excluded), measured inside the worker.
    pub wall_s: f64,
    pub metrics: RunMetrics,
    pub cost: CostTracker,
    pub stats: RunStats,
    pub bill_series: Option<BillSeries>,
    /// Fraction of offered requests whose TTFT met the per-function SLO
    /// (the function spec's `slo_ttft_s`); failed requests count as misses.
    pub slo_attainment: f64,
}

/// One scenario's results: one [`SeedRun`] per seed, in seed order.
pub struct ScenarioReport {
    pub name: String,
    /// The resolved system's display name (e.g. "ServerlessLoRA-NPL").
    pub system: String,
    pub runs: Vec<SeedRun>,
}

impl ScenarioReport {
    /// The single run of a one-seed scenario (panics otherwise — grid
    /// code that fans one engine seed per cell uses this).
    pub fn only(&self) -> &SeedRun {
        assert_eq!(self.runs.len(), 1, "scenario '{}' has {} runs", self.name, self.runs.len());
        &self.runs[0]
    }

    /// Owning variant of [`ScenarioReport::only`]: the system name and
    /// the single run, asserting the report holds exactly one (a
    /// `runs.pop()` would silently take the *last* seed of a
    /// multi-seed cell instead of failing).
    pub fn into_only(self) -> (String, SeedRun) {
        assert_eq!(self.runs.len(), 1, "scenario '{}' has {} runs", self.name, self.runs.len());
        let mut runs = self.runs;
        (self.system, runs.pop().expect("length asserted above"))
    }
}

/// Validate and run one scenario: every seed fans out through the
/// parallel runner; results come back in seed order.
pub fn run(spec: &ScenarioSpec) -> Result<ScenarioReport, ScenarioError> {
    Ok(run_grid(std::slice::from_ref(spec))?.pop().expect("one spec, one report"))
}

/// Validate and run a grid of scenarios. All `(spec, seed)` pairs share
/// one order-preserving parallel fan-out (`exp::runner`), so a 12-cell
/// grid parallelizes exactly like the historical hand-wired experiment
/// loops did.
pub fn run_grid(specs: &[ScenarioSpec]) -> Result<Vec<ScenarioReport>, ScenarioError> {
    for sp in specs {
        sp.validate()?;
    }
    let tasks: Vec<(usize, u64)> = specs
        .iter()
        .enumerate()
        .flat_map(|(i, sp)| sp.seeds.iter().map(move |&seed| (i, seed)))
        .collect();
    let runs = runner::parallel_map(tasks, |(i, seed)| (i, run_seed(&specs[i], seed)));
    let mut reports: Vec<ScenarioReport> = specs
        .iter()
        .map(|sp| ScenarioReport {
            name: sp.name.clone(),
            system: sp.system_name(),
            runs: Vec::new(),
        })
        .collect();
    for (i, run) in runs {
        reports[i].runs.push(run);
    }
    Ok(reports)
}

fn run_seed(sp: &ScenarioSpec, seed: u64) -> SeedRun {
    let workload = sp.workload.materialize(sp.horizon_s);
    let requests = workload.requests.len();
    // Per-function SLO snapshot (the workload moves into the engine).
    let slos: Vec<f64> = workload.functions.iter().map(|f| f.slo_ttft_s).collect();
    let cfg = sp
        .system
        .resolve(sp.workload.pattern().unwrap_or(Pattern::Normal))
        .expect("specs are validated before running");
    let t0 = Instant::now();
    let out = if sp.cluster.zones() > 1 {
        // Zone-sharded cluster: one engine thread per zone, coupled at
        // conservative window boundaries (sim::sharded).
        sharded::run_zones(
            &cfg,
            sp.cluster.materialize_zones(),
            workload,
            seed,
            sharded::Mode::Parallel,
            sp.sinks.bill_timing,
            sp.sinks.bill_series_bucket_s,
        )
    } else {
        let mut engine = Engine::new(cfg, sp.cluster.materialize(), workload, seed);
        if sp.sinks.bill_timing {
            engine.set_bill_timing(true);
        }
        if let Some(bucket_s) = sp.sinks.bill_series_bucket_s {
            engine.enable_bill_series(bucket_s);
        }
        if let Some(t) = &sp.sinks.request_trace {
            let path = t.path_for_seed(seed);
            engine.attach_observer(Box::new(match t.format {
                TraceFormat::Csv => crate::sim::TraceExport::csv(&path),
                TraceFormat::Json => crate::sim::TraceExport::json(&path),
            }));
        }
        engine.run_full()
    };
    let slo_attainment = out.metrics.slo_attainment(|f| slos[f]);
    SeedRun {
        seed,
        requests,
        wall_s: t0.elapsed().as_secs_f64(),
        metrics: out.metrics,
        cost: out.cost,
        stats: out.stats,
        bill_series: out.bill_series,
        slo_attainment,
    }
}

// ------------------------------------------------------- summarization

/// Mean ± half-width of a 95% confidence interval across seeds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricSummary {
    pub mean: f64,
    /// `1.96 · s / √n` with the sample (n − 1) standard deviation;
    /// zero for a single observation.
    pub ci95: f64,
}

impl MetricSummary {
    pub fn of(xs: &[f64]) -> Self {
        let n = xs.len();
        if n == 0 {
            return MetricSummary { mean: 0.0, ci95: 0.0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        if n == 1 {
            return MetricSummary { mean, ci95: 0.0 };
        }
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0);
        MetricSummary { mean, ci95: 1.96 * var.sqrt() / (n as f64).sqrt() }
    }

    /// A "12.3 ± 0.4"-style table cell; plain mean when the CI is zero.
    pub fn cell(&self, decimals: usize) -> String {
        if self.ci95 == 0.0 {
            format!("{:.*}", decimals, self.mean)
        } else {
            format!("{:.*} ± {:.*}", decimals, self.mean, decimals, self.ci95)
        }
    }
}

/// Cross-seed aggregate of one scenario's runs: mean ± 95% CI for the
/// headline metrics, including the fault-injection goodput/failure view.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSummary {
    pub name: String,
    pub system: String,
    pub seeds: usize,
    /// Offered requests per seed (identical across engine seeds: the
    /// workload generator has its own seed in the spec).
    pub requests: usize,
    pub completed: MetricSummary,
    pub failed: MetricSummary,
    pub goodput: MetricSummary,
    /// Deadline hit-rate: TTFT ≤ the profile SLO, failures as misses.
    pub slo_attainment: MetricSummary,
    pub ttft_ms: MetricSummary,
    pub e2e_ms: MetricSummary,
    pub cost_usd: MetricSummary,
}

/// Collapse a multi-seed report into mean ± 95% CI per metric.
pub fn summarize(report: &ScenarioReport) -> ScenarioSummary {
    fn of(report: &ScenarioReport, f: fn(&SeedRun) -> f64) -> MetricSummary {
        MetricSummary::of(&report.runs.iter().map(f).collect::<Vec<f64>>())
    }
    ScenarioSummary {
        name: report.name.clone(),
        system: report.system.clone(),
        seeds: report.runs.len(),
        requests: report.runs.first().map_or(0, |r| r.requests),
        completed: of(report, |r| r.metrics.outcomes.len() as f64),
        failed: of(report, |r| r.metrics.failed as f64),
        goodput: of(report, |r| r.metrics.goodput()),
        slo_attainment: of(report, |r| r.slo_attainment),
        ttft_ms: of(report, |r| r.metrics.ttft().mean * 1000.0),
        e2e_ms: of(report, |r| r.metrics.e2e().mean * 1000.0),
        cost_usd: of(report, |r| r.cost.total_usd()),
    }
}

/// Render summaries as one row per scenario (the multi-seed companion
/// to [`render_reports`]' one-row-per-seed view).
pub fn render_summaries(summaries: &[ScenarioSummary]) -> String {
    let mut t = Table::new(
        "Scenario summary (mean ± 95% CI across seeds)",
        &[
            "scenario",
            "system",
            "seeds",
            "requests",
            "completed",
            "failed",
            "goodput",
            "SLO-att",
            "TTFT(ms)",
            "E2E(ms)",
            "cost($)",
        ],
    );
    for s in summaries {
        t.row(vec![
            s.name.clone(),
            s.system.clone(),
            s.seeds.to_string(),
            s.requests.to_string(),
            s.completed.cell(1),
            s.failed.cell(1),
            s.goodput.cell(3),
            s.slo_attainment.cell(3),
            s.ttft_ms.cell(1),
            s.e2e_ms.cell(1),
            s.cost_usd.cell(2),
        ]);
    }
    t.render()
}

/// Parse a scenario file's JSON: either one spec object or an array of
/// them (a grid).
pub fn specs_from_json(j: &Json) -> Result<Vec<ScenarioSpec>, ScenarioError> {
    match j {
        Json::Arr(xs) => {
            if xs.is_empty() {
                return Err(ScenarioError::Parse(
                    "scenario file holds an empty array".to_string(),
                ));
            }
            xs.iter().map(ScenarioSpec::from_json).collect()
        }
        Json::Obj(_) => Ok(vec![ScenarioSpec::from_json(j)?]),
        _ => Err(ScenarioError::Parse(
            "a scenario file must hold a JSON object or an array of them".to_string(),
        )),
    }
}

/// Render a grid's reports: one summary row per (scenario, seed), plus a
/// per-class cost-trajectory table for every run that enabled the
/// series sink.
pub fn render_reports(reports: &[ScenarioReport]) -> String {
    let mut t = Table::new(
        "Scenario report",
        &[
            "scenario",
            "system",
            "seed",
            "requests",
            "completed",
            "TTFT(ms)",
            "TTFT-p99(ms)",
            "E2E(ms)",
            "cost($)",
            "bill samples",
        ],
    );
    for r in reports {
        for run in &r.runs {
            t.row(vec![
                r.name.clone(),
                r.system.clone(),
                run.seed.to_string(),
                run.requests.to_string(),
                run.metrics.outcomes.len().to_string(),
                ms(run.metrics.ttft().mean),
                ms(run.metrics.ttft().p99),
                ms(run.metrics.e2e().mean),
                f(run.cost.total_usd()),
                run.stats.bill_samples.to_string(),
            ]);
        }
    }
    let mut out = t.render();
    for r in reports {
        for run in &r.runs {
            if let Some(series) = &run.bill_series {
                out.push_str(&render_series(&r.name, run.seed, series));
            }
        }
    }
    out
}

fn render_series(name: &str, seed: u64, series: &BillSeries) -> String {
    let mut t = Table::new(
        &format!(
            "Per-class cost trajectory — {name} (seed {seed}, {} s buckets)",
            series.bucket_s
        ),
        &[
            "t0(s)",
            "active GB*s",
            "loading GB*s",
            "idle-warm GB*s",
            "idle-cold GB*s",
            "active GPU*s",
            "idle-warm GPU*s",
        ],
    );
    for (i, b) in series.buckets.iter().enumerate() {
        t.row(vec![
            format!("{}", i as f64 * series.bucket_s),
            f(b.active_gb_s),
            f(b.loading_gb_s),
            f(b.idle_warm_gb_s),
            f(b.idle_cold_gb_s),
            f(b.active_gpu_s),
            f(b.idle_warm_gpu_s),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SystemConfig;

    fn quick_spec(name: &str, system: &str, seeds: Vec<u64>) -> ScenarioSpec {
        ScenarioSpec::builder(name)
            .system(system)
            .cluster(ClusterSpec::Uniform {
                nodes: 1,
                gpus_per_node: 2,
                containers_per_node: 4,
                trim_gpus: None,
                zones: 1,
            })
            .workload(WorkloadSpec::Paper { pattern: Pattern::Bursty, seed: 9 })
            .horizon_s(300.0)
            .seeds(seeds)
            .build()
            .unwrap()
    }

    #[test]
    fn run_conserves_requests_and_orders_seeds() {
        let spec = quick_spec("t", "serverless-lora", vec![1, 7, 23]);
        let report = run(&spec).unwrap();
        assert_eq!(report.system, "ServerlessLoRA");
        assert_eq!(report.runs.len(), 3);
        assert_eq!(
            report.runs.iter().map(|r| r.seed).collect::<Vec<_>>(),
            vec![1, 7, 23],
            "seed order must be preserved"
        );
        for r in &report.runs {
            assert_eq!(r.metrics.outcomes.len(), r.requests, "lost requests");
            assert!(r.wall_s >= 0.0);
        }
    }

    #[test]
    fn run_grid_preserves_spec_order() {
        let specs = vec![
            quick_spec("a", "serverless-lora", vec![1]),
            quick_spec("b", "serverless-llm", vec![1]),
            quick_spec("c", "npl", vec![1]),
        ];
        let reports = run_grid(&specs).unwrap();
        let names: Vec<&str> = reports.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        assert_eq!(reports[1].system, "ServerlessLLM");
        assert_eq!(reports[2].system, "ServerlessLoRA-NPL");
    }

    /// The acceptance contract: a scenario run is the SAME code path as
    /// the historical hand-wired run — bit-identical metrics and cost.
    #[test]
    fn scenario_run_matches_direct_engine_run_bitwise() {
        let spec = quick_spec("parity", "serverless-lora", vec![7]);
        let report = run(&spec).unwrap();
        let w = crate::sim::workloads::paper_workload(Pattern::Bursty, 300.0, 9);
        let (m, c, _) = Engine::new(
            SystemConfig::serverless_lora(),
            crate::cluster::Cluster::new(1, 2, 4),
            w,
            7,
        )
        .run();
        let r = report.only();
        assert_eq!(r.metrics.outcomes.len(), m.outcomes.len());
        assert_eq!(r.metrics.ttft().mean.to_bits(), m.ttft().mean.to_bits());
        assert_eq!(r.cost.total_usd().to_bits(), c.total_usd().to_bits());
    }

    /// Enabling the series sink must not perturb metrics or cost by one
    /// bit, and must add zero extra billing samples.
    #[test]
    fn series_sink_is_observation_only() {
        let plain = run(&quick_spec("off", "serverless-lora", vec![3])).unwrap();
        let mut spec = quick_spec("on", "serverless-lora", vec![3]);
        spec.sinks.bill_series_bucket_s = Some(60.0);
        let sampled = run(&spec).unwrap();
        let (p, q) = (plain.only(), sampled.only());
        assert!(p.bill_series.is_none());
        let series = q.bill_series.as_ref().expect("series sink enabled");
        assert!(!series.buckets.is_empty());
        assert_eq!(p.metrics.ttft().mean.to_bits(), q.metrics.ttft().mean.to_bits());
        assert_eq!(p.cost.total_usd().to_bits(), q.cost.total_usd().to_bits());
        assert_eq!(p.stats.bill_samples, q.stats.bill_samples, "sampler took extra samples");
        // The trajectory integrates to the cost tracker's totals
        // (shared billing prices used GB of active + loading classes).
        use crate::sim::BillClass;
        let active = series.total_gb_s(BillClass::ActiveExec)
            + series.total_gb_s(BillClass::ActiveLoading);
        let idle = series.total_gb_s(BillClass::IdleWarm);
        assert!(
            (active - q.cost.gpu_active_gb_s).abs() <= 1e-6 * q.cost.gpu_active_gb_s.max(1.0),
            "series active {active} vs cost {}",
            q.cost.gpu_active_gb_s
        );
        assert!(
            (idle - q.cost.gpu_idle_gb_s).abs() <= 1e-6 * q.cost.gpu_idle_gb_s.max(1.0),
            "series idle {idle} vs cost {}",
            q.cost.gpu_idle_gb_s
        );
    }

    #[test]
    fn grid_rejects_any_invalid_spec_before_running() {
        let mut bad = quick_spec("bad", "serverless-lora", vec![1]);
        bad.seeds.clear();
        let specs = vec![quick_spec("ok", "serverless-lora", vec![1]), bad];
        assert_eq!(run_grid(&specs).unwrap_err(), ScenarioError::EmptySeeds);
    }

    #[test]
    fn specs_from_json_accepts_object_and_array() {
        let one = quick_spec("solo", "vllm", vec![1]);
        let parsed = specs_from_json(&one.to_json()).unwrap();
        assert_eq!(parsed, vec![one.clone()]);
        let grid = Json::Arr(vec![one.to_json(), quick_spec("b", "npl", vec![2]).to_json()]);
        assert_eq!(specs_from_json(&grid).unwrap().len(), 2);
        assert!(specs_from_json(&Json::Num(3.0)).is_err());
        assert!(specs_from_json(&Json::Arr(vec![])).is_err());
    }

    /// The request-trace sink writes one file per seed ({seed}
    /// substituted), with the documented CSV header and one row per
    /// completion — and, like every observer, perturbs nothing.
    #[test]
    fn request_trace_sink_writes_files_per_seed() {
        let dir = std::env::temp_dir().join(format!("sl-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        let plain = run(&quick_spec("plain", "serverless-lora", vec![1, 7])).unwrap();
        let mut spec = quick_spec("traced", "serverless-lora", vec![1, 7]);
        spec.sinks.request_trace = Some(TraceSinkSpec {
            path: dir.join("trace-{seed}.csv").to_str().unwrap().to_string(),
            format: TraceFormat::Csv,
        });
        let report = run(&spec).unwrap();
        for (p, q) in plain.runs.iter().zip(&report.runs) {
            assert_eq!(
                p.metrics.ttft().mean.to_bits(),
                q.metrics.ttft().mean.to_bits(),
                "trace sink perturbed the run"
            );
            let path = dir.join(format!("trace-{}.csv", q.seed));
            let text = std::fs::read_to_string(&path).unwrap();
            let mut lines = text.lines();
            let header = lines.next().unwrap();
            assert!(header.starts_with("id,function,arrival_s,ttft_s"), "{header}");
            assert!(header.contains("backbone_tier"));
            assert!(header.contains("backbone_load_s"));
            assert_eq!(lines.count(), q.metrics.outcomes.len(), "one row per completion");
        }

        // JSON format parses back with one object per completion.
        let json_path = dir.join("trace.json");
        let mut spec = quick_spec("traced-json", "serverless-lora", vec![7]);
        spec.sinks.request_trace = Some(TraceSinkSpec {
            path: json_path.to_str().unwrap().to_string(),
            format: TraceFormat::Json,
        });
        let report = run(&spec).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
        let Json::Arr(rows) = parsed else { panic!("trace must be a JSON array") };
        assert_eq!(rows.len(), report.only().metrics.outcomes.len());
        for key in ["id", "ttft_s", "e2e_s", "phases"] {
            assert!(rows[0].get(key).is_some(), "row missing '{key}'");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metric_summary_mean_and_ci() {
        assert_eq!(MetricSummary::of(&[]), MetricSummary { mean: 0.0, ci95: 0.0 });
        let one = MetricSummary::of(&[4.0]);
        assert_eq!((one.mean, one.ci95), (4.0, 0.0), "n = 1 has no interval");
        let m = MetricSummary::of(&[2.0, 4.0, 6.0]);
        assert!((m.mean - 4.0).abs() < 1e-12);
        // s = 2, so the half-width is 1.96 · 2 / √3.
        assert!((m.ci95 - 1.96 * 2.0 / 3f64.sqrt()).abs() < 1e-12);
        assert!(m.cell(2).contains("±"), "{}", m.cell(2));
        assert!(!one.cell(2).contains("±"), "{}", one.cell(2));
    }

    #[test]
    fn summarize_collapses_seeds() {
        let spec = quick_spec("sum", "serverless-lora", vec![1, 7, 23]);
        let report = run(&spec).unwrap();
        let sum = summarize(&report);
        assert_eq!(sum.seeds, 3);
        assert_eq!(sum.requests, report.runs[0].requests);
        assert_eq!(sum.failed.mean, 0.0, "no faults, no failures");
        assert_eq!(sum.goodput.mean, 1.0);
        assert!(
            sum.slo_attainment.mean > 0.0 && sum.slo_attainment.mean <= 1.0,
            "SLO attainment must be a hit-rate: {}",
            sum.slo_attainment.mean
        );
        assert!(sum.ttft_ms.mean > 0.0 && sum.ttft_ms.ci95 >= 0.0);
        let mean_cost = report.runs.iter().map(|r| r.cost.total_usd()).sum::<f64>() / 3.0;
        assert!((sum.cost_usd.mean - mean_cost).abs() < 1e-12);
        let out = render_summaries(std::slice::from_ref(&sum));
        assert!(out.contains("sum") && out.contains("goodput"), "{out}");
    }

    #[test]
    fn report_renders_rows_and_series() {
        let mut spec = quick_spec("render", "serverless-lora", vec![1]);
        spec.sinks.bill_series_bucket_s = Some(150.0);
        let reports = run_grid(std::slice::from_ref(&spec)).unwrap();
        let out = render_reports(&reports);
        assert!(out.contains("render"));
        assert!(out.contains("ServerlessLoRA"));
        assert!(out.contains("cost trajectory"));
    }
}
