//! The typed scenario description: **one** (system × workload × cluster
//! × seeds) evaluation cell, with a builder, validation, and JSON
//! round-trip via `util::json` (serde is not vendored in this offline
//! build).
//!
//! A `ScenarioSpec` is declarative and self-contained: everything a run
//! depends on — the system id plus config overrides, the cluster shape,
//! the workload generator and its seed, the horizon, the engine seeds,
//! and the output sinks — lives in the spec, so a JSON file fully
//! reproduces a result and the experiment suites build their grids from
//! the same type the CLI loads from disk.

use crate::artifact::ModelProfile;
use crate::cluster::Cluster;
use crate::coldstart::{ColdStartKind, ColdStartSpec};
use crate::sim::config::{BatchingMode, CacheMode, PreloadMode, SystemConfig, TierSpec};
use crate::sim::workloads as wl;
use crate::sim::{DegradeSpec, DomainLevel, DomainSpec, FaultSpec, RetrySpec, Workload};
use crate::trace::Pattern;
use crate::util::json::{arr, num, obj, s, Json};

/// Every system id [`SystemSpec::resolve`] accepts, in registry order.
pub const SYSTEM_IDS: [&str; 12] = [
    "serverless-lora",
    "predictive",
    "serverless-llm",
    "instainfer",
    "vllm",
    "dlora",
    "nbs",
    "npl",
    "ndo",
    "nab1",
    "nab2",
    "nab3",
];

/// A scenario that fails validation, with an actionable message.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioError {
    EmptyName,
    EmptySeeds,
    UnknownSystem(String),
    /// A system override that does not type-check against its system
    /// (e.g. `hit_rate` on a non-InstaInfer system, a non-positive
    /// keep-alive).
    BadOverride(String),
    BadHorizon(f64),
    BadCluster(String),
    BadWorkload(String),
    BadSkew(f64),
    BadSeriesBucket(String),
    /// A sink selection that cannot work as configured (e.g. a request
    /// trace on a sharded cluster, or a multi-seed run without a
    /// `{seed}` placeholder in the trace path).
    BadSink(String),
    /// Malformed JSON shape (missing/ill-typed field); carries the path.
    Parse(String),
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioError::EmptyName => {
                write!(w, "scenario needs a non-empty \"name\"")
            }
            ScenarioError::EmptySeeds => {
                write!(w, "scenario needs at least one engine seed (e.g. \"seeds\": [1])")
            }
            ScenarioError::UnknownSystem(id) => {
                write!(w, "unknown system id '{id}'; valid ids: {}", SYSTEM_IDS.join(", "))
            }
            ScenarioError::BadOverride(msg) => write!(w, "bad system override: {msg}"),
            ScenarioError::BadHorizon(h) => {
                write!(w, "horizon_s must be a positive finite number of seconds, got {h}")
            }
            ScenarioError::BadCluster(msg) => write!(w, "bad cluster: {msg}"),
            ScenarioError::BadWorkload(msg) => write!(w, "bad workload: {msg}"),
            ScenarioError::BadSkew(x) => {
                write!(w, "Zipf skew must be a positive finite number, got {x}")
            }
            ScenarioError::BadSeriesBucket(msg) => {
                write!(w, "bad bill_series_bucket_s: {msg}")
            }
            ScenarioError::BadSink(msg) => write!(w, "bad sink: {msg}"),
            ScenarioError::Parse(msg) => write!(w, "{msg}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

// -------------------------------------------------------------- system

/// Batching override for a system (maps onto `sim::BatchingMode`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchingOverride {
    Adaptive,
    Fixed { size: usize, delay_s: f64 },
}

/// A system under test: a registry id plus optional config overrides.
/// `resolve` turns it into the exact `SystemConfig` the engine runs.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemSpec {
    pub id: String,
    pub keepalive_s: Option<f64>,
    pub backbone_sharing: Option<bool>,
    pub dynamic_offload: Option<bool>,
    pub batching: Option<BatchingOverride>,
    /// InstaInfer only: pin the opportunistic predictor's hit rate
    /// (e.g. `1.0` for the §6.3 best case) instead of deriving it from
    /// the workload's arrival pattern.
    pub hit_rate: Option<f64>,
    /// Tiered artifact store + link contention (`sim::TierSpec`):
    /// per-node host-RAM checkpoint cache, per-link bandwidths, and the
    /// cache policy. `None` keeps the flat-latency fast path.
    pub tiers: Option<TierSpec>,
    /// Fault injection (`sim::FaultSpec`): GPU crash/recover from
    /// MTBF/MTTR, transient cold-load failures, and the retry/deadline
    /// policy. `None` (the default) keeps the fault-free fast path.
    pub faults: Option<FaultSpec>,
    /// Cold-start strategy (`crate::coldstart::ColdStartSpec`): tiered
    /// (the historical path), snapshot-restore, or pipelined multi-GPU
    /// loading, optionally mixed head-vs-tail per function class.
    /// Requires `tiers`; `None` keeps the pre-subsystem path bit-for-bit.
    pub cold_start: Option<ColdStartSpec>,
}

impl SystemSpec {
    pub fn new(id: &str) -> Self {
        SystemSpec {
            id: id.to_string(),
            keepalive_s: None,
            backbone_sharing: None,
            dynamic_offload: None,
            batching: None,
            hit_rate: None,
            tiers: None,
            faults: None,
            cold_start: None,
        }
    }

    /// Build the concrete `SystemConfig`. `pattern` is the workload's
    /// arrival pattern (InstaInfer's predictor hit rate is
    /// pattern-dependent, exactly as the experiment suites construct it;
    /// pattern-free workloads default to Normal).
    pub fn resolve(&self, pattern: Pattern) -> Result<SystemConfig, ScenarioError> {
        let mut cfg = match self.id.as_str() {
            "serverless-lora" => SystemConfig::serverless_lora(),
            "predictive" => SystemConfig::predictive(),
            "serverless-llm" => SystemConfig::serverless_llm(),
            "instainfer" => SystemConfig::instainfer(pattern),
            "vllm" => SystemConfig::vllm(),
            "dlora" => SystemConfig::dlora(),
            "nbs" => SystemConfig::nbs(),
            "npl" => SystemConfig::npl(),
            "ndo" => SystemConfig::ndo(),
            "nab1" => SystemConfig::nab(1),
            "nab2" => SystemConfig::nab(2),
            "nab3" => SystemConfig::nab(3),
            other => return Err(ScenarioError::UnknownSystem(other.to_string())),
        };
        if let Some(h) = self.hit_rate {
            if self.id != "instainfer" {
                return Err(ScenarioError::BadOverride(format!(
                    "hit_rate only applies to 'instainfer', not '{}'",
                    self.id
                )));
            }
            if !(h.is_finite() && h > 0.0 && h <= 1.0) {
                return Err(ScenarioError::BadOverride(format!(
                    "hit_rate must be in (0, 1], got {h}"
                )));
            }
            cfg.preload = PreloadMode::ContainerOpportunistic { hit_rate: h };
        }
        if let Some(k) = self.keepalive_s {
            if !(k.is_finite() && k > 0.0) {
                return Err(ScenarioError::BadOverride(format!(
                    "keepalive_s must be a positive finite number, got {k}"
                )));
            }
            cfg.keepalive_s = k;
        }
        if let Some(b) = self.backbone_sharing {
            cfg.backbone_sharing = b;
        }
        if let Some(d) = self.dynamic_offload {
            cfg.dynamic_offload = d;
        }
        match self.batching {
            Some(BatchingOverride::Adaptive) => cfg.batching = BatchingMode::Adaptive,
            Some(BatchingOverride::Fixed { size, delay_s }) => {
                if size == 0 || !(delay_s.is_finite() && delay_s >= 0.0) {
                    return Err(ScenarioError::BadOverride(format!(
                        "fixed batching needs size >= 1 and a non-negative \
                         finite delay, got size {size}, delay {delay_s}"
                    )));
                }
                cfg.batching = BatchingMode::Fixed { size, delay_s };
            }
            None => {}
        }
        if let Some(t) = self.tiers {
            if !(t.host_cache_gb.is_finite() && t.host_cache_gb >= 0.0) {
                return Err(ScenarioError::BadOverride(format!(
                    "tiers.host_cache_gb must be a non-negative finite number of GB \
                     (0 disables the cache), got {}",
                    t.host_cache_gb
                )));
            }
            for (bw, key) in [
                (t.nic_gbps, "nic_gbps"),
                (t.nvme_gbps, "nvme_gbps"),
                (t.pcie_gbps, "pcie_gbps"),
            ] {
                if !(bw.is_finite() && bw > 0.0) {
                    return Err(ScenarioError::BadOverride(format!(
                        "tiers.{key} must be a positive finite bandwidth in GB/s, got {bw}"
                    )));
                }
            }
            cfg = cfg.with_tiers(t);
        }
        if let Some(fa) = self.faults {
            for (v, key) in [(fa.mtbf_s, "mtbf_s"), (fa.mttr_s, "mttr_s")] {
                if !(v.is_finite() && v > 0.0) {
                    return Err(ScenarioError::BadOverride(format!(
                        "faults.{key} must be a positive finite number of seconds, got {v}"
                    )));
                }
            }
            if !(fa.load_fail_prob.is_finite() && (0.0..=1.0).contains(&fa.load_fail_prob)) {
                return Err(ScenarioError::BadOverride(format!(
                    "faults.load_fail_prob must be in [0, 1], got {}",
                    fa.load_fail_prob
                )));
            }
            for (v, key) in [
                (fa.retry.backoff_base_s, "backoff_base_s"),
                (fa.retry.backoff_cap_s, "backoff_cap_s"),
            ] {
                if !(v.is_finite() && v >= 0.0) {
                    return Err(ScenarioError::BadOverride(format!(
                        "faults.retry.{key} must be a non-negative finite number of \
                         seconds, got {v}"
                    )));
                }
            }
            if !(fa.retry.deadline_s.is_finite() && fa.retry.deadline_s > 0.0) {
                return Err(ScenarioError::BadOverride(format!(
                    "faults.retry.deadline_s must be a positive finite number of \
                     seconds, got {}",
                    fa.retry.deadline_s
                )));
            }
            if let Some(d) = fa.domains {
                for (lvl, name) in [(d.node, "node"), (d.zone, "zone")] {
                    let Some(l) = lvl else { continue };
                    for (v, key) in [(l.mtbf_s, "mtbf_s"), (l.mttr_s, "mttr_s")] {
                        if !(v.is_finite() && v > 0.0) {
                            return Err(ScenarioError::BadOverride(format!(
                                "faults.domains.{name}.{key} must be a positive finite \
                                 number of seconds, got {v}"
                            )));
                        }
                    }
                }
            }
            if let Some(dg) = fa.degrade {
                for (v, key) in [(dg.mtbf_s, "mtbf_s"), (dg.duration_s, "duration_s")] {
                    if !(v.is_finite() && v > 0.0) {
                        return Err(ScenarioError::BadOverride(format!(
                            "faults.degrade.{key} must be a positive finite number of \
                             seconds, got {v}"
                        )));
                    }
                }
                if !(dg.factor_min.is_finite()
                    && dg.factor_max.is_finite()
                    && dg.factor_min >= 1.0
                    && dg.factor_max >= dg.factor_min)
                {
                    return Err(ScenarioError::BadOverride(format!(
                        "faults.degrade factors must satisfy 1 ≤ factor_min ≤ factor_max, \
                         got [{}, {}]",
                        dg.factor_min, dg.factor_max
                    )));
                }
            }
            if !(fa.failure_tau_s.is_finite() && fa.failure_tau_s > 0.0) {
                return Err(ScenarioError::BadOverride(format!(
                    "faults.failure_tau_s must be a positive finite number of seconds, \
                     got {}",
                    fa.failure_tau_s
                )));
            }
            if !(fa.failure_penalty_gb.is_finite() && fa.failure_penalty_gb >= 0.0) {
                return Err(ScenarioError::BadOverride(format!(
                    "faults.failure_penalty_gb must be a non-negative finite number, \
                     got {}",
                    fa.failure_penalty_gb
                )));
            }
            cfg = cfg.with_faults(fa);
        }
        if let Some(cs) = self.cold_start {
            if self.tiers.is_none() {
                return Err(ScenarioError::BadOverride(
                    "cold_start requires tiers (the strategies restructure the \
                     tiered load path; there is nothing to restructure on the \
                     flat-latency path)"
                        .to_string(),
                ));
            }
            if cs.head.is_some() && cs.head_fns == 0 {
                return Err(ScenarioError::BadOverride(
                    "cold_start.head_fns must be >= 1 when a head strategy is set"
                        .to_string(),
                ));
            }
            for (v, key) in [
                (cs.snapshot.build_s, "snapshot.build_s"),
                (cs.snapshot.restore_s, "snapshot.restore_s"),
            ] {
                if !(v.is_finite() && v > 0.0) {
                    return Err(ScenarioError::BadOverride(format!(
                        "cold_start.{key} must be a positive finite number of \
                         seconds, got {v}"
                    )));
                }
            }
            if !(cs.snapshot.storage_usd_per_gb_h.is_finite()
                && cs.snapshot.storage_usd_per_gb_h >= 0.0)
            {
                return Err(ScenarioError::BadOverride(format!(
                    "cold_start.snapshot.storage_usd_per_gb_h must be a non-negative \
                     finite rate, got {}",
                    cs.snapshot.storage_usd_per_gb_h
                )));
            }
            if !(2..=8).contains(&cs.pipeline.k) {
                return Err(ScenarioError::BadOverride(format!(
                    "cold_start.pipeline.k must be in 2..=8 (one target + up to 7 \
                     sibling shards), got {}",
                    cs.pipeline.k
                )));
            }
            if !(cs.pipeline.consolidate_frac.is_finite()
                && cs.pipeline.consolidate_frac > 0.0
                && cs.pipeline.consolidate_frac <= 1.0)
            {
                return Err(ScenarioError::BadOverride(format!(
                    "cold_start.pipeline.consolidate_frac must be in (0, 1], got {}",
                    cs.pipeline.consolidate_frac
                )));
            }
            cfg = cfg.with_cold_start(cs);
        }
        Ok(cfg)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![("id", s(&self.id))];
        if let Some(k) = self.keepalive_s {
            fields.push(("keepalive_s", num(k)));
        }
        if let Some(b) = self.backbone_sharing {
            fields.push(("backbone_sharing", Json::Bool(b)));
        }
        if let Some(d) = self.dynamic_offload {
            fields.push(("dynamic_offload", Json::Bool(d)));
        }
        if let Some(h) = self.hit_rate {
            fields.push(("hit_rate", num(h)));
        }
        match self.batching {
            Some(BatchingOverride::Adaptive) => {
                fields.push(("batching", obj(vec![("kind", s("adaptive"))])));
            }
            Some(BatchingOverride::Fixed { size, delay_s }) => {
                fields.push((
                    "batching",
                    obj(vec![
                        ("kind", s("fixed")),
                        ("size", num(size as f64)),
                        ("delay_s", num(delay_s)),
                    ]),
                ));
            }
            None => {}
        }
        if let Some(t) = self.tiers {
            fields.push((
                "tiers",
                obj(vec![
                    ("host_cache_gb", num(t.host_cache_gb)),
                    ("nic_gbps", num(t.nic_gbps)),
                    ("nvme_gbps", num(t.nvme_gbps)),
                    ("pcie_gbps", num(t.pcie_gbps)),
                    ("ssd_seeded", Json::Bool(t.ssd_seeded)),
                    ("cache", s(t.cache.id())),
                ]),
            ));
        }
        if let Some(fa) = self.faults {
            let mut ff = vec![
                ("mtbf_s", num(fa.mtbf_s)),
                ("mttr_s", num(fa.mttr_s)),
                ("load_fail_prob", num(fa.load_fail_prob)),
                (
                    "retry",
                    obj(vec![
                        ("max_retries", num(fa.retry.max_retries as f64)),
                        ("backoff_base_s", num(fa.retry.backoff_base_s)),
                        ("backoff_cap_s", num(fa.retry.backoff_cap_s)),
                        ("deadline_s", num(fa.retry.deadline_s)),
                    ]),
                ),
            ];
            // The PR-9 sub-specs are emitted only when present / set, so
            // pre-domain specs serialize exactly as they always did.
            if let Some(d) = fa.domains {
                let mut df = Vec::new();
                if let Some(l) = d.node {
                    df.push((
                        "node",
                        obj(vec![("mtbf_s", num(l.mtbf_s)), ("mttr_s", num(l.mttr_s))]),
                    ));
                }
                if let Some(l) = d.zone {
                    df.push((
                        "zone",
                        obj(vec![("mtbf_s", num(l.mtbf_s)), ("mttr_s", num(l.mttr_s))]),
                    ));
                }
                ff.push(("domains", obj(df)));
            }
            if let Some(dg) = fa.degrade {
                ff.push((
                    "degrade",
                    obj(vec![
                        ("mtbf_s", num(dg.mtbf_s)),
                        ("duration_s", num(dg.duration_s)),
                        ("factor_min", num(dg.factor_min)),
                        ("factor_max", num(dg.factor_max)),
                    ]),
                ));
            }
            if fa.failure_aware {
                ff.push(("failure_aware", Json::Bool(true)));
            }
            if fa.failure_tau_s != FaultSpec::default().failure_tau_s {
                ff.push(("failure_tau_s", num(fa.failure_tau_s)));
            }
            if fa.failure_penalty_gb != FaultSpec::default().failure_penalty_gb {
                ff.push(("failure_penalty_gb", num(fa.failure_penalty_gb)));
            }
            fields.push(("faults", obj(ff)));
        }
        if let Some(cs) = self.cold_start {
            let mut cf = vec![("strategy", s(cs.strategy.id()))];
            if let Some(h) = cs.head {
                cf.push(("head", s(h.id())));
                cf.push(("head_fns", num(cs.head_fns as f64)));
            }
            let d = ColdStartSpec::default();
            if cs.snapshot != d.snapshot {
                cf.push((
                    "snapshot",
                    obj(vec![
                        ("build_s", num(cs.snapshot.build_s)),
                        ("restore_s", num(cs.snapshot.restore_s)),
                        ("storage_usd_per_gb_h", num(cs.snapshot.storage_usd_per_gb_h)),
                    ]),
                ));
            }
            if cs.pipeline != d.pipeline {
                cf.push((
                    "pipeline",
                    obj(vec![
                        ("k", num(cs.pipeline.k as f64)),
                        ("consolidate_frac", num(cs.pipeline.consolidate_frac)),
                    ]),
                ));
            }
            fields.push(("cold_start", obj(cf)));
        }
        obj(fields)
    }

    fn from_json(j: &Json) -> Result<Self, ScenarioError> {
        let id = req_str(j, "id", "system")?;
        let mut spec = SystemSpec::new(&id);
        spec.keepalive_s = opt_num(j, "keepalive_s", "system")?;
        spec.backbone_sharing = opt_bool(j, "backbone_sharing", "system")?;
        spec.dynamic_offload = opt_bool(j, "dynamic_offload", "system")?;
        spec.hit_rate = opt_num(j, "hit_rate", "system")?;
        if let Some(tj) = j.get("tiers") {
            let mut t = TierSpec::default();
            if let Some(x) = opt_num(tj, "host_cache_gb", "system.tiers")? {
                t.host_cache_gb = x;
            }
            if let Some(x) = opt_num(tj, "nic_gbps", "system.tiers")? {
                t.nic_gbps = x;
            }
            if let Some(x) = opt_num(tj, "nvme_gbps", "system.tiers")? {
                t.nvme_gbps = x;
            }
            if let Some(x) = opt_num(tj, "pcie_gbps", "system.tiers")? {
                t.pcie_gbps = x;
            }
            if let Some(b) = opt_bool(tj, "ssd_seeded", "system.tiers")? {
                t.ssd_seeded = b;
            }
            if let Some(c) = tj.get("cache") {
                let name = c.as_str().ok_or_else(|| {
                    ScenarioError::Parse(
                        "system.tiers.cache must be a policy id string".to_string(),
                    )
                })?;
                t.cache = CacheMode::from_id(name).ok_or_else(|| {
                    ScenarioError::Parse(format!(
                        "system.tiers.cache must be one of {}, got '{name}'",
                        CacheMode::IDS.join(", ")
                    ))
                })?;
            }
            spec.tiers = Some(t);
        }
        if let Some(fj) = j.get("faults") {
            let mut fa = FaultSpec::default();
            if let Some(x) = opt_num(fj, "mtbf_s", "system.faults")? {
                fa.mtbf_s = x;
            }
            if let Some(x) = opt_num(fj, "mttr_s", "system.faults")? {
                fa.mttr_s = x;
            }
            if let Some(x) = opt_num(fj, "load_fail_prob", "system.faults")? {
                fa.load_fail_prob = x;
            }
            if let Some(rj) = fj.get("retry") {
                if let Some(x) = opt_usize(rj, "max_retries", "system.faults.retry")? {
                    fa.retry.max_retries = x as u32;
                }
                if let Some(x) = opt_num(rj, "backoff_base_s", "system.faults.retry")? {
                    fa.retry.backoff_base_s = x;
                }
                if let Some(x) = opt_num(rj, "backoff_cap_s", "system.faults.retry")? {
                    fa.retry.backoff_cap_s = x;
                }
                if let Some(x) = opt_num(rj, "deadline_s", "system.faults.retry")? {
                    fa.retry.deadline_s = x;
                }
            }
            if let Some(dj) = fj.get("domains") {
                let mut dom = DomainSpec::default();
                if let Some(nj) = dj.get("node") {
                    dom.node = Some(DomainLevel {
                        mtbf_s: req_num(nj, "mtbf_s", "system.faults.domains.node")?,
                        mttr_s: req_num(nj, "mttr_s", "system.faults.domains.node")?,
                    });
                }
                if let Some(zj) = dj.get("zone") {
                    dom.zone = Some(DomainLevel {
                        mtbf_s: req_num(zj, "mtbf_s", "system.faults.domains.zone")?,
                        mttr_s: req_num(zj, "mttr_s", "system.faults.domains.zone")?,
                    });
                }
                fa.domains = Some(dom);
            }
            if let Some(gj) = fj.get("degrade") {
                let mut dg = DegradeSpec::default();
                if let Some(x) = opt_num(gj, "mtbf_s", "system.faults.degrade")? {
                    dg.mtbf_s = x;
                }
                if let Some(x) = opt_num(gj, "duration_s", "system.faults.degrade")? {
                    dg.duration_s = x;
                }
                if let Some(x) = opt_num(gj, "factor_min", "system.faults.degrade")? {
                    dg.factor_min = x;
                }
                if let Some(x) = opt_num(gj, "factor_max", "system.faults.degrade")? {
                    dg.factor_max = x;
                }
                fa.degrade = Some(dg);
            }
            if let Some(b) = opt_bool(fj, "failure_aware", "system.faults")? {
                fa.failure_aware = b;
            }
            if let Some(x) = opt_num(fj, "failure_tau_s", "system.faults")? {
                fa.failure_tau_s = x;
            }
            if let Some(x) = opt_num(fj, "failure_penalty_gb", "system.faults")? {
                fa.failure_penalty_gb = x;
            }
            spec.faults = Some(fa);
        }
        if let Some(cj) = j.get("cold_start") {
            let kind_field = |key: &str| -> Result<Option<ColdStartKind>, ScenarioError> {
                match cj.get(key) {
                    None => Ok(None),
                    Some(x) => {
                        let id = x.as_str().ok_or_else(|| {
                            ScenarioError::Parse(format!(
                                "system.cold_start.{key} must be a strategy id string"
                            ))
                        })?;
                        ColdStartKind::from_id(id)
                            .map(Some)
                            .ok_or_else(|| {
                                ScenarioError::Parse(format!(
                                    "system.cold_start.{key} must be one of {}, got '{id}'",
                                    ColdStartKind::IDS.join(", ")
                                ))
                            })
                    }
                }
            };
            let mut cs = ColdStartSpec::default();
            if let Some(k) = kind_field("strategy")? {
                cs.strategy = k;
            }
            cs.head = kind_field("head")?;
            if let Some(n) = opt_usize(cj, "head_fns", "system.cold_start")? {
                cs.head_fns = n;
            }
            if let Some(sj) = cj.get("snapshot") {
                if let Some(x) = opt_num(sj, "build_s", "system.cold_start.snapshot")? {
                    cs.snapshot.build_s = x;
                }
                if let Some(x) = opt_num(sj, "restore_s", "system.cold_start.snapshot")? {
                    cs.snapshot.restore_s = x;
                }
                if let Some(x) =
                    opt_num(sj, "storage_usd_per_gb_h", "system.cold_start.snapshot")?
                {
                    cs.snapshot.storage_usd_per_gb_h = x;
                }
            }
            if let Some(pj) = cj.get("pipeline") {
                if let Some(x) = opt_usize(pj, "k", "system.cold_start.pipeline")? {
                    cs.pipeline.k = x;
                }
                if let Some(x) =
                    opt_num(pj, "consolidate_frac", "system.cold_start.pipeline")?
                {
                    cs.pipeline.consolidate_frac = x;
                }
            }
            spec.cold_start = Some(cs);
        }
        if let Some(b) = j.get("batching") {
            let kind = req_str(b, "kind", "system.batching")?;
            spec.batching = Some(match kind.as_str() {
                "adaptive" => BatchingOverride::Adaptive,
                "fixed" => BatchingOverride::Fixed {
                    size: req_usize(b, "size", "system.batching")?,
                    delay_s: req_num(b, "delay_s", "system.batching")?,
                },
                other => {
                    return Err(ScenarioError::Parse(format!(
                        "system.batching.kind must be 'adaptive' or 'fixed', got '{other}'"
                    )))
                }
            });
        }
        Ok(spec)
    }
}

// -------------------------------------------------------------- cluster

/// Cluster shape. `Paper` is the evaluation testbed (4 × g6e.24xlarge,
/// 16 GPUs); `Uniform` is `Cluster::new(nodes, gpus_per_node,
/// containers_per_node)` optionally trimmed to an exact GPU count (the
/// fleet experiment's non-multiple-of-8 shapes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClusterSpec {
    Paper,
    Uniform {
        nodes: usize,
        gpus_per_node: usize,
        containers_per_node: usize,
        trim_gpus: Option<usize>,
        /// Weakly-coupled zones for the sharded engine
        /// (`sim::sharded`): the nodes are split evenly across zones
        /// and each zone simulates on its own thread. `1` = the plain
        /// single-engine path.
        zones: usize,
    },
}

impl ClusterSpec {
    pub fn materialize(&self) -> Cluster {
        match *self {
            ClusterSpec::Paper => Cluster::paper_multinode(),
            ClusterSpec::Uniform { nodes, gpus_per_node, containers_per_node, trim_gpus, .. } => {
                let mut c = Cluster::new(nodes, gpus_per_node, containers_per_node);
                if let Some(t) = trim_gpus {
                    c.trim_gpus(t);
                }
                c
            }
        }
    }

    /// How many engine zones this cluster runs as (1 = unsharded).
    pub fn zones(&self) -> usize {
        match *self {
            ClusterSpec::Paper => 1,
            ClusterSpec::Uniform { zones, .. } => zones,
        }
    }

    /// One cluster per zone: the node set (and any GPU trim) divided
    /// evenly. `validate` guarantees the divisions are exact.
    pub fn materialize_zones(&self) -> Vec<Cluster> {
        match *self {
            ClusterSpec::Uniform {
                nodes,
                gpus_per_node,
                containers_per_node,
                trim_gpus,
                zones,
            } if zones > 1 => (0..zones)
                .map(|_| {
                    let mut c = Cluster::new(nodes / zones, gpus_per_node, containers_per_node);
                    if let Some(t) = trim_gpus {
                        c.trim_gpus(t / zones);
                    }
                    c
                })
                .collect(),
            _ => vec![self.materialize()],
        }
    }

    fn validate(&self) -> Result<(), ScenarioError> {
        if let ClusterSpec::Uniform {
            nodes,
            gpus_per_node,
            containers_per_node,
            trim_gpus,
            zones,
        } = *self
        {
            if nodes == 0 || gpus_per_node == 0 || containers_per_node == 0 {
                return Err(ScenarioError::BadCluster(format!(
                    "nodes, gpus_per_node and containers_per_node must all be >= 1, \
                     got {nodes}/{gpus_per_node}/{containers_per_node}"
                )));
            }
            if let Some(t) = trim_gpus {
                let total = nodes * gpus_per_node;
                if t == 0 || t > total {
                    return Err(ScenarioError::BadCluster(format!(
                        "trim_gpus must be in 1..={total} for this shape, got {t}"
                    )));
                }
            }
            if zones == 0 {
                return Err(ScenarioError::BadCluster("zones must be >= 1".to_string()));
            }
            if nodes % zones != 0 {
                return Err(ScenarioError::BadCluster(format!(
                    "zones must divide the node count evenly, got {nodes} nodes / \
                     {zones} zones"
                )));
            }
            if let Some(t) = trim_gpus {
                if t % zones != 0 {
                    return Err(ScenarioError::BadCluster(format!(
                        "zones must divide trim_gpus evenly, got {t} GPUs / \
                         {zones} zones"
                    )));
                }
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        match *self {
            ClusterSpec::Paper => obj(vec![("kind", s("paper"))]),
            ClusterSpec::Uniform {
                nodes,
                gpus_per_node,
                containers_per_node,
                trim_gpus,
                zones,
            } => {
                let mut fields = vec![
                    ("kind", s("uniform")),
                    ("nodes", num(nodes as f64)),
                    ("gpus_per_node", num(gpus_per_node as f64)),
                    ("containers_per_node", num(containers_per_node as f64)),
                ];
                if let Some(t) = trim_gpus {
                    fields.push(("trim_gpus", num(t as f64)));
                }
                if zones > 1 {
                    fields.push(("zones", num(zones as f64)));
                }
                obj(fields)
            }
        }
    }

    fn from_json(j: &Json) -> Result<Self, ScenarioError> {
        match req_str(j, "kind", "cluster")?.as_str() {
            "paper" => Ok(ClusterSpec::Paper),
            "uniform" => Ok(ClusterSpec::Uniform {
                nodes: req_usize(j, "nodes", "cluster")?,
                gpus_per_node: req_usize(j, "gpus_per_node", "cluster")?,
                containers_per_node: req_usize(j, "containers_per_node", "cluster")?,
                trim_gpus: opt_usize(j, "trim_gpus", "cluster")?,
                zones: opt_usize(j, "zones", "cluster")?.unwrap_or(1),
            }),
            other => Err(ScenarioError::Parse(format!(
                "cluster.kind must be 'paper' or 'uniform', got '{other}'"
            ))),
        }
    }

    fn describe(&self) -> String {
        match *self {
            ClusterSpec::Paper => "paper (16 GPUs, 4 nodes)".to_string(),
            ClusterSpec::Uniform {
                nodes,
                gpus_per_node,
                containers_per_node,
                trim_gpus,
                zones,
            } => {
                let mut d = match trim_gpus {
                    Some(t) => format!(
                        "{nodes}x{gpus_per_node}g/{containers_per_node}c trimmed to {t} GPUs"
                    ),
                    None => format!("{nodes}x{gpus_per_node}g/{containers_per_node}c"),
                };
                if zones > 1 {
                    d.push_str(&format!(", {zones} zones"));
                }
                d
            }
        }
    }
}

// ------------------------------------------------------------- workload

/// Workload generator + its generator seed. Each variant maps 1:1 onto
/// a `sim::workloads` constructor, so a spec-built workload is
/// bit-identical to the experiment suites' hand-wired one.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// The paper's standard 8-function deployment (§6.1).
    Paper { pattern: Pattern, seed: u64 },
    /// Fig. 2 motivation: `n_fns` 7B functions splitting one hot
    /// function's demand.
    SmallMulti { n_fns: usize, seed: u64 },
    /// Fig. 1 motivation: three 13B functions, descending rates.
    Breakdown13b { seed: u64 },
    /// §6.3: one function, one request (`model`: llama2-7b | llama2-13b).
    SingleInvocation { model: String },
    /// §6.5 saturating throughput workload (4× 7B at 12 req/s each).
    Throughput { seed: u64 },
    /// Weak-scaling: `scale` × the 8-function base deployment.
    Scaled { pattern: Pattern, scale: usize, seed: u64 },
    /// Fleet-scale uniform-tier workload (engine-health experiment).
    Fleet { fns: usize, seed: u64 },
    /// Zipf(skew) function popularity, aggregate Poisson stream.
    ZipfFleet { fns: usize, skew: f64, seed: u64 },
    /// Zipf popularity with CoV-classed head/tail burstiness.
    ZipfFleetCov { fns: usize, skew: f64, head: Pattern, tail: Pattern, seed: u64 },
}

impl WorkloadSpec {
    pub fn materialize(&self, horizon_s: f64) -> Workload {
        match self {
            WorkloadSpec::Paper { pattern, seed } => {
                wl::paper_workload(*pattern, horizon_s, *seed)
            }
            WorkloadSpec::SmallMulti { n_fns, seed } => {
                wl::small_multi_workload(*n_fns, horizon_s, *seed)
            }
            WorkloadSpec::Breakdown13b { seed } => {
                wl::breakdown_13b_workload(horizon_s, *seed)
            }
            WorkloadSpec::SingleInvocation { model } => wl::single_invocation(
                Self::model_profile(model).expect("validated before materialize"),
            ),
            WorkloadSpec::Throughput { seed } => wl::throughput_workload(horizon_s, *seed),
            WorkloadSpec::Scaled { pattern, scale, seed } => {
                wl::scaled_workload(*pattern, horizon_s, *scale, *seed)
            }
            WorkloadSpec::Fleet { fns, seed } => wl::fleet_workload(*fns, horizon_s, *seed),
            WorkloadSpec::ZipfFleet { fns, skew, seed } => {
                wl::zipf_fleet_workload(*fns, horizon_s, *skew, *seed)
            }
            WorkloadSpec::ZipfFleetCov { fns, skew, head, tail, seed } => {
                wl::zipf_fleet_workload_cov(*fns, horizon_s, *skew, *seed, *head, *tail)
            }
        }
    }

    /// The workload's arrival-pattern class, when it has a single one
    /// (drives pattern-dependent system resolution, e.g. InstaInfer's
    /// predictor hit rate). Throughput runs a Predictable stream; the
    /// fleet/Zipf generators have no single class and default to Normal.
    pub fn pattern(&self) -> Option<Pattern> {
        match self {
            WorkloadSpec::Paper { pattern, .. } | WorkloadSpec::Scaled { pattern, .. } => {
                Some(*pattern)
            }
            WorkloadSpec::SmallMulti { .. } | WorkloadSpec::Breakdown13b { .. } => {
                Some(Pattern::Normal)
            }
            WorkloadSpec::Throughput { .. } => Some(Pattern::Predictable),
            _ => None,
        }
    }

    fn model_profile(name: &str) -> Option<ModelProfile> {
        match name {
            "llama2-7b" => Some(ModelProfile::llama2_7b()),
            "llama2-13b" => Some(ModelProfile::llama2_13b()),
            _ => None,
        }
    }

    fn validate(&self) -> Result<(), ScenarioError> {
        let check_fns = |fns: usize| {
            if fns == 0 {
                Err(ScenarioError::BadWorkload("fns must be >= 1".to_string()))
            } else {
                Ok(())
            }
        };
        let check_skew = |skew: f64| {
            if skew.is_finite() && skew > 0.0 {
                Ok(())
            } else {
                Err(ScenarioError::BadSkew(skew))
            }
        };
        match self {
            WorkloadSpec::SmallMulti { n_fns, .. } => check_fns(*n_fns),
            WorkloadSpec::SingleInvocation { model } => match Self::model_profile(model) {
                Some(_) => Ok(()),
                None => Err(ScenarioError::BadWorkload(format!(
                    "unknown model '{model}'; valid: llama2-7b, llama2-13b"
                ))),
            },
            WorkloadSpec::Scaled { scale, .. } => {
                if *scale == 0 {
                    Err(ScenarioError::BadWorkload("scale must be >= 1".to_string()))
                } else {
                    Ok(())
                }
            }
            WorkloadSpec::Fleet { fns, .. } => check_fns(*fns),
            WorkloadSpec::ZipfFleet { fns, skew, .. } => {
                check_fns(*fns)?;
                check_skew(*skew)
            }
            WorkloadSpec::ZipfFleetCov { fns, skew, .. } => {
                check_fns(*fns)?;
                check_skew(*skew)
            }
            _ => Ok(()),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            WorkloadSpec::Paper { pattern, seed } => obj(vec![
                ("kind", s("paper")),
                ("pattern", s(pattern.name())),
                ("seed", num(*seed as f64)),
            ]),
            WorkloadSpec::SmallMulti { n_fns, seed } => obj(vec![
                ("kind", s("small-multi")),
                ("n_fns", num(*n_fns as f64)),
                ("seed", num(*seed as f64)),
            ]),
            WorkloadSpec::Breakdown13b { seed } => {
                obj(vec![("kind", s("breakdown-13b")), ("seed", num(*seed as f64))])
            }
            WorkloadSpec::SingleInvocation { model } => {
                obj(vec![("kind", s("single-invocation")), ("model", s(model))])
            }
            WorkloadSpec::Throughput { seed } => {
                obj(vec![("kind", s("throughput")), ("seed", num(*seed as f64))])
            }
            WorkloadSpec::Scaled { pattern, scale, seed } => obj(vec![
                ("kind", s("scaled")),
                ("pattern", s(pattern.name())),
                ("scale", num(*scale as f64)),
                ("seed", num(*seed as f64)),
            ]),
            WorkloadSpec::Fleet { fns, seed } => obj(vec![
                ("kind", s("fleet")),
                ("fns", num(*fns as f64)),
                ("seed", num(*seed as f64)),
            ]),
            WorkloadSpec::ZipfFleet { fns, skew, seed } => obj(vec![
                ("kind", s("zipf-fleet")),
                ("fns", num(*fns as f64)),
                ("skew", num(*skew)),
                ("seed", num(*seed as f64)),
            ]),
            WorkloadSpec::ZipfFleetCov { fns, skew, head, tail, seed } => obj(vec![
                ("kind", s("zipf-fleet-cov")),
                ("fns", num(*fns as f64)),
                ("skew", num(*skew)),
                ("head", s(head.name())),
                ("tail", s(tail.name())),
                ("seed", num(*seed as f64)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<Self, ScenarioError> {
        // The experiment suites' canonical workload seed.
        const DEFAULT_SEED: u64 = 11;
        let seed = opt_u64(j, "seed", "workload")?.unwrap_or(DEFAULT_SEED);
        match req_str(j, "kind", "workload")?.as_str() {
            "paper" => Ok(WorkloadSpec::Paper {
                pattern: pattern_field(j, "pattern", "workload")?.unwrap_or(Pattern::Normal),
                seed,
            }),
            "small-multi" => Ok(WorkloadSpec::SmallMulti {
                n_fns: req_usize(j, "n_fns", "workload")?,
                seed,
            }),
            "breakdown-13b" => Ok(WorkloadSpec::Breakdown13b { seed }),
            "single-invocation" => Ok(WorkloadSpec::SingleInvocation {
                model: req_str(j, "model", "workload")?,
            }),
            "throughput" => Ok(WorkloadSpec::Throughput { seed }),
            "scaled" => Ok(WorkloadSpec::Scaled {
                pattern: pattern_field(j, "pattern", "workload")?.unwrap_or(Pattern::Normal),
                scale: req_usize(j, "scale", "workload")?,
                seed,
            }),
            "fleet" => Ok(WorkloadSpec::Fleet { fns: req_usize(j, "fns", "workload")?, seed }),
            "zipf-fleet" => Ok(WorkloadSpec::ZipfFleet {
                fns: req_usize(j, "fns", "workload")?,
                skew: req_num(j, "skew", "workload")?,
                seed,
            }),
            "zipf-fleet-cov" => Ok(WorkloadSpec::ZipfFleetCov {
                fns: req_usize(j, "fns", "workload")?,
                skew: req_num(j, "skew", "workload")?,
                head: pattern_field(j, "head", "workload")?.ok_or_else(|| {
                    ScenarioError::Parse("workload: missing 'head' pattern".into())
                })?,
                tail: pattern_field(j, "tail", "workload")?.ok_or_else(|| {
                    ScenarioError::Parse("workload: missing 'tail' pattern".into())
                })?,
                seed,
            }),
            other => Err(ScenarioError::Parse(format!(
                "unknown workload kind '{other}'; valid: paper, small-multi, \
                 breakdown-13b, single-invocation, throughput, scaled, fleet, \
                 zipf-fleet, zipf-fleet-cov"
            ))),
        }
    }

    fn describe(&self) -> String {
        match self {
            WorkloadSpec::Paper { pattern, seed } => {
                format!("paper 8-fn ({}, seed {seed})", pattern.name())
            }
            WorkloadSpec::SmallMulti { n_fns, seed } => {
                format!("small-multi {n_fns} fn (seed {seed})")
            }
            WorkloadSpec::Breakdown13b { seed } => format!("3x 13B breakdown (seed {seed})"),
            WorkloadSpec::SingleInvocation { model } => format!("single invocation ({model})"),
            WorkloadSpec::Throughput { seed } => format!("saturating throughput (seed {seed})"),
            WorkloadSpec::Scaled { pattern, scale, seed } => {
                format!("scaled x{scale} ({}, seed {seed})", pattern.name())
            }
            WorkloadSpec::Fleet { fns, seed } => format!("fleet {fns} fn (seed {seed})"),
            WorkloadSpec::ZipfFleet { fns, skew, seed } => {
                format!("zipf({skew}) fleet {fns} fn (seed {seed})")
            }
            WorkloadSpec::ZipfFleetCov { fns, skew, head, tail, seed } => format!(
                "zipf({skew}) fleet {fns} fn, {}-head/{}-tail (seed {seed})",
                head.name(),
                tail.name()
            ),
        }
    }
}

// ---------------------------------------------------------------- sinks

/// Per-request trace format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceFormat {
    /// One CSV row per request with a header line.
    #[default]
    Csv,
    /// One JSON object per request, wrapped in a top-level array.
    Json,
}

impl TraceFormat {
    pub fn id(self) -> &'static str {
        match self {
            TraceFormat::Csv => "csv",
            TraceFormat::Json => "json",
        }
    }

    pub fn from_id(id: &str) -> Option<Self> {
        match id {
            "csv" => Some(TraceFormat::Csv),
            "json" => Some(TraceFormat::Json),
            _ => None,
        }
    }
}

/// Per-request trace export: every completed request's phases, tier and
/// latencies, written to `path` when the run finishes
/// (`sim::observe::TraceExport`). Multi-seed scenarios must embed the
/// literal `{seed}` placeholder in the path so runs do not clobber each
/// other; single-seed paths may omit it.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSinkSpec {
    pub path: String,
    pub format: TraceFormat,
}

impl TraceSinkSpec {
    /// The concrete file path for one engine seed.
    pub fn path_for_seed(&self, seed: u64) -> String {
        self.path.replace("{seed}", &seed.to_string())
    }
}

/// Output-sink selection: what a run records beyond metrics + cost.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SinkSpec {
    /// Meter billing wall-clock into
    /// `RunStats::bill_{sample,reclass}_wall_s` (the fleet bench).
    pub bill_timing: bool,
    /// Enable the coarse per-billing-class time-series sampler with
    /// this bucket width (seconds). Off (`None`) by default.
    pub bill_series_bucket_s: Option<f64>,
    /// Export a per-request trace to disk. Off (`None`) by default.
    pub request_trace: Option<TraceSinkSpec>,
}

impl SinkSpec {
    fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if self.bill_timing {
            fields.push(("bill_timing", Json::Bool(true)));
        }
        if let Some(b) = self.bill_series_bucket_s {
            fields.push(("bill_series_bucket_s", num(b)));
        }
        if let Some(t) = &self.request_trace {
            fields.push((
                "request_trace",
                obj(vec![("path", s(&t.path)), ("format", s(t.format.id()))]),
            ));
        }
        obj(fields)
    }

    fn from_json(j: &Json) -> Result<Self, ScenarioError> {
        let request_trace = match j.get("request_trace") {
            None => None,
            Some(t) => {
                let path = req_str(t, "path", "sinks.request_trace")?;
                let format = match t.get("format") {
                    None => TraceFormat::default(),
                    Some(x) => {
                        let id = x.as_str().ok_or_else(|| {
                            ScenarioError::Parse(
                                "sinks.request_trace.format must be a string"
                                    .to_string(),
                            )
                        })?;
                        TraceFormat::from_id(id).ok_or_else(|| {
                            ScenarioError::Parse(format!(
                                "sinks.request_trace.format must be 'csv' or \
                                 'json', got '{id}'"
                            ))
                        })?
                    }
                };
                Some(TraceSinkSpec { path, format })
            }
        };
        Ok(SinkSpec {
            bill_timing: opt_bool(j, "bill_timing", "sinks")?.unwrap_or(false),
            bill_series_bucket_s: opt_num(j, "bill_series_bucket_s", "sinks")?,
            request_trace,
        })
    }
}

// ----------------------------------------------------------------- spec

/// One declarative evaluation cell. Build with [`ScenarioSpec::builder`]
/// or load from JSON with [`ScenarioSpec::from_json`]; run with
/// [`crate::scenario::run`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub system: SystemSpec,
    pub cluster: ClusterSpec,
    pub workload: WorkloadSpec,
    pub horizon_s: f64,
    /// Engine seeds: one run per seed, fanned out in parallel.
    pub seeds: Vec<u64>,
    pub sinks: SinkSpec,
}

impl ScenarioSpec {
    pub fn builder(name: &str) -> ScenarioBuilder {
        ScenarioBuilder {
            spec: ScenarioSpec {
                name: name.to_string(),
                system: SystemSpec::new("serverless-lora"),
                cluster: ClusterSpec::Paper,
                workload: WorkloadSpec::Paper { pattern: Pattern::Normal, seed: 11 },
                horizon_s: 3600.0,
                seeds: vec![1],
                sinks: SinkSpec::default(),
            },
        }
    }

    /// Check every field; the error names what to fix.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        if self.name.trim().is_empty() {
            return Err(ScenarioError::EmptyName);
        }
        if self.seeds.is_empty() {
            return Err(ScenarioError::EmptySeeds);
        }
        if !(self.horizon_s.is_finite() && self.horizon_s > 0.0) {
            return Err(ScenarioError::BadHorizon(self.horizon_s));
        }
        self.cluster.validate()?;
        self.workload.validate()?;
        // Resolution type-checks the system id + every override.
        self.system.resolve(self.workload.pattern().unwrap_or(Pattern::Normal))?;
        if let Some(b) = self.sinks.bill_series_bucket_s {
            if !(b.is_finite() && b > 0.0) {
                return Err(ScenarioError::BadSeriesBucket(format!(
                    "bucket must be a positive number of seconds, got {b}"
                )));
            }
            if self.horizon_s / b > 100_000.0 {
                return Err(ScenarioError::BadSeriesBucket(format!(
                    "bucket {b} s over a {} s horizon means > 100000 buckets; \
                     the series sampler is deliberately coarse — widen the bucket",
                    self.horizon_s
                )));
            }
        }
        if let Some(t) = &self.sinks.request_trace {
            if t.path.trim().is_empty() {
                return Err(ScenarioError::BadSink(
                    "request_trace.path must be a non-empty file path".to_string(),
                ));
            }
            if self.cluster.zones() > 1 {
                return Err(ScenarioError::BadSink(
                    "request_trace requires zones = 1 (the sharded engine does \
                     not carry per-zone observers)"
                        .to_string(),
                ));
            }
            if self.seeds.len() > 1 && !t.path.contains("{seed}") {
                return Err(ScenarioError::BadSink(format!(
                    "request_trace.path '{}' would be overwritten by each of the \
                     {} seeds; embed the literal {{seed}} placeholder",
                    t.path,
                    self.seeds.len()
                )));
            }
        }
        Ok(())
    }

    /// The resolved system's display name (e.g. "ServerlessLoRA-NPL").
    pub fn system_name(&self) -> String {
        self.system
            .resolve(self.workload.pattern().unwrap_or(Pattern::Normal))
            .map(|c| c.name.to_string())
            .unwrap_or_else(|_| self.system.id.clone())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("system", self.system.to_json()),
            ("cluster", self.cluster.to_json()),
            ("workload", self.workload.to_json()),
            ("horizon_s", num(self.horizon_s)),
            ("seeds", arr(self.seeds.iter().map(|&x| num(x as f64)))),
            ("sinks", self.sinks.to_json()),
        ])
    }

    /// Parse one spec object. Missing optional fields default (cluster:
    /// paper, horizon_s: 3600, seeds: [1], sinks: off); `name`, `system`
    /// and `workload` are required.
    pub fn from_json(j: &Json) -> Result<Self, ScenarioError> {
        let Json::Obj(map) = j else {
            return Err(ScenarioError::Parse("a scenario must be a JSON object".into()));
        };
        // Reject unknown top-level keys outright: a typo ("horizon" for
        // "horizon_s") silently running 3600 s would be worse than an
        // error naming the valid vocabulary.
        const TOP_KEYS: [&str; 7] =
            ["name", "system", "cluster", "workload", "horizon_s", "seeds", "sinks"];
        for k in map.keys() {
            if !TOP_KEYS.contains(&k.as_str()) {
                return Err(ScenarioError::Parse(format!(
                    "scenario: unknown top-level key \"{k}\"; valid keys: {}",
                    TOP_KEYS.join(", ")
                )));
            }
        }
        let name = req_str(j, "name", "scenario")?;
        let system = SystemSpec::from_json(j.get("system").ok_or_else(|| {
            ScenarioError::Parse(format!("scenario '{name}': missing \"system\""))
        })?)?;
        let workload = WorkloadSpec::from_json(j.get("workload").ok_or_else(|| {
            ScenarioError::Parse(format!("scenario '{name}': missing \"workload\""))
        })?)?;
        let cluster = match j.get("cluster") {
            Some(c) => ClusterSpec::from_json(c)?,
            None => ClusterSpec::Paper,
        };
        let horizon_s = opt_num(j, "horizon_s", "scenario")?.unwrap_or(3600.0);
        let seeds = match j.get("seeds") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|x| {
                    x.as_u64().ok_or_else(|| {
                        ScenarioError::Parse(
                            "seeds must be non-negative integers".to_string(),
                        )
                    })
                })
                .collect::<Result<Vec<u64>, _>>()?,
            Some(_) => {
                return Err(ScenarioError::Parse(
                    "\"seeds\" must be an array of integers".to_string(),
                ))
            }
            None => vec![1],
        };
        let sinks = match j.get("sinks") {
            Some(x) => SinkSpec::from_json(x)?,
            None => SinkSpec::default(),
        };
        Ok(ScenarioSpec { name, system, cluster, workload, horizon_s, seeds, sinks })
    }

    /// One-line description (the CLI's `--dry-run` output).
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        if self.sinks.bill_timing {
            parts.push("bill-timing".to_string());
        }
        if let Some(b) = self.sinks.bill_series_bucket_s {
            parts.push(format!("bill-series@{b}s"));
        }
        if let Some(t) = &self.sinks.request_trace {
            parts.push(format!("trace→{} ({})", t.path, t.format.id()));
        }
        let sinks = if parts.is_empty() {
            String::new()
        } else {
            format!(" | sinks: {}", parts.join(", "))
        };
        format!(
            "scenario '{}': {} on {} | {} | horizon {} s | seeds {:?}{}",
            self.name,
            self.system_name(),
            self.cluster.describe(),
            self.workload.describe(),
            self.horizon_s,
            self.seeds,
            sinks
        )
    }
}

/// Typed builder over [`ScenarioSpec`]; `build` validates.
pub struct ScenarioBuilder {
    spec: ScenarioSpec,
}

impl ScenarioBuilder {
    /// Select the system by registry id (see [`SYSTEM_IDS`]).
    pub fn system(mut self, id: &str) -> Self {
        self.spec.system = SystemSpec::new(id);
        self
    }

    /// Replace the whole system spec (id + overrides).
    pub fn system_spec(mut self, sys: SystemSpec) -> Self {
        self.spec.system = sys;
        self
    }

    pub fn keepalive_s(mut self, k: f64) -> Self {
        self.spec.system.keepalive_s = Some(k);
        self
    }

    pub fn hit_rate(mut self, h: f64) -> Self {
        self.spec.system.hit_rate = Some(h);
        self
    }

    /// Enable the tiered artifact store (host-RAM cache + link
    /// contention) with the given tier shape.
    pub fn tiers(mut self, t: TierSpec) -> Self {
        self.spec.system.tiers = Some(t);
        self
    }

    /// Enable deterministic fault injection (GPU crash/recover, load
    /// failures, retry/deadline policy) with the given fault shape.
    pub fn faults(mut self, f: FaultSpec) -> Self {
        self.spec.system.faults = Some(f);
        self
    }

    /// Select a cold-start strategy (snapshot-restore, pipelined, or an
    /// explicit tiered policy; requires [`ScenarioBuilder::tiers`]).
    pub fn cold_start(mut self, cs: ColdStartSpec) -> Self {
        self.spec.system.cold_start = Some(cs);
        self
    }

    pub fn cluster(mut self, c: ClusterSpec) -> Self {
        self.spec.cluster = c;
        self
    }

    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.spec.workload = w;
        self
    }

    pub fn horizon_s(mut self, h: f64) -> Self {
        self.spec.horizon_s = h;
        self
    }

    pub fn seeds(mut self, seeds: Vec<u64>) -> Self {
        self.spec.seeds = seeds;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seeds = vec![seed];
        self
    }

    pub fn bill_timing(mut self, on: bool) -> Self {
        self.spec.sinks.bill_timing = on;
        self
    }

    pub fn bill_series(mut self, bucket_s: f64) -> Self {
        self.spec.sinks.bill_series_bucket_s = Some(bucket_s);
        self
    }

    /// Export a per-request trace to `path` when each run finishes.
    pub fn request_trace(mut self, path: &str, format: TraceFormat) -> Self {
        self.spec.sinks.request_trace =
            Some(TraceSinkSpec { path: path.to_string(), format });
        self
    }

    pub fn build(self) -> Result<ScenarioSpec, ScenarioError> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

// --------------------------------------------------------- json helpers

fn req_str(j: &Json, key: &str, ctx: &str) -> Result<String, ScenarioError> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| ScenarioError::Parse(format!("{ctx}: missing string field \"{key}\"")))
}

fn req_num(j: &Json, key: &str, ctx: &str) -> Result<f64, ScenarioError> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| ScenarioError::Parse(format!("{ctx}: missing numeric field \"{key}\"")))
}

fn req_usize(j: &Json, key: &str, ctx: &str) -> Result<usize, ScenarioError> {
    req_num(j, key, ctx).and_then(|v| {
        if v.fract() == 0.0 && (0.0..9.0e15).contains(&v) {
            Ok(v as usize)
        } else {
            Err(ScenarioError::Parse(format!(
                "{ctx}: \"{key}\" must be a non-negative integer, got {v}"
            )))
        }
    })
}

fn opt_num(j: &Json, key: &str, ctx: &str) -> Result<Option<f64>, ScenarioError> {
    match j.get(key) {
        None => Ok(None),
        Some(x) => x.as_f64().map(Some).ok_or_else(|| {
            ScenarioError::Parse(format!("{ctx}: \"{key}\" must be a number"))
        }),
    }
}

fn opt_usize(j: &Json, key: &str, ctx: &str) -> Result<Option<usize>, ScenarioError> {
    match j.get(key) {
        None => Ok(None),
        Some(_) => req_usize(j, key, ctx).map(Some),
    }
}

fn opt_u64(j: &Json, key: &str, ctx: &str) -> Result<Option<u64>, ScenarioError> {
    opt_usize(j, key, ctx).map(|o| o.map(|v| v as u64))
}

fn opt_bool(j: &Json, key: &str, ctx: &str) -> Result<Option<bool>, ScenarioError> {
    match j.get(key) {
        None => Ok(None),
        Some(x) => x.as_bool().map(Some).ok_or_else(|| {
            ScenarioError::Parse(format!("{ctx}: \"{key}\" must be true or false"))
        }),
    }
}

/// A pattern field: a class name ("Bursty") or a numeric CoV mapped via
/// the paper's Fig. 5 bands (`Pattern::for_cov`).
fn pattern_field(j: &Json, key: &str, ctx: &str) -> Result<Option<Pattern>, ScenarioError> {
    let Some(x) = j.get(key) else { return Ok(None) };
    match x {
        Json::Str(name) => match name.to_ascii_lowercase().as_str() {
            "predictable" => Ok(Some(Pattern::Predictable)),
            "normal" => Ok(Some(Pattern::Normal)),
            "bursty" => Ok(Some(Pattern::Bursty)),
            other => Err(ScenarioError::Parse(format!(
                "{ctx}: unknown pattern '{other}' (Predictable, Normal, Bursty, \
                 or a numeric CoV)"
            ))),
        },
        Json::Num(cov) if cov.is_finite() && *cov > 0.0 => Ok(Some(Pattern::for_cov(*cov))),
        _ => Err(ScenarioError::Parse(format!(
            "{ctx}: \"{key}\" must be a pattern name or a positive CoV number"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lora_spec() -> ScenarioSpec {
        ScenarioSpec::builder("t")
            .workload(WorkloadSpec::Paper { pattern: Pattern::Bursty, seed: 9 })
            .cluster(ClusterSpec::Uniform {
                nodes: 1,
                gpus_per_node: 2,
                containers_per_node: 4,
                trim_gpus: None,
                zones: 1,
            })
            .horizon_s(300.0)
            .seeds(vec![1, 7])
            .build()
            .unwrap()
    }

    #[test]
    fn builder_defaults_validate() {
        let spec = ScenarioSpec::builder("default").build().unwrap();
        assert_eq!(spec.system.id, "serverless-lora");
        assert_eq!(spec.seeds, vec![1]);
        assert_eq!(spec.system_name(), "ServerlessLoRA");
    }

    #[test]
    fn every_system_id_resolves() {
        for id in SYSTEM_IDS {
            let cfg = SystemSpec::new(id).resolve(Pattern::Normal).unwrap();
            assert!(!cfg.name.is_empty(), "{id}");
        }
    }

    #[test]
    fn instainfer_hit_rate_tracks_workload_pattern() {
        let sys = SystemSpec::new("instainfer");
        let get = |p| match sys.resolve(p).unwrap().preload {
            PreloadMode::ContainerOpportunistic { hit_rate } => hit_rate,
            _ => unreachable!(),
        };
        assert!(get(Pattern::Predictable) > get(Pattern::Bursty));
        // A pinned hit rate overrides the pattern-derived one.
        let mut pinned = SystemSpec::new("instainfer");
        pinned.hit_rate = Some(1.0);
        match pinned.resolve(Pattern::Bursty).unwrap().preload {
            PreloadMode::ContainerOpportunistic { hit_rate } => assert_eq!(hit_rate, 1.0),
            _ => unreachable!(),
        }
    }

    #[test]
    fn overrides_apply() {
        let mut sys = SystemSpec::new("serverless-lora");
        sys.keepalive_s = Some(20.0);
        sys.backbone_sharing = Some(false);
        sys.dynamic_offload = Some(false);
        sys.batching = Some(BatchingOverride::Fixed { size: 4, delay_s: 0.1 });
        let cfg = sys.resolve(Pattern::Normal).unwrap();
        assert_eq!(cfg.keepalive_s, 20.0);
        assert!(!cfg.backbone_sharing);
        assert!(!cfg.dynamic_offload);
        assert!(matches!(cfg.batching, BatchingMode::Fixed { size: 4, .. }));
    }

    #[test]
    fn json_roundtrip_preserves_every_variant() {
        let mut insta = SystemSpec::new("instainfer");
        insta.hit_rate = Some(0.9);
        insta.keepalive_s = Some(60.0);
        insta.batching = Some(BatchingOverride::Fixed { size: 8, delay_s: 0.25 });
        let specs = vec![
            lora_spec(),
            ScenarioSpec::builder("insta")
                .system_spec(insta)
                .workload(WorkloadSpec::SmallMulti { n_fns: 4, seed: 5 })
                .horizon_s(600.0)
                .build()
                .unwrap(),
            ScenarioSpec::builder("fleet")
                .cluster(ClusterSpec::Uniform {
                    nodes: 2,
                    gpus_per_node: 8,
                    containers_per_node: 16,
                    trim_gpus: Some(12),
                    zones: 2,
                })
                .workload(WorkloadSpec::ZipfFleetCov {
                    fns: 32,
                    skew: 1.2,
                    head: Pattern::Bursty,
                    tail: Pattern::Predictable,
                    seed: 3,
                })
                .horizon_s(600.0)
                .bill_timing(true)
                .bill_series(60.0)
                .build()
                .unwrap(),
            ScenarioSpec::builder("single")
                .workload(WorkloadSpec::SingleInvocation { model: "llama2-13b".into() })
                .horizon_s(30.0)
                .build()
                .unwrap(),
            ScenarioSpec::builder("scaled")
                .system("npl")
                .workload(WorkloadSpec::Scaled {
                    pattern: Pattern::Predictable,
                    scale: 2,
                    seed: 13,
                })
                .horizon_s(600.0)
                .build()
                .unwrap(),
            ScenarioSpec::builder("tp")
                .system("nab2")
                .workload(WorkloadSpec::Throughput { seed: 21 })
                .horizon_s(120.0)
                .seeds(vec![2])
                .build()
                .unwrap(),
            ScenarioSpec::builder("b13")
                .system("ndo")
                .workload(WorkloadSpec::Breakdown13b { seed: 7 })
                .horizon_s(600.0)
                .build()
                .unwrap(),
            ScenarioSpec::builder("zipf")
                .workload(WorkloadSpec::ZipfFleet { fns: 16, skew: 1.1, seed: 4 })
                .horizon_s(300.0)
                .build()
                .unwrap(),
            ScenarioSpec::builder("flt")
                .system("vllm")
                .workload(WorkloadSpec::Fleet { fns: 16, seed: 2 })
                .horizon_s(300.0)
                .build()
                .unwrap(),
        ];
        for spec in specs {
            let text = spec.to_json().dump();
            let parsed = ScenarioSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(parsed, spec, "round-trip changed the spec:\n{text}");
            parsed.validate().unwrap();
        }
    }

    #[test]
    fn parse_defaults_fill_optional_fields() {
        let j = Json::parse(
            r#"{"name":"min","system":{"id":"serverless-lora"},
                "workload":{"kind":"paper","pattern":"Normal"}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(spec.cluster, ClusterSpec::Paper);
        assert_eq!(spec.horizon_s, 3600.0);
        assert_eq!(spec.seeds, vec![1]);
        assert_eq!(spec.sinks, SinkSpec::default());
        match spec.workload {
            WorkloadSpec::Paper { seed, .. } => assert_eq!(seed, 11),
            _ => unreachable!(),
        }
        spec.validate().unwrap();
    }

    #[test]
    fn numeric_cov_maps_onto_pattern_bands() {
        let j = Json::parse(
            r#"{"name":"cov","system":{"id":"serverless-lora"},
                "workload":{"kind":"zipf-fleet-cov","fns":16,"skew":1.2,
                            "head":6.0,"tail":0.5,"seed":3}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&j).unwrap();
        match spec.workload {
            WorkloadSpec::ZipfFleetCov { head, tail, .. } => {
                assert_eq!(head, Pattern::Bursty);
                assert_eq!(tail, Pattern::Predictable);
            }
            _ => unreachable!(),
        }
    }

    // ------------------------------------------- rejection paths

    #[test]
    fn rejects_empty_name() {
        let err = ScenarioSpec::builder("  ").build().unwrap_err();
        assert_eq!(err, ScenarioError::EmptyName);
    }

    #[test]
    fn rejects_empty_seeds() {
        let err = ScenarioSpec::builder("t").seeds(vec![]).build().unwrap_err();
        assert_eq!(err, ScenarioError::EmptySeeds);
        assert!(err.to_string().contains("seed"));
    }

    #[test]
    fn rejects_unknown_system_and_lists_valid_ids() {
        let err = ScenarioSpec::builder("t").system("serverless-lroa").build().unwrap_err();
        assert_eq!(err, ScenarioError::UnknownSystem("serverless-lroa".into()));
        let msg = err.to_string();
        for id in SYSTEM_IDS {
            assert!(msg.contains(id), "message must list '{id}': {msg}");
        }
    }

    #[test]
    fn rejects_bad_skew() {
        for skew in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let err = ScenarioSpec::builder("t")
                .workload(WorkloadSpec::ZipfFleet { fns: 16, skew, seed: 1 })
                .build()
                .unwrap_err();
            assert!(matches!(err, ScenarioError::BadSkew(_)), "skew {skew}: {err}");
        }
    }

    #[test]
    fn rejects_bad_horizon() {
        for h in [0.0, -5.0, f64::NAN] {
            let err = ScenarioSpec::builder("t").horizon_s(h).build().unwrap_err();
            assert!(matches!(err, ScenarioError::BadHorizon(_)), "h {h}");
        }
    }

    #[test]
    fn rejects_bad_cluster_shapes() {
        let err = ScenarioSpec::builder("t")
            .cluster(ClusterSpec::Uniform {
                nodes: 0,
                gpus_per_node: 8,
                containers_per_node: 16,
                trim_gpus: None,
                zones: 1,
            })
            .build()
            .unwrap_err();
        assert!(matches!(err, ScenarioError::BadCluster(_)));
        let err = ScenarioSpec::builder("t")
            .cluster(ClusterSpec::Uniform {
                nodes: 1,
                gpus_per_node: 8,
                containers_per_node: 16,
                trim_gpus: Some(9),
                zones: 1,
            })
            .build()
            .unwrap_err();
        assert!(matches!(err, ScenarioError::BadCluster(_)));
    }

    #[test]
    fn rejects_indivisible_zone_shapes() {
        // zones must split both the node count and any trim exactly.
        for (nodes, trim, zones) in
            [(2, None, 0), (3, None, 2), (2, Some(15), 2)]
        {
            let err = ScenarioSpec::builder("t")
                .cluster(ClusterSpec::Uniform {
                    nodes,
                    gpus_per_node: 8,
                    containers_per_node: 16,
                    trim_gpus: trim,
                    zones,
                })
                .build()
                .unwrap_err();
            assert!(
                matches!(err, ScenarioError::BadCluster(_)),
                "nodes {nodes} trim {trim:?} zones {zones}"
            );
        }
    }

    #[test]
    fn zone_materialization_splits_nodes_and_trim_evenly() {
        let spec = ClusterSpec::Uniform {
            nodes: 4,
            gpus_per_node: 8,
            containers_per_node: 16,
            trim_gpus: Some(24),
            zones: 2,
        };
        assert_eq!(spec.zones(), 2);
        let parts = spec.materialize_zones();
        assert_eq!(parts.len(), 2);
        for c in &parts {
            assert_eq!(c.n_gpus(), 12, "each zone gets half the trimmed GPUs");
        }
        // Unsharded specs (and Paper) materialize as a single cluster.
        assert_eq!(ClusterSpec::Paper.zones(), 1);
        assert_eq!(ClusterSpec::Paper.materialize_zones().len(), 1);
    }

    #[test]
    fn rejects_hit_rate_on_non_instainfer() {
        let err = ScenarioSpec::builder("t").hit_rate(0.9).build().unwrap_err();
        assert!(matches!(err, ScenarioError::BadOverride(_)));
        assert!(err.to_string().contains("instainfer"));
    }

    #[test]
    fn rejects_bad_keepalive_and_batching_overrides() {
        let err = ScenarioSpec::builder("t").keepalive_s(-3.0).build().unwrap_err();
        assert!(matches!(err, ScenarioError::BadOverride(_)));
        let mut sys = SystemSpec::new("serverless-lora");
        sys.batching = Some(BatchingOverride::Fixed { size: 0, delay_s: 0.1 });
        let err = ScenarioSpec::builder("t").system_spec(sys).build().unwrap_err();
        assert!(matches!(err, ScenarioError::BadOverride(_)));
    }

    #[test]
    fn rejects_too_fine_series_bucket() {
        let err = ScenarioSpec::builder("t")
            .horizon_s(3600.0)
            .bill_series(0.01)
            .build()
            .unwrap_err();
        assert!(matches!(err, ScenarioError::BadSeriesBucket(_)));
        let err = ScenarioSpec::builder("t").bill_series(-1.0).build().unwrap_err();
        assert!(matches!(err, ScenarioError::BadSeriesBucket(_)));
    }

    #[test]
    fn rejects_unknown_model_and_workload_kind() {
        let err = ScenarioSpec::builder("t")
            .workload(WorkloadSpec::SingleInvocation { model: "gpt-5".into() })
            .build()
            .unwrap_err();
        assert!(matches!(err, ScenarioError::BadWorkload(_)));
        let j = Json::parse(
            r#"{"name":"x","system":{"id":"vllm"},"workload":{"kind":"nope"}}"#,
        )
        .unwrap();
        let err = ScenarioSpec::from_json(&j).unwrap_err();
        assert!(matches!(err, ScenarioError::Parse(_)));
        assert!(err.to_string().contains("zipf-fleet"), "lists valid kinds: {err}");
    }

    #[test]
    fn parse_reports_missing_required_fields() {
        for (text, needle) in [
            (r#"{"system":{"id":"vllm"},"workload":{"kind":"paper"}}"#, "name"),
            (r#"{"name":"x","workload":{"kind":"paper"}}"#, "system"),
            (r#"{"name":"x","system":{"id":"vllm"}}"#, "workload"),
        ] {
            let err = ScenarioSpec::from_json(&Json::parse(text).unwrap()).unwrap_err();
            assert!(err.to_string().contains(needle), "{text} -> {err}");
        }
    }

    #[test]
    fn summary_names_the_pieces() {
        let sum = lora_spec().summary();
        assert!(sum.contains("ServerlessLoRA"));
        assert!(sum.contains("Bursty"));
        assert!(sum.contains("300"));
    }

    // ------------------------------------------- tiers & trace sinks

    fn tiered_spec() -> ScenarioSpec {
        let mut spec = lora_spec();
        spec.system.tiers = Some(TierSpec {
            host_cache_gb: 32.0,
            ssd_seeded: false,
            cache: CacheMode::PinHot,
            ..TierSpec::default()
        });
        spec.sinks.request_trace = Some(TraceSinkSpec {
            path: "trace-{seed}.csv".to_string(),
            format: TraceFormat::Json,
        });
        spec
    }

    #[test]
    fn tiers_and_trace_survive_json_roundtrip() {
        let spec = tiered_spec();
        spec.validate().unwrap();
        let text = spec.to_json().dump();
        let parsed = ScenarioSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, spec, "round-trip changed the spec:\n{text}");
        // The resolved config carries the tiers through to the engine.
        let cfg = parsed.system.resolve(Pattern::Normal).unwrap();
        let t = cfg.tiers.expect("tiers resolved");
        assert_eq!(t.host_cache_gb, 32.0);
        assert_eq!(t.cache, CacheMode::PinHot);
        assert!(!t.ssd_seeded);
    }

    #[test]
    fn tiers_parse_fills_defaults_and_rejects_bad_cache_id() {
        let j = Json::parse(
            r#"{"name":"t","system":{"id":"npl","tiers":{"host_cache_gb":16.0}},
                "workload":{"kind":"paper"}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&j).unwrap();
        let t = spec.system.tiers.expect("tiers parsed");
        assert_eq!(t.host_cache_gb, 16.0);
        assert_eq!(t.cache, TierSpec::default().cache, "unset fields default");
        assert_eq!(t.nvme_gbps, TierSpec::default().nvme_gbps);

        let j = Json::parse(
            r#"{"name":"t","system":{"id":"npl","tiers":{"cache":"mru"}},
                "workload":{"kind":"paper"}}"#,
        )
        .unwrap();
        let err = ScenarioSpec::from_json(&j).unwrap_err();
        assert!(matches!(err, ScenarioError::Parse(_)));
        for id in CacheMode::IDS {
            assert!(err.to_string().contains(id), "lists '{id}': {err}");
        }
    }

    #[test]
    fn rejects_bad_tier_numbers() {
        let patches: [fn(&mut TierSpec); 5] = [
            |t| t.host_cache_gb = -1.0,
            |t| t.host_cache_gb = f64::NAN,
            |t| t.nic_gbps = 0.0,
            |t| t.nvme_gbps = -2.0,
            |t| t.pcie_gbps = f64::INFINITY,
        ];
        for patch in patches {
            let mut t = TierSpec::default();
            patch(&mut t);
            let mut sys = SystemSpec::new("npl");
            sys.tiers = Some(t);
            let err =
                ScenarioSpec::builder("t").system_spec(sys).build().unwrap_err();
            assert!(matches!(err, ScenarioError::BadOverride(_)), "{t:?}: {err}");
        }
    }

    // ------------------------------------------- fault injection

    #[test]
    fn faults_survive_json_roundtrip() {
        let spec = ScenarioSpec::builder("faulty")
            .faults(FaultSpec {
                mtbf_s: 600.0,
                mttr_s: 45.0,
                load_fail_prob: 0.05,
                retry: RetrySpec {
                    max_retries: 5,
                    backoff_base_s: 0.5,
                    backoff_cap_s: 16.0,
                    deadline_s: 90.0,
                },
                domains: Some(DomainSpec {
                    node: Some(DomainLevel { mtbf_s: 7200.0, mttr_s: 120.0 }),
                    zone: Some(DomainLevel { mtbf_s: 86400.0, mttr_s: 300.0 }),
                }),
                degrade: Some(DegradeSpec {
                    mtbf_s: 1800.0,
                    duration_s: 90.0,
                    factor_min: 2.0,
                    factor_max: 5.0,
                }),
                failure_aware: true,
                failure_tau_s: 300.0,
                failure_penalty_gb: 6.0,
            })
            .build()
            .unwrap();
        let text = spec.to_json().dump();
        let parsed = ScenarioSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, spec, "round-trip changed the spec:\n{text}");
        // The resolved config carries the faults through to the engine.
        let cfg = parsed.system.resolve(Pattern::Normal).unwrap();
        let fa = cfg.faults.expect("faults resolved");
        assert_eq!(fa.mtbf_s, 600.0);
        assert_eq!(fa.retry.max_retries, 5);
        let dom = fa.domains.expect("domains resolved");
        assert_eq!(dom.node.expect("node level").mttr_s, 120.0);
        assert_eq!(dom.zone.expect("zone level").mtbf_s, 86400.0);
        assert_eq!(fa.degrade.expect("degrade resolved").factor_max, 5.0);
        assert!(fa.failure_aware);
        assert_eq!(fa.failure_tau_s, 300.0);
        // A spec without faults resolves to the fault-free fast path.
        let plain = ScenarioSpec::builder("plain").build().unwrap();
        assert!(plain.system.resolve(Pattern::Normal).unwrap().faults.is_none());
    }

    #[test]
    fn partial_domains_and_degrade_parse_with_defaults() {
        // Node-only domains; degrade with only a factor range. Absent
        // levels stay `None` (and so draw nothing from the stream);
        // absent degrade fields fill from `DegradeSpec::default()`.
        let j = Json::parse(
            r#"{"name":"t","system":{"id":"serverless-lora",
                "faults":{"domains":{"node":{"mtbf_s":3600.0,"mttr_s":60.0}},
                          "degrade":{"factor_min":2.0,"factor_max":2.5}}},
                "workload":{"kind":"paper"}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&j).unwrap();
        let fa = spec.system.faults.expect("faults parsed");
        let dom = fa.domains.expect("domains parsed");
        assert_eq!(dom.node.expect("node level").mtbf_s, 3600.0);
        assert!(dom.zone.is_none(), "absent level must stay off");
        let dg = fa.degrade.expect("degrade parsed");
        assert_eq!(dg.factor_min, 2.0);
        assert_eq!(dg.mtbf_s, DegradeSpec::default().mtbf_s, "unset fields default");
        assert!(!fa.failure_aware, "failure-aware routing defaults off");
        spec.validate().unwrap();
        let text = spec.to_json().dump();
        let parsed = ScenarioSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, spec, "partial sub-specs must round-trip:\n{text}");
    }

    #[test]
    fn faults_parse_fills_defaults() {
        let j = Json::parse(
            r#"{"name":"t","system":{"id":"serverless-lora",
                "faults":{"mtbf_s":900.0,"retry":{"max_retries":1}}},
                "workload":{"kind":"paper"}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&j).unwrap();
        let fa = spec.system.faults.expect("faults parsed");
        assert_eq!(fa.mtbf_s, 900.0);
        assert_eq!(fa.mttr_s, FaultSpec::default().mttr_s, "unset fields default");
        assert_eq!(fa.load_fail_prob, FaultSpec::default().load_fail_prob);
        assert_eq!(fa.retry.max_retries, 1);
        assert_eq!(fa.retry.deadline_s, RetrySpec::default().deadline_s);
        spec.validate().unwrap();
    }

    // ------------------------------------------- cold-start strategies

    #[test]
    fn cold_start_survives_json_roundtrip() {
        use crate::coldstart::{PipelineParams, SnapshotParams};
        // Head-vs-tail mix with every parameter off its default.
        let spec = ScenarioSpec::builder("coldstarts")
            .tiers(TierSpec::default())
            .cold_start(ColdStartSpec {
                strategy: ColdStartKind::Pipelined,
                head: Some(ColdStartKind::SnapshotRestore),
                head_fns: 3,
                snapshot: SnapshotParams {
                    build_s: 4.0,
                    restore_s: 0.25,
                    storage_usd_per_gb_h: 1e-4,
                },
                pipeline: PipelineParams { k: 3, consolidate_frac: 0.5 },
            })
            .build()
            .unwrap();
        let text = spec.to_json().dump();
        let parsed = ScenarioSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, spec, "round-trip changed the spec:\n{text}");
        // The resolved config carries the strategy mix through.
        let cfg = parsed.system.resolve(Pattern::Normal).unwrap();
        let cs = cfg.cold_start.expect("cold_start resolved");
        assert_eq!(cs.strategy, ColdStartKind::Pipelined);
        assert_eq!(cs.head, Some(ColdStartKind::SnapshotRestore));
        assert_eq!(cs.head_fns, 3);
        assert_eq!(cs.pipeline.k, 3);
        assert_eq!(cs.snapshot.restore_s, 0.25);
        assert_eq!(cs.strategy_for(0), ColdStartKind::SnapshotRestore);
        assert_eq!(cs.strategy_for(3), ColdStartKind::Pipelined);
        // A spec without cold_start resolves to the pre-subsystem path.
        let plain = ScenarioSpec::builder("plain").build().unwrap();
        assert!(plain.system.resolve(Pattern::Normal).unwrap().cold_start.is_none());
    }

    #[test]
    fn cold_start_parse_fills_defaults() {
        let j = Json::parse(
            r#"{"name":"t","system":{"id":"npl","tiers":{},
                "cold_start":{"strategy":"snapshot-restore"}},
                "workload":{"kind":"paper"}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&j).unwrap();
        let cs = spec.system.cold_start.expect("cold_start parsed");
        assert_eq!(cs.strategy, ColdStartKind::SnapshotRestore);
        assert!(cs.head.is_none());
        assert_eq!(cs.snapshot, ColdStartSpec::default().snapshot, "unset fields default");
        assert_eq!(cs.pipeline, ColdStartSpec::default().pipeline);
        spec.validate().unwrap();
    }

    #[test]
    fn cold_start_rejects_missing_tiers_bad_params_and_bad_ids() {
        // Without tiers there is no tiered path to restructure.
        let err = ScenarioSpec::builder("t")
            .cold_start(ColdStartSpec::uniform(ColdStartKind::SnapshotRestore))
            .build()
            .unwrap_err();
        assert!(matches!(err, ScenarioError::BadOverride(_)), "{err}");
        assert!(err.to_string().contains("tiers"), "{err}");

        let patches: [fn(&mut ColdStartSpec); 5] = [
            |c| c.pipeline.k = 1,
            |c| c.pipeline.k = 9,
            |c| c.pipeline.consolidate_frac = 0.0,
            |c| c.snapshot.build_s = -1.0,
            |c| c.snapshot.restore_s = f64::NAN,
        ];
        for patch in patches {
            let mut cs = ColdStartSpec::uniform(ColdStartKind::Pipelined);
            patch(&mut cs);
            let err = ScenarioSpec::builder("t")
                .tiers(TierSpec::default())
                .cold_start(cs)
                .build()
                .unwrap_err();
            assert!(matches!(err, ScenarioError::BadOverride(_)), "{cs:?}: {err}");
        }

        // An unknown strategy id names the valid vocabulary.
        let j = Json::parse(
            r#"{"name":"t","system":{"id":"npl","tiers":{},
                "cold_start":{"strategy":"lazy"}},
                "workload":{"kind":"paper"}}"#,
        )
        .unwrap();
        let err = ScenarioSpec::from_json(&j).unwrap_err();
        assert!(matches!(err, ScenarioError::Parse(_)));
        for id in ColdStartKind::IDS {
            assert!(err.to_string().contains(id), "lists '{id}': {err}");
        }
    }

    #[test]
    fn rejects_bad_fault_numbers() {
        fn node_level(f: &mut FaultSpec, mtbf_s: f64) {
            f.domains = Some(DomainSpec {
                node: Some(DomainLevel { mtbf_s, mttr_s: 60.0 }),
                zone: None,
            });
        }
        let patches: [fn(&mut FaultSpec); 13] = [
            |f| f.mtbf_s = 0.0,
            |f| f.mtbf_s = f64::NAN,
            |f| f.mttr_s = -5.0,
            |f| f.load_fail_prob = 1.5,
            |f| f.retry.backoff_base_s = -0.1,
            |f| f.retry.backoff_cap_s = f64::INFINITY,
            |f| f.retry.deadline_s = 0.0,
            |f| node_level(f, 0.0),
            |f| node_level(f, f64::NAN),
            |f| f.degrade = Some(DegradeSpec { duration_s: -1.0, ..DegradeSpec::default() }),
            |f| f.degrade = Some(DegradeSpec { factor_min: 0.5, ..DegradeSpec::default() }),
            |f| {
                f.degrade = Some(DegradeSpec {
                    factor_min: 3.0,
                    factor_max: 2.0,
                    ..DegradeSpec::default()
                })
            },
            |f| f.failure_tau_s = 0.0,
        ];
        for patch in patches {
            let mut fa = FaultSpec::default();
            patch(&mut fa);
            let err = ScenarioSpec::builder("t").faults(fa).build().unwrap_err();
            assert!(matches!(err, ScenarioError::BadOverride(_)), "{fa:?}: {err}");
            assert!(err.to_string().contains("faults"), "{err}");
        }
    }

    #[test]
    fn rejects_unknown_top_level_key() {
        let j = Json::parse(
            r#"{"name":"x","system":{"id":"vllm"},"workload":{"kind":"paper"},
                "horizon":600.0}"#,
        )
        .unwrap();
        let err = ScenarioSpec::from_json(&j).unwrap_err();
        assert!(matches!(err, ScenarioError::Parse(_)));
        assert!(err.to_string().contains("horizon"), "{err}");
        assert!(err.to_string().contains("horizon_s"), "lists valid keys: {err}");
    }

    #[test]
    fn rejects_unworkable_trace_sinks() {
        // Sharded clusters carry no per-zone observers.
        let mut spec = tiered_spec();
        spec.cluster = ClusterSpec::Uniform {
            nodes: 2,
            gpus_per_node: 8,
            containers_per_node: 16,
            trim_gpus: None,
            zones: 2,
        };
        let err = spec.validate().unwrap_err();
        assert!(matches!(err, ScenarioError::BadSink(_)), "{err}");
        assert!(err.to_string().contains("zones"));

        // Multi-seed paths must embed the {seed} placeholder.
        let mut spec = tiered_spec();
        spec.sinks.request_trace.as_mut().unwrap().path = "trace.csv".to_string();
        let err = spec.validate().unwrap_err();
        assert!(matches!(err, ScenarioError::BadSink(_)), "{err}");
        assert!(err.to_string().contains("{seed}"), "{err}");

        // Empty paths are never valid.
        let mut spec = tiered_spec();
        spec.sinks.request_trace.as_mut().unwrap().path = "  ".to_string();
        assert!(matches!(spec.validate(), Err(ScenarioError::BadSink(_))));
    }

    #[test]
    fn trace_path_substitutes_seed() {
        let t = TraceSinkSpec {
            path: "out/trace-{seed}.json".to_string(),
            format: TraceFormat::Json,
        };
        assert_eq!(t.path_for_seed(23), "out/trace-23.json");
        let sum = tiered_spec().summary();
        assert!(sum.contains("trace→trace-{seed}.csv (json)"), "{sum}");
    }
}
