//! Per-node host-RAM checkpoint cache — the pinned-DRAM tier of the
//! ServerlessLLM-style loading hierarchy (GPU HBM ← host RAM ← NVMe ←
//! remote store).
//!
//! The cache is a passive ledger: it tracks which model checkpoints are
//! resident in a node's pinned host memory, their sizes, and recency /
//! frequency of use.  *What* gets admitted and *who* gets evicted is
//! decided by the `CachePolicy` trait (`coordinator/policy.rs`) — the
//! fifth policy axis — which manipulates this ledger through
//! `insert`/`remove`/`touch`.  A capacity of 0 disables the tier (the
//! default): the engine then keeps the historical flat-latency path.
//!
//! Occupancy is recomputed from the entries on demand (caches hold a
//! handful of multi-GB checkpoints, not thousands of objects), which
//! keeps `check()`-style invariants trivial: there is no second counter
//! to drift.

use std::collections::BTreeMap;

/// One cached checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    pub size_gb: f64,
    /// Last hit or admission time (sim seconds).
    pub last_use_s: f64,
    /// Hits + admissions — the pin-hot policy's frequency signal.
    pub uses: u64,
}

/// The host-RAM checkpoint cache of one node, keyed by model name.
#[derive(Debug, Clone, Default)]
pub struct HostCache {
    pub capacity_gb: f64,
    entries: BTreeMap<&'static str, CacheEntry>,
}

impl HostCache {
    pub fn new(capacity_gb: f64) -> Self {
        HostCache { capacity_gb, entries: BTreeMap::new() }
    }

    /// A zero-capacity cache is the disabled (flat-latency) tier.
    pub fn enabled(&self) -> bool {
        self.capacity_gb > 0.0
    }

    pub fn contains(&self, model: &str) -> bool {
        self.entries.contains_key(model)
    }

    pub fn get(&self, model: &str) -> Option<&CacheEntry> {
        self.entries.get(model)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Occupied bytes — recomputed from the ledger (see module docs).
    pub fn used_gb(&self) -> f64 {
        self.entries.values().map(|e| e.size_gb).sum()
    }

    pub fn free_gb(&self) -> f64 {
        (self.capacity_gb - self.used_gb()).max(0.0)
    }

    /// Occupied bytes of entries whose key starts with `prefix` — the
    /// snapshot-storage surcharge ("snap:" keys) reads this after every
    /// ledger mutation.
    pub fn prefixed_gb(&self, prefix: &str) -> f64 {
        self.entries
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, e)| e.size_gb)
            .sum()
    }

    /// Entries in model-name order (deterministic iteration for policies).
    pub fn entries(&self) -> impl Iterator<Item = (&'static str, &CacheEntry)> {
        self.entries.iter().map(|(k, v)| (*k, v))
    }

    /// Record a hit: bump recency and use count.  No-op if absent.
    pub fn touch(&mut self, model: &str, now_s: f64) {
        if let Some(e) = self.entries.get_mut(model) {
            e.last_use_s = now_s;
            e.uses += 1;
        }
    }

    /// Admit a checkpoint.  Callers (cache policies) must have made room;
    /// over-capacity insertion is a policy bug, caught here.  Re-inserting
    /// a resident model just touches it.
    pub fn insert(&mut self, model: &'static str, size_gb: f64, now_s: f64) {
        if self.entries.contains_key(model) {
            self.touch(model, now_s);
            return;
        }
        debug_assert!(
            size_gb <= self.free_gb() + 1e-9,
            "cache admission over capacity: {size_gb} GB into {} GB free",
            self.free_gb()
        );
        self.entries.insert(model, CacheEntry { size_gb, last_use_s: now_s, uses: 1 });
    }

    /// Evict a checkpoint; returns whether it was resident.
    pub fn remove(&mut self, model: &str) -> bool {
        self.entries.remove(model).is_some()
    }

    /// Wipe the whole cache (node outage: the worker process died and
    /// its pinned host memory with it). Returns how many checkpoints
    /// were lost, for the engine's eviction accounting.
    pub fn drain(&mut self) -> usize {
        let n = self.entries.len();
        self.entries.clear();
        n
    }

    /// Least-recently-used entry, ties broken by model name — the
    /// deterministic default victim.
    pub fn lru_victim(&self) -> Option<&'static str> {
        self.entries
            .iter()
            .min_by(|a, b| a.1.last_use_s.total_cmp(&b.1.last_use_s).then(a.0.cmp(b.0)))
            .map(|(k, _)| *k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_tracks_occupancy_and_recency() {
        let mut c = HostCache::new(40.0);
        assert!(c.enabled() && c.is_empty());
        c.insert("a", 13.5, 1.0);
        c.insert("b", 26.0, 2.0);
        assert_eq!(c.len(), 2);
        assert!((c.used_gb() - 39.5).abs() < 1e-12);
        assert!((c.free_gb() - 0.5).abs() < 1e-12);
        c.touch("a", 5.0);
        assert_eq!(c.get("a").unwrap().uses, 2);
        assert_eq!(c.get("a").unwrap().last_use_s, 5.0);
        // Re-insert of a resident model is a touch, not a double-count.
        c.insert("a", 13.5, 6.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get("a").unwrap().uses, 3);
        assert!(c.remove("b") && !c.remove("b"));
        assert!((c.used_gb() - 13.5).abs() < 1e-12);
        assert_eq!(c.drain(), 1);
        assert!(c.is_empty());
        assert_eq!(c.drain(), 0, "drain of an empty cache is a no-op");
    }

    #[test]
    fn prefixed_occupancy_splits_snapshots_from_checkpoints() {
        let mut c = HostCache::new(100.0);
        c.insert("llama2-7b", 13.5, 1.0);
        c.insert("snap:llama2-7b-lora0", 14.0, 2.0);
        c.insert("snap:llama2-7b-lora1", 14.0, 3.0);
        assert!((c.prefixed_gb("snap:") - 28.0).abs() < 1e-12);
        assert!((c.prefixed_gb("") - c.used_gb()).abs() < 1e-12);
        c.remove("snap:llama2-7b-lora0");
        assert!((c.prefixed_gb("snap:") - 14.0).abs() < 1e-12);
        assert_eq!(c.prefixed_gb("other:"), 0.0);
    }

    #[test]
    fn lru_victim_is_oldest_then_name_ordered() {
        let mut c = HostCache::new(100.0);
        c.insert("m2", 1.0, 3.0);
        c.insert("m1", 1.0, 1.0);
        c.insert("m3", 1.0, 1.0);
        // Oldest last_use wins; the 1.0 tie breaks toward "m1" by name.
        assert_eq!(c.lru_victim(), Some("m1"));
        c.touch("m1", 9.0);
        assert_eq!(c.lru_victim(), Some("m3"));
        assert_eq!(HostCache::new(0.0).lru_victim(), None);
        assert!(!HostCache::new(0.0).enabled());
    }
}
