//! GPU memory ledger: shared backbones (refcounted), per-function
//! artifacts, CUDA contexts, and KV-cache reservations.
//!
//! This is the accounting substrate under the pre-loading scheduler
//! (§4.1), the offloader (§4.3) and the sharing registry (§4.4): every
//! byte that the paper's policies reason about is tracked here explicitly,
//! and over-commit is a hard error (the policies must *prevent* it).

use std::collections::{BTreeMap, BTreeSet};

use crate::artifact::{params, ArtifactKind};

/// Identifier of a GPU within the cluster: (node, local index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId {
    pub node: usize,
    pub index: usize,
}

impl std::fmt::Display for GpuId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gpu{}.{}", self.node, self.index)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum GpuError {
    #[error("GPU {gpu} out of memory: need {need_gb:.2} GB, free {free_gb:.2} GB")]
    OutOfMemory { gpu: String, need_gb: f64, free_gb: f64 },
    #[error("backbone {0} not resident")]
    BackboneMissing(String),
    #[error("function {0} artifact {1:?} not resident")]
    ArtifactMissing(usize, ArtifactKind),
    #[error("refcount underflow for backbone {0}")]
    RefcountUnderflow(String),
}

/// A shared backbone segment: one copy, many readers (§4.4). The refcount
/// counts attached function instances (IPC handle holders).
#[derive(Debug, Clone)]
pub struct SharedSegment {
    pub size_gb: f64,
    pub refcount: usize,
}

/// Per-function artifact bytes resident on this GPU.
#[derive(Debug, Clone, Default)]
pub struct FunctionResidency {
    pub kinds: BTreeMap<ArtifactKind, f64>, // kind → GB
    pub has_cuda_context: bool,
}

#[derive(Debug, Clone)]
pub struct Gpu {
    pub id: GpuId,
    pub total_gb: f64,
    reserved_gb: f64,
    /// model-name → shared backbone segment.
    shared: BTreeMap<String, SharedSegment>,
    /// function-id → residency.
    functions: BTreeMap<usize, FunctionResidency>,
    /// KV-cache reservations: batch-id → GB.
    kv: BTreeMap<u64, f64>,
    /// Incrementally-maintained sum of shared + per-function + KV bytes
    /// (billing runs on every simulator event; re-summing the maps there
    /// dominated the profile).
    used_cache_gb: f64,
    /// Residency-flip journal: `(function, now_resident)` appended each
    /// time a function's residency predicate (any artifact bytes or a
    /// CUDA context) flips. Drained by the billing index to maintain its
    /// per-(gpu, function) warm-pair set without walking the full
    /// resident snapshot. Shared backbones and KV never flip residency.
    res_log: Vec<(usize, bool)>,
}

impl Gpu {
    pub fn new(id: GpuId) -> Self {
        Self::with_capacity(id, params::GPU_MEM_GB)
    }

    pub fn with_capacity(id: GpuId, total_gb: f64) -> Self {
        Gpu {
            id,
            total_gb,
            reserved_gb: params::GPU_RESERVED_GB,
            shared: BTreeMap::new(),
            functions: BTreeMap::new(),
            kv: BTreeMap::new(),
            used_cache_gb: 0.0,
            res_log: Vec::new(),
        }
    }

    fn is_resident(&self, function: usize) -> bool {
        self.functions
            .get(&function)
            .map(|f| !f.kinds.is_empty() || f.has_cuda_context)
            .unwrap_or(false)
    }

    pub fn used_gb(&self) -> f64 {
        debug_assert!({
            let shared: f64 = self.shared.values().map(|s| s.size_gb).sum();
            let func: f64 = self
                .functions
                .values()
                .map(|f| {
                    f.kinds.values().sum::<f64>()
                        + if f.has_cuda_context { params::CUDA_CONTEXT_GB } else { 0.0 }
                })
                .sum();
            let kv: f64 = self.kv.values().sum();
            (shared + func + kv - self.used_cache_gb).abs() < 1e-6
        });
        self.reserved_gb + self.used_cache_gb
    }

    pub fn free_gb(&self) -> f64 {
        self.total_gb - self.used_gb()
    }

    fn check(&self, need_gb: f64) -> Result<(), GpuError> {
        // Tolerate f64 rounding at the nanobyte level.
        if need_gb > self.free_gb() + 1e-9 {
            Err(GpuError::OutOfMemory {
                gpu: self.id.to_string(),
                need_gb,
                free_gb: self.free_gb(),
            })
        } else {
            Ok(())
        }
    }

    // ----------------------------------------------------------- backbones

    /// Load a shared backbone copy (first loader pays the bytes).
    pub fn load_shared_backbone(
        &mut self,
        model: &str,
        size_gb: f64,
    ) -> Result<(), GpuError> {
        if self.shared.contains_key(model) {
            return Ok(());
        }
        self.check(size_gb)?;
        self.shared
            .insert(model.to_string(), SharedSegment { size_gb, refcount: 0 });
        self.used_cache_gb += size_gb;
        Ok(())
    }

    pub fn has_shared_backbone(&self, model: &str) -> bool {
        self.shared.contains_key(model)
    }

    /// Attach a function instance to the shared backbone (IPC-handle open).
    pub fn attach_backbone(&mut self, model: &str) -> Result<(), GpuError> {
        self.shared
            .get_mut(model)
            .ok_or_else(|| GpuError::BackboneMissing(model.into()))?
            .refcount += 1;
        Ok(())
    }

    pub fn detach_backbone(&mut self, model: &str) -> Result<(), GpuError> {
        let seg = self
            .shared
            .get_mut(model)
            .ok_or_else(|| GpuError::BackboneMissing(model.into()))?;
        if seg.refcount == 0 {
            return Err(GpuError::RefcountUnderflow(model.into()));
        }
        seg.refcount -= 1;
        Ok(())
    }

    pub fn backbone_refcount(&self, model: &str) -> usize {
        self.shared.get(model).map(|s| s.refcount).unwrap_or(0)
    }

    /// Unload a shared backbone. Only legal at refcount 0 (§4.4 safety:
    /// never yank memory under a live reader).
    pub fn unload_shared_backbone(&mut self, model: &str) -> Result<f64, GpuError> {
        match self.shared.get(model) {
            None => Err(GpuError::BackboneMissing(model.into())),
            Some(seg) if seg.refcount > 0 => {
                Err(GpuError::RefcountUnderflow(model.into()))
            }
            Some(seg) => {
                let gb = seg.size_gb;
                self.shared.remove(model);
                self.used_cache_gb -= gb;
                Ok(gb)
            }
        }
    }

    pub fn shared_models(&self) -> impl Iterator<Item = (&String, &SharedSegment)> {
        self.shared.iter()
    }

    // ------------------------------------------------- per-function bytes

    /// Place a per-function artifact (adapter bytes, kernel workspace, or a
    /// *private* unshared backbone for the no-sharing baselines).
    pub fn place_artifact(
        &mut self,
        function: usize,
        kind: ArtifactKind,
        size_gb: f64,
    ) -> Result<(), GpuError> {
        debug_assert!(kind.gpu_placeable(), "{kind:?} is not GPU-placeable");
        let already = self
            .functions
            .get(&function)
            .and_then(|f| f.kinds.get(&kind))
            .copied()
            .unwrap_or(0.0);
        if already >= size_gb {
            return Ok(());
        }
        self.check(size_gb - already)?;
        let was_resident = self.is_resident(function);
        self.functions
            .entry(function)
            .or_default()
            .kinds
            .insert(kind, size_gb);
        self.used_cache_gb += size_gb - already;
        if !was_resident {
            self.res_log.push((function, true));
        }
        Ok(())
    }

    pub fn has_artifact(&self, function: usize, kind: ArtifactKind) -> bool {
        self.functions
            .get(&function)
            .map(|f| f.kinds.contains_key(&kind))
            .unwrap_or(false)
    }

    /// Evict one per-function artifact; returns the bytes freed.
    pub fn evict_artifact(
        &mut self,
        function: usize,
        kind: ArtifactKind,
    ) -> Result<f64, GpuError> {
        let f = self
            .functions
            .get_mut(&function)
            .ok_or(GpuError::ArtifactMissing(function, kind))?;
        let gb = f
            .kinds
            .remove(&kind)
            .ok_or(GpuError::ArtifactMissing(function, kind))?;
        // The kind was present ⇒ the function *was* resident; it flips
        // off only when nothing else keeps it resident.
        let still_resident = !f.kinds.is_empty() || f.has_cuda_context;
        self.used_cache_gb -= gb;
        if !still_resident {
            self.res_log.push((function, false));
        }
        Ok(gb)
    }

    /// Create the per-process CUDA context (billed 473 MB, §6.9).
    pub fn create_cuda_context(&mut self, function: usize) -> Result<(), GpuError> {
        if self
            .functions
            .get(&function)
            .map(|f| f.has_cuda_context)
            .unwrap_or(false)
        {
            return Ok(());
        }
        self.check(params::CUDA_CONTEXT_GB)?;
        let was_resident = self.is_resident(function);
        self.functions.entry(function).or_default().has_cuda_context = true;
        self.used_cache_gb += params::CUDA_CONTEXT_GB;
        if !was_resident {
            self.res_log.push((function, true));
        }
        Ok(())
    }

    pub fn has_cuda_context(&self, function: usize) -> bool {
        self.functions
            .get(&function)
            .map(|f| f.has_cuda_context)
            .unwrap_or(false)
    }

    pub fn destroy_cuda_context(&mut self, function: usize) {
        if let Some(f) = self.functions.get_mut(&function) {
            if f.has_cuda_context {
                self.used_cache_gb -= params::CUDA_CONTEXT_GB;
                f.has_cuda_context = false;
                if f.kinds.is_empty() {
                    self.res_log.push((function, false));
                }
            }
        }
    }

    /// Functions with any residency on this GPU.
    pub fn resident_functions(&self) -> BTreeSet<usize> {
        self.functions
            .iter()
            .filter(|(_, f)| !f.kinds.is_empty() || f.has_cuda_context)
            .map(|(id, _)| *id)
            .collect()
    }

    pub fn function_residency(&self, function: usize) -> Option<&FunctionResidency> {
        self.functions.get(&function)
    }

    /// Drain the residency-flip journal into `buf` (cleared first; its
    /// capacity is recycled as the new empty journal).
    pub fn take_res_log(&mut self, buf: &mut Vec<(usize, bool)>) {
        buf.clear();
        std::mem::swap(&mut self.res_log, buf);
    }

    /// Pending (undrained) residency flips, in mutation order.
    pub fn res_log(&self) -> &[(usize, bool)] {
        &self.res_log
    }

    pub fn clear_res_log(&mut self) {
        self.res_log.clear();
    }

    // ------------------------------------------------------------ KV cache

    /// Reserve KV-cache memory for an in-flight batch.
    pub fn reserve_kv(&mut self, batch_id: u64, gb: f64) -> Result<(), GpuError> {
        self.check(gb)?;
        *self.kv.entry(batch_id).or_insert(0.0) += gb;
        self.used_cache_gb += gb;
        Ok(())
    }

    pub fn release_kv(&mut self, batch_id: u64) -> f64 {
        let gb = self.kv.remove(&batch_id).unwrap_or(0.0);
        self.used_cache_gb -= gb;
        gb
    }

    pub fn kv_reserved_gb(&self) -> f64 {
        self.kv.values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> Gpu {
        Gpu::with_capacity(GpuId { node: 0, index: 0 }, 48.0)
    }

    #[test]
    fn ledger_accounting() {
        let mut g = gpu();
        let base = g.used_gb();
        g.load_shared_backbone("7b", 13.5).unwrap();
        g.place_artifact(1, ArtifactKind::Adapter, 0.16).unwrap();
        g.create_cuda_context(1).unwrap();
        g.reserve_kv(100, 2.0).unwrap();
        let used = g.used_gb();
        assert!((used - base - 13.5 - 0.16 - params::CUDA_CONTEXT_GB - 2.0).abs() < 1e-9);
        assert_eq!(g.release_kv(100), 2.0);
        assert_eq!(g.evict_artifact(1, ArtifactKind::Adapter).unwrap(), 0.16);
    }

    #[test]
    fn shared_backbone_loaded_once() {
        let mut g = gpu();
        g.load_shared_backbone("7b", 13.5).unwrap();
        let used = g.used_gb();
        g.load_shared_backbone("7b", 13.5).unwrap(); // idempotent
        assert_eq!(g.used_gb(), used);
    }

    #[test]
    fn refcount_protects_unload() {
        let mut g = gpu();
        g.load_shared_backbone("7b", 13.5).unwrap();
        g.attach_backbone("7b").unwrap();
        assert!(matches!(
            g.unload_shared_backbone("7b"),
            Err(GpuError::RefcountUnderflow(_))
        ));
        g.detach_backbone("7b").unwrap();
        assert_eq!(g.unload_shared_backbone("7b").unwrap(), 13.5);
    }

    #[test]
    fn refcount_underflow_detected() {
        let mut g = gpu();
        g.load_shared_backbone("7b", 13.5).unwrap();
        assert!(g.detach_backbone("7b").is_err());
    }

    #[test]
    fn oom_is_an_error_not_a_panic() {
        let mut g = gpu();
        assert!(g.load_shared_backbone("huge", 100.0).is_err());
        assert!(g.reserve_kv(1, 100.0).is_err());
        // Failed ops must not leak partial state.
        assert!(!g.has_shared_backbone("huge"));
        assert_eq!(g.kv_reserved_gb(), 0.0);
    }

    #[test]
    fn artifact_upsize_charges_delta_only() {
        let mut g = gpu();
        g.place_artifact(1, ArtifactKind::CudaKernel, 0.5).unwrap();
        let used = g.used_gb();
        g.place_artifact(1, ArtifactKind::CudaKernel, 0.5).unwrap();
        assert_eq!(g.used_gb(), used);
    }

    #[test]
    fn residency_flip_journal_records_edges_only() {
        let mut g = gpu();
        g.place_artifact(3, ArtifactKind::Adapter, 0.1).unwrap(); // flip on
        g.place_artifact(3, ArtifactKind::CudaKernel, 0.5).unwrap(); // no flip
        g.create_cuda_context(3).unwrap(); // no flip
        g.create_cuda_context(7).unwrap(); // flip on
        assert_eq!(g.res_log(), &[(3, true), (7, true)]);
        let mut buf = Vec::new();
        g.take_res_log(&mut buf);
        assert_eq!(buf, vec![(3, true), (7, true)]);
        assert!(g.res_log().is_empty());
        g.evict_artifact(3, ArtifactKind::Adapter).unwrap(); // still resident
        g.destroy_cuda_context(3); // still resident (kernel)
        g.evict_artifact(3, ArtifactKind::CudaKernel).unwrap(); // flip off
        g.destroy_cuda_context(7); // flip off
        g.destroy_cuda_context(7); // idempotent: no flip
        assert_eq!(g.res_log(), &[(3, false), (7, false)]);
    }

    #[test]
    fn resident_functions_tracked() {
        let mut g = gpu();
        g.place_artifact(3, ArtifactKind::Adapter, 0.1).unwrap();
        g.create_cuda_context(7).unwrap();
        let r = g.resident_functions();
        assert!(r.contains(&3) && r.contains(&7));
        g.destroy_cuda_context(7);
        assert!(!g.resident_functions().contains(&7));
    }
}
