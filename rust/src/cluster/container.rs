//! Container (host-side) memory ledger.
//!
//! Paper §4.1 principle 2: serverless functions are habitually
//! over-allocated, so idle containers have a running/idle memory gap the
//! pre-loader can fill — and a container may host *multiple* functions'
//! pre-loaded artifacts (shared container in the pre-loading stage).

use std::collections::BTreeMap;

use crate::artifact::{params, ArtifactKind};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContainerId {
    pub node: usize,
    pub index: usize,
}

impl std::fmt::Display for ContainerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ctr{}.{}", self.node, self.index)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ContainerError {
    #[error("container {ctr} out of memory: need {need_gb:.2}, free {free_gb:.2}")]
    OutOfMemory { ctr: String, need_gb: f64, free_gb: f64 },
    #[error("function {0} artifact {1:?} not present")]
    Missing(usize, ArtifactKind),
}

/// Warm container slot with a host-memory ledger.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: ContainerId,
    pub mem_gb: f64,
    /// (function, kind) → GB pre-loaded in this container's RAM.
    items: BTreeMap<(usize, ArtifactKind), f64>,
    /// Warm container slots avoid the cold `CONTAINER_INIT_S`.
    pub warm: bool,
}

impl Container {
    pub fn new(id: ContainerId) -> Self {
        Container {
            id,
            mem_gb: params::CONTAINER_MEM_GB,
            items: BTreeMap::new(),
            warm: true,
        }
    }

    pub fn used_gb(&self) -> f64 {
        self.items.values().sum()
    }

    pub fn free_gb(&self) -> f64 {
        self.mem_gb - self.used_gb()
    }

    pub fn place(
        &mut self,
        function: usize,
        kind: ArtifactKind,
        size_gb: f64,
    ) -> Result<(), ContainerError> {
        debug_assert!(
            kind.container_placeable(),
            "{kind:?} is not container-placeable"
        );
        let key = (function, kind);
        let already = self.items.get(&key).copied().unwrap_or(0.0);
        if already >= size_gb {
            return Ok(());
        }
        if size_gb - already > self.free_gb() + 1e-9 {
            return Err(ContainerError::OutOfMemory {
                ctr: self.id.to_string(),
                need_gb: size_gb - already,
                free_gb: self.free_gb(),
            });
        }
        self.items.insert(key, size_gb);
        Ok(())
    }

    pub fn has(&self, function: usize, kind: ArtifactKind) -> bool {
        self.items.contains_key(&(function, kind))
    }

    pub fn evict(
        &mut self,
        function: usize,
        kind: ArtifactKind,
    ) -> Result<f64, ContainerError> {
        self.items
            .remove(&(function, kind))
            .ok_or(ContainerError::Missing(function, kind))
    }

    /// All (function, kind, GB) triples currently resident.
    pub fn items(&self) -> impl Iterator<Item = (usize, ArtifactKind, f64)> + '_ {
        self.items.iter().map(|(&(f, k), &gb)| (f, k, gb))
    }

    pub fn functions_hosted(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.items.keys().map(|&(f, _)| f).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctr() -> Container {
        Container::new(ContainerId { node: 0, index: 0 })
    }

    #[test]
    fn ledger_basics() {
        let mut c = ctr();
        c.place(1, ArtifactKind::Library, 2.5).unwrap();
        c.place(1, ArtifactKind::Backbone, 13.5).unwrap();
        assert!((c.used_gb() - 16.0).abs() < 1e-9);
        assert_eq!(c.evict(1, ArtifactKind::Backbone).unwrap(), 13.5);
        assert!((c.used_gb() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn multiple_functions_share_one_container() {
        // §4.1 principle 2.
        let mut c = ctr();
        c.place(1, ArtifactKind::Library, 2.5).unwrap();
        c.place(2, ArtifactKind::Adapter, 0.2).unwrap();
        assert_eq!(c.functions_hosted(), vec![1, 2]);
    }

    #[test]
    fn oom_checked() {
        let mut c = ctr();
        let e = c.place(1, ArtifactKind::Backbone, 1e9);
        assert!(matches!(e, Err(ContainerError::OutOfMemory { .. })));
        assert_eq!(c.used_gb(), 0.0);
    }

    #[test]
    fn idempotent_place() {
        let mut c = ctr();
        c.place(1, ArtifactKind::Library, 2.5).unwrap();
        c.place(1, ArtifactKind::Library, 2.5).unwrap();
        assert!((c.used_gb() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn missing_evict_is_error() {
        let mut c = ctr();
        assert!(c.evict(9, ArtifactKind::Library).is_err());
    }
}
