//! Simulated GPU cluster substrate: worker nodes with GPUs and warm
//! container slots, mirroring the paper's AWS testbeds (8× L40S single
//! node / 16× L40S four-node). All memory movements the scheduler reasons
//! about are tracked by the per-device ledgers in `gpu.rs`/`container.rs`.
//!
//! The cluster also maintains lazily-repaired **routing indexes** so the
//! per-dispatch hot paths stay sub-linear at fleet scale:
//!
//! * a free-memory ordering over all GPUs (`scan_free_desc`) — the
//!   router's zero-warmth frontier and `maybe_replicate`'s idle-GPU
//!   search walk it from the top instead of scoring every GPU;
//! * per-function GPU residency (`gpus_with_function`) — the warm
//!   candidates for a function that has no shared-backbone host yet;
//! * container residency counts (`container_has`) — replaces the
//!   per-cold-dispatch scan over every container.
//!
//! Mutation goes through `gpu_mut` / `container_mut`, which mark the
//! device dirty; the next index query repairs exactly the dirty entries.
//! `Engine::check_indexes` re-derives everything by brute force in tests.

pub mod cache;
pub mod container;
pub mod gpu;

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};

pub use cache::{CacheEntry, HostCache};
pub use container::{Container, ContainerError, ContainerId};
pub use gpu::{Gpu, GpuError, GpuId};

use crate::artifact::ArtifactKind;
use crate::util::f64_key;

/// One worker node: a set of GPUs plus warm container slots, and (when the
/// tiered store is enabled) a host-RAM checkpoint cache shared by them.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub gpus: Vec<Gpu>,
    pub containers: Vec<Container>,
    /// Host-RAM checkpoint cache (capacity 0 = tier disabled, the default).
    pub cache: HostCache,
}

impl Node {
    pub fn new(id: usize, n_gpus: usize, n_containers: usize) -> Self {
        Node {
            id,
            gpus: (0..n_gpus)
                .map(|i| Gpu::new(GpuId { node: id, index: i }))
                .collect(),
            containers: (0..n_containers)
                .map(|i| Container::new(ContainerId { node: id, index: i }))
                .collect(),
            cache: HostCache::default(),
        }
    }
}

/// Lazily-repaired routing indexes (see module docs). `built == false`
/// means a full rebuild happens on the next query.
#[derive(Debug, Clone, Default)]
struct ClusterIndex {
    built: bool,
    /// Ascending (free-memory total-order key, GpuId); iterate `.rev()`
    /// for the descending frontier.
    free: BTreeSet<(u64, GpuId)>,
    /// GPU → its current key in `free`.
    free_key: BTreeMap<GpuId, u64>,
    /// function → GPUs holding any of its residency (artifacts/context).
    fn_gpus: BTreeMap<usize, BTreeSet<GpuId>>,
    /// GPU → snapshot of the functions counted into `fn_gpus`.
    gpu_fns: BTreeMap<GpuId, Vec<usize>>,
    dirty_gpus: Vec<GpuId>,
    /// (function, kind) → number of containers holding it.
    cres: BTreeMap<(usize, ArtifactKind), usize>,
    /// Container → snapshot of the pairs counted into `cres`.
    container_items: BTreeMap<ContainerId, Vec<(usize, ArtifactKind)>>,
    dirty_containers: Vec<ContainerId>,
}

impl ClusterIndex {
    fn add_gpu(&mut self, g: &Gpu) {
        let k = f64_key(g.free_gb());
        self.free.insert((k, g.id));
        self.free_key.insert(g.id, k);
        let fns: Vec<usize> = g.resident_functions().into_iter().collect();
        for &f in &fns {
            self.fn_gpus.entry(f).or_default().insert(g.id);
        }
        self.gpu_fns.insert(g.id, fns);
    }

    fn remove_gpu(&mut self, id: GpuId) {
        if let Some(k) = self.free_key.remove(&id) {
            self.free.remove(&(k, id));
        }
        if let Some(fns) = self.gpu_fns.remove(&id) {
            for f in fns {
                if let Some(s) = self.fn_gpus.get_mut(&f) {
                    s.remove(&id);
                    if s.is_empty() {
                        self.fn_gpus.remove(&f);
                    }
                }
            }
        }
    }

    fn add_container(&mut self, c: &Container) {
        let items: Vec<(usize, ArtifactKind)> =
            c.items().map(|(f, k, _)| (f, k)).collect();
        for &key in &items {
            *self.cres.entry(key).or_insert(0) += 1;
        }
        self.container_items.insert(c.id, items);
    }

    fn remove_container(&mut self, id: ContainerId) {
        if let Some(items) = self.container_items.remove(&id) {
            for key in items {
                let n = self.cres.get_mut(&key).expect("count for snapshotted item");
                *n -= 1;
                if *n == 0 {
                    self.cres.remove(&key);
                }
            }
        }
    }
}

/// Dense GPU addressing for the engine's arena state: `GpuId` ↔ a
/// contiguous `0..n_gpus` index, in `GpuId` (node, index) order — so
/// iterating the dense range replays the same order as the historical
/// `BTreeMap<GpuId, _>` walks. The GPU set is fixed for a run
/// (`trim_gpus` happens before the engine is built), so the map is
/// computed once.
///
/// `dense()` of an id that was trimmed away can alias a *valid* slot of
/// a later node; callers translating possibly-stale ids (the billing
/// drain) must gate on [`Cluster::try_gpu`] first — try_gpu success is
/// exactly dense validity.
#[derive(Debug, Clone)]
pub struct GpuDenseMap {
    ids: Vec<GpuId>,
    node_base: Vec<usize>,
}

impl GpuDenseMap {
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn dense(&self, id: GpuId) -> usize {
        self.node_base[id.node] + id.index
    }

    pub fn id(&self, dense: usize) -> GpuId {
        self.ids[dense]
    }

    /// All GPU ids in dense (= `GpuId` Ord) order.
    pub fn ids(&self) -> &[GpuId] {
        &self.ids
    }
}

/// The whole deployment.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    index: RefCell<ClusterIndex>,
    /// GPUs whose memory ledger changed since the engine's last billing
    /// drain. A second dirty channel beside the routing index's: the
    /// routing index repairs lazily on queries, while the billing
    /// aggregates drain this once per event — the two must not steal
    /// each other's marks.
    bill_dirty: Vec<GpuId>,
    /// GPUs currently down (fault injection). Empty unless faults are
    /// enabled, so health checks on the routing hot paths are one
    /// `is_empty()` when the subsystem is off.
    down: BTreeSet<GpuId>,
    /// Nodes currently down (correlated failure domains). A GPU is up
    /// only if it is not in `down` *and* its node is not here — so a
    /// GPU-level recover while the node is still out does not make the
    /// GPU routable. Empty unless domain faults are enabled.
    node_down: BTreeSet<usize>,
    /// Observed failure history for failure-aware routing. `None` (the
    /// default) keeps `failure_penalty` at exactly 0.0 so score
    /// arithmetic is bit-identical to the failure-blind build.
    fail_hist: Option<FailureHistory>,
}

/// Per-GPU failure observations the router may consult as a score
/// penalty (behind the `failure_aware` knob, default off).
///
/// Crash history is an event-driven EWMA: each crash decays the stored
/// value by `exp(-Δt/τ)` and adds 1. The value is *not* re-decayed at
/// read time — the router has no clock — so the penalty is piecewise
/// constant between crashes, which keeps scoring deterministic and
/// allocation-free on the dispatch hot path.
#[derive(Debug, Clone, Default)]
pub struct FailureHistory {
    /// EWMA decay time constant (seconds).
    tau_s: f64,
    /// Score penalty (in the router's GB-equivalent units) per unit of
    /// decayed crash count, and per unit of excess slowdown factor.
    penalty_gb: f64,
    /// GPU → (decayed crash count, time of last crash).
    crash_ewma: BTreeMap<GpuId, (f64, f64)>,
    /// GPU → current slowdown factor while degraded (absent = healthy).
    degraded: BTreeMap<GpuId, f64>,
}

impl Cluster {
    /// `n_nodes` × `gpus_per_node`, with `containers_per_node` warm slots.
    pub fn new(n_nodes: usize, gpus_per_node: usize, containers_per_node: usize) -> Self {
        Cluster {
            nodes: (0..n_nodes)
                .map(|i| Node::new(i, gpus_per_node, containers_per_node))
                .collect(),
            index: RefCell::new(ClusterIndex::default()),
            bill_dirty: Vec::new(),
            down: BTreeSet::new(),
            node_down: BTreeSet::new(),
            fail_hist: None,
        }
    }

    /// The paper's multi-node testbed: 4 nodes × 4 L40S.
    pub fn paper_multinode() -> Self {
        Cluster::new(4, 4, 8)
    }

    /// The paper's single-node testbed: 1 node × 8 L40S.
    pub fn paper_singlenode() -> Self {
        Cluster::new(1, 8, 16)
    }

    pub fn gpu(&self, id: GpuId) -> &Gpu {
        &self.nodes[id.node].gpus[id.index]
    }

    /// Mutable GPU access. Marks the GPU dirty in the routing indexes
    /// (repaired lazily on the next query) and in the billing channel
    /// (drained by the engine once per event).
    pub fn gpu_mut(&mut self, id: GpuId) -> &mut Gpu {
        self.index.get_mut().dirty_gpus.push(id);
        self.bill_dirty.push(id);
        &mut self.nodes[id.node].gpus[id.index]
    }

    /// GPU access that tolerates ids removed by `trim_gpus` (the billing
    /// drain may hold marks for GPUs that no longer exist).
    pub fn try_gpu(&self, id: GpuId) -> Option<&Gpu> {
        self.nodes.get(id.node).and_then(|n| n.gpus.get(id.index))
    }

    pub fn container(&self, id: ContainerId) -> &Container {
        &self.nodes[id.node].containers[id.index]
    }

    /// Mutable container access. Marks the container dirty in the
    /// residency index (repaired lazily on the next query).
    pub fn container_mut(&mut self, id: ContainerId) -> &mut Container {
        self.index.get_mut().dirty_containers.push(id);
        &mut self.nodes[id.node].containers[id.index]
    }

    /// Replace a GPU wholesale (test fixtures with custom capacities).
    pub fn replace_gpu(&mut self, id: GpuId, gpu: Gpu) {
        assert_eq!(gpu.id, id, "replacement GPU must keep its id");
        self.index.get_mut().dirty_gpus.push(id);
        self.bill_dirty.push(id);
        self.nodes[id.node].gpus[id.index] = gpu;
    }

    /// Give every node a host-RAM checkpoint cache of `gb` (0 disables
    /// the tier).  Called once at engine build from the tier config.
    pub fn set_host_cache_gb(&mut self, gb: f64) {
        for n in &mut self.nodes {
            n.cache = HostCache::new(gb);
        }
    }

    /// Drop GPUs from the tail of the node list until exactly
    /// `total.max(1)` remain (fleet-experiment cluster shaping).
    pub fn trim_gpus(&mut self, total: usize) {
        while self.n_gpus() > total.max(1) {
            let node = self
                .nodes
                .iter_mut()
                .rev()
                .find(|n| !n.gpus.is_empty())
                .expect("n_gpus > 0 implies a non-empty node");
            if let Some(g) = node.gpus.pop() {
                self.bill_dirty.push(g.id);
            }
        }
        self.index.get_mut().built = false; // full rebuild on next query
    }

    /// Take (and clear) the billing-dirty marks accumulated since the
    /// last drain. Entries may repeat and may name removed GPUs; the
    /// engine dedups and uses [`Cluster::try_gpu`].
    pub fn take_bill_dirty(&mut self) -> Vec<GpuId> {
        std::mem::take(&mut self.bill_dirty)
    }

    /// Allocation-free variant for the per-event drain: swap the dirty
    /// marks with the caller's (cleared) scratch buffer, so both sides
    /// keep their capacity across millions of events.
    pub fn swap_bill_dirty(&mut self, buf: &mut Vec<GpuId>) {
        std::mem::swap(&mut self.bill_dirty, buf);
    }

    /// Drain one GPU's residency-flip journal into `buf` (cleared first).
    /// Deliberately does **not** mark the GPU dirty: every flip was
    /// produced through `gpu_mut`, so the GPU already carries routing and
    /// billing marks from the mutation itself.
    pub fn take_res_log(&mut self, id: GpuId, buf: &mut Vec<(usize, bool)>) {
        buf.clear();
        if let Some(g) = self.nodes.get_mut(id.node).and_then(|n| n.gpus.get_mut(id.index))
        {
            g.take_res_log(buf);
        }
    }

    /// Discard every GPU's pending residency flips (billing re-init).
    pub fn clear_res_logs(&mut self) {
        for n in &mut self.nodes {
            for g in &mut n.gpus {
                g.clear_res_log();
            }
        }
    }

    // ------------------------------------------------------- health state

    /// Is this GPU up?  Routing, replication, and staging policies must
    /// skip down GPUs; with faults off both sets are empty and this is
    /// two branches. A GPU is down if either it crashed individually or
    /// its whole node is out — the two dimensions recover independently.
    pub fn gpu_is_up(&self, id: GpuId) -> bool {
        (self.down.is_empty() || !self.down.contains(&id))
            && (self.node_down.is_empty() || !self.node_down.contains(&id.node))
    }

    /// Flip a GPU's health (fault injection only). The caller (engine
    /// crash/recover handlers) is responsible for killing batches and
    /// invalidating residency on the way down.
    pub fn set_gpu_health(&mut self, id: GpuId, up: bool) {
        if up {
            self.down.remove(&id);
        } else {
            self.down.insert(id);
        }
    }

    /// Flip a whole node's health (correlated-domain fault injection).
    /// Does not touch per-GPU health: a member GPU that also crashed
    /// individually stays down after the node repairs, and a member GPU
    /// whose individual repair lands while the node is out stays
    /// unroutable until the node comes back.
    pub fn set_node_health(&mut self, node: usize, up: bool) {
        if up {
            self.node_down.remove(&node);
        } else {
            self.node_down.insert(node);
        }
    }

    /// Is this node up (node dimension only — its GPUs may still be
    /// individually down)?
    pub fn node_is_up(&self, node: usize) -> bool {
        self.node_down.is_empty() || !self.node_down.contains(&node)
    }

    /// Number of GPUs currently down (GPU dimension only).
    pub fn n_down(&self) -> usize {
        self.down.len()
    }

    /// Number of nodes currently down.
    pub fn n_nodes_down(&self) -> usize {
        self.node_down.len()
    }

    // ------------------------------------------------- failure history

    /// Turn on failure-history tracking (the `failure_aware` knob).
    /// Until this is called, `failure_penalty` returns exactly 0.0.
    pub fn enable_failure_tracking(&mut self, tau_s: f64, penalty_gb: f64) {
        self.fail_hist = Some(FailureHistory {
            tau_s: tau_s.max(1e-9),
            penalty_gb,
            ..FailureHistory::default()
        });
    }

    pub fn failure_tracking_enabled(&self) -> bool {
        self.fail_hist.is_some()
    }

    /// Record a crash observation for `id` at `now` (individual crash or
    /// a correlated outage taking the GPU down). No-op when tracking is
    /// off.
    pub fn note_crash(&mut self, id: GpuId, now_s: f64) {
        if let Some(h) = &mut self.fail_hist {
            let e = h.crash_ewma.entry(id).or_insert((0.0, now_s));
            let dt = (now_s - e.1).max(0.0);
            e.0 = e.0 * (-dt / h.tau_s).exp() + 1.0;
            e.1 = now_s;
        }
    }

    /// Record that `id` entered (factor > 1) or left degraded mode.
    /// No-op when tracking is off.
    pub fn note_degrade(&mut self, id: GpuId, factor: f64) {
        if let Some(h) = &mut self.fail_hist {
            if factor > 1.0 {
                h.degraded.insert(id, factor);
            } else {
                h.degraded.remove(&id);
            }
        }
    }

    /// Routing-score penalty for `id`, in the router's GB-equivalent
    /// units: decayed crash count plus the excess slowdown factor while
    /// degraded, each scaled by `penalty_gb`. Exactly 0.0 when tracking
    /// is off — `score - 0.0` is bit-identical to `score`, so enabling
    /// the code path without the knob perturbs nothing.
    pub fn failure_penalty(&self, id: GpuId) -> f64 {
        match &self.fail_hist {
            None => 0.0,
            Some(h) => {
                let crashes = h.crash_ewma.get(&id).map_or(0.0, |&(v, _)| v);
                let slow = h.degraded.get(&id).map_or(0.0, |&f| f - 1.0);
                h.penalty_gb * (crashes + slow)
            }
        }
    }

    pub fn gpus(&self) -> impl Iterator<Item = &Gpu> {
        self.nodes.iter().flat_map(|n| n.gpus.iter())
    }

    pub fn gpu_ids(&self) -> Vec<GpuId> {
        self.nodes
            .iter()
            .flat_map(|n| n.gpus.iter().map(|g| g.id))
            .collect()
    }

    pub fn container_ids(&self) -> Vec<ContainerId> {
        self.nodes
            .iter()
            .flat_map(|n| n.containers.iter().map(|c| c.id))
            .collect()
    }

    pub fn n_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.gpus.len()).sum()
    }

    /// Build the dense GPU index map (see [`GpuDenseMap`]).
    pub fn dense_map(&self) -> GpuDenseMap {
        let mut node_base = Vec::with_capacity(self.nodes.len());
        let mut ids = Vec::with_capacity(self.n_gpus());
        let mut base = 0;
        for n in &self.nodes {
            node_base.push(base);
            base += n.gpus.len();
            ids.extend(n.gpus.iter().map(|g| g.id));
        }
        GpuDenseMap { ids, node_base }
    }

    pub fn total_gpu_mem_gb(&self) -> f64 {
        self.gpus().map(|g| g.total_gb).sum()
    }

    pub fn total_gpu_free_gb(&self) -> f64 {
        self.gpus().map(|g| g.free_gb()).sum()
    }

    // ------------------------------------------------------ routing indexes

    /// Apply pending dirty-marks (or a full rebuild) to the indexes.
    fn repair(&self) {
        let mut ix = self.index.borrow_mut();
        if !ix.built {
            *ix = ClusterIndex { built: true, ..Default::default() };
            for n in &self.nodes {
                for g in &n.gpus {
                    ix.add_gpu(g);
                }
                for c in &n.containers {
                    ix.add_container(c);
                }
            }
            return;
        }
        while let Some(id) = ix.dirty_gpus.pop() {
            ix.remove_gpu(id);
            if let Some(g) = self
                .nodes
                .get(id.node)
                .and_then(|n| n.gpus.get(id.index))
            {
                ix.add_gpu(g);
            }
        }
        while let Some(id) = ix.dirty_containers.pop() {
            ix.remove_container(id);
            if let Some(c) = self
                .nodes
                .get(id.node)
                .and_then(|n| n.containers.get(id.index))
            {
                ix.add_container(c);
            }
        }
    }

    /// Walk GPUs in descending `(free memory, id)` order, calling `visit`
    /// until it returns true; returns the accepted GPU. Equal free memory
    /// visits the higher `GpuId` first — the same selection the historical
    /// full scan's last-max-wins produced. `visit` must not re-enter the
    /// cluster's index queries (plain GPU/container reads are fine).
    pub fn scan_free_desc(
        &self,
        mut visit: impl FnMut(GpuId, f64) -> bool,
    ) -> Option<GpuId> {
        self.repair();
        let ix = self.index.borrow();
        for &(_, g) in ix.free.iter().rev() {
            if visit(g, self.gpu(g).free_gb()) {
                return Some(g);
            }
        }
        None
    }

    /// GPUs where `function` has any residency (artifacts or a CUDA
    /// context) — the warm routing candidates when no shared-backbone
    /// host exists.
    pub fn gpus_with_function(&self, function: usize) -> Vec<GpuId> {
        self.repair();
        self.index
            .borrow()
            .fn_gpus
            .get(&function)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    /// Visit the functions resident on one GPU via the index's per-GPU
    /// snapshot — no `Gpu::resident_functions()` BTreeSet allocation.
    /// The billing drain's warm-count refresh runs on this. `visit`
    /// must not re-enter the cluster's index queries.
    pub fn for_each_resident(&self, gpu: GpuId, mut visit: impl FnMut(usize)) {
        self.repair();
        if let Some(fns) = self.index.borrow().gpu_fns.get(&gpu) {
            for &f in fns {
                visit(f);
            }
        }
    }

    /// Does any container hold this (function, kind) artifact? O(log)
    /// via the residency count index — replaces the per-cold-dispatch
    /// scan over every container.
    pub fn container_has(&self, function: usize, kind: ArtifactKind) -> bool {
        self.repair();
        self.index
            .borrow()
            .cres
            .get(&(function, kind))
            .copied()
            .unwrap_or(0)
            > 0
    }

    /// Brute-force re-derivation of every routing index, asserting each
    /// matches its incremental counterpart. Called from
    /// `Engine::check_indexes` and tests; never by the simulation.
    pub fn check_index(&self) {
        self.repair();
        let ix = self.index.borrow();
        let mut free = BTreeSet::new();
        let mut fn_gpus: BTreeMap<usize, BTreeSet<GpuId>> = BTreeMap::new();
        let mut cres: BTreeMap<(usize, ArtifactKind), usize> = BTreeMap::new();
        for n in &self.nodes {
            for g in &n.gpus {
                free.insert((f64_key(g.free_gb()), g.id));
                for f in g.resident_functions() {
                    fn_gpus.entry(f).or_default().insert(g.id);
                }
            }
            for c in &n.containers {
                for (f, k, _) in c.items() {
                    *cres.entry((f, k)).or_insert(0) += 1;
                }
            }
        }
        assert_eq!(ix.free, free, "free-memory index drifted");
        assert_eq!(ix.fn_gpus, fn_gpus, "per-function GPU residency index drifted");
        assert_eq!(ix.cres, cres, "container residency index drifted");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbeds() {
        assert_eq!(Cluster::paper_multinode().n_gpus(), 16);
        assert_eq!(Cluster::paper_singlenode().n_gpus(), 8);
    }

    #[test]
    fn ids_address_correctly() {
        let c = Cluster::new(2, 3, 2);
        assert_eq!(c.n_gpus(), 6);
        let ids = c.gpu_ids();
        assert_eq!(ids.len(), 6);
        for id in ids {
            assert_eq!(c.gpu(id).id, id);
        }
        for id in c.container_ids() {
            assert_eq!(c.container(id).id, id);
        }
    }

    #[test]
    fn total_memory_sums() {
        let c = Cluster::new(2, 2, 1);
        assert!((c.total_gpu_mem_gb() - 4.0 * 48.0).abs() < 1e-9);
    }

    #[test]
    fn free_index_tracks_mutations() {
        let mut c = Cluster::new(1, 3, 2);
        let ids = c.gpu_ids();
        c.check_index();
        // Equal free memory: the frontier visits the highest id first.
        let first = c.scan_free_desc(|_, _| true).unwrap();
        assert_eq!(first, ids[2]);
        // Consume memory on the last GPU: the frontier moves.
        c.gpu_mut(ids[2]).reserve_kv(1, 10.0).unwrap();
        c.check_index();
        let first = c.scan_free_desc(|_, _| true).unwrap();
        assert_eq!(first, ids[1]);
        // Free it again.
        c.gpu_mut(ids[2]).release_kv(1);
        c.check_index();
        assert_eq!(c.scan_free_desc(|_, _| true).unwrap(), ids[2]);
    }

    #[test]
    fn fn_residency_index_tracks_mutations() {
        let mut c = Cluster::new(1, 2, 2);
        let ids = c.gpu_ids();
        assert!(c.gpus_with_function(7).is_empty());
        c.gpu_mut(ids[1])
            .place_artifact(7, ArtifactKind::Adapter, 0.2)
            .unwrap();
        c.check_index();
        assert_eq!(c.gpus_with_function(7), vec![ids[1]]);
        c.gpu_mut(ids[0]).create_cuda_context(7).unwrap();
        assert_eq!(c.gpus_with_function(7), vec![ids[0], ids[1]]);
        c.gpu_mut(ids[1])
            .evict_artifact(7, ArtifactKind::Adapter)
            .unwrap();
        c.gpu_mut(ids[0]).destroy_cuda_context(7);
        c.check_index();
        assert!(c.gpus_with_function(7).is_empty());
    }

    #[test]
    fn container_residency_counts() {
        let mut c = Cluster::new(1, 1, 2);
        let cids = c.container_ids();
        assert!(!c.container_has(3, ArtifactKind::Library));
        c.container_mut(cids[0])
            .place(3, ArtifactKind::Library, 2.5)
            .unwrap();
        c.container_mut(cids[1])
            .place(3, ArtifactKind::Library, 2.5)
            .unwrap();
        c.check_index();
        assert!(c.container_has(3, ArtifactKind::Library));
        c.container_mut(cids[0]).evict(3, ArtifactKind::Library).unwrap();
        assert!(c.container_has(3, ArtifactKind::Library), "second copy remains");
        c.container_mut(cids[1]).evict(3, ArtifactKind::Library).unwrap();
        c.check_index();
        assert!(!c.container_has(3, ArtifactKind::Library));
    }

    #[test]
    fn bill_dirty_channel_tracks_gpu_mutations() {
        let mut c = Cluster::new(1, 2, 1);
        let ids = c.gpu_ids();
        assert!(c.take_bill_dirty().is_empty());
        c.gpu_mut(ids[0]).reserve_kv(1, 1.0).unwrap();
        c.gpu_mut(ids[1])
            .place_artifact(3, ArtifactKind::Adapter, 0.2)
            .unwrap();
        c.gpu_mut(ids[0]).release_kv(1);
        let mut dirty = c.take_bill_dirty();
        dirty.sort_unstable();
        dirty.dedup();
        assert_eq!(dirty, ids, "every mutated GPU is marked exactly once");
        // The drain clears the channel; routing-index queries do not.
        assert!(c.take_bill_dirty().is_empty());
        let _ = c.gpus_with_function(3);
        assert!(c.take_bill_dirty().is_empty());
        // Swap variant: marks move into the buffer, the channel takes
        // the (cleared) buffer back.
        c.gpu_mut(ids[0]).reserve_kv(2, 1.0).unwrap();
        let mut buf = Vec::new();
        c.swap_bill_dirty(&mut buf);
        assert_eq!(buf, vec![ids[0]]);
        assert!(c.take_bill_dirty().is_empty());
    }

    #[test]
    fn for_each_resident_matches_ledger() {
        let mut c = Cluster::new(1, 2, 1);
        let ids = c.gpu_ids();
        c.gpu_mut(ids[0])
            .place_artifact(3, ArtifactKind::Adapter, 0.2)
            .unwrap();
        c.gpu_mut(ids[0]).create_cuda_context(7).unwrap();
        let mut seen = Vec::new();
        c.for_each_resident(ids[0], |f| seen.push(f));
        seen.sort_unstable();
        assert_eq!(seen, vec![3, 7]);
        let mut other = Vec::new();
        c.for_each_resident(ids[1], |f| other.push(f));
        assert!(other.is_empty());
    }

    #[test]
    fn dense_map_round_trips_in_id_order() {
        let mut c = Cluster::new(3, 4, 1);
        c.trim_gpus(10); // last node keeps 2 GPUs
        let m = c.dense_map();
        assert_eq!(m.len(), 10);
        let ids = c.gpu_ids();
        assert_eq!(m.ids(), &ids[..]);
        for (d, &id) in ids.iter().enumerate() {
            assert_eq!(m.dense(id), d);
            assert_eq!(m.id(d), id);
        }
    }

    #[test]
    fn res_log_drains_without_marking_dirty() {
        let mut c = Cluster::new(1, 2, 1);
        let ids = c.gpu_ids();
        c.gpu_mut(ids[0])
            .place_artifact(3, ArtifactKind::Adapter, 0.2)
            .unwrap();
        let _ = c.take_bill_dirty();
        let mut buf = vec![(99, true)]; // stale content must be cleared
        c.take_res_log(ids[0], &mut buf);
        assert_eq!(buf, vec![(3, true)]);
        assert!(c.gpu(ids[0]).res_log().is_empty());
        assert!(c.take_bill_dirty().is_empty(), "drain must not re-mark");
        c.gpu_mut(ids[1]).create_cuda_context(5).unwrap();
        c.clear_res_logs();
        assert!(c.gpu(ids[1]).res_log().is_empty());
    }

    #[test]
    fn health_state_flips_and_defaults_up() {
        let mut c = Cluster::new(1, 2, 1);
        let ids = c.gpu_ids();
        assert!(ids.iter().all(|&g| c.gpu_is_up(g)));
        assert_eq!(c.n_down(), 0);
        c.set_gpu_health(ids[0], false);
        assert!(!c.gpu_is_up(ids[0]));
        assert!(c.gpu_is_up(ids[1]));
        assert_eq!(c.n_down(), 1);
        c.set_gpu_health(ids[0], true);
        assert!(c.gpu_is_up(ids[0]));
        assert_eq!(c.n_down(), 0);
    }

    #[test]
    fn node_health_is_a_second_dimension() {
        let mut c = Cluster::new(2, 2, 1);
        let ids = c.gpu_ids();
        assert!(c.node_is_up(0));
        c.set_node_health(0, false);
        assert_eq!(c.n_nodes_down(), 1);
        assert_eq!(c.n_down(), 0, "node outage is not per-GPU down state");
        assert!(!c.gpu_is_up(ids[0]) && !c.gpu_is_up(ids[1]));
        assert!(c.gpu_is_up(ids[2]) && c.gpu_is_up(ids[3]));
        // An individual crash on a node-down GPU, then its individual
        // repair while the node is still out: not routable.
        c.set_gpu_health(ids[0], false);
        c.set_gpu_health(ids[0], true);
        assert!(!c.gpu_is_up(ids[0]), "node still down");
        // Node repair with a member GPU individually down: only the
        // healthy member comes back.
        c.set_gpu_health(ids[1], false);
        c.set_node_health(0, true);
        assert!(c.gpu_is_up(ids[0]));
        assert!(!c.gpu_is_up(ids[1]), "individual crash outlives node repair");
    }

    #[test]
    fn failure_penalty_is_zero_until_enabled() {
        let mut c = Cluster::new(1, 2, 1);
        let ids = c.gpu_ids();
        c.note_crash(ids[0], 10.0); // no-op: tracking off
        c.note_degrade(ids[0], 3.0);
        assert_eq!(c.failure_penalty(ids[0]).to_bits(), 0.0_f64.to_bits());
        c.enable_failure_tracking(100.0, 2.0);
        assert_eq!(c.failure_penalty(ids[0]), 0.0, "no observations yet");
        c.note_crash(ids[0], 10.0);
        assert!((c.failure_penalty(ids[0]) - 2.0).abs() < 1e-12);
        // A second crash one time-constant later: e^-1 decay plus 1.
        c.note_crash(ids[0], 110.0);
        let want = 2.0 * ((-1.0_f64).exp() + 1.0);
        assert!((c.failure_penalty(ids[0]) - want).abs() < 1e-12);
        // Degrade adds (factor - 1) in the same units; restore clears it.
        c.note_degrade(ids[1], 2.5);
        assert!((c.failure_penalty(ids[1]) - 3.0).abs() < 1e-12);
        c.note_degrade(ids[1], 1.0);
        assert_eq!(c.failure_penalty(ids[1]), 0.0);
    }

    #[test]
    fn trim_and_replace_keep_index_coherent() {
        let mut c = Cluster::new(2, 8, 2);
        c.trim_gpus(11);
        assert_eq!(c.n_gpus(), 11);
        c.check_index();
        let id = c.gpu_ids()[0];
        c.replace_gpu(id, Gpu::with_capacity(id, 96.0));
        c.check_index();
        // The doubled-capacity GPU is now the free-memory frontier.
        assert_eq!(c.scan_free_desc(|_, _| true), Some(id));
    }
}
