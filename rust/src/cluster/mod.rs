//! Simulated GPU cluster substrate: worker nodes with GPUs and warm
//! container slots, mirroring the paper's AWS testbeds (8× L40S single
//! node / 16× L40S four-node). All memory movements the scheduler reasons
//! about are tracked by the per-device ledgers in `gpu.rs`/`container.rs`.

pub mod container;
pub mod gpu;

pub use container::{Container, ContainerError, ContainerId};
pub use gpu::{Gpu, GpuError, GpuId};

/// One worker node: a set of GPUs plus warm container slots.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub gpus: Vec<Gpu>,
    pub containers: Vec<Container>,
}

impl Node {
    pub fn new(id: usize, n_gpus: usize, n_containers: usize) -> Self {
        Node {
            id,
            gpus: (0..n_gpus)
                .map(|i| Gpu::new(GpuId { node: id, index: i }))
                .collect(),
            containers: (0..n_containers)
                .map(|i| Container::new(ContainerId { node: id, index: i }))
                .collect(),
        }
    }
}

/// The whole deployment.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: Vec<Node>,
}

impl Cluster {
    /// `n_nodes` × `gpus_per_node`, with `containers_per_node` warm slots.
    pub fn new(n_nodes: usize, gpus_per_node: usize, containers_per_node: usize) -> Self {
        Cluster {
            nodes: (0..n_nodes)
                .map(|i| Node::new(i, gpus_per_node, containers_per_node))
                .collect(),
        }
    }

    /// The paper's multi-node testbed: 4 nodes × 4 L40S.
    pub fn paper_multinode() -> Self {
        Cluster::new(4, 4, 8)
    }

    /// The paper's single-node testbed: 1 node × 8 L40S.
    pub fn paper_singlenode() -> Self {
        Cluster::new(1, 8, 16)
    }

    pub fn gpu(&self, id: GpuId) -> &Gpu {
        &self.nodes[id.node].gpus[id.index]
    }

    pub fn gpu_mut(&mut self, id: GpuId) -> &mut Gpu {
        &mut self.nodes[id.node].gpus[id.index]
    }

    pub fn container(&self, id: ContainerId) -> &Container {
        &self.nodes[id.node].containers[id.index]
    }

    pub fn container_mut(&mut self, id: ContainerId) -> &mut Container {
        &mut self.nodes[id.node].containers[id.index]
    }

    pub fn gpus(&self) -> impl Iterator<Item = &Gpu> {
        self.nodes.iter().flat_map(|n| n.gpus.iter())
    }

    pub fn gpu_ids(&self) -> Vec<GpuId> {
        self.nodes
            .iter()
            .flat_map(|n| n.gpus.iter().map(|g| g.id))
            .collect()
    }

    pub fn container_ids(&self) -> Vec<ContainerId> {
        self.nodes
            .iter()
            .flat_map(|n| n.containers.iter().map(|c| c.id))
            .collect()
    }

    pub fn n_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.gpus.len()).sum()
    }

    pub fn total_gpu_mem_gb(&self) -> f64 {
        self.gpus().map(|g| g.total_gb).sum()
    }

    pub fn total_gpu_free_gb(&self) -> f64 {
        self.gpus().map(|g| g.free_gb()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbeds() {
        assert_eq!(Cluster::paper_multinode().n_gpus(), 16);
        assert_eq!(Cluster::paper_singlenode().n_gpus(), 8);
    }

    #[test]
    fn ids_address_correctly() {
        let c = Cluster::new(2, 3, 2);
        assert_eq!(c.n_gpus(), 6);
        let ids = c.gpu_ids();
        assert_eq!(ids.len(), 6);
        for id in ids {
            assert_eq!(c.gpu(id).id, id);
        }
        for id in c.container_ids() {
            assert_eq!(c.container(id).id, id);
        }
    }

    #[test]
    fn total_memory_sums() {
        let c = Cluster::new(2, 2, 1);
        assert!((c.total_gpu_mem_gb() - 4.0 * 48.0).abs() < 1e-9);
    }
}
