//! Calibration constants for the simulated testbed.
//!
//! Every constant carries its provenance.  The goal is NOT to match the
//! paper's absolute numbers on AWS L40S hardware, but to preserve the
//! *shape* of its results (who wins, by what rough factor, where
//! crossovers fall) — see DESIGN.md §1 "Substitutions".

/// Remote object storage → node, GB/s. S3-class sustained throughput per
/// instance stream (≈8 Gbit/s effective).
pub const BW_REMOTE_GBPS: f64 = 1.0;

/// Local NVMe SSD → host, GB/s (gen4 NVMe, matches ServerlessLLM's
/// reported multi-GB/s checkpoint loads).
pub const BW_SSD_GBPS: f64 = 5.0;

/// Host DRAM → GPU HBM over PCIe gen4 x16, GB/s (24 theoretical, ~20
/// with pinned-memory streams — the paper's CUDA-stream overlap trick).
pub const BW_PCIE_GBPS: f64 = 20.0;

/// Cold `import torch; import transformers` + CUDA userspace init, s.
/// Measured values in the InstaInfer paper are 3–6 s for the full ML stack.
pub const LIBRARY_IMPORT_S: f64 = 4.0;

/// Residual import cost when libraries are already resident in the
/// container's page cache / preloaded by the agent, s.
pub const LIBRARY_WARM_IMPORT_S: f64 = 0.15;

/// Attaching a LoRA adapter to a live model object (PEFT-style graph
/// surgery), s — paid on top of the raw copy.
pub const ADAPTER_ATTACH_S: f64 = 0.3;

/// Cold container creation (runc + runtime bootstrap), s. Azure/AWS
/// measurements put GPU-container cold starts at 1–2 s.
pub const CONTAINER_INIT_S: f64 = 1.2;

/// CUDA context creation per process, s (driver + context + cudnn handles).
pub const CUDA_CONTEXT_INIT_S: f64 = 0.8;

/// CUDA-context GPU memory overhead per process, GB — the paper §6.9
/// measures 473 MB.
pub const CUDA_CONTEXT_GB: f64 = 0.473;

/// GPU under test: NVIDIA L40S (the paper's testbed), 48 GB HBM.
pub const GPU_MEM_GB: f64 = 48.0;

/// HBM reserved for the serving runtime (allocator arenas, workspace).
pub const GPU_RESERVED_GB: f64 = 2.0;

/// Container memory available for pre-loading per idle function slot, GB.
/// Paper §2.4: functions are habitually over-allocated; the running/idle
/// gap is what the pre-loader exploits.
pub const CONTAINER_MEM_GB: f64 = 32.0;

/// Backbone-load speedup when another *zone* of the cluster already
/// hosts the model on a GPU: the load streams GPU-to-GPU over the
/// datacenter fabric (λScale-style RDMA multicast) instead of from
/// remote storage. Multiplies the `Phase::BackboneLoad` duration; ~2×
/// faster is deliberately conservative vs. intra-node NVLink numbers
/// since cross-zone hops traverse the spine.
pub const CROSS_ZONE_BACKBONE_FACTOR: f64 = 0.5;

// ---------------------------------------------------------------------------
// Pricing (paper uses the Alibaba Cloud Function Compute GPU pricing rule;
// §2.2 notes GPU ≈ 90% of an invocation's cost).

/// Serverless: active GPU memory, $ per GB-second of *allocated* GPU memory.
/// Alibaba FC GPU price ≈ CNY 0.00011 /GB-s ≈ $1.5e-5.
pub const PRICE_GPU_GB_S: f64 = 1.5e-5;

/// Serverless: idle (keep-alive) GPU memory, $ per GB-second. FC's "idle
/// mode" bills GPU instances at a heavily reduced rate (~1/15 of active)
/// while they hold memory but execute nothing.
pub const PRICE_GPU_IDLE_GB_S: f64 = 0.1e-5;

/// Serverless: vCPU, $ per core-second.
pub const PRICE_CPU_CORE_S: f64 = 1.4e-5;

/// Serverless: host memory, $ per GB-second.
pub const PRICE_MEM_GB_S: f64 = 1.4e-6;

/// Serverful: on-demand L40S GPU instance, $ per GPU-second
/// (g6e on-demand ≈ $1.86/h per GPU).
pub const PRICE_SERVERFUL_GPU_S: f64 = 5.17e-4;

// ---------------------------------------------------------------------------

/// Per-model coefficients. 7B/13B are the paper's models (modeled — never
/// compiled here); tiny/100m are the real PJRT-served configs whose
/// coefficients are *measured* by the runtime at startup.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: &'static str,
    /// fp16 backbone checkpoint size, GB.
    pub weights_gb: f64,
    /// Library stack size resident in container RAM, GB.
    pub library_gb: f64,
    /// LoRA adapter (q/k/v/o, rank 8–64) size, GB.
    pub adapter_gb: f64,
    /// Compiled-kernel + workspace footprint on GPU, GB.
    pub kernel_gb: f64,
    /// First-inference JIT compile time (torch.compile / cuDNN autotune), s.
    pub kernel_jit_s: f64,
    /// Loading pre-compiled kernels from a warm cache, s.
    pub kernel_cache_load_s: f64,
    /// Eq. 2 base prefill latency T0 (warm, batch=1), s.
    pub t0_prefill_s: f64,
    /// Eq. 2 marginal prefill cost α per extra request in the batch, s.
    pub alpha_prefill_s: f64,
    /// Time-per-output-token at batch=1, s.
    pub tpot_s: f64,
    /// Relative TPOT growth per extra batched request (≈0.4%/req: larger
    /// batches raise TPOT ~12% at b≈30, matching §6.2).
    pub tpot_batch_factor: f64,
    /// KV-cache + activation GPU memory per in-flight request, GB
    /// (≈0.45 GB: 7B fp16 KV at ~350 ctx + workspace & fragmentation —
    /// chosen so peak batch sizes land where Table 2 puts them).
    pub kv_per_request_gb: f64,
    /// Host memory allocated per container, GB (billing input).
    pub container_mem_gb: f64,
    /// vCPU cores allocated per function (billing input).
    pub cpu_cores: f64,
}

impl ModelProfile {
    /// Warm-start TTFT (what the SLO is keyed from): CUDA-context-warm,
    /// kernel-warm prefill of one request.
    pub fn warm_ttft_s(&self) -> f64 {
        self.t0_prefill_s
    }

    /// Paper §6.8: TTFT SLO = 5 × first warm-start TTFT
    /// (2500 ms for 7B-class, 4000 ms for 13B-class).
    pub fn slo_ttft_s(&self) -> f64 {
        5.0 * self.warm_ttft_s()
    }

    /// GPU memory needed to *run* (weights resident) excluding KV.
    pub fn gpu_resident_gb(&self) -> f64 {
        self.weights_gb + self.adapter_gb + self.kernel_gb + CUDA_CONTEXT_GB
    }

    /// Eq. 2: T_i(b) = T0 + α (b − 1).
    pub fn prefill_s(&self, batch: usize) -> f64 {
        self.t0_prefill_s + self.alpha_prefill_s * (batch.max(1) - 1) as f64
    }

    /// Per-token decode latency at the given batch size.
    pub fn tpot_at(&self, batch: usize) -> f64 {
        self.tpot_s * (1.0 + self.tpot_batch_factor * (batch.max(1) - 1) as f64)
    }

    /// Max batch size within the TTFT SLO (offline-profiling bound of §4.2),
    /// before memory constraints.
    pub fn slo_max_batch(&self) -> usize {
        let budget = self.slo_ttft_s() - self.t0_prefill_s;
        (1.0 + budget / self.alpha_prefill_s).floor().max(1.0) as usize
    }

    pub fn llama2_7b() -> Self {
        ModelProfile {
            name: "llama2-7b",
            weights_gb: 13.5, // 6.74e9 params × 2 B (fp16)
            library_gb: 2.5,  // torch + transformers + cuda userspace
            adapter_gb: 0.16, // rank-64 q/k/v/o adapter ≈ 160 MB fp16
            kernel_gb: 0.5,
            kernel_jit_s: 2.5,
            kernel_cache_load_s: 0.3,
            t0_prefill_s: 0.5, // ⇒ SLO 2500 ms, the paper's 7B setting
            alpha_prefill_s: 0.025,
            tpot_s: 0.030, // ~33 tok/s single-stream 7B on L40S-class
            tpot_batch_factor: 0.004,
            kv_per_request_gb: 0.45,
            container_mem_gb: 16.0,
            cpu_cores: 4.0,
        }
    }

    pub fn llama2_13b() -> Self {
        ModelProfile {
            name: "llama2-13b",
            weights_gb: 26.0, // 13e9 × 2 B
            library_gb: 2.5,
            adapter_gb: 0.25,
            kernel_gb: 0.6,
            kernel_jit_s: 3.5,
            kernel_cache_load_s: 0.35,
            t0_prefill_s: 0.8, // ⇒ SLO 4000 ms, the paper's 13B setting
            alpha_prefill_s: 0.040,
            tpot_s: 0.048,
            tpot_batch_factor: 0.004,
            kv_per_request_gb: 0.70,
            container_mem_gb: 24.0,
            cpu_cores: 4.0,
        }
    }

    /// The real PJRT-served model (artifacts/llama-tiny). Coefficients are
    /// placeholders that `runtime::Engine::profile()` overwrites with
    /// measured values at startup.
    pub fn llama_tiny() -> Self {
        ModelProfile {
            name: "llama-tiny",
            weights_gb: 0.0127, // 3.16M params × 4 B (fp32)
            library_gb: 0.05,
            adapter_gb: 0.0009,
            kernel_gb: 0.01,
            kernel_jit_s: 0.5,
            kernel_cache_load_s: 0.05,
            t0_prefill_s: 0.010,
            alpha_prefill_s: 0.002,
            tpot_s: 0.004,
            tpot_batch_factor: 0.004,
            kv_per_request_gb: 0.0005,
            container_mem_gb: 1.0,
            cpu_cores: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_matches_paper_settings() {
        // §6.8: 2500 ms for 7B-series, 4000 ms for 13B-series functions.
        assert!((ModelProfile::llama2_7b().slo_ttft_s() - 2.5).abs() < 1e-9);
        assert!((ModelProfile::llama2_13b().slo_ttft_s() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn prefill_eq2_linear() {
        let m = ModelProfile::llama2_7b();
        assert_eq!(m.prefill_s(1), m.t0_prefill_s);
        let d1 = m.prefill_s(5) - m.prefill_s(4);
        let d2 = m.prefill_s(17) - m.prefill_s(16);
        assert!((d1 - m.alpha_prefill_s).abs() < 1e-12);
        assert!((d1 - d2).abs() < 1e-12);
    }

    #[test]
    fn slo_max_batch_is_within_slo() {
        for m in [ModelProfile::llama2_7b(), ModelProfile::llama2_13b()] {
            let b = m.slo_max_batch();
            assert!(m.prefill_s(b) <= m.slo_ttft_s() + 1e-9);
            assert!(m.prefill_s(b + 1) > m.slo_ttft_s());
        }
    }

    #[test]
    fn tpot_rises_with_batch() {
        let m = ModelProfile::llama2_7b();
        // ~12% higher TPOT at b≈30 (paper §6.2 observation).
        let ratio = m.tpot_at(31) / m.tpot_at(1);
        assert!(ratio > 1.10 && ratio < 1.15, "ratio={ratio}");
    }

    #[test]
    fn two_full_7b_fit_one_l40s_but_not_three() {
        let m = ModelProfile::llama2_7b();
        let usable = GPU_MEM_GB - GPU_RESERVED_GB;
        assert!(2.0 * m.gpu_resident_gb() < usable);
        assert!(3.0 * m.gpu_resident_gb() + 3.0 > usable);
    }

    #[test]
    fn serverless_cheaper_than_serverful_when_idle() {
        // A fully idle hour of keep-alive (20 GB) must cost far less than a
        // dedicated GPU hour — the premise of Fig. 2a.
        let keepalive = 20.0 * 3600.0 * PRICE_GPU_IDLE_GB_S;
        let serverful = 3600.0 * PRICE_SERVERFUL_GPU_S;
        assert!(keepalive < 0.2 * serverful);
    }
}
