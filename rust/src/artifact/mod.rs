//! LLM artifact model (paper §2.2, §4.1).
//!
//! ServerlessLoRA manages four classes of artifacts per function — user
//! libraries, the backbone LLM, the LoRA adapter, and (JIT-compiled) CUDA
//! kernels — each with a size, a home (container RAM and/or GPU memory),
//! a load path, and a precedence position (libraries before models before
//! kernels).  The pre-loading scheduler, the offloader and the simulator
//! all consume the same `ArtifactSpec`s defined here.

pub mod params;

pub use params::ModelProfile;

/// The four artifact classes of §4.1, plus container initialisation which
/// the paper's time-breakdown figures (Fig. 1, Fig. 8) track as its own
/// phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// Runtime container (process sandbox) — phase only, not preloadable
    /// as data; "preloading" it means keeping a warm container.
    Container,
    /// User libraries (PyTorch, Transformers, CUDA userspace, ...).
    /// Preloadable **only into container memory**.
    Library,
    /// Backbone LLM weights. Preloadable into container RAM or GPU HBM;
    /// shareable read-only across functions (§4.4).
    Backbone,
    /// LoRA adapter weights. Preloadable into container RAM or GPU HBM;
    /// must be coupled to a GPU that hosts (or will host) its backbone.
    Adapter,
    /// JIT-compiled CUDA kernels (+ CUDA context warmup). Preloadable
    /// **only on the GPU** and only after the model is resident.
    CudaKernel,
}

impl ArtifactKind {
    pub const ALL: [ArtifactKind; 5] = [
        ArtifactKind::Container,
        ArtifactKind::Library,
        ArtifactKind::Backbone,
        ArtifactKind::Adapter,
        ArtifactKind::CudaKernel,
    ];

    /// Can this artifact be pre-loaded into container (host) memory?
    pub fn container_placeable(self) -> bool {
        matches!(
            self,
            ArtifactKind::Library | ArtifactKind::Backbone | ArtifactKind::Adapter
        )
    }

    /// Can this artifact be pre-loaded into GPU memory?
    pub fn gpu_placeable(self) -> bool {
        matches!(
            self,
            ArtifactKind::Backbone | ArtifactKind::Adapter | ArtifactKind::CudaKernel
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::Container => "container",
            ArtifactKind::Library => "library",
            ArtifactKind::Backbone => "backbone",
            ArtifactKind::Adapter => "adapter",
            ArtifactKind::CudaKernel => "cuda-kernel",
        }
    }
}

/// Where a (copy of an) artifact currently lives.  The load path walks
/// Remote → ContainerRam → Gpu; each hop has its own bandwidth (params.rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// Remote object storage (S3-like).
    Remote,
    /// Local NVMe SSD on the worker node.
    Ssd,
    /// Container / host DRAM.
    ContainerRam,
    /// GPU HBM.
    Gpu,
}

/// One concrete artifact of one function.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub kind: ArtifactKind,
    /// Size in GB at its destination tier.
    pub size_gb: f64,
    /// Latency (s) to make it GPU-ready from each source tier, including
    /// any fixed overheads (deserialization, cudaMalloc, JIT compile).
    pub load_from_remote_s: f64,
    pub load_from_ssd_s: f64,
    pub load_from_ram_s: f64,
}

/// A deployed serverless function: one LoRA adapter over one backbone.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub id: usize,
    pub name: String,
    /// Which backbone model (index into the deployment's model profiles).
    pub model: ModelProfile,
    /// Which adapter of that backbone this function serves.
    pub adapter_id: usize,
    /// TTFT SLO in seconds (paper §6.8: 5 × first warm-start TTFT).
    pub slo_ttft_s: f64,
}

impl FunctionSpec {
    pub fn new(id: usize, model: ModelProfile, adapter_id: usize) -> Self {
        let slo = model.slo_ttft_s();
        Self {
            id,
            name: format!("{}-lora{}", model.name, adapter_id),
            model,
            adapter_id,
            slo_ttft_s: slo,
        }
    }

    /// The artifact set of this function, in precedence order.
    pub fn artifacts(&self) -> Vec<ArtifactSpec> {
        let m = &self.model;
        vec![
            ArtifactSpec {
                kind: ArtifactKind::Library,
                size_gb: m.library_gb,
                load_from_remote_s: m.library_gb / params::BW_REMOTE_GBPS
                    + params::LIBRARY_IMPORT_S,
                load_from_ssd_s: m.library_gb / params::BW_SSD_GBPS
                    + params::LIBRARY_IMPORT_S,
                // Libraries already in container RAM are imported (=mapped);
                // only the residual python-import cost remains.
                load_from_ram_s: params::LIBRARY_WARM_IMPORT_S,
            },
            ArtifactSpec {
                kind: ArtifactKind::Backbone,
                size_gb: m.weights_gb,
                load_from_remote_s: m.weights_gb / params::BW_REMOTE_GBPS,
                load_from_ssd_s: m.weights_gb / params::BW_SSD_GBPS,
                load_from_ram_s: m.weights_gb / params::BW_PCIE_GBPS,
            },
            ArtifactSpec {
                kind: ArtifactKind::Adapter,
                size_gb: m.adapter_gb,
                load_from_remote_s: m.adapter_gb / params::BW_REMOTE_GBPS
                    + params::ADAPTER_ATTACH_S,
                load_from_ssd_s: m.adapter_gb / params::BW_SSD_GBPS
                    + params::ADAPTER_ATTACH_S,
                load_from_ram_s: m.adapter_gb / params::BW_PCIE_GBPS
                    + params::ADAPTER_ATTACH_S,
            },
            ArtifactSpec {
                kind: ArtifactKind::CudaKernel,
                size_gb: m.kernel_gb,
                // Kernels are *compiled*, not copied: all tiers cost the JIT
                // time; a warm kernel cache (SSD/RAM) only skips codegen.
                load_from_remote_s: m.kernel_jit_s,
                load_from_ssd_s: m.kernel_cache_load_s,
                load_from_ram_s: m.kernel_cache_load_s,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_rules_match_paper() {
        // §4.1: "libraries can only be pre-loaded on containers, CUDA
        // kernels on GPUs, and backbones and adapters on both".
        assert!(ArtifactKind::Library.container_placeable());
        assert!(!ArtifactKind::Library.gpu_placeable());
        assert!(!ArtifactKind::CudaKernel.container_placeable());
        assert!(ArtifactKind::CudaKernel.gpu_placeable());
        for k in [ArtifactKind::Backbone, ArtifactKind::Adapter] {
            assert!(k.container_placeable() && k.gpu_placeable());
        }
    }

    #[test]
    fn artifacts_in_precedence_order() {
        let f = FunctionSpec::new(0, ModelProfile::llama2_7b(), 0);
        let kinds: Vec<ArtifactKind> =
            f.artifacts().iter().map(|a| a.kind).collect();
        assert_eq!(
            kinds,
            vec![
                ArtifactKind::Library,
                ArtifactKind::Backbone,
                ArtifactKind::Adapter,
                ArtifactKind::CudaKernel
            ]
        );
    }

    #[test]
    fn faster_tiers_load_faster() {
        let f = FunctionSpec::new(0, ModelProfile::llama2_13b(), 1);
        for a in f.artifacts() {
            assert!(a.load_from_remote_s >= a.load_from_ssd_s);
            assert!(a.load_from_ssd_s >= a.load_from_ram_s * 0.99);
        }
    }

    #[test]
    fn backbone_dominates_size() {
        // Observation 1: ~99% of weights are the backbone.
        let f = FunctionSpec::new(0, ModelProfile::llama2_7b(), 0);
        let arts = f.artifacts();
        let backbone = arts
            .iter()
            .find(|a| a.kind == ArtifactKind::Backbone)
            .unwrap();
        let adapter = arts
            .iter()
            .find(|a| a.kind == ArtifactKind::Adapter)
            .unwrap();
        assert!(backbone.size_gb / (backbone.size_gb + adapter.size_gb) > 0.97);
    }
}
