//! LLM artifact model (paper §2.2, §4.1).
//!
//! ServerlessLoRA manages four classes of artifacts per function — user
//! libraries, the backbone LLM, the LoRA adapter, and (JIT-compiled) CUDA
//! kernels — each with a size, a home (container RAM and/or GPU memory),
//! a load path, and a precedence position (libraries before models before
//! kernels).  The pre-loading scheduler, the offloader and the simulator
//! all consume the same `ArtifactSpec`s defined here.

pub mod params;

pub use params::ModelProfile;

/// The four artifact classes of §4.1, plus container initialisation which
/// the paper's time-breakdown figures (Fig. 1, Fig. 8) track as its own
/// phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// Runtime container (process sandbox) — phase only, not preloadable
    /// as data; "preloading" it means keeping a warm container.
    Container,
    /// User libraries (PyTorch, Transformers, CUDA userspace, ...).
    /// Preloadable **only into container memory**.
    Library,
    /// Backbone LLM weights. Preloadable into container RAM or GPU HBM;
    /// shareable read-only across functions (§4.4).
    Backbone,
    /// LoRA adapter weights. Preloadable into container RAM or GPU HBM;
    /// must be coupled to a GPU that hosts (or will host) its backbone.
    Adapter,
    /// JIT-compiled CUDA kernels (+ CUDA context warmup). Preloadable
    /// **only on the GPU** and only after the model is resident.
    CudaKernel,
}

impl ArtifactKind {
    pub const ALL: [ArtifactKind; 5] = [
        ArtifactKind::Container,
        ArtifactKind::Library,
        ArtifactKind::Backbone,
        ArtifactKind::Adapter,
        ArtifactKind::CudaKernel,
    ];

    /// Can this artifact be pre-loaded into container (host) memory?
    pub fn container_placeable(self) -> bool {
        matches!(
            self,
            ArtifactKind::Library | ArtifactKind::Backbone | ArtifactKind::Adapter
        )
    }

    /// Can this artifact be pre-loaded into GPU memory?
    pub fn gpu_placeable(self) -> bool {
        matches!(
            self,
            ArtifactKind::Backbone | ArtifactKind::Adapter | ArtifactKind::CudaKernel
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::Container => "container",
            ArtifactKind::Library => "library",
            ArtifactKind::Backbone => "backbone",
            ArtifactKind::Adapter => "adapter",
            ArtifactKind::CudaKernel => "cuda-kernel",
        }
    }
}

/// Where a (copy of an) artifact currently lives.  The load path walks
/// Remote → ContainerRam → Gpu; each hop has its own bandwidth (params.rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    /// Remote object storage (S3-like).
    Remote,
    /// Local NVMe SSD on the worker node.
    Ssd,
    /// Container / host DRAM.
    ContainerRam,
    /// GPU HBM.
    Gpu,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Remote => "remote",
            Tier::Ssd => "ssd",
            Tier::ContainerRam => "ram",
            Tier::Gpu => "gpu",
        }
    }
}

/// A physical transfer link of one node.  Each node has one of each; under
/// the tiered store, concurrent loads on the same `(node, link)` split its
/// bandwidth fairly (processor sharing, `sim/flow.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LinkKind {
    /// NIC: remote object store → node.
    Nic,
    /// Local NVMe SSD → host DRAM.
    Nvme,
    /// Host DRAM → GPU HBM (PCIe).
    Pcie,
}

impl LinkKind {
    pub const COUNT: usize = 3;
    pub const ALL: [LinkKind; 3] = [LinkKind::Nic, LinkKind::Nvme, LinkKind::Pcie];

    /// Dense index for per-node link-state arrays.
    pub fn index(self) -> usize {
        match self {
            LinkKind::Nic => 0,
            LinkKind::Nvme => 1,
            LinkKind::Pcie => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LinkKind::Nic => "nic",
            LinkKind::Nvme => "nvme",
            LinkKind::Pcie => "pcie",
        }
    }
}

/// Per-link bandwidth capacities (GB/s) of one node.  `DEFAULT` reproduces
/// the calibration constants in `params.rs`, so costs evaluated against it
/// are bit-identical to the flat latencies this module used to hard-code.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkCaps {
    pub nic_gbps: f64,
    pub nvme_gbps: f64,
    pub pcie_gbps: f64,
}

impl LinkCaps {
    pub const DEFAULT: LinkCaps = LinkCaps {
        nic_gbps: params::BW_REMOTE_GBPS,
        nvme_gbps: params::BW_SSD_GBPS,
        pcie_gbps: params::BW_PCIE_GBPS,
    };

    pub fn gbps(&self, link: LinkKind) -> f64 {
        match link {
            LinkKind::Nic => self.nic_gbps,
            LinkKind::Nvme => self.nvme_gbps,
            LinkKind::Pcie => self.pcie_gbps,
        }
    }
}

impl Default for LinkCaps {
    fn default() -> Self {
        LinkCaps::DEFAULT
    }
}

/// One term of a load cost: a fixed CPU/driver-side latency, or a bulk
/// transfer across a specific link (the contended part).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Term {
    /// Fixed overhead (deserialization, import, attach, JIT compile).
    Fixed(f64),
    /// Bulk copy of `gb` across `link`; duration = gb / share of the
    /// link's bandwidth.
    Xfer { link: LinkKind, gb: f64 },
}

impl Term {
    /// Uncontended duration of this term.
    pub fn seconds(&self, caps: &LinkCaps) -> f64 {
        match *self {
            Term::Fixed(s) => s,
            Term::Xfer { link, gb } => gb / caps.gbps(link),
        }
    }
}

/// The ordered terms making up one load phase.  `total` folds left-to-right
/// starting from 0.0 — the exact float-op order of the flat expressions it
/// replaced — so solo (uncontended) totals are bit-identical to the
/// pre-tiered latencies.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PhaseCost(pub Vec<Term>);

impl PhaseCost {
    pub fn fixed(s: f64) -> Self {
        PhaseCost(vec![Term::Fixed(s)])
    }

    pub fn xfer(link: LinkKind, gb: f64) -> Self {
        PhaseCost(vec![Term::Xfer { link, gb }])
    }

    pub fn push(&mut self, t: Term) {
        self.0.push(t);
    }

    /// Uncontended total, left-fold from 0.0 (see type docs).
    pub fn total(&self, caps: &LinkCaps) -> f64 {
        self.0.iter().fold(0.0, |acc, t| acc + t.seconds(caps))
    }

    /// Total at the calibration bandwidths of `params.rs`.
    pub fn total_default(&self) -> f64 {
        self.total(&LinkCaps::DEFAULT)
    }

    /// Does any term move bytes across a link?
    pub fn has_xfer(&self) -> bool {
        self.0.iter().any(|t| matches!(t, Term::Xfer { .. }))
    }

    /// Does any term fetch from below host RAM (NVMe or NIC)?  True means
    /// the artifact is *not* already staged host-side and the load should
    /// resolve through the tier hierarchy.
    pub fn fetches_below_ram(&self) -> bool {
        self.0.iter().any(|t| {
            matches!(
                t,
                Term::Xfer { link: LinkKind::Nic, .. }
                    | Term::Xfer { link: LinkKind::Nvme, .. }
            )
        })
    }

    /// Largest single transfer payload (GB) among the terms — the artifact
    /// body (multi-hop costs repeat the same payload per hop).
    pub fn payload_gb(&self) -> f64 {
        let mut gb = 0.0f64;
        for t in &self.0 {
            if let Term::Xfer { gb: g, .. } = t {
                if *g > gb {
                    gb = *g;
                }
            }
        }
        gb
    }

    /// Scale every term by `k` (cross-zone discount).  `k` is a power of
    /// two in practice (0.5), so scaling terms individually folds to the
    /// bit-identical total as scaling the folded sum.
    pub fn scale(&mut self, k: f64) {
        for t in &mut self.0 {
            match t {
                Term::Fixed(s) => *s *= k,
                Term::Xfer { gb, .. } => *gb *= k,
            }
        }
    }

    /// Re-source from host RAM (tier hit): every bulk transfer collapses
    /// into one PCIe hop of the artifact payload; fixed terms survive.
    pub fn source_from_ram(&mut self) {
        let gb = self.payload_gb();
        self.0.retain(|t| matches!(t, Term::Fixed(_)));
        if gb > 0.0 {
            self.0.push(Term::Xfer { link: LinkKind::Pcie, gb });
        }
    }

    /// Re-source from the remote store (node holds no local checkpoint):
    /// NVMe reads become NIC fetches; PCIe hops and fixed terms survive.
    pub fn source_from_remote(&mut self) {
        for t in &mut self.0 {
            if let Term::Xfer { link, .. } = t {
                if *link == LinkKind::Nvme {
                    *link = LinkKind::Nic;
                }
            }
        }
    }
}

/// One concrete artifact of one function.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub kind: ArtifactKind,
    /// Size in GB at its destination tier.
    pub size_gb: f64,
    /// Cost (ordered terms) to make it GPU-ready from each source tier,
    /// including any fixed overheads (deserialization, cudaMalloc, JIT).
    pub from_remote: PhaseCost,
    pub from_ssd: PhaseCost,
    pub from_ram: PhaseCost,
}

impl ArtifactSpec {
    /// Term view of the load from a source tier (None for Gpu: resident).
    pub fn cost_from(&self, tier: Tier) -> Option<&PhaseCost> {
        match tier {
            Tier::Remote => Some(&self.from_remote),
            Tier::Ssd => Some(&self.from_ssd),
            Tier::ContainerRam => Some(&self.from_ram),
            Tier::Gpu => None,
        }
    }

    /// Flat (uncontended, default-bandwidth) latency from a source tier —
    /// the pre-tiered scalar view, bit-identical to the old constants.
    pub fn load_s(&self, tier: Tier) -> f64 {
        self.cost_from(tier).map_or(0.0, |c| c.total_default())
    }
}

/// A deployed serverless function: one LoRA adapter over one backbone.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub id: usize,
    pub name: String,
    /// Which backbone model (index into the deployment's model profiles).
    pub model: ModelProfile,
    /// Which adapter of that backbone this function serves.
    pub adapter_id: usize,
    /// TTFT SLO in seconds (paper §6.8: 5 × first warm-start TTFT).
    pub slo_ttft_s: f64,
}

impl FunctionSpec {
    pub fn new(id: usize, model: ModelProfile, adapter_id: usize) -> Self {
        let slo = model.slo_ttft_s();
        Self {
            id,
            name: format!("{}-lora{}", model.name, adapter_id),
            model,
            adapter_id,
            slo_ttft_s: slo,
        }
    }

    /// The artifact set of this function, in precedence order.
    pub fn artifacts(&self) -> Vec<ArtifactSpec> {
        use LinkKind::{Nic, Nvme, Pcie};
        let m = &self.model;
        vec![
            ArtifactSpec {
                kind: ArtifactKind::Library,
                size_gb: m.library_gb,
                from_remote: PhaseCost(vec![
                    Term::Xfer { link: Nic, gb: m.library_gb },
                    Term::Fixed(params::LIBRARY_IMPORT_S),
                ]),
                from_ssd: PhaseCost(vec![
                    Term::Xfer { link: Nvme, gb: m.library_gb },
                    Term::Fixed(params::LIBRARY_IMPORT_S),
                ]),
                // Libraries already in container RAM are imported (=mapped);
                // only the residual python-import cost remains — no copy.
                from_ram: PhaseCost::fixed(params::LIBRARY_WARM_IMPORT_S),
            },
            ArtifactSpec {
                kind: ArtifactKind::Backbone,
                size_gb: m.weights_gb,
                from_remote: PhaseCost::xfer(Nic, m.weights_gb),
                from_ssd: PhaseCost::xfer(Nvme, m.weights_gb),
                from_ram: PhaseCost::xfer(Pcie, m.weights_gb),
            },
            ArtifactSpec {
                kind: ArtifactKind::Adapter,
                size_gb: m.adapter_gb,
                from_remote: PhaseCost(vec![
                    Term::Xfer { link: Nic, gb: m.adapter_gb },
                    Term::Fixed(params::ADAPTER_ATTACH_S),
                ]),
                from_ssd: PhaseCost(vec![
                    Term::Xfer { link: Nvme, gb: m.adapter_gb },
                    Term::Fixed(params::ADAPTER_ATTACH_S),
                ]),
                from_ram: PhaseCost(vec![
                    Term::Xfer { link: Pcie, gb: m.adapter_gb },
                    Term::Fixed(params::ADAPTER_ATTACH_S),
                ]),
            },
            ArtifactSpec {
                kind: ArtifactKind::CudaKernel,
                size_gb: m.kernel_gb,
                // Kernels are *compiled*, not copied: all tiers cost the JIT
                // time; a warm kernel cache (SSD/RAM) only skips codegen.
                from_remote: PhaseCost::fixed(m.kernel_jit_s),
                from_ssd: PhaseCost::fixed(m.kernel_cache_load_s),
                from_ram: PhaseCost::fixed(m.kernel_cache_load_s),
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_rules_match_paper() {
        // §4.1: "libraries can only be pre-loaded on containers, CUDA
        // kernels on GPUs, and backbones and adapters on both".
        assert!(ArtifactKind::Library.container_placeable());
        assert!(!ArtifactKind::Library.gpu_placeable());
        assert!(!ArtifactKind::CudaKernel.container_placeable());
        assert!(ArtifactKind::CudaKernel.gpu_placeable());
        for k in [ArtifactKind::Backbone, ArtifactKind::Adapter] {
            assert!(k.container_placeable() && k.gpu_placeable());
        }
    }

    #[test]
    fn artifacts_in_precedence_order() {
        let f = FunctionSpec::new(0, ModelProfile::llama2_7b(), 0);
        let kinds: Vec<ArtifactKind> =
            f.artifacts().iter().map(|a| a.kind).collect();
        assert_eq!(
            kinds,
            vec![
                ArtifactKind::Library,
                ArtifactKind::Backbone,
                ArtifactKind::Adapter,
                ArtifactKind::CudaKernel
            ]
        );
    }

    #[test]
    fn faster_tiers_load_faster() {
        // With explicit per-link bandwidths (NIC 1 ≤ NVMe 5 ≤ PCIe 20 GB/s)
        // tier monotonicity is exact — no slack factor.  The one artifact
        // whose RAM cost is not a transfer at all is the library: its RAM
        // "load" is a warm re-import (LIBRARY_WARM_IMPORT_S), which is
        // legitimately cheaper than any copy and still satisfies ssd ≥ ram.
        for m in [ModelProfile::llama2_7b(), ModelProfile::llama2_13b()] {
            let f = FunctionSpec::new(0, m, 1);
            for a in f.artifacts() {
                let (remote, ssd, ram) = (
                    a.load_s(Tier::Remote),
                    a.load_s(Tier::Ssd),
                    a.load_s(Tier::ContainerRam),
                );
                assert!(remote >= ssd, "{:?}: remote {remote} < ssd {ssd}", a.kind);
                assert!(ssd >= ram, "{:?}: ssd {ssd} < ram {ram}", a.kind);
                assert!(ram >= a.load_s(Tier::Gpu));
            }
        }
    }

    #[test]
    fn phase_costs_fold_bit_identical_to_flat_expressions() {
        // The per-tier term lists must reproduce the pre-tiered flat
        // latencies *bitwise* at default bandwidths — golden runs depend
        // on it.
        let m = ModelProfile::llama2_13b();
        let arts = FunctionSpec::new(0, m.clone(), 1).artifacts();
        let bits = |x: f64| x.to_bits();
        let lib = &arts[0];
        assert_eq!(
            bits(lib.load_s(Tier::Remote)),
            bits(m.library_gb / params::BW_REMOTE_GBPS + params::LIBRARY_IMPORT_S)
        );
        assert_eq!(
            bits(lib.load_s(Tier::Ssd)),
            bits(m.library_gb / params::BW_SSD_GBPS + params::LIBRARY_IMPORT_S)
        );
        assert_eq!(bits(lib.load_s(Tier::ContainerRam)), bits(params::LIBRARY_WARM_IMPORT_S));
        let bb = &arts[1];
        assert_eq!(bits(bb.load_s(Tier::Remote)), bits(m.weights_gb / params::BW_REMOTE_GBPS));
        assert_eq!(bits(bb.load_s(Tier::Ssd)), bits(m.weights_gb / params::BW_SSD_GBPS));
        assert_eq!(bits(bb.load_s(Tier::ContainerRam)), bits(m.weights_gb / params::BW_PCIE_GBPS));
        let ad = &arts[2];
        assert_eq!(
            bits(ad.load_s(Tier::Ssd)),
            bits(m.adapter_gb / params::BW_SSD_GBPS + params::ADAPTER_ATTACH_S)
        );
        let k = &arts[3];
        assert_eq!(bits(k.load_s(Tier::Remote)), bits(m.kernel_jit_s));
        assert_eq!(bits(k.load_s(Tier::Ssd)), bits(m.kernel_cache_load_s));
    }

    #[test]
    fn custom_link_caps_scale_transfers_only() {
        let m = ModelProfile::llama2_7b();
        let arts = FunctionSpec::new(0, m.clone(), 0).artifacts();
        let fast = LinkCaps { nic_gbps: 2.0, nvme_gbps: 10.0, pcie_gbps: 40.0 };
        // Backbone: pure transfer — halves with doubled bandwidth.
        assert_eq!(arts[1].from_ssd.total(&fast), m.weights_gb / 10.0);
        // Kernel: pure fixed — unaffected by bandwidth.
        assert_eq!(arts[3].from_remote.total(&fast), m.kernel_jit_s);
        // Library: fixed part survives, transfer part scales.
        assert_eq!(
            arts[0].from_remote.total(&fast),
            m.library_gb / 2.0 + params::LIBRARY_IMPORT_S
        );
    }

    #[test]
    fn source_rewrites_follow_the_hierarchy() {
        let m = ModelProfile::llama2_7b();
        let arts = FunctionSpec::new(0, m.clone(), 0).artifacts();
        // Host-cache hit: a two-hop (NVMe + PCIe) cost collapses into one
        // PCIe hop of the same payload; fixed terms survive.
        let mut two_hop = PhaseCost(vec![
            Term::Xfer { link: LinkKind::Nvme, gb: m.weights_gb },
            Term::Xfer { link: LinkKind::Pcie, gb: m.weights_gb },
        ]);
        two_hop.source_from_ram();
        assert_eq!(two_hop.0, vec![Term::Xfer { link: LinkKind::Pcie, gb: m.weights_gb }]);
        // Remote miss: NVMe reads become NIC fetches, nothing else moves.
        let mut ssd = arts[2].from_ssd.clone();
        ssd.source_from_remote();
        assert_eq!(
            ssd.0,
            vec![
                Term::Xfer { link: LinkKind::Nic, gb: m.adapter_gb },
                Term::Fixed(params::ADAPTER_ATTACH_S),
            ]
        );
        // Fixed-only costs are untouched by both rewrites.
        let mut kernel = arts[3].from_ssd.clone();
        kernel.source_from_ram();
        kernel.source_from_remote();
        assert_eq!(kernel.0, vec![Term::Fixed(m.kernel_cache_load_s)]);
        assert!(!kernel.has_xfer() && !kernel.fetches_below_ram());
        assert!(arts[1].from_ssd.fetches_below_ram());
        assert!(!arts[1].from_ram.fetches_below_ram());
    }

    #[test]
    fn backbone_dominates_size() {
        // Observation 1: ~99% of weights are the backbone.
        let f = FunctionSpec::new(0, ModelProfile::llama2_7b(), 0);
        let arts = f.artifacts();
        let backbone = arts
            .iter()
            .find(|a| a.kind == ArtifactKind::Backbone)
            .unwrap();
        let adapter = arts
            .iter()
            .find(|a| a.kind == ArtifactKind::Adapter)
            .unwrap();
        assert!(backbone.size_gb / (backbone.size_gb + adapter.size_gb) > 0.97);
    }
}
