//! ServerlessLoRA launcher.
//!
//! ```text
//! serverless-lora simulate --exp fig6 [--full] [--jobs N]
//!                                                  regenerate a paper table/figure
//! serverless-lora simulate --all [--full] [--jobs N]
//!                                                  regenerate everything
//! serverless-lora fleet [--full] [--skew S] [--cov-head H] [--cov-tail T] [--check]
//!                                                  engine scaling sweep
//!                                                  (alias: simulate --exp fleet;
//!                                                  --skew: Zipf popularity;
//!                                                  --cov-head/--cov-tail: CoV class
//!                                                  of the Zipf head/tail, needs --skew;
//!                                                  --check: CI counter guard)
//! serverless-lora serve [--model llama-tiny] [--requests N] [--batch B]
//!                                                  real PJRT serving demo (`pjrt` feature)
//! serverless-lora info [--model llama-tiny]        artifact/manifest inventory
//! ```
//!
//! (CLI is hand-rolled: `clap` is not vendored in this build environment.)

use std::collections::BTreeMap;

use serverless_lora::exp;

/// Flags that never take a value: their presence means "true", and the
/// token after them is a positional argument, not their value.
const BOOL_FLAGS: &[&str] = &["full", "all", "quick", "check"];

/// Hand-rolled flag parser.
///
/// Rules, in order:
/// * `--name=value` binds explicitly.
/// * `--name` for a declared boolean flag is `true` and never consumes
///   the next token (`--all simulate` keeps `simulate` positional).
/// * `--name <tok>` binds `<tok>` unless it is another `--flag`; a
///   single-dash token is a value, so negatives work (`--delay -0.5`).
/// * A bare `--` ends flag parsing; everything after is positional.
fn parse_flags(
    args: &[String],
    bool_flags: &[&str],
) -> (Vec<String>, BTreeMap<String, String>) {
    let looks_like_flag = |tok: &str| tok.starts_with("--") && tok.len() > 2;
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(name) = a.strip_prefix("--") else {
            pos.push(a.clone());
            i += 1;
            continue;
        };
        if name.is_empty() {
            // `--` separator: the rest is positional.
            pos.extend(args[i + 1..].iter().cloned());
            break;
        }
        if let Some((k, v)) = name.split_once('=') {
            flags.insert(k.to_string(), v.to_string());
            i += 1;
            continue;
        }
        if bool_flags.contains(&name) {
            flags.insert(name.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let next_is_value = args
            .get(i + 1)
            .map(|n| !looks_like_flag(n))
            .unwrap_or(false);
        if next_is_value {
            flags.insert(name.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            flags.insert(name.to_string(), "true".to_string());
            i += 1;
        }
    }
    (pos, flags)
}

fn usage() -> ! {
    eprintln!(
        "usage: serverless-lora <simulate|fleet|serve|info> [options]\n\
         \n\
         simulate --exp <id>|--all [--full] [--jobs N]   ids: {}\n\
         fleet    [--full] [--skew S] [--cov-head H] [--cov-tail T] [--check]\n\
                  engine scaling sweep\n\
                  (--skew: Zipf(S) popularity; --cov-head/--cov-tail: inter-arrival\n\
                  CoV class for the Zipf head/tail, requires --skew, missing side\n\
                  defaults to the Normal class; --check: counter regression guard)\n\
         serve    [--model llama-tiny] [--requests 16] [--batch 4]\n\
         info     [--model llama-tiny]",
        exp::ALL_EXPERIMENTS.join(", ")
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args, BOOL_FLAGS);
    if let Some(jobs) = flags.get("jobs").and_then(|v| v.parse::<usize>().ok()) {
        exp::runner::set_jobs(jobs);
    }
    match pos.first().map(String::as_str) {
        Some("simulate") => {
            let quick = !flags.contains_key("full");
            if flags.contains_key("all") {
                for id in exp::ALL_EXPERIMENTS {
                    print!("{}", exp::run_experiment(id, quick));
                }
            } else if let Some(id) = flags.get("exp") {
                print!("{}", exp::run_experiment(id, quick));
            } else {
                usage()
            }
        }
        Some("fleet") => {
            let quick = !flags.contains_key("full");
            if flags.contains_key("check") {
                // CI regression guard: deterministic engine counters vs
                // the committed structural bounds.
                match exp::fleet::check() {
                    Ok(report) => print!("{report}"),
                    Err(msg) => {
                        eprintln!("{msg}");
                        std::process::exit(1);
                    }
                }
            } else {
                let skew = match flags.get("skew") {
                    Some(v) => match v.parse::<f64>() {
                        Ok(s) if s.is_finite() && s > 0.0 => Some(s),
                        _ => {
                            eprintln!("--skew needs a positive number, got '{v}'");
                            std::process::exit(2);
                        }
                    },
                    None => None,
                };
                // CoV classes for the Zipf head/tail (validation matches
                // --skew: positive finite numbers, mapped onto the
                // paper's CoV bands).
                let cov_of = |name: &str| -> Option<f64> {
                    let v = flags.get(name)?;
                    match v.parse::<f64>() {
                        Ok(c) if c.is_finite() && c > 0.0 => Some(c),
                        _ => {
                            eprintln!("--{name} needs a positive number, got '{v}'");
                            std::process::exit(2);
                        }
                    }
                };
                let (head, tail) = (cov_of("cov-head"), cov_of("cov-tail"));
                let cov = if head.is_some() || tail.is_some() {
                    if skew.is_none() {
                        eprintln!("--cov-head/--cov-tail require --skew");
                        std::process::exit(2);
                    }
                    use serverless_lora::trace::Pattern;
                    Some((
                        Pattern::for_cov(head.unwrap_or(2.5)),
                        Pattern::for_cov(tail.unwrap_or(2.5)),
                    ))
                } else {
                    None
                };
                print!("{}", exp::fleet::fleet_with(quick, skew, cov));
            }
        }
        Some("serve") => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "llama-tiny".into());
            let n: usize = flags
                .get("requests")
                .and_then(|v| v.parse().ok())
                .unwrap_or(16);
            let batch: usize =
                flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(4);
            pjrt::serve_demo(&model, n, batch)?;
        }
        Some("info") => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "llama-tiny".into());
            pjrt::info(&model)?;
        }
        _ => usage(),
    }
    Ok(())
}

/// Real-runtime subcommands, only compiled with the `pjrt` feature (the
/// data plane needs the external `xla` crate).
#[cfg(feature = "pjrt")]
mod pjrt {
    use serverless_lora::runtime::{server, Manifest};

    pub fn info(model: &str) -> anyhow::Result<()> {
        let m = Manifest::load(Manifest::default_dir(model))?;
        println!(
            "model={} params={} layers={} d_model={} adapters={}",
            m.model,
            m.dims.param_count,
            m.dims.n_layers,
            m.dims.d_model,
            m.n_adapters
        );
        for a in &m.artifacts {
            println!("  artifact {} (batch={}, seq={})", a.name, a.batch, a.seq);
        }
        Ok(())
    }

    /// Minimal real-serving demo: spin up the PJRT server, push a burst
    /// of requests across all adapters, report latencies.
    pub fn serve_demo(model: &str, n: usize, batch: usize) -> anyhow::Result<()> {
        let dir = Manifest::default_dir(model);
        let manifest = Manifest::load(&dir)?;
        println!(
            "serving {} ({} params, {} adapters) — PJRT CPU, shared backbone",
            manifest.model, manifest.dims.param_count, manifest.n_adapters
        );
        let (tx, rx) = server::spawn(
            dir,
            server::ServerConfig {
                max_batch: batch,
                batch_delay: std::time::Duration::from_millis(20),
            },
        );
        for i in 0..n as u64 {
            tx.send(server::LiveRequest {
                id: i,
                adapter: (i as usize) % manifest.n_adapters,
                prompt: (0..12).map(|t| ((i as i32) * 7 + t) % 100).collect(),
                max_new_tokens: 8,
            })?;
        }
        drop(tx);
        let mut ttfts = Vec::new();
        while let Ok(r) = rx.recv_timeout(std::time::Duration::from_secs(300)) {
            println!(
                "  req {} adapter={} batch={} ttft={:.1}ms tpot={:.1}ms e2e={:.1}ms",
                r.id,
                r.adapter,
                r.batch_size,
                r.ttft.as_secs_f64() * 1000.0,
                r.tpot.as_secs_f64() * 1000.0,
                r.e2e.as_secs_f64() * 1000.0
            );
            ttfts.push(r.ttft.as_secs_f64());
            if ttfts.len() == n {
                break;
            }
        }
        let s = serverless_lora::util::stats::summarize(&ttfts);
        println!(
            "served {} requests: TTFT mean {:.1} ms p99 {:.1} ms",
            s.count,
            s.mean * 1000.0,
            s.p99 * 1000.0
        );
        Ok(())
    }
}

/// Without the `pjrt` feature the real-runtime subcommands explain how to
/// enable themselves instead of failing to link.
#[cfg(not(feature = "pjrt"))]
mod pjrt {
    pub fn info(_model: &str) -> anyhow::Result<()> {
        unavailable()
    }

    pub fn serve_demo(_model: &str, _n: usize, _batch: usize) -> anyhow::Result<()> {
        unavailable()
    }

    fn unavailable() -> anyhow::Result<()> {
        Err(anyhow::anyhow!(
            "this binary was built without the `pjrt` feature. To serve the \
             real model: on a networked machine, add `xla = \"0.1\"` to \
             rust/Cargo.toml [dependencies], then `cargo build --features pjrt` \
             (see the feature note in Cargo.toml)"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> (Vec<String>, BTreeMap<String, String>) {
        let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        parse_flags(&v, BOOL_FLAGS)
    }

    #[test]
    fn negative_number_binds_as_value() {
        let (pos, flags) = p(&["simulate", "--delay", "-0.5"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("delay").map(String::as_str), Some("-0.5"));
    }

    #[test]
    fn boolean_flag_before_positional_keeps_positional() {
        // The old parser swallowed `simulate` as the value of `--all`.
        let (pos, flags) = p(&["--all", "simulate"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("all").map(String::as_str), Some("true"));
    }

    #[test]
    fn trailing_boolean_flag() {
        let (pos, flags) = p(&["simulate", "--full"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("full").map(String::as_str), Some("true"));
    }

    #[test]
    fn equals_syntax_binds() {
        let (_, flags) = p(&["--exp=fig6", "--jobs=4"]);
        assert_eq!(flags.get("exp").map(String::as_str), Some("fig6"));
        assert_eq!(flags.get("jobs").map(String::as_str), Some("4"));
    }

    #[test]
    fn value_flag_followed_by_flag_stays_boolean() {
        let (_, flags) = p(&["--exp", "--all"]);
        assert_eq!(flags.get("exp").map(String::as_str), Some("true"));
        assert_eq!(flags.get("all").map(String::as_str), Some("true"));
    }

    #[test]
    fn double_dash_ends_flag_parsing() {
        let (pos, flags) = p(&["--jobs", "2", "--", "--weird-positional"]);
        assert_eq!(flags.get("jobs").map(String::as_str), Some("2"));
        assert_eq!(pos, vec!["--weird-positional"]);
    }

    #[test]
    fn normal_value_flags_still_work() {
        let (pos, flags) = p(&["simulate", "--exp", "fig6", "--jobs", "4"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("exp").map(String::as_str), Some("fig6"));
        assert_eq!(flags.get("jobs").map(String::as_str), Some("4"));
    }
}
