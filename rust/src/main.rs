//! ServerlessLoRA launcher.
//!
//! ```text
//! serverless-lora simulate --exp fig6 [--full]     regenerate a paper table/figure
//! serverless-lora simulate --all [--full]          regenerate everything
//! serverless-lora serve [--model llama-tiny] [--requests N] [--batch B]
//!                                                  real PJRT serving demo
//! serverless-lora info [--model llama-tiny]        artifact/manifest inventory
//! ```
//!
//! (CLI is hand-rolled: `clap` is not vendored in this build environment.)

use std::collections::BTreeMap;

use serverless_lora::exp;
use serverless_lora::runtime::{server, Manifest};

fn parse_flags(args: &[String]) -> (Vec<String>, BTreeMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let next_is_value =
                i + 1 < args.len() && !args[i + 1].starts_with("--");
            if next_is_value {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn usage() -> ! {
    eprintln!(
        "usage: serverless-lora <simulate|serve|info> [options]\n\
         \n\
         simulate --exp <id>|--all [--full]   ids: {}\n\
         serve    [--model llama-tiny] [--requests 16] [--batch 4]\n\
         info     [--model llama-tiny]",
        exp::ALL_EXPERIMENTS.join(", ")
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    match pos.first().map(String::as_str) {
        Some("simulate") => {
            let quick = !flags.contains_key("full");
            if flags.contains_key("all") {
                for id in exp::ALL_EXPERIMENTS {
                    print!("{}", exp::run_experiment(id, quick));
                }
            } else if let Some(id) = flags.get("exp") {
                print!("{}", exp::run_experiment(id, quick));
            } else {
                usage()
            }
        }
        Some("serve") => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "llama-tiny".into());
            let n: usize = flags
                .get("requests")
                .and_then(|v| v.parse().ok())
                .unwrap_or(16);
            let batch: usize =
                flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(4);
            serve_demo(&model, n, batch)?;
        }
        Some("info") => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "llama-tiny".into());
            let m = Manifest::load(Manifest::default_dir(&model))?;
            println!(
                "model={} params={} layers={} d_model={} adapters={}",
                m.model,
                m.dims.param_count,
                m.dims.n_layers,
                m.dims.d_model,
                m.n_adapters
            );
            for a in &m.artifacts {
                println!("  artifact {} (batch={}, seq={})", a.name, a.batch, a.seq);
            }
        }
        _ => usage(),
    }
    Ok(())
}

/// Minimal real-serving demo: spin up the PJRT server, push a burst of
/// requests across all adapters, report latencies.
fn serve_demo(model: &str, n: usize, batch: usize) -> anyhow::Result<()> {
    let dir = Manifest::default_dir(model);
    let manifest = Manifest::load(&dir)?;
    println!(
        "serving {} ({} params, {} adapters) — PJRT CPU, shared backbone",
        manifest.model, manifest.dims.param_count, manifest.n_adapters
    );
    let (tx, rx) = server::spawn(
        dir,
        server::ServerConfig {
            max_batch: batch,
            batch_delay: std::time::Duration::from_millis(20),
        },
    );
    for i in 0..n as u64 {
        tx.send(server::LiveRequest {
            id: i,
            adapter: (i as usize) % manifest.n_adapters,
            prompt: (0..12).map(|t| ((i as i32) * 7 + t) % 100).collect(),
            max_new_tokens: 8,
        })?;
    }
    drop(tx);
    let mut ttfts = Vec::new();
    while let Ok(r) = rx.recv_timeout(std::time::Duration::from_secs(300)) {
        println!(
            "  req {} adapter={} batch={} ttft={:.1}ms tpot={:.1}ms e2e={:.1}ms",
            r.id,
            r.adapter,
            r.batch_size,
            r.ttft.as_secs_f64() * 1000.0,
            r.tpot.as_secs_f64() * 1000.0,
            r.e2e.as_secs_f64() * 1000.0
        );
        ttfts.push(r.ttft.as_secs_f64());
        if ttfts.len() == n {
            break;
        }
    }
    let s = serverless_lora::util::stats::summarize(&ttfts);
    println!(
        "served {} requests: TTFT mean {:.1} ms p99 {:.1} ms",
        s.count,
        s.mean * 1000.0,
        s.p99 * 1000.0
    );
    Ok(())
}
