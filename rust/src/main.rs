//! ServerlessLoRA launcher.
//!
//! ```text
//! serverless-lora simulate --exp fig6 [--full] [--jobs N]
//!                                                  regenerate a paper table/figure
//! serverless-lora simulate --all [--full] [--jobs N]
//!                                                  regenerate everything
//! serverless-lora run --scenario <file.json> [--dry-run] [--jobs N]
//!                                                  run a declarative scenario file
//!                                                  (one spec object or an array;
//!                                                  --dry-run: validate + summarize
//!                                                  without simulating)
//! serverless-lora fleet [--full] [--skew S] [--cov-head H] [--cov-tail T] [--check] [--zones N]
//!                                                  engine scaling sweep
//!                                                  (alias: simulate --exp fleet;
//!                                                  --skew: Zipf popularity;
//!                                                  --cov-head/--cov-tail: CoV class
//!                                                  of the Zipf head/tail, needs --skew;
//!                                                  --check: CI counter guard;
//!                                                  --zones N: one zone-sharded
//!                                                  1024-GPU point on N threads)
//! serverless-lora serve [--model llama-tiny] [--requests N] [--batch B]
//!                                                  real PJRT serving demo (`pjrt` feature)
//! serverless-lora info [--model llama-tiny]        artifact/manifest inventory
//! ```
//!
//! Unknown flags and malformed values (e.g. `--jobs four`) are rejected
//! with exit code 2 — no silent fallbacks.
//!
//! (CLI is hand-rolled: `clap` is not vendored in this build environment.)

use std::collections::BTreeMap;

use serverless_lora::exp;
use serverless_lora::scenario;
use serverless_lora::util::json::Json;

/// Flags that never take a value: their presence means "true", and the
/// token after them is a positional argument, not their value.
const BOOL_FLAGS: &[&str] = &["full", "all", "quick", "check", "dry-run"];

/// The flags each subcommand understands; anything else is rejected.
fn known_flags(cmd: &str) -> Option<&'static [&'static str]> {
    match cmd {
        "simulate" => Some(&["exp", "all", "full", "quick", "jobs"]),
        "run" => Some(&["scenario", "dry-run", "jobs"]),
        "fleet" => Some(&[
            "full", "quick", "skew", "cov-head", "cov-tail", "check", "zones", "jobs",
        ]),
        "serve" => Some(&["model", "requests", "batch"]),
        "info" => Some(&["model"]),
        _ => None,
    }
}

/// Reject flags the subcommand does not declare (historically they were
/// silently ignored — a typo like `--ful` ran the wrong mode).
fn check_flags(
    cmd: &str,
    flags: &BTreeMap<String, String>,
    allowed: &[&str],
) -> Result<(), String> {
    for k in flags.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(format!(
                "unknown flag --{k} for '{cmd}' (valid: {})",
                allowed
                    .iter()
                    .map(|a| format!("--{a}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
    }
    Ok(())
}

/// Parse `--jobs`: a malformed value (e.g. `--jobs four`, `--jobs 0`)
/// is an error, not a silent fallback to 1 worker.
fn parse_jobs(flags: &BTreeMap<String, String>) -> Result<Option<usize>, String> {
    let Some(v) = flags.get("jobs") else { return Ok(None) };
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(Some(n)),
        _ => Err(format!("--jobs needs a positive integer, got '{v}'")),
    }
}

/// Parse a positive-count flag (`--requests`, `--batch`): absent →
/// `default`, malformed → error (no silent fallback).
fn parse_count(
    flags: &BTreeMap<String, String>,
    name: &str,
    default: usize,
) -> Result<usize, String> {
    let Some(v) = flags.get(name) else { return Ok(default) };
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(format!("--{name} needs a positive integer, got '{v}'")),
    }
}

/// Validate `--exp` against the registry (`--exp --all` used to bind
/// `exp="true"` and run the unknown-experiment path).
fn check_exp_id(id: &str) -> Result<(), String> {
    if exp::ALL_EXPERIMENTS.contains(&id) {
        Ok(())
    } else {
        Err(format!(
            "unknown experiment '{id}'; valid ids: {}",
            exp::ALL_EXPERIMENTS.join(", ")
        ))
    }
}

/// Usage-error exit: message to stderr, exit code 2.
fn fail(msg: &str) -> ! {
    eprintln!("{msg}");
    std::process::exit(2)
}

/// Hand-rolled flag parser.
///
/// Rules, in order:
/// * `--name=value` binds explicitly.
/// * `--name` for a declared boolean flag is `true` and never consumes
///   the next token (`--all simulate` keeps `simulate` positional).
/// * `--name <tok>` binds `<tok>` unless it is another `--flag`; a
///   single-dash token is a value, so negatives work (`--delay -0.5`).
/// * A bare `--` ends flag parsing; everything after is positional.
fn parse_flags(
    args: &[String],
    bool_flags: &[&str],
) -> (Vec<String>, BTreeMap<String, String>) {
    let looks_like_flag = |tok: &str| tok.starts_with("--") && tok.len() > 2;
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(name) = a.strip_prefix("--") else {
            pos.push(a.clone());
            i += 1;
            continue;
        };
        if name.is_empty() {
            // `--` separator: the rest is positional.
            pos.extend(args[i + 1..].iter().cloned());
            break;
        }
        if let Some((k, v)) = name.split_once('=') {
            flags.insert(k.to_string(), v.to_string());
            i += 1;
            continue;
        }
        if bool_flags.contains(&name) {
            flags.insert(name.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let next_is_value = args
            .get(i + 1)
            .map(|n| !looks_like_flag(n))
            .unwrap_or(false);
        if next_is_value {
            flags.insert(name.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            flags.insert(name.to_string(), "true".to_string());
            i += 1;
        }
    }
    (pos, flags)
}

fn usage() -> ! {
    eprintln!(
        "usage: serverless-lora <simulate|run|fleet|serve|info> [options]\n\
         \n\
         simulate --exp <id>|--all [--full] [--jobs N]   ids: {}\n\
         run      --scenario <file.json> [--dry-run] [--jobs N]\n\
                  run a declarative scenario file (one JSON spec object or an\n\
                  array of them; see examples/scenarios/ and DESIGN.md\n\
                  \"Scenario API & observers\"; --dry-run validates and\n\
                  summarizes without simulating)\n\
         fleet    [--full] [--skew S] [--cov-head H] [--cov-tail T] [--check] [--zones N]\n\
                  engine scaling sweep\n\
                  (--skew: Zipf(S) popularity; --cov-head/--cov-tail: inter-arrival\n\
                  CoV class for the Zipf head/tail, requires --skew, missing side\n\
                  defaults to the Normal class; --check: counter regression guard;\n\
                  --zones N: one 1024-GPU/16384-fn point sharded over N engine threads)\n\
         serve    [--model llama-tiny] [--requests 16] [--batch 4]\n\
         info     [--model llama-tiny]",
        exp::ALL_EXPERIMENTS.join(", ")
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args, BOOL_FLAGS);
    let Some(cmd) = pos.first().map(String::as_str) else { usage() };
    let Some(allowed) = known_flags(cmd) else { usage() };
    if let Some(extra) = pos.get(1) {
        fail(&format!("unexpected positional argument '{extra}' after '{cmd}'"));
    }
    if let Err(e) = check_flags(cmd, &flags, allowed) {
        fail(&e);
    }
    match parse_jobs(&flags) {
        Ok(Some(jobs)) => exp::runner::set_jobs(jobs),
        Ok(None) => {}
        Err(e) => fail(&e),
    }
    match cmd {
        "simulate" => {
            let quick = !flags.contains_key("full");
            if flags.contains_key("all") {
                for id in exp::ALL_EXPERIMENTS {
                    print!("{}", exp::run_experiment(id, quick));
                }
            } else if let Some(id) = flags.get("exp") {
                if let Err(e) = check_exp_id(id) {
                    fail(&e);
                }
                print!("{}", exp::run_experiment(id, quick));
            } else {
                usage()
            }
        }
        "run" => {
            let Some(path) = flags.get("scenario") else {
                fail("run needs --scenario <file.json>");
            };
            if path == "true" {
                // `--scenario --dry-run` binds the boolean sentinel.
                fail("--scenario needs a file path");
            }
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| fail(&format!("cannot read scenario file '{path}': {e}")));
            let json = Json::parse(&text)
                .unwrap_or_else(|e| fail(&format!("{path}: invalid JSON: {e}")));
            let specs = scenario::specs_from_json(&json)
                .unwrap_or_else(|e| fail(&format!("{path}: {e}")));
            for spec in &specs {
                if let Err(e) = spec.validate() {
                    fail(&format!("{path}: scenario '{}': {e}", spec.name));
                }
            }
            if flags.contains_key("dry-run") {
                for spec in &specs {
                    println!("{}", spec.summary());
                }
                println!("{path}: {} scenario(s) valid", specs.len());
            } else {
                let reports = scenario::run_grid(&specs)
                    .unwrap_or_else(|e| fail(&format!("{path}: {e}")));
                print!("{}", scenario::render_reports(&reports));
            }
        }
        "fleet" => {
            let quick = !flags.contains_key("full");
            if flags.contains_key("check") {
                // CI regression guard: deterministic engine counters vs
                // the committed structural bounds.
                match exp::fleet::check() {
                    Ok(report) => print!("{report}"),
                    Err(msg) => {
                        eprintln!("{msg}");
                        std::process::exit(1);
                    }
                }
            } else if let Some(v) = flags.get("zones") {
                // One zone-sharded smoke point (CI: `fleet --zones 4`).
                match v.parse::<usize>() {
                    Ok(z) if z >= 1 && 1024 % z == 0 => {
                        print!("{}", exp::fleet::fleet_zones(z));
                    }
                    _ => {
                        eprintln!("--zones needs a positive divisor of 1024, got '{v}'");
                        std::process::exit(2);
                    }
                }
            } else {
                let skew = match flags.get("skew") {
                    Some(v) => match v.parse::<f64>() {
                        Ok(s) if s.is_finite() && s > 0.0 => Some(s),
                        _ => {
                            eprintln!("--skew needs a positive number, got '{v}'");
                            std::process::exit(2);
                        }
                    },
                    None => None,
                };
                // CoV classes for the Zipf head/tail (validation matches
                // --skew: positive finite numbers, mapped onto the
                // paper's CoV bands).
                let cov_of = |name: &str| -> Option<f64> {
                    let v = flags.get(name)?;
                    match v.parse::<f64>() {
                        Ok(c) if c.is_finite() && c > 0.0 => Some(c),
                        _ => {
                            eprintln!("--{name} needs a positive number, got '{v}'");
                            std::process::exit(2);
                        }
                    }
                };
                let (head, tail) = (cov_of("cov-head"), cov_of("cov-tail"));
                let cov = if head.is_some() || tail.is_some() {
                    if skew.is_none() {
                        eprintln!("--cov-head/--cov-tail require --skew");
                        std::process::exit(2);
                    }
                    use serverless_lora::trace::Pattern;
                    Some((
                        Pattern::for_cov(head.unwrap_or(2.5)),
                        Pattern::for_cov(tail.unwrap_or(2.5)),
                    ))
                } else {
                    None
                };
                print!("{}", exp::fleet::fleet_with(quick, skew, cov));
            }
        }
        "serve" => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "llama-tiny".into());
            let n = match parse_count(&flags, "requests", 16) {
                Ok(n) => n,
                Err(e) => fail(&e),
            };
            let batch = match parse_count(&flags, "batch", 4) {
                Ok(b) => b,
                Err(e) => fail(&e),
            };
            pjrt::serve_demo(&model, n, batch)?;
        }
        "info" => {
            let model = flags
                .get("model")
                .cloned()
                .unwrap_or_else(|| "llama-tiny".into());
            pjrt::info(&model)?;
        }
        _ => unreachable!("known_flags gated the subcommand"),
    }
    Ok(())
}

/// Real-runtime subcommands, only compiled with the `pjrt` feature (the
/// data plane needs the external `xla` crate).
#[cfg(feature = "pjrt")]
mod pjrt {
    use serverless_lora::runtime::{server, Manifest};

    pub fn info(model: &str) -> anyhow::Result<()> {
        let m = Manifest::load(Manifest::default_dir(model))?;
        println!(
            "model={} params={} layers={} d_model={} adapters={}",
            m.model,
            m.dims.param_count,
            m.dims.n_layers,
            m.dims.d_model,
            m.n_adapters
        );
        for a in &m.artifacts {
            println!("  artifact {} (batch={}, seq={})", a.name, a.batch, a.seq);
        }
        Ok(())
    }

    /// Minimal real-serving demo: spin up the PJRT server, push a burst
    /// of requests across all adapters, report latencies.
    pub fn serve_demo(model: &str, n: usize, batch: usize) -> anyhow::Result<()> {
        let dir = Manifest::default_dir(model);
        let manifest = Manifest::load(&dir)?;
        println!(
            "serving {} ({} params, {} adapters) — PJRT CPU, shared backbone",
            manifest.model, manifest.dims.param_count, manifest.n_adapters
        );
        let (tx, rx) = server::spawn(
            dir,
            server::ServerConfig {
                max_batch: batch,
                batch_delay: std::time::Duration::from_millis(20),
            },
        );
        for i in 0..n as u64 {
            tx.send(server::LiveRequest {
                id: i,
                adapter: (i as usize) % manifest.n_adapters,
                prompt: (0..12).map(|t| ((i as i32) * 7 + t) % 100).collect(),
                max_new_tokens: 8,
            })?;
        }
        drop(tx);
        let mut ttfts = Vec::new();
        while let Ok(r) = rx.recv_timeout(std::time::Duration::from_secs(300)) {
            println!(
                "  req {} adapter={} batch={} ttft={:.1}ms tpot={:.1}ms e2e={:.1}ms",
                r.id,
                r.adapter,
                r.batch_size,
                r.ttft.as_secs_f64() * 1000.0,
                r.tpot.as_secs_f64() * 1000.0,
                r.e2e.as_secs_f64() * 1000.0
            );
            ttfts.push(r.ttft.as_secs_f64());
            if ttfts.len() == n {
                break;
            }
        }
        let s = serverless_lora::util::stats::summarize(&ttfts);
        println!(
            "served {} requests: TTFT mean {:.1} ms p99 {:.1} ms",
            s.count,
            s.mean * 1000.0,
            s.p99 * 1000.0
        );
        Ok(())
    }
}

/// Without the `pjrt` feature the real-runtime subcommands explain how to
/// enable themselves instead of failing to link.
#[cfg(not(feature = "pjrt"))]
mod pjrt {
    pub fn info(_model: &str) -> anyhow::Result<()> {
        unavailable()
    }

    pub fn serve_demo(_model: &str, _n: usize, _batch: usize) -> anyhow::Result<()> {
        unavailable()
    }

    fn unavailable() -> anyhow::Result<()> {
        Err(anyhow::anyhow!(
            "this binary was built without the `pjrt` feature. To serve the \
             real model: on a networked machine, add `xla = \"0.1\"` to \
             rust/Cargo.toml [dependencies], then `cargo build --features pjrt` \
             (see the feature note in Cargo.toml)"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> (Vec<String>, BTreeMap<String, String>) {
        let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        parse_flags(&v, BOOL_FLAGS)
    }

    #[test]
    fn negative_number_binds_as_value() {
        let (pos, flags) = p(&["simulate", "--delay", "-0.5"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("delay").map(String::as_str), Some("-0.5"));
    }

    #[test]
    fn boolean_flag_before_positional_keeps_positional() {
        // The old parser swallowed `simulate` as the value of `--all`.
        let (pos, flags) = p(&["--all", "simulate"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("all").map(String::as_str), Some("true"));
    }

    #[test]
    fn trailing_boolean_flag() {
        let (pos, flags) = p(&["simulate", "--full"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("full").map(String::as_str), Some("true"));
    }

    #[test]
    fn equals_syntax_binds() {
        let (_, flags) = p(&["--exp=fig6", "--jobs=4"]);
        assert_eq!(flags.get("exp").map(String::as_str), Some("fig6"));
        assert_eq!(flags.get("jobs").map(String::as_str), Some("4"));
    }

    #[test]
    fn value_flag_followed_by_flag_stays_boolean() {
        let (_, flags) = p(&["--exp", "--all"]);
        assert_eq!(flags.get("exp").map(String::as_str), Some("true"));
        assert_eq!(flags.get("all").map(String::as_str), Some("true"));
    }

    #[test]
    fn double_dash_ends_flag_parsing() {
        let (pos, flags) = p(&["--jobs", "2", "--", "--weird-positional"]);
        assert_eq!(flags.get("jobs").map(String::as_str), Some("2"));
        assert_eq!(pos, vec!["--weird-positional"]);
    }

    #[test]
    fn normal_value_flags_still_work() {
        let (pos, flags) = p(&["simulate", "--exp", "fig6", "--jobs", "4"]);
        assert_eq!(pos, vec!["simulate"]);
        assert_eq!(flags.get("exp").map(String::as_str), Some("fig6"));
        assert_eq!(flags.get("jobs").map(String::as_str), Some("4"));
    }

    // ------------------------------------------- strict validation

    #[test]
    fn jobs_rejects_garbage_instead_of_ignoring_it() {
        // `--jobs four` used to fall through silently to 1 worker.
        let (_, flags) = p(&["simulate", "--jobs", "four"]);
        let err = parse_jobs(&flags).unwrap_err();
        assert!(err.contains("four"), "{err}");
        let (_, flags) = p(&["simulate", "--jobs", "0"]);
        assert!(parse_jobs(&flags).is_err(), "0 workers is meaningless");
        let (_, flags) = p(&["simulate", "--jobs", "-3"]);
        assert!(parse_jobs(&flags).is_err());
    }

    #[test]
    fn jobs_accepts_positive_integers_or_absence() {
        let (_, flags) = p(&["simulate", "--jobs", "8"]);
        assert_eq!(parse_jobs(&flags).unwrap(), Some(8));
        let (_, flags) = p(&["simulate"]);
        assert_eq!(parse_jobs(&flags).unwrap(), None);
    }

    #[test]
    fn serve_counts_default_or_reject_never_fall_back() {
        let (_, flags) = p(&["serve"]);
        assert_eq!(parse_count(&flags, "requests", 16).unwrap(), 16);
        let (_, flags) = p(&["serve", "--requests", "32"]);
        assert_eq!(parse_count(&flags, "requests", 16).unwrap(), 32);
        // `--requests ten` used to silently serve the default 16.
        let (_, flags) = p(&["serve", "--requests", "ten"]);
        let err = parse_count(&flags, "requests", 16).unwrap_err();
        assert!(err.contains("ten"), "{err}");
        let (_, flags) = p(&["serve", "--batch", "0"]);
        assert!(parse_count(&flags, "batch", 4).is_err());
    }

    #[test]
    fn unknown_flags_are_rejected_and_valid_ones_listed() {
        // `--ful` (a typo for --full) used to be silently accepted.
        let (_, flags) = p(&["simulate", "--ful"]);
        let err = check_flags("simulate", &flags, known_flags("simulate").unwrap())
            .unwrap_err();
        assert!(err.contains("--ful"), "{err}");
        assert!(err.contains("--full"), "must list the valid flags: {err}");
        let (_, flags) = p(&["simulate", "--all", "--jobs", "2"]);
        assert!(check_flags("simulate", &flags, known_flags("simulate").unwrap()).is_ok());
    }

    #[test]
    fn every_subcommand_declares_its_flags() {
        for cmd in ["simulate", "run", "fleet", "serve", "info"] {
            assert!(known_flags(cmd).is_some(), "{cmd}");
        }
        assert!(known_flags("simulat").is_none());
    }

    #[test]
    fn exp_id_validated_against_registry() {
        assert!(check_exp_id("fig6").is_ok());
        // `--exp --all` binds exp="true"; the validator catches it and
        // names the real ids.
        let err = check_exp_id("true").unwrap_err();
        assert!(err.contains("'true'"), "{err}");
        assert!(err.contains("fig6") && err.contains("fleet"), "{err}");
    }
}
