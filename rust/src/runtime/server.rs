//! Real serving loop over the PJRT engine: a multi-producer request
//! channel, the §4.2 fill-or-expire batcher per function, and an executor
//! thread that runs prefill/decode on the shared-backbone engine.
//!
//! This is the live analogue of the simulator's serving stage — Python is
//! nowhere on this path. Used by `examples/e2e_serving.rs` and the tab2
//! throughput bench.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::Engine;

/// An inference request on the live path.
#[derive(Debug, Clone)]
pub struct LiveRequest {
    pub id: u64,
    /// Which LoRA function (adapter) this request targets.
    pub adapter: usize,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Completed response with serving latencies.
#[derive(Debug, Clone)]
pub struct LiveResponse {
    pub id: u64,
    pub adapter: usize,
    pub tokens: Vec<i32>,
    /// Arrival → first token.
    pub ttft: Duration,
    /// Mean per-token latency over the decode.
    pub tpot: Duration,
    /// Arrival → last token.
    pub e2e: Duration,
    pub batch_size: usize,
}

/// Batching knobs for the live server (mirrors §4.2's local layer).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max requests batched per function invocation (clamped to the
    /// largest AOT batch bucket).
    pub max_batch: usize,
    /// Fill-or-expire window.
    pub batch_delay: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 8, batch_delay: Duration::from_millis(20) }
    }
}

struct Pending {
    req: LiveRequest,
    arrived: Instant,
}

/// Single-threaded serving core (the PJRT CPU device is one execution
/// stream; extra executor threads would only contend). Callers submit
/// via a channel; responses flow back per request.
pub struct Server {
    engine: Engine,
    cfg: ServerConfig,
    queues: BTreeMap<usize, Vec<Pending>>,
}

impl Server {
    pub fn new(engine: Engine, cfg: ServerConfig) -> Self {
        Server { engine, cfg, queues: BTreeMap::new() }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Serve until `rx` disconnects; push responses into `tx`.
    pub fn run(mut self, rx: Receiver<LiveRequest>, tx: Sender<LiveResponse>) -> Result<()> {
        let max_bucket = self.engine.manifest.batch_buckets.last().copied().unwrap_or(1);
        let max_batch = self.cfg.max_batch.min(max_bucket);
        // One instance per adapter, created lazily — each holds its own
        // adapter buffers and shares the backbone.
        let mut instances: BTreeMap<usize, super::engine::FunctionInstance> = BTreeMap::new();

        loop {
            // Drain whatever is available; block briefly when idle.
            let mut disconnected = false;
            loop {
                match rx.try_recv() {
                    Ok(req) => {
                        self.queues
                            .entry(req.adapter)
                            .or_default()
                            .push(Pending { req, arrived: Instant::now() });
                    }
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }

            // Fill-or-expire dispatch per function.
            let now = Instant::now();
            let ready: Vec<usize> = self
                .queues
                .iter()
                .filter(|(_, q)| {
                    !q.is_empty()
                        && (q.len() >= max_batch
                            || disconnected
                            || now.duration_since(q[0].arrived) >= self.cfg.batch_delay)
                })
                .map(|(&a, _)| a)
                .collect();

            if ready.is_empty() {
                if disconnected && self.queues.values().all(|q| q.is_empty()) {
                    return Ok(());
                }
                std::thread::sleep(Duration::from_micros(200));
                continue;
            }

            for adapter in ready {
                let mut q = std::mem::take(self.queues.get_mut(&adapter).unwrap());
                let take = q.len().min(max_batch);
                let rest = q.split_off(take);
                self.queues.insert(adapter, rest);

                if !instances.contains_key(&adapter) {
                    instances.insert(adapter, self.engine.instance(adapter)?);
                }
                let inst = &instances[&adapter];

                let prompts: Vec<Vec<i32>> =
                    q.iter().map(|p| p.req.prompt.clone()).collect();
                let max_new = q.iter().map(|p| p.req.max_new_tokens).max().unwrap();

                let t_exec = Instant::now();
                let (logits, mut kv) = self.engine.prefill(inst, &prompts)?;
                let t_first = Instant::now();
                let mut next: Vec<i32> =
                    logits.iter().map(|l| argmax(l)).collect();
                let mut outs: Vec<Vec<i32>> = vec![vec![]; q.len()];
                for (i, &t) in next.iter().enumerate() {
                    outs[i].push(t);
                }
                for _ in 1..max_new {
                    if kv.pos >= self.engine.manifest.dims.max_seq {
                        break;
                    }
                    let logits = self.engine.decode(inst, &next, &mut kv)?;
                    next = logits.iter().map(|l| argmax(l)).collect();
                    for (i, &t) in next.iter().enumerate() {
                        if outs[i].len() < q[i].req.max_new_tokens {
                            outs[i].push(t);
                        }
                    }
                }
                let t_done = Instant::now();
                let decode_time = t_done.duration_since(t_first);
                let b = q.len();
                for (p, tokens) in q.into_iter().zip(outs) {
                    let n_tok = tokens.len().max(1) as u32;
                    let ttft = t_first.duration_since(p.arrived);
                    let _ = tx.send(LiveResponse {
                        id: p.req.id,
                        adapter,
                        tokens,
                        ttft,
                        tpot: decode_time / n_tok,
                        e2e: t_done.duration_since(p.arrived),
                        batch_size: b,
                    });
                }
                let _ = t_exec; // (kept for future per-phase reporting)
            }
        }
    }
}

/// Spawn the server on a background thread; returns (request tx, response rx).
///
/// The PJRT client is `Rc`-based (not `Send`), so the engine is constructed
/// *inside* the serving thread from the artifact directory — which also
/// mirrors the deployment reality: the serving process owns its runtime.
pub fn spawn(
    artifact_dir: std::path::PathBuf,
    cfg: ServerConfig,
) -> (Sender<LiveRequest>, Receiver<LiveResponse>) {
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    std::thread::spawn(move || match Engine::load(&artifact_dir) {
        Ok(engine) => {
            let server = Server::new(engine, cfg);
            if let Err(e) = server.run(req_rx, resp_tx) {
                eprintln!("server error: {e:#}");
            }
        }
        Err(e) => eprintln!("engine load error: {e:#}"),
    });
    (req_tx, resp_rx)
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn artifact_dir() -> Option<std::path::PathBuf> {
        let dir = Manifest::default_dir("llama-tiny");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn serves_batched_requests_end_to_end() {
        let Some(dir) = artifact_dir() else { return };
        let (tx, rx) = spawn(dir, ServerConfig::default());
        for i in 0..6u64 {
            tx.send(LiveRequest {
                id: i,
                adapter: (i % 2) as usize,
                prompt: vec![(i as i32) % 100; 12],
                max_new_tokens: 4,
            })
            .unwrap();
        }
        drop(tx);
        let mut got = 0;
        while let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
            assert_eq!(resp.tokens.len(), 4);
            assert!(resp.e2e >= resp.ttft);
            got += 1;
            if got == 6 {
                break;
            }
        }
        assert_eq!(got, 6, "all requests served");
    }

    #[test]
    fn batching_groups_same_adapter() {
        let Some(dir) = artifact_dir() else { return };
        let (tx, rx) = spawn(
            dir,
            ServerConfig { max_batch: 4, batch_delay: Duration::from_millis(100) },
        );
        for i in 0..4u64 {
            tx.send(LiveRequest {
                id: i,
                adapter: 0,
                prompt: vec![7; 8],
                max_new_tokens: 2,
            })
            .unwrap();
        }
        drop(tx);
        let mut sizes = vec![];
        for _ in 0..4 {
            let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            sizes.push(r.batch_size);
        }
        // All four arrived within the window ⇒ served as one batch of 4.
        assert!(sizes.iter().all(|&s| s == 4), "batch sizes {sizes:?}");
    }
}
