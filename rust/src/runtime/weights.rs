//! Load the AOT-exported weight blobs (`backbone.bin`, `adapter_<i>.bin`)
//! — raw little-endian f32 in manifest parameter order — and stage them as
//! PJRT device buffers.
//!
//! The backbone buffer set is created **once** and shared (`Arc`) across
//! every function instance: this is the data-plane realisation of §4.4's
//! CUDA-IPC sharing — one read-only copy, many isolated readers, each
//! function bringing only its own adapter buffers and KV state.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};
use xla::{PjRtBuffer, PjRtClient};

use super::manifest::ParamSpec;

/// Read a `.bin` blob into f32s, validating the total element count.
pub fn read_flat_f32(path: &Path, expect_elements: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() != expect_elements * 4 {
        return Err(anyhow!(
            "{}: {} bytes, expected {} (= {} f32)",
            path.display(),
            bytes.len(),
            expect_elements * 4,
            expect_elements
        ));
    }
    let mut out = Vec::with_capacity(expect_elements);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(out)
}

/// Split a flat weight vector into per-parameter device buffers following
/// the manifest order.
pub fn to_device_buffers(
    client: &PjRtClient,
    flat: &[f32],
    specs: &[ParamSpec],
) -> Result<Vec<PjRtBuffer>> {
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for s in specs {
        let n = s.elements();
        let data = flat
            .get(off..off + n)
            .ok_or_else(|| anyhow!("weight blob too short at {}", s.name))?;
        let buf = client
            .buffer_from_host_buffer(data, &s.shape, None)
            .with_context(|| format!("uploading {}", s.name))?;
        out.push(buf);
        off += n;
    }
    if off != flat.len() {
        return Err(anyhow!("weight blob has {} trailing elements", flat.len() - off));
    }
    Ok(out)
}

/// The shared, read-only backbone weights: one device copy, refcounted by
/// `Arc` — function instances clone the handle, never the bytes.
#[derive(Clone)]
pub struct SharedBackbone {
    buffers: Arc<Vec<PjRtBuffer>>,
}

impl SharedBackbone {
    pub fn new(buffers: Vec<PjRtBuffer>) -> Self {
        SharedBackbone { buffers: Arc::new(buffers) }
    }

    pub fn buffers(&self) -> &[PjRtBuffer] {
        &self.buffers
    }

    /// Number of live handles (≈ attached function instances + the engine).
    pub fn refcount(&self) -> usize {
        Arc::strong_count(&self.buffers)
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_flat_validates_length() {
        let dir = std::env::temp_dir().join("sl_weights_test.bin");
        std::fs::write(&dir, [0u8; 16]).unwrap();
        assert_eq!(read_flat_f32(&dir, 4).unwrap(), vec![0.0; 4]);
        assert!(read_flat_f32(&dir, 5).is_err());
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn read_flat_little_endian() {
        let dir = std::env::temp_dir().join("sl_weights_le.bin");
        std::fs::write(&dir, 1.5f32.to_le_bytes()).unwrap();
        assert_eq!(read_flat_f32(&dir, 1).unwrap(), vec![1.5]);
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn shared_backbone_refcounts() {
        let b = SharedBackbone::new(vec![]);
        assert_eq!(b.refcount(), 1);
        let c = b.clone();
        assert_eq!(b.refcount(), 2);
        drop(c);
        assert_eq!(b.refcount(), 1);
    }
}
