//! The PJRT data plane: load the AOT HLO-text artifacts, compile them once
//! on the CPU PJRT client, and serve prefill/decode with a **shared
//! backbone** and **isolated per-function state** — the runtime
//! realisation of §4.4:
//!
//! * the backbone weight buffers are uploaded once and shared (`Arc`)
//!   across all function instances (zero-copy, read-only);
//! * each `FunctionInstance` owns its adapter buffers and its KV caches —
//!   nothing dynamic is shared between functions;
//! * compiling the HLO executables here is this stack's "CUDA kernel JIT"
//!   artifact: the measured `compile_s` feeds the artifact model.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{ArtifactKind, Manifest};
use super::weights::{read_flat_f32, to_device_buffers, SharedBackbone};

/// Key for the executable cache: (is_decode, batch, seq).
type ExeKey = (bool, usize, usize);

/// Per-function isolated state: adapter weights + KV caches. Holding a
/// `SharedBackbone` clone is the IPC-handle analogue — it pins the shared
/// weights but cannot mutate them.
pub struct FunctionInstance {
    pub adapter_id: usize,
    adapter: Vec<xla::PjRtBuffer>,
    backbone: SharedBackbone,
}

impl FunctionInstance {
    pub fn backbone_refcount(&self) -> usize {
        self.backbone.refcount()
    }
}

/// KV cache for one in-flight batch of one function (never shared).
pub struct KvState {
    k: Literal,
    v: Literal,
    pub pos: usize,
    pub batch: usize,
    /// Batch bucket the caches are shaped for.
    pub bucket: usize,
}

/// Timing profile measured at engine start (feeds the simulator's
/// `llama-tiny` ModelProfile and EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Default)]
pub struct EngineProfile {
    pub compile_s: f64,
    pub n_executables: usize,
    pub backbone_upload_s: f64,
    pub backbone_bytes: usize,
}

pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    exes: BTreeMap<ExeKey, PjRtLoadedExecutable>,
    backbone: SharedBackbone,
    pub profile: EngineProfile,
}

impl Engine {
    /// Load + compile everything under an artifact directory
    /// (`artifacts/llama-tiny`). This is the once-per-deployment cost —
    /// Python is never involved at or after this point.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()?;

        let t0 = Instant::now();
        let flat = read_flat_f32(
            &manifest.dir.join("backbone.bin"),
            manifest.backbone_elements(),
        )?;
        let backbone =
            SharedBackbone::new(to_device_buffers(&client, &flat, &manifest.backbone_params)?);
        let backbone_upload_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let mut exes = BTreeMap::new();
        for a in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                a.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", a.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", a.name))?;
            let key = (a.kind == ArtifactKind::Decode, a.batch, a.seq);
            exes.insert(key, exe);
        }
        let compile_s = t0.elapsed().as_secs_f64();

        let profile = EngineProfile {
            compile_s,
            n_executables: exes.len(),
            backbone_upload_s,
            backbone_bytes: flat.len() * 4,
        };
        Ok(Engine { client, manifest, exes, backbone, profile })
    }

    /// Spawn an isolated function instance for one LoRA adapter. The
    /// backbone is *attached* (Arc clone), the adapter is loaded privately.
    pub fn instance(&self, adapter_id: usize) -> Result<FunctionInstance> {
        if adapter_id >= self.manifest.n_adapters {
            return Err(anyhow!(
                "adapter {adapter_id} out of range ({} available)",
                self.manifest.n_adapters
            ));
        }
        let flat = read_flat_f32(
            &self.manifest.dir.join(format!("adapter_{adapter_id}.bin")),
            self.manifest.adapter_elements(),
        )?;
        let adapter = to_device_buffers(&self.client, &flat, &self.manifest.adapter_params)?;
        Ok(FunctionInstance {
            adapter_id,
            adapter,
            backbone: self.backbone.clone(),
        })
    }

    /// Live shared-backbone handle count (engine's own + instances).
    pub fn backbone_refcount(&self) -> usize {
        self.backbone.refcount()
    }

    fn exe(&self, decode: bool, batch: usize, seq: usize) -> Result<&PjRtLoadedExecutable> {
        self.exes
            .get(&(decode, batch, seq))
            .ok_or_else(|| anyhow!("no artifact for decode={decode} b={batch} s={seq}"))
    }

    /// Prefill a batch of prompts (all padded/truncated to one seq
    /// bucket). Returns per-request logits and the KV state.
    ///
    /// Prompts shorter than the bucket are right-padded with token 0;
    /// the synthetic-workload semantics treat the padded prompt as the
    /// prompt (no attention masking in the tiny model — see DESIGN.md).
    pub fn prefill(
        &self,
        inst: &FunctionInstance,
        prompts: &[Vec<i32>],
    ) -> Result<(Vec<Vec<f32>>, KvState)> {
        let n = prompts.len();
        if n == 0 {
            return Err(anyhow!("empty batch"));
        }
        let bucket = self
            .manifest
            .batch_bucket(n)
            .ok_or_else(|| anyhow!("batch {n} exceeds largest bucket"))?;
        let longest = prompts.iter().map(|p| p.len()).max().unwrap();
        let seq = self
            .manifest
            .seq_bucket(longest)
            .ok_or_else(|| anyhow!("prompt len {longest} exceeds largest bucket"))?;

        let mut toks = vec![0i32; bucket * seq];
        for (i, p) in prompts.iter().enumerate() {
            toks[i * seq..i * seq + p.len()].copy_from_slice(p);
        }
        // Pad rows replicate row 0 so padded lanes stay numerically tame.
        for i in n..bucket {
            let (head, tail) = toks.split_at_mut(i * seq);
            tail[..seq].copy_from_slice(&head[..seq]);
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&toks, &[bucket, seq], None)?;

        let exe = self.exe(false, bucket, seq)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(
            self.backbone.len() + inst.adapter.len() + 1,
        );
        args.extend(self.backbone.buffers());
        args.extend(inst.adapter.iter());
        args.push(&tok_buf);
        let result = exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let (logits_l, k, v) = tuple.to_tuple3()?;
        let logits = split_logits(&logits_l, bucket, self.manifest.dims.vocab, n)?;
        Ok((
            logits,
            KvState { k, v, pos: seq, batch: n, bucket },
        ))
    }

    /// One lock-step decode step: feed one token per request, get logits.
    /// The KV cache advances in place (positions beyond `pos` are unused).
    pub fn decode(
        &self,
        inst: &FunctionInstance,
        tokens: &[i32],
        kv: &mut KvState,
    ) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != kv.batch {
            return Err(anyhow!("token count {} != batch {}", tokens.len(), kv.batch));
        }
        if kv.pos >= self.manifest.dims.max_seq {
            return Err(anyhow!("KV cache exhausted at pos {}", kv.pos));
        }
        let bucket = kv.bucket;
        let mut padded = vec![0i32; bucket];
        padded[..tokens.len()].copy_from_slice(tokens);
        let tok_buf = self.client.buffer_from_host_buffer(&padded, &[bucket], None)?;
        let k_buf = self.client.buffer_from_host_literal(None, &kv.k)?;
        let v_buf = self.client.buffer_from_host_literal(None, &kv.v)?;
        let pos_l = Literal::scalar(kv.pos as i32);
        let pos_buf = self.client.buffer_from_host_literal(None, &pos_l)?;

        let exe = self.exe(true, bucket, self.manifest.dims.max_seq)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(
            self.backbone.len() + inst.adapter.len() + 4,
        );
        args.extend(self.backbone.buffers());
        args.extend(inst.adapter.iter());
        args.push(&tok_buf);
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&pos_buf);
        let result = exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let (logits_l, k, v) = tuple.to_tuple3()?;
        kv.k = k;
        kv.v = v;
        kv.pos += 1;
        split_logits(&logits_l, bucket, self.manifest.dims.vocab, kv.batch)
    }

    /// Greedy generation: prefill + `max_new` lock-step decode steps.
    /// Returns the generated token ids per request.
    pub fn generate(
        &self,
        inst: &FunctionInstance,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let (logits, mut kv) = self.prefill(inst, prompts)?;
        let mut out: Vec<Vec<i32>> = vec![Vec::with_capacity(max_new); prompts.len()];
        let mut next: Vec<i32> = logits.iter().map(|l| argmax(l)).collect();
        for (i, &t) in next.iter().enumerate() {
            out[i].push(t);
        }
        for _ in 1..max_new {
            if kv.pos >= self.manifest.dims.max_seq {
                break;
            }
            let logits = self.decode(inst, &next, &mut kv)?;
            next = logits.iter().map(|l| argmax(l)).collect();
            for (i, &t) in next.iter().enumerate() {
                out[i].push(t);
            }
        }
        Ok(out)
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

fn split_logits(
    l: &Literal,
    bucket: usize,
    vocab: usize,
    n: usize,
) -> Result<Vec<Vec<f32>>> {
    let flat: Vec<f32> = l.to_vec()?;
    if flat.len() != bucket * vocab {
        return Err(anyhow!("logits shape mismatch: {} != {}", flat.len(), bucket * vocab));
    }
    Ok((0..n).map(|i| flat[i * vocab..(i + 1) * vocab].to_vec()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = Manifest::default_dir("llama-tiny");
        if !dir.join("manifest.json").exists() {
            return None; // artifacts not built in this checkout
        }
        Some(Engine::load(dir).expect("engine loads"))
    }

    #[test]
    fn golden_prompt_matches_python() {
        // Mirror of aot.golden_prompt's LCG.
        let toks = golden_prompt(1, 16, 512, 0);
        assert_eq!(toks.len(), 16);
        assert!(toks.iter().all(|&t| (0..512).contains(&t)));
    }

    pub fn golden_prompt(batch: usize, seq: usize, vocab: usize, adapter: usize) -> Vec<i32> {
        let mut state: u64 =
            (0x9E3779B9u64) ^ (batch as u64 * 1000003 + seq as u64 * 101 + adapter as u64);
        let mut out = Vec::with_capacity(batch * seq);
        for _ in 0..batch * seq {
            state = (state.wrapping_mul(1664525).wrapping_add(1013904223)) % (1 << 32);
            out.push((state % vocab as u64) as i32);
        }
        out
    }

    #[test]
    fn prefill_matches_python_golden() {
        let Some(e) = engine() else { return };
        let g = &e.manifest.goldens[0];
        let inst = e.instance(g.adapter).unwrap();
        let prompt = golden_prompt(g.batch, g.seq, e.manifest.dims.vocab, g.adapter);
        let prompts: Vec<Vec<i32>> =
            prompt.chunks(g.seq).map(|c| c.to_vec()).collect();
        let (logits, kv) = e.prefill(&inst, &prompts).unwrap();
        assert_eq!(kv.pos, g.seq);
        for (i, expect) in g.prefill_logits_head.iter().enumerate() {
            let got = logits[0][i] as f64;
            assert!(
                (got - expect).abs() < 1e-3 * expect.abs().max(1.0),
                "logit[{i}] {got} != {expect}"
            );
        }
        // Argmax agreement per batch row.
        for (row, &am) in g.prefill_argmax.iter().enumerate() {
            assert_eq!(argmax(&logits[row]) as usize, am, "row {row}");
        }
    }

    #[test]
    fn decode_matches_python_golden() {
        let Some(e) = engine() else { return };
        let g = &e.manifest.goldens[0];
        let inst = e.instance(g.adapter).unwrap();
        let prompt = golden_prompt(g.batch, g.seq, e.manifest.dims.vocab, g.adapter);
        let prompts: Vec<Vec<i32>> =
            prompt.chunks(g.seq).map(|c| c.to_vec()).collect();
        let (logits, mut kv) = e.prefill(&inst, &prompts).unwrap();
        let next: Vec<i32> = logits.iter().map(|l| argmax(l)).collect();
        let l2 = e.decode(&inst, &next, &mut kv).unwrap();
        for (i, expect) in g.decode_logits_head.iter().enumerate() {
            let got = l2[0][i] as f64;
            assert!(
                (got - expect).abs() < 2e-3 * expect.abs().max(1.0),
                "decode logit[{i}] {got} != {expect}"
            );
        }
        for (row, &am) in g.decode_argmax.iter().enumerate() {
            assert_eq!(argmax(&l2[row]) as usize, am, "row {row}");
        }
    }

    #[test]
    fn backbone_shared_across_instances() {
        let Some(e) = engine() else { return };
        let before = e.backbone_refcount();
        let i0 = e.instance(0).unwrap();
        let i1 = e.instance(1).unwrap();
        assert_eq!(e.backbone_refcount(), before + 2);
        assert_eq!(i0.backbone_refcount(), before + 2);
        drop(i0);
        drop(i1);
        assert_eq!(e.backbone_refcount(), before);
    }

    #[test]
    fn adapters_produce_different_logits() {
        let Some(e) = engine() else { return };
        let i0 = e.instance(0).unwrap();
        let i1 = e.instance(1).unwrap();
        let prompt = vec![vec![5i32; 16]];
        let (l0, _) = e.prefill(&i0, &prompt).unwrap();
        let (l1, _) = e.prefill(&i1, &prompt).unwrap();
        let max_diff = l0[0]
            .iter()
            .zip(&l1[0])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff > 1e-3, "adapters indistinguishable: {max_diff}");
    }

    #[test]
    fn generate_produces_tokens() {
        let Some(e) = engine() else { return };
        let inst = e.instance(0).unwrap();
        let out = e.generate(&inst, &[vec![1, 2, 3, 4]], 8).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 8);
        assert!(out[0].iter().all(|&t| (t as usize) < e.manifest.dims.vocab));
    }

    #[test]
    fn batch_rows_match_single_row() {
        // Isolation check: request 0's logits must not depend on request 1
        // sharing the batch.
        let Some(e) = engine() else { return };
        let inst = e.instance(0).unwrap();
        let p0: Vec<i32> = (0..16).collect();
        let p1: Vec<i32> = (16..32).collect();
        let (lb, _) = e.prefill(&inst, &[p0.clone(), p1]).unwrap();
        let (ls, _) = e.prefill(&inst, &[p0]).unwrap();
        let max_diff = lb[0]
            .iter()
            .zip(&ls[0])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "batching changed numerics: {max_diff}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let Some(e) = engine() else { return };
        assert!(e.instance(99).is_err());
        let inst = e.instance(0).unwrap();
        assert!(e.prefill(&inst, &[]).is_err());
        let too_long = vec![vec![0i32; 4096]];
        assert!(e.prefill(&inst, &too_long).is_err());
    }
}
