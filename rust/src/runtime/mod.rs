//! Real PJRT data plane (never simulated): loads the HLO-text artifacts
//! produced by `python/compile/aot.py`, compiles them on the PJRT CPU
//! client, and serves the tiny-Llama LoRA model with a genuinely shared
//! backbone (one buffer set, Arc-refcounted) and isolated per-function
//! adapter buffers + KV caches — the §4.4 design running for real.

pub mod engine;
pub mod manifest;
pub mod server;
pub mod weights;

pub use engine::{Engine, EngineProfile, FunctionInstance, KvState};
pub use manifest::{ArtifactKind, Manifest};
pub use weights::SharedBackbone;
