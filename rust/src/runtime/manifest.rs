//! Parse `artifacts/<model>/manifest.json` written by `python/compile/aot.py`
//! — the single source of truth the Rust runtime shares with the L2 code:
//! model config, parameter layout (order + shapes), artifact inventory
//! (HLO-text files per batch/seq bucket) and cross-language goldens.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub param_count: usize,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Prefill,
    Decode,
}

#[derive(Debug, Clone)]
pub struct HloArtifact {
    pub name: String,
    pub kind: ArtifactKind,
    pub batch: usize,
    pub seq: usize,
    pub file: PathBuf,
}

#[derive(Debug, Clone)]
pub struct Golden {
    pub adapter: usize,
    pub batch: usize,
    pub seq: usize,
    pub prefill_logits_head: Vec<f64>,
    pub prefill_argmax: Vec<usize>,
    pub decode_logits_head: Vec<f64>,
    pub decode_argmax: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub lora_rank: usize,
    pub lora_scale: f64,
    pub n_adapters: usize,
    pub batch_buckets: Vec<usize>,
    pub seq_buckets: Vec<usize>,
    pub backbone_params: Vec<ParamSpec>,
    pub adapter_params: Vec<ParamSpec>,
    pub artifacts: Vec<HloArtifact>,
    pub goldens: Vec<Golden>,
}

fn specs(j: &Json) -> Result<Vec<ParamSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of param specs"))?
        .iter()
        .map(|s| {
            Ok(ParamSpec {
                name: s
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("param name"))?
                    .to_string(),
                shape: s
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("param shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
            })
        })
        .collect()
}

fn f64s(j: &Json) -> Vec<f64> {
    j.as_arr()
        .map(|a| a.iter().filter_map(Json::as_f64).collect())
        .unwrap_or_default()
}

fn usizes(j: &Json) -> Vec<usize> {
    j.as_arr()
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let cfg = j.get("config").ok_or_else(|| anyhow!("missing config"))?;
        let u = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("config.{k}"))
        };
        let dims = ModelDims {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            d_ff: u("d_ff")?,
            max_seq: u("max_seq")?,
            head_dim: u("head_dim")?,
            param_count: u("param_count")?,
        };
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifacts"))?
            .iter()
            .map(|a| {
                let kind = match a.get("kind").and_then(Json::as_str) {
                    Some("prefill") => ArtifactKind::Prefill,
                    Some("decode") => ArtifactKind::Decode,
                    k => return Err(anyhow!("unknown artifact kind {k:?}")),
                };
                Ok(HloArtifact {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    kind,
                    batch: a.get("batch").and_then(Json::as_usize).unwrap_or(1),
                    seq: a.get("seq").and_then(Json::as_usize).unwrap_or(0),
                    file: dir.join(a.get("file").and_then(Json::as_str).unwrap_or("")),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let goldens = j
            .get("goldens")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .map(|g| Golden {
                adapter: g.get("adapter").and_then(Json::as_usize).unwrap_or(0),
                batch: g.get("batch").and_then(Json::as_usize).unwrap_or(1),
                seq: g.get("seq").and_then(Json::as_usize).unwrap_or(16),
                prefill_logits_head: g
                    .get("prefill_logits_head")
                    .map(f64s)
                    .unwrap_or_default(),
                prefill_argmax: g.get("prefill_argmax").map(usizes).unwrap_or_default(),
                decode_logits_head: g
                    .get("decode_logits_head")
                    .map(f64s)
                    .unwrap_or_default(),
                decode_argmax: g.get("decode_argmax").map(usizes).unwrap_or_default(),
            })
            .collect();
        Ok(Manifest {
            model: j
                .get("model")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            dims,
            lora_rank: j
                .get("lora")
                .and_then(|l| l.get("rank"))
                .and_then(Json::as_usize)
                .unwrap_or(8),
            lora_scale: j
                .get("lora")
                .and_then(|l| l.get("scale"))
                .and_then(Json::as_f64)
                .unwrap_or(2.0),
            n_adapters: j.get("n_adapters").and_then(Json::as_usize).unwrap_or(0),
            batch_buckets: j.get("batch_buckets").map(usizes).unwrap_or_default(),
            seq_buckets: j.get("seq_buckets").map(usizes).unwrap_or_default(),
            backbone_params: specs(j.get("backbone_params").ok_or_else(|| anyhow!("bb"))?)?,
            adapter_params: specs(j.get("adapter_params").ok_or_else(|| anyhow!("ad"))?)?,
            artifacts,
            goldens,
            dir,
        })
    }

    /// Default artifact directory for a model name, resolved relative to
    /// the crate root (works from tests, benches and examples).
    pub fn default_dir(model: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(model)
    }

    pub fn find(&self, kind: ArtifactKind, batch: usize, seq: Option<usize>) -> Option<&HloArtifact> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.batch == batch && seq.map_or(true, |s| a.seq == s))
    }

    /// Smallest batch bucket that fits `n` requests.
    pub fn batch_bucket(&self, n: usize) -> Option<usize> {
        self.batch_buckets.iter().copied().find(|&b| b >= n)
    }

    /// Smallest seq bucket that fits `len` tokens.
    pub fn seq_bucket(&self, len: usize) -> Option<usize> {
        self.seq_buckets.iter().copied().find(|&s| s >= len)
    }

    pub fn backbone_elements(&self) -> usize {
        self.backbone_params.iter().map(|p| p.elements()).sum()
    }

    pub fn adapter_elements(&self) -> usize {
        self.adapter_params.iter().map(|p| p.elements()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir("llama-tiny");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).expect("manifest parses"))
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.model, "llama-tiny");
        assert_eq!(m.dims.d_model, 256);
        assert_eq!(m.dims.n_layers, 4);
        assert_eq!(m.backbone_params.len(), 1 + 9 * 4 + 2);
        assert_eq!(m.adapter_params.len(), 8 * 4);
        assert_eq!(m.n_adapters, 4);
        assert!(!m.goldens.is_empty());
    }

    #[test]
    fn param_count_consistent() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.backbone_elements(), m.dims.param_count);
    }

    #[test]
    fn artifact_files_exist() {
        let Some(m) = manifest() else { return };
        for a in &m.artifacts {
            assert!(a.file.exists(), "{} missing", a.file.display());
        }
    }

    #[test]
    fn bucket_selection() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.batch_bucket(1), Some(1));
        assert_eq!(m.batch_bucket(3), Some(4));
        assert_eq!(m.batch_bucket(1000), None);
        assert_eq!(m.seq_bucket(10), Some(16));
        assert_eq!(m.seq_bucket(17), Some(64));
    }

    #[test]
    fn find_artifacts() {
        let Some(m) = manifest() else { return };
        assert!(m.find(ArtifactKind::Prefill, 1, Some(16)).is_some());
        assert!(m.find(ArtifactKind::Decode, 1, None).is_some());
        assert!(m.find(ArtifactKind::Prefill, 999, None).is_none());
    }
}
