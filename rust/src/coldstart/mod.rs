//! Cold-start strategy subsystem — the *spec* side of the sixth policy
//! axis (see `coordinator::policy::ColdStartPolicy` for the trait and
//! DESIGN.md "Cold-start strategies" for the design).
//!
//! A cold-start strategy owns the plan for bringing a cold function up:
//!
//! * **Tiered** (default) — today's segmented tiered load, bit-for-bit.
//!   `cold_start: None` in `SystemConfig` selects it implicitly and
//!   performs zero additional work — the same dormancy discipline as
//!   `tiers: None` and `faults: None`.
//! * **SnapshotRestore** — SnapStart + memfd: after a function's first
//!   full load a snapshot is built into the node's host cache; later
//!   cold starts pay a near-constant restore instead of the tiered
//!   walk, bought with a snapshot-*storage* billing surcharge.
//! * **Pipelined** — HydraServe/ParaServe: a backbone cold load splits
//!   across K nodes as concurrent flows, prefill overlaps the tail of
//!   loading, and an explicit consolidation transfer pays the bytes
//!   back onto the target GPU.
//!
//! This module holds only plain data (kinds, parameter blocks, the
//! per-request `ColdPath` tag, and the snapshot-key interner); the
//! mechanism lives in `sim::coldstart` and the policy boxes in
//! `coordinator::policy`.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::artifact::params;

/// Which cold-start strategy a function class uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColdStartKind {
    /// The segmented tiered load (the pre-subsystem behaviour).
    Tiered,
    /// SnapStart-style snapshot build + near-constant restore.
    SnapshotRestore,
    /// K-way pipelined multi-GPU load with late consolidation.
    Pipelined,
}

impl ColdStartKind {
    /// Stable string ids (scenario JSON / CLI).
    pub const IDS: [&'static str; 3] = ["tiered", "snapshot-restore", "pipelined"];

    pub fn id(self) -> &'static str {
        match self {
            ColdStartKind::Tiered => "tiered",
            ColdStartKind::SnapshotRestore => "snapshot-restore",
            ColdStartKind::Pipelined => "pipelined",
        }
    }

    pub fn from_id(s: &str) -> Option<Self> {
        match s {
            "tiered" => Some(ColdStartKind::Tiered),
            "snapshot-restore" => Some(ColdStartKind::SnapshotRestore),
            "pipelined" => Some(ColdStartKind::Pipelined),
            _ => None,
        }
    }
}

/// SnapStart parameters: what a snapshot costs to build, to restore
/// from, and to keep resident.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SnapshotParams {
    /// Wall time to serialize a loaded function into its snapshot
    /// (memfd dump), measured from the load that seeded it.
    pub build_s: f64,
    /// Fixed restore overhead (process re-hydration) paid instead of
    /// container init + library import + JIT; the snapshot body still
    /// streams host RAM → HBM over PCIe.
    pub restore_s: f64,
    /// Storage surcharge for resident snapshot bytes, USD per GB·hour.
    /// Defaults to the host-memory price — a snapshot pins host RAM.
    pub storage_usd_per_gb_h: f64,
}

impl Default for SnapshotParams {
    fn default() -> Self {
        SnapshotParams {
            build_s: 2.0,
            restore_s: 0.5,
            storage_usd_per_gb_h: params::PRICE_MEM_GB_S * 3600.0,
        }
    }
}

/// Pipelined multi-GPU load parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineParams {
    /// Maximum pipeline width: the load splits across up to `k` nodes
    /// (the target plus `k-1` siblings with an idle up GPU). Effective
    /// width shrinks to what the cluster can offer; width 1 falls back
    /// to the tiered path.
    pub k: usize,
    /// Consolidation trigger: the transfer starts once
    /// `ceil(frac · siblings)` sibling shards have landed (1.0 = wait
    /// for all of them).
    pub consolidate_frac: f64,
}

impl Default for PipelineParams {
    fn default() -> Self {
        PipelineParams { k: 4, consolidate_frac: 1.0 }
    }
}

/// The full cold-start strategy configuration carried by
/// `SystemConfig::cold_start` / scenario JSON. Strategies can be mixed
/// per function class: the `head_fns` hottest functions (Zipf orders
/// functions hottest-first, so low ids are the head) use `head`, the
/// rest use `strategy`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ColdStartSpec {
    /// Strategy for every function (the tail, when `head` is set).
    pub strategy: ColdStartKind,
    /// Optional head-class override for function ids `< head_fns`.
    pub head: Option<ColdStartKind>,
    /// Size of the head class (ignored when `head` is `None`).
    pub head_fns: usize,
    pub snapshot: SnapshotParams,
    pub pipeline: PipelineParams,
}

impl Default for ColdStartSpec {
    fn default() -> Self {
        ColdStartSpec {
            strategy: ColdStartKind::Tiered,
            head: None,
            head_fns: 0,
            snapshot: SnapshotParams::default(),
            pipeline: PipelineParams::default(),
        }
    }
}

impl ColdStartSpec {
    /// All-functions single-strategy spec with default parameters.
    pub fn uniform(strategy: ColdStartKind) -> Self {
        ColdStartSpec { strategy, ..ColdStartSpec::default() }
    }

    /// The strategy class of one function id (head vs tail).
    pub fn strategy_for(&self, function: usize) -> ColdStartKind {
        match self.head {
            Some(h) if function < self.head_fns => h,
            _ => self.strategy,
        }
    }
}

/// Which path a request's batch took through the cold-start machinery —
/// exported per request on `RequestOutcome` and the trace sink.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ColdPath {
    /// No cold phase at all: the function was warm on its GPU.
    #[default]
    Warm,
    /// The segmented tiered load (the default cold path).
    Tiered,
    /// Restored from a host-resident snapshot.
    SnapshotRestore,
    /// K-way pipelined load with consolidation.
    Pipelined,
}

impl ColdPath {
    pub fn name(self) -> &'static str {
        match self {
            ColdPath::Warm => "warm",
            ColdPath::Tiered => "tiered",
            ColdPath::SnapshotRestore => "snapshot-restore",
            ColdPath::Pipelined => "pipelined",
        }
    }
}

/// Intern the host-cache key of one function's snapshot. `HostCache`
/// keys are `&'static str`; function names are bounded by the
/// deployment (one key per function), so leaking each distinct key once
/// keeps the map — and the leak — bounded.
pub fn snap_key(function_name: &str) -> &'static str {
    static KEYS: OnceLock<Mutex<BTreeMap<String, &'static str>>> = OnceLock::new();
    let mut keys = KEYS.get_or_init(|| Mutex::new(BTreeMap::new())).lock().unwrap();
    if let Some(&k) = keys.get(function_name) {
        return k;
    }
    let leaked: &'static str = Box::leak(format!("snap:{function_name}").into_boxed_str());
    keys.insert(function_name.to_string(), leaked);
    leaked
}

/// Prefix shared by every snapshot key — the billing surcharge and the
/// invariants tell snapshot bytes from model checkpoints with it.
pub const SNAP_PREFIX: &str = "snap:";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_ids_round_trip() {
        for (i, id) in ColdStartKind::IDS.iter().enumerate() {
            let k = ColdStartKind::from_id(id).expect("listed id parses");
            assert_eq!(k.id(), *id);
            // IDS order is the enum order (scenario docs rely on it).
            let by_order = [
                ColdStartKind::Tiered,
                ColdStartKind::SnapshotRestore,
                ColdStartKind::Pipelined,
            ][i];
            assert_eq!(k, by_order);
        }
        assert_eq!(ColdStartKind::from_id("nope"), None);
    }

    #[test]
    fn head_tail_mixing_splits_on_head_fns() {
        let spec = ColdStartSpec {
            strategy: ColdStartKind::Pipelined,
            head: Some(ColdStartKind::SnapshotRestore),
            head_fns: 2,
            ..ColdStartSpec::default()
        };
        assert_eq!(spec.strategy_for(0), ColdStartKind::SnapshotRestore);
        assert_eq!(spec.strategy_for(1), ColdStartKind::SnapshotRestore);
        assert_eq!(spec.strategy_for(2), ColdStartKind::Pipelined);
        // No head class: everything is the tail strategy.
        let uni = ColdStartSpec::uniform(ColdStartKind::SnapshotRestore);
        assert_eq!(uni.strategy_for(0), ColdStartKind::SnapshotRestore);
        assert_eq!(uni.strategy_for(99), ColdStartKind::SnapshotRestore);
    }

    #[test]
    fn snap_keys_intern_stably() {
        let a = snap_key("llama2-7b-lora0");
        let b = snap_key("llama2-7b-lora0");
        let c = snap_key("llama2-7b-lora1");
        assert!(std::ptr::eq(a, b), "same name must intern to the same key");
        assert_eq!(a, "snap:llama2-7b-lora0");
        assert_ne!(a, c);
        assert!(a.starts_with(SNAP_PREFIX) && c.starts_with(SNAP_PREFIX));
    }

    #[test]
    fn defaults_are_sane() {
        let s = SnapshotParams::default();
        assert!(s.build_s > 0.0 && s.restore_s > 0.0 && s.storage_usd_per_gb_h > 0.0);
        let p = PipelineParams::default();
        assert!(p.k >= 2 && p.consolidate_frac > 0.0 && p.consolidate_frac <= 1.0);
        assert_eq!(ColdStartSpec::default().strategy, ColdStartKind::Tiered);
        assert_eq!(ColdPath::default(), ColdPath::Warm);
    }
}
