//! Processor-sharing GPU executor — the generalisation of Eq. 4.
//!
//! The paper models contention as: M batches sharing a GPU each stretch to
//! M·T_i(b).  A discrete-event simulator needs the continuous version: the
//! GPU is a processor-sharing server; each active job owns `work` seconds
//! of dedicated GPU time and progresses at rate 1/M while M jobs are
//! active.  With a constant job set this reduces exactly to Eq. 4.

use std::collections::BTreeMap;

/// Processor-sharing executor for one GPU, with per-job weights.
///
/// Weighted generalisation: job i progresses at rate w_i / Σw. With all
/// weights 1 this is exactly Eq. 4. The engine gives decode jobs a lower
/// weight than prefill jobs (`DECODE_WEIGHT`): decode is memory-bound and
/// interleaves with an incoming prefill at iteration granularity, so it
/// contends far less than a second compute-bound prefill would.
#[derive(Debug, Clone)]
pub struct GpuExec {
    /// job id → (remaining dedicated-GPU seconds, weight).
    jobs: BTreeMap<u64, (f64, f64)>,
    last_update_s: f64,
    /// Service rate of the whole server (degraded-mode fault injection):
    /// all jobs progress at `rate × w_i / Σw`. 1.0 = healthy. At exactly
    /// 1.0 every expression below multiplies or divides by 1.0 — an IEEE
    /// identity — so a never-degraded run is bit-identical to the
    /// pre-degrade build.
    rate: f64,
}

impl Default for GpuExec {
    fn default() -> Self {
        GpuExec { jobs: BTreeMap::new(), last_update_s: 0.0, rate: 1.0 }
    }
}

/// Relative PS weight of a decode-phase job vs a prefill-phase job.
pub const DECODE_WEIGHT: f64 = 0.4;

impl GpuExec {
    fn total_weight(&self) -> f64 {
        self.jobs.values().map(|&(_, w)| w).sum()
    }

    /// Advance all jobs' progress to `now`.
    fn advance(&mut self, now_s: f64) {
        let total = self.total_weight();
        if total > 0.0 {
            let dt = (now_s - self.last_update_s).max(0.0);
            for (r, w) in self.jobs.values_mut() {
                *r -= dt * self.rate * *w / total;
            }
        }
        self.last_update_s = now_s;
    }

    /// Add a job with `work` seconds of dedicated GPU time at weight 1.
    pub fn add(&mut self, now_s: f64, job: u64, work_s: f64) {
        self.add_weighted(now_s, job, work_s, 1.0);
    }

    pub fn add_weighted(&mut self, now_s: f64, job: u64, work_s: f64, weight: f64) {
        debug_assert!(weight > 0.0);
        self.advance(now_s);
        self.jobs.insert(job, (work_s.max(0.0), weight));
    }

    /// Remove a job (completion or cancellation).
    pub fn remove(&mut self, now_s: f64, job: u64) -> Option<f64> {
        self.advance(now_s);
        self.jobs.remove(&job).map(|(r, _)| r)
    }

    /// Number of active jobs (the instantaneous contention M).
    pub fn contention(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_active(&self) -> bool {
        !self.jobs.is_empty()
    }

    /// The next job to finish and its wall-clock completion time, under
    /// the current job set.
    pub fn next_completion(&self) -> Option<(u64, f64)> {
        let total = self.total_weight();
        self.jobs
            .iter()
            .min_by(|a, b| (a.1 .0 / a.1 .1).total_cmp(&(b.1 .0 / b.1 .1)))
            .map(|(&id, &(rem, w))| {
                (id, self.last_update_s + (rem.max(0.0) / w) * total / self.rate)
            })
    }

    /// Change the server's service rate at `now`. Progress up to `now` is
    /// settled at the old rate first, so a rate change never rewrites
    /// history — only the future slope.
    pub fn set_rate(&mut self, now_s: f64, rate: f64) {
        debug_assert!(rate > 0.0);
        self.advance(now_s);
        self.rate = rate;
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Complete `job` unconditionally at `now`, returning true if it was
    /// present. The engine calls this when a completion tick finds the
    /// job it was scheduled for still carrying residual work above the
    /// sweep epsilon: `next_completion` computes the completion instant
    /// with a different floating-point expression than `advance`
    /// subtracts, so at large magnitudes the residue can exceed the
    /// absolute `1e-9` threshold and the engine would otherwise
    /// re-schedule a same-time tick forever. The job was scheduled to
    /// finish at this instant, so it finishes.
    pub fn force_complete(&mut self, now_s: f64, job: u64) -> bool {
        self.remove(now_s, job).is_some()
    }

    /// Jobs whose remaining work is ~zero at `now` (completion sweep).
    pub fn finished_at(&mut self, now_s: f64) -> Vec<u64> {
        self.advance(now_s);
        let done: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, &(r, _))| r <= 1e-9)
            .map(|(&id, _)| id)
            .collect();
        for id in &done {
            self.jobs.remove(id);
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_job_runs_at_full_rate() {
        let mut e = GpuExec::default();
        e.add(0.0, 1, 2.0);
        assert_eq!(e.next_completion(), Some((1, 2.0)));
        assert_eq!(e.finished_at(2.0), vec![1]);
        assert!(!e.is_active());
    }

    #[test]
    fn eq4_two_jobs_double_latency() {
        // Two equal jobs started together: each takes 2 × its work.
        let mut e = GpuExec::default();
        e.add(0.0, 1, 1.0);
        e.add(0.0, 2, 1.0);
        let (_, t) = e.next_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
        let done = e.finished_at(2.0);
        assert_eq!(done.len(), 2); // both finish together
    }

    #[test]
    fn eq4_m_jobs_m_x_latency() {
        let mut e = GpuExec::default();
        for i in 0..4 {
            e.add(0.0, i, 1.0);
        }
        assert!((e.next_completion().unwrap().1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn late_arrival_slows_survivor() {
        // Job 1 (2 s) alone for 1 s, then job 2 (0.5 s) joins:
        // job1 has 1 s left, runs at 1/2 ⇒ job2 (0.5 left at 1/2 = 1 s
        // wall) finishes at t=2; job1 then has 0.5 left alone ⇒ t=2.5.
        let mut e = GpuExec::default();
        e.add(0.0, 1, 2.0);
        e.add(1.0, 2, 0.5);
        let (id, t) = e.next_completion().unwrap();
        assert_eq!(id, 2);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
        assert_eq!(e.finished_at(2.0), vec![2]);
        let (id, t) = e.next_completion().unwrap();
        assert_eq!(id, 1);
        assert!((t - 2.5).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn weighted_sharing_favors_heavy_job() {
        // Prefill (w=1) beside a decode (w=0.4): prefill runs at
        // 1/1.4 ≈ 0.71 of full rate, not 0.5.
        let mut e = GpuExec::default();
        e.add_weighted(0.0, 1, 1.0, 1.0);
        e.add_weighted(0.0, 2, 10.0, DECODE_WEIGHT);
        let (id, t) = e.next_completion().unwrap();
        assert_eq!(id, 1);
        assert!((t - 1.4).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn force_complete_breaks_float_drift_stall() {
        // Regression: at large work magnitudes the residue at the
        // scheduled completion instant can vastly exceed the absolute
        // 1e-9 sweep epsilon. A tick at such an instant used to find
        // nothing finished and re-schedule itself at the same time
        // forever; the engine now force-completes the scheduled job.
        let mut e = GpuExec::default();
        e.add(0.0, 1, 1e7);
        let (id, t) = e.next_completion().unwrap();
        assert_eq!(id, 1);
        // Adversarial drift: the tick lands a hair (1e-10 relative)
        // before the true completion — remaining ≈ 1e-3 s of work.
        let drift_t = t * (1.0 - 1e-10);
        assert!(e.finished_at(drift_t).is_empty(), "residue under epsilon");
        assert!(e.force_complete(drift_t, id));
        assert!(!e.is_active());
        assert!(!e.force_complete(drift_t, id), "already gone");
    }

    #[test]
    fn adversarial_weights_drain_under_tick_protocol() {
        // Emulate the engine's on_gpu_tick loop over a PS mix with
        // awkward weights/durations and late joiners: every iteration
        // must retire at least the scheduled job (sweep or force), and
        // the set must drain in a bounded number of ticks.
        let mut e = GpuExec::default();
        let jobs: [(u64, f64, f64); 5] = [
            (1, 1e6, 1.0),
            (2, 0.1 + 1e-13, DECODE_WEIGHT),
            (3, 1.0 / 3.0, 1.0 / 3.0),
            (4, 7.0 / 11.0, 0.123456789),
            (5, 1e-7, 0.999_999_9),
        ];
        for (id, work, w) in jobs {
            e.add_weighted(0.0, id, work, w);
        }
        e.add_weighted(0.05, 6, 2.5e5, 0.4);
        let mut steps = 0;
        while let Some((job, t)) = e.next_completion() {
            steps += 1;
            assert!(steps < 100, "tick loop stalled");
            let done = e.finished_at(t);
            if done.is_empty() {
                assert!(e.force_complete(t, job), "scheduled job must finish");
            }
        }
        assert!(!e.is_active());
    }

    #[test]
    fn degraded_rate_stretches_completion_and_restore_resumes() {
        // 2 s of work at rate 1; at t=1 the server degrades to rate 0.5:
        // 1 s of residual work now takes 2 s of wall time ⇒ done at t=3.
        let mut e = GpuExec::default();
        e.add(0.0, 1, 2.0);
        e.set_rate(1.0, 0.5);
        let (_, t) = e.next_completion().unwrap();
        assert!((t - 3.0).abs() < 1e-9, "t={t}");
        // Restore at t=2 (0.5 s of work left): finishes at t=2.5.
        e.set_rate(2.0, 1.0);
        let (_, t) = e.next_completion().unwrap();
        assert!((t - 2.5).abs() < 1e-9, "t={t}");
        assert_eq!(e.finished_at(2.5), vec![1]);
    }

    #[test]
    fn rate_one_is_exact_identity() {
        // Setting rate to exactly 1.0 must not perturb any stored float:
        // ×1.0 and ÷1.0 are IEEE identities, so the dormant degrade path
        // leaves fingerprints byte-identical.
        let mut a = GpuExec::default();
        let mut b = GpuExec::default();
        a.add(0.0, 1, 1.0 / 3.0);
        b.add(0.0, 1, 1.0 / 3.0);
        b.set_rate(0.0, 1.0);
        let (ia, ta) = a.next_completion().unwrap();
        let (ib, tb) = b.next_completion().unwrap();
        assert_eq!(ia, ib);
        assert_eq!(ta.to_bits(), tb.to_bits());
    }

    #[test]
    fn work_conservation() {
        // Total wall time to drain any job set equals total work,
        // regardless of arrival interleaving (single server, no idling).
        let mut e = GpuExec::default();
        e.add(0.0, 1, 1.0);
        e.add(0.0, 2, 2.0);
        e.add(0.0, 3, 3.0);
        let mut now = 0.0;
        let mut drained = vec![];
        while let Some((_, t)) = e.next_completion() {
            now = t;
            drained.extend(e.finished_at(t));
        }
        assert!((now - 6.0).abs() < 1e-9, "drain time {now}");
        assert_eq!(drained, vec![1, 2, 3]); // shortest-first under PS
    }
}
