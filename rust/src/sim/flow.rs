//! Fair-share (processor-sharing) link contention for tiered artifact
//! loads.
//!
//! Under the tiered store (`SystemConfig::tiers`), every bulk transfer of
//! a cold load is a *flow* on one `(node, link)` pair — NIC, NVMe, or
//! PCIe (`artifact::LinkKind`).  `N` concurrent flows on a link each get
//! `1/N` of its bandwidth, so a flow's *work* is measured in
//! **solo-seconds** (its uncontended duration at full bandwidth) and
//! drains at `dt / N` solo-seconds per wall-second.  Every membership
//! change (join or finish) re-times the completion of every other flow on
//! that link; the engine turns each [`Retime`] into an O(1)
//! `EventQueue::cancel` + fresh push.
//!
//! ## Exactness contract
//!
//! * A flow that is **alone for its whole life** completes at exactly the
//!   `nominal_end_s` the engine precomputed from the flat fold — the
//!   entry carries the nominal end verbatim and never passes it through
//!   arithmetic, so solo tiered loads are bit-identical to the flat
//!   fast path.  The first contending join invalidates it.
//! * A flow that finishes is removed **at its own scheduled event**
//!   without recomputing its remaining work — avoiding the
//!   `(r * n) / n` one-ulp round trip.
//! * Same-tick joins/finishes drain with `dt == 0.0`, an exact no-op
//!   (`x - 0.0 / n == x` for finite `x`), so event-tick collisions
//!   cannot perturb other flows.
//! * All state transitions are replayable: the test oracle re-integrates
//!   bandwidth shares epoch-by-epoch from the op history with the same
//!   left-to-right subtraction chain and must match bit-for-bit.
//!
//! Completion times are `now + remaining * N`.  `remaining` is clamped at
//! 0 for *scheduling* only (an `N`-way split can leave `-1 ulp` of work
//! on a flow whose end coincides with the draining event), never in the
//! drain itself — the oracle mirrors both choices.
//!
//! ## Degraded-mode boundary
//!
//! GPU degrade episodes (`sim/fault.rs`) deliberately do **not** re-time
//! flows: segmented tiered loads are DMA/link-bandwidth-bound, and SM
//! throttling slows compute, not the copy engines — so only exec ticks
//! and the flat (single-timer) load path stretch under a degrade factor
//! (see DESIGN.md "Correlated faults & degraded mode").

use crate::artifact::LinkKind;

/// A re-scheduled completion for a flow already in the event queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Retime {
    /// Batch whose `LoadDone` event moves.
    pub batch: u64,
    /// New absolute completion time.
    pub end_s: f64,
}

/// One in-flight transfer on a link.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowEntry {
    pub batch: u64,
    /// Solo-seconds of work left (uncontended duration remaining).
    pub remaining_s: f64,
    /// Last time this entry was drained to.
    pub updated_s: f64,
    /// Engine-precomputed exact end; `Some` only while the flow has never
    /// shared its link (see module docs).
    pub nominal_end_s: Option<f64>,
    /// The completion time currently scheduled in the event queue.
    pub scheduled_end_s: f64,
}

/// All link state of the cluster: `nodes × {Nic, Nvme, Pcie}` flow lists.
#[derive(Debug, Clone, Default)]
pub struct FlowNet {
    /// Indexed `node * LinkKind::COUNT + link.index()`.  Flows are kept
    /// in join order (deterministic: joins are driven by the event loop).
    links: Vec<Vec<FlowEntry>>,
}

impl FlowNet {
    pub fn new(node_count: usize) -> Self {
        FlowNet { links: vec![Vec::new(); node_count * LinkKind::COUNT] }
    }

    fn slot(node: usize, link: LinkKind) -> usize {
        node * LinkKind::COUNT + link.index()
    }

    pub fn active(&self, node: usize, link: LinkKind) -> usize {
        self.links[Self::slot(node, link)].len()
    }

    pub fn total_active(&self) -> usize {
        self.links.iter().map(|l| l.len()).sum()
    }

    /// Iterate every in-flight flow as `(node, link, entry)`.
    pub fn iter(&self) -> impl Iterator<Item = (usize, LinkKind, &FlowEntry)> {
        self.links.iter().enumerate().flat_map(|(slot, flows)| {
            let node = slot / LinkKind::COUNT;
            let link = LinkKind::ALL[slot % LinkKind::COUNT];
            flows.iter().map(move |f| (node, link, f))
        })
    }

    pub fn scheduled_end(&self, node: usize, link: LinkKind, batch: u64) -> Option<f64> {
        self.links[Self::slot(node, link)]
            .iter()
            .find(|f| f.batch == batch)
            .map(|f| f.scheduled_end_s)
    }

    /// Drain all flows on a link to `now` at the current `1/N` share.
    /// Exact no-op for `dt == 0` (same-tick events).
    fn drain(flows: &mut [FlowEntry], now_s: f64) {
        let n = flows.len() as f64;
        for f in flows.iter_mut() {
            let dt = now_s - f.updated_s;
            if dt > 0.0 {
                f.remaining_s -= dt / n;
            }
            f.updated_s = now_s;
        }
    }

    /// A new transfer of `solo_s` uncontended seconds starts on
    /// `(node, link)` at `now_s`.  `nominal_end_s` is the engine's exact
    /// flat-fold completion time, honored verbatim iff the flow has the
    /// link to itself.  Returns the joiner's scheduled end plus a
    /// [`Retime`] for every displaced neighbor.
    pub fn join(
        &mut self,
        node: usize,
        link: LinkKind,
        batch: u64,
        solo_s: f64,
        nominal_end_s: f64,
        now_s: f64,
    ) -> (f64, Vec<Retime>) {
        let flows = &mut self.links[Self::slot(node, link)];
        debug_assert!(
            !flows.iter().any(|f| f.batch == batch),
            "batch {batch} joined {link:?} twice"
        );
        Self::drain(flows, now_s);
        let alone = flows.is_empty();
        for f in flows.iter_mut() {
            f.nominal_end_s = None; // contended from this instant on
        }
        flows.push(FlowEntry {
            batch,
            remaining_s: solo_s,
            updated_s: now_s,
            nominal_end_s: if alone { Some(nominal_end_s) } else { None },
            scheduled_end_s: 0.0,
        });
        let n = flows.len() as f64;
        let mut my_end = 0.0;
        let mut retimes = Vec::with_capacity(flows.len() - 1);
        for f in flows.iter_mut() {
            let end = match f.nominal_end_s {
                Some(e) => e,
                None => now_s + f.remaining_s.max(0.0) * n,
            };
            f.scheduled_end_s = end;
            if f.batch == batch {
                my_end = end;
            } else {
                retimes.push(Retime { batch: f.batch, end_s: end });
            }
        }
        (my_end, retimes)
    }

    /// The scheduled completion event of `batch` fired: remove it (without
    /// recomputing its own remaining — see module docs) and re-time the
    /// survivors at their fatter share.  Returns whether the finished flow
    /// was still on its nominal (never-contended) schedule, plus the
    /// survivors' retimes.
    pub fn finish(
        &mut self,
        node: usize,
        link: LinkKind,
        batch: u64,
        now_s: f64,
    ) -> (bool, Vec<Retime>) {
        let flows = &mut self.links[Self::slot(node, link)];
        Self::drain(flows, now_s);
        let pos = flows
            .iter()
            .position(|f| f.batch == batch)
            .unwrap_or_else(|| panic!("finish of unknown flow: batch {batch} on {link:?}"));
        let was_nominal = flows[pos].nominal_end_s.is_some();
        flows.remove(pos);
        let n = flows.len() as f64;
        let mut retimes = Vec::with_capacity(flows.len());
        for f in flows.iter_mut() {
            // Survivors coexisted with the finisher, so their nominal
            // schedule is long gone.
            let end = now_s + f.remaining_s.max(0.0) * n;
            f.scheduled_end_s = end;
            retimes.push(Retime { batch: f.batch, end_s: end });
        }
        (was_nominal, retimes)
    }

    /// Structural invariants, `Cluster::check_index` style.  `now_s` is
    /// the engine clock: no flow may be scheduled in the past, drained
    /// into the future, or carry more than rounding-level negative work.
    pub fn check(&self, now_s: f64) {
        for (node, link, f) in self.iter() {
            assert!(
                f.updated_s <= now_s,
                "flow {} on node{node} {link:?} drained into the future",
                f.batch
            );
            assert!(
                f.scheduled_end_s >= now_s,
                "flow {} on node{node} {link:?} scheduled in the past \
                 ({} < {now_s})",
                f.batch,
                f.scheduled_end_s
            );
            assert!(
                f.remaining_s > -1e-9,
                "flow {} on node{node} {link:?} has {} solo-seconds left",
                f.batch,
                f.remaining_s
            );
            if let Some(nominal) = f.nominal_end_s {
                assert_eq!(
                    self.active(node, link),
                    1,
                    "nominal flow {} is sharing its link",
                    f.batch
                );
                assert_eq!(
                    nominal.to_bits(),
                    f.scheduled_end_s.to_bits(),
                    "nominal flow {} not scheduled at its nominal end",
                    f.batch
                );
            }
        }
    }
}

// ---------------------------------------------------------------- oracle

/// Brute-force re-integration of bandwidth shares from an op history —
/// the test oracle.  Structurally independent of [`FlowNet`]'s
/// incremental state: it recounts link membership per epoch from the
/// history and re-derives every drain, but uses the same left-to-right
/// subtraction chain, so agreement must be bit-exact.
#[cfg(test)]
pub mod oracle {
    use super::*;

    #[derive(Debug, Clone, Copy)]
    pub enum Op {
        Join { node: usize, link: LinkKind, batch: u64, solo_s: f64, nominal_end_s: f64 },
        Finish { node: usize, link: LinkKind, batch: u64 },
    }

    /// One history record: the op and the time it was applied.
    pub type Record = (f64, Op);

    /// Integrate the share history of `batch` on its link and return
    /// `(remaining_solo_s, expected_end_s, epochs)`.  For a finished flow
    /// the end is its `Finish` record's time; for an in-flight flow it is
    /// the completion the scheduler must currently have on the books —
    /// computed, like the scheduler does, *at the flow's last membership
    /// change* (never-contended flows keep their nominal end verbatim).
    /// `epochs` is the `(dt, n)` list the flow lived through — its
    /// drains.  Panics if the batch never joined.
    pub fn integrate(history: &[Record], batch: u64) -> (f64, f64, Vec<(f64, f64)>) {
        // Locate the join.
        let (join_idx, join_t, node, link, solo, nominal) = history
            .iter()
            .enumerate()
            .find_map(|(i, (t, op))| match *op {
                Op::Join { node, link, batch: b, solo_s, nominal_end_s } if b == batch => {
                    Some((i, *t, node, link, solo_s, nominal_end_s))
                }
                _ => None,
            })
            .expect("oracle: batch never joined");

        // Membership of the link at join time (before the join applies):
        // replay all earlier ops.
        let mut members: Vec<u64> = Vec::new();
        for (_, op) in &history[..join_idx] {
            match *op {
                Op::Join { node: n, link: l, batch: b, .. } if (n, l) == (node, link) => {
                    members.push(b)
                }
                Op::Finish { node: n, link: l, batch: b } if (n, l) == (node, link) => {
                    members.retain(|m| *m != b)
                }
                _ => {}
            }
        }
        let never_shared_at_join = members.is_empty();
        members.push(batch);

        // Walk epochs: every subsequent membership change on this link
        // closes an epoch of width dt shared n ways.
        let mut remaining = solo;
        let mut epochs: Vec<(f64, f64)> = Vec::new();
        let mut last_t = join_t;
        let mut contended = !never_shared_at_join;
        for (t, op) in &history[join_idx + 1..] {
            let relevant = match *op {
                Op::Join { node: n, link: l, .. } | Op::Finish { node: n, link: l, .. } => {
                    (n, l) == (node, link)
                }
            };
            if !relevant {
                continue;
            }
            let n = members.len() as f64;
            let dt = *t - last_t;
            if dt > 0.0 {
                remaining -= dt / n;
                epochs.push((dt, n));
            }
            last_t = *t;
            match *op {
                Op::Join { batch: b, .. } => {
                    members.push(b);
                    contended = true;
                }
                Op::Finish { batch: b, .. } => {
                    if b == batch {
                        // The flow's own completion: its end is this t.
                        return (remaining, *t, epochs);
                    }
                    members.retain(|m| *m != b);
                }
            }
        }
        // Still in flight: the scheduled end was last computed at
        // `last_t` with the membership of that instant.
        let n = members.len() as f64;
        let end = if !contended { nominal } else { last_t + remaining.max(0.0) * n };
        (remaining, end, epochs)
    }
}

#[cfg(test)]
mod tests {
    use super::oracle::{integrate, Op, Record};
    use super::*;

    const NIC: LinkKind = LinkKind::Nic;
    const NVME: LinkKind = LinkKind::Nvme;

    #[test]
    fn solo_flow_completes_at_the_nominal_end_verbatim() {
        let mut net = FlowNet::new(2);
        // The engine computes nominal ends by a *prefix-sum* fold
        // ((10 + a) + b), which can differ by an ulp from the
        // `now + solo` chain (10 + (a + b)) the contended path would use
        // — the nominal must be honored verbatim, not re-derived.
        let nominal = 10.0 + 13.5f64 / 5.0 + 4.0;
        let (end, retimes) = net.join(1, NVME, 7, 13.5 / 5.0 + 4.0, nominal, 10.0);
        assert_eq!(end.to_bits(), nominal.to_bits());
        assert!(retimes.is_empty());
        net.check(10.0);
        let (was_nominal, retimes) = net.finish(1, NVME, 7, end);
        assert!(was_nominal && retimes.is_empty());
        assert_eq!(net.total_active(), 0);
    }

    #[test]
    fn two_flows_halve_the_link_and_retimes_stretch_them() {
        let mut net = FlowNet::new(1);
        let (e1, _) = net.join(0, NIC, 1, 10.0, 10.0, 0.0);
        assert_eq!(e1, 10.0);
        // Second flow joins at t=4: flow 1 has 6 solo-seconds left, now
        // at half bandwidth → ends at 4 + 6*2 = 16.  Joiner: 4 + 8*2 = 20.
        let (e2, retimes) = net.join(0, NIC, 2, 8.0, 12.0, 4.0);
        assert_eq!(e2, 20.0);
        assert_eq!(retimes, vec![Retime { batch: 1, end_s: 16.0 }]);
        net.check(4.0);
        // Flow 1 finishes at 16; flow 2 drained 12/2 = 6 of its 8, so it
        // runs solo from 16 with 2 left → 18.
        let (was_nominal, retimes) = net.finish(0, NIC, 1, 16.0);
        assert!(!was_nominal);
        assert_eq!(retimes, vec![Retime { batch: 2, end_s: 18.0 }]);
        net.check(16.0);
        let (was_nominal, _) = net.finish(0, NIC, 2, 18.0);
        assert!(!was_nominal); // it shared its link once — never nominal again
    }

    #[test]
    fn links_and_nodes_are_independent() {
        let mut net = FlowNet::new(2);
        let (e1, r1) = net.join(0, NIC, 1, 5.0, 5.0, 0.0);
        let (e2, r2) = net.join(0, NVME, 2, 5.0, 5.0, 0.0);
        let (e3, r3) = net.join(1, NIC, 3, 5.0, 5.0, 0.0);
        // Three solo flows: same wall times, no cross-talk.
        assert_eq!((e1, e2, e3), (5.0, 5.0, 5.0));
        assert!(r1.is_empty() && r2.is_empty() && r3.is_empty());
        assert_eq!(net.active(0, NIC), 1);
        assert_eq!(net.total_active(), 3);
        net.check(0.0);
    }

    #[test]
    fn same_tick_join_and_finish_do_not_perturb_neighbors() {
        let mut net = FlowNet::new(1);
        net.join(0, NIC, 1, 10.0, 10.0, 0.0);
        net.join(0, NIC, 2, 10.0, 10.0, 0.0); // both end at 20
        // At t=20 flow 1's event fires first (lower seq).  Its same-tick
        // finish drains flow 2 by exactly dt/2 with dt computed from the
        // *previous* drain point: 20/2 = 10 → remaining exactly 0.
        let (_, retimes) = net.finish(0, NIC, 1, 20.0);
        assert_eq!(retimes, vec![Retime { batch: 2, end_s: 20.0 }]);
        // A same-tick join at 20 must not shift flow 2's (zero) remainder.
        let (_, retimes) = net.join(0, NIC, 3, 4.0, 24.0, 20.0);
        assert_eq!(retimes, vec![Retime { batch: 2, end_s: 20.0 }]);
        net.check(20.0);
        let (_, retimes) = net.finish(0, NIC, 2, 20.0);
        // Flow 3 alone again: 20 + 4*1 = 24, recomputed (not nominal).
        assert_eq!(retimes, vec![Retime { batch: 3, end_s: 24.0 }]);
        let (was_nominal, _) = net.finish(0, NIC, 3, 24.0);
        assert!(!was_nominal);
        assert_eq!(net.total_active(), 0);
    }

    #[test]
    fn four_way_contention_stretches_each_flow_toward_4x() {
        let mut net = FlowNet::new(1);
        let mut ends = Vec::new();
        for b in 0..4u64 {
            let (end, _) = net.join(0, NVME, b, 10.0, 10.0, 0.0);
            ends.push(end);
        }
        // All four join at t=0: each sees 10 * n at its own join time.
        assert_eq!(ends, vec![10.0, 20.0, 30.0, 40.0]);
        // The last join leaves every flow scheduled at 0 + 10*4 = 40.
        for b in 0..4u64 {
            assert_eq!(net.scheduled_end(0, NVME, b), Some(40.0));
        }
        net.check(0.0);
    }

    /// Deterministic xorshift for the property tests (no external rng).
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
        fn f01(&mut self) -> f64 {
            (self.next() >> 11) as f64 / (1u64 << 53) as f64
        }
        fn below(&mut self, n: u64) -> u64 {
            self.next() % n
        }
    }

    /// Randomized mini-DES: flows arrive on random (node, link) pairs;
    /// completions fire in (t, insertion) order.  Every completion and
    /// every in-flight schedule must match the oracle's re-integration of
    /// the recorded history bit-for-bit, and every completed flow must
    /// have drained exactly its solo work (byte conservation).
    #[test]
    fn random_histories_match_oracle_bitwise_and_conserve_bytes() {
        for seed in [1u64, 7, 23] {
            let mut rng = Lcg(seed);
            let nodes = 2usize;
            let mut net = FlowNet::new(nodes);
            let mut history: Vec<Record> = Vec::new();

            // Pending arrivals, pre-sorted by time.
            let mut arrivals: Vec<(f64, usize, LinkKind, u64, f64)> = (0..40u64)
                .map(|b| {
                    let t = rng.f01() * 50.0;
                    let node = rng.below(nodes as u64) as usize;
                    let link = LinkKind::ALL[rng.below(3) as usize];
                    let solo = 0.5 + rng.f01() * 9.5;
                    (t, node, link, b, solo)
                })
                .collect();
            arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.3.cmp(&b.3)));
            arrivals.reverse(); // pop() takes the earliest

            // Active completions: (end_s, seq, node, link, batch).  Linear
            // scan for the minimum keeps (t, seq) ordering explicit.
            let mut active: Vec<(f64, u64, usize, LinkKind, u64)> = Vec::new();
            let mut seq = 0u64;
            let mut solo_of = std::collections::BTreeMap::new();
            let mut completions = 0u64;

            loop {
                let next_arrival = arrivals.last().map(|a| a.0);
                let next_done = active
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0).then(a.1 .1.cmp(&b.1 .1)))
                    .map(|(i, c)| (i, *c));
                let (t, is_arrival) = match (next_arrival, next_done) {
                    (None, None) => break,
                    (Some(ta), None) => (ta, true),
                    (None, Some((_, c))) => (c.0, false),
                    // Arrival and completion at the same instant: the
                    // completion event was pushed first, so it fires first.
                    (Some(ta), Some((_, c))) => {
                        if ta < c.0 {
                            (ta, true)
                        } else {
                            (c.0, false)
                        }
                    }
                };

                if is_arrival {
                    let (t, node, link, batch, solo) = arrivals.pop().unwrap();
                    let nominal = t + solo;
                    history.push((t, Op::Join { node, link, batch, solo_s: solo, nominal_end_s: nominal }));
                    let (end, retimes) = net.join(node, link, batch, solo, nominal, t);
                    solo_of.insert(batch, solo);
                    active.push((end, seq, node, link, batch));
                    seq += 1;
                    for r in retimes {
                        let slot =
                            active.iter_mut().find(|c| c.4 == r.batch).expect("retime target");
                        slot.0 = r.end_s;
                        slot.1 = seq; // cancel + repush ⇒ fresh, later seq
                        seq += 1;
                    }
                } else {
                    let (idx, (end, _, node, link, batch)) = next_done.unwrap();
                    active.swap_remove(idx);

                    // Oracle check BEFORE applying the finish: predicted
                    // end of this flow from history alone.
                    let (remaining, predicted, epochs) = integrate(&history, batch);
                    assert_eq!(
                        predicted.to_bits(),
                        end.to_bits(),
                        "seed {seed}: batch {batch} end mismatch"
                    );
                    // Byte conservation: drains + terminal remainder make
                    // up exactly the solo work (terminal remainder is the
                    // sub-ulp scheduling clamp residue).
                    let drained: f64 = epochs.iter().map(|(dt, n)| dt / n).sum();
                    let solo = solo_of[&batch];
                    assert!(
                        (drained - solo).abs() <= 1e-9 * solo.max(1.0) + remaining.abs(),
                        "seed {seed}: batch {batch} leaked bytes: drained {drained} of {solo}"
                    );

                    history.push((end, Op::Finish { node, link, batch }));
                    let (_, retimes) = net.finish(node, link, batch, end);
                    completions += 1;
                    for r in retimes {
                        let slot =
                            active.iter_mut().find(|c| c.4 == r.batch).expect("retime target");
                        slot.0 = r.end_s;
                        slot.1 = seq;
                        seq += 1;
                    }
                }

                net.check(t);
                // Every in-flight flow's incremental schedule must equal
                // the oracle's re-integration at this instant.
                for &(end, _, node, link, batch) in &active {
                    let (_, predicted, _) = integrate(&history, batch);
                    assert_eq!(
                        predicted.to_bits(),
                        end.to_bits(),
                        "seed {seed}: batch {batch} schedule drifted from oracle"
                    );
                    assert_eq!(net.scheduled_end(node, link, batch), Some(end));
                }
            }

            assert_eq!(completions, 40, "seed {seed}: lost flows");
            assert_eq!(net.total_active(), 0);
        }
    }

    /// Shape of a pipelined cold load (`sim::coldstart`): one backbone
    /// payload split into K equal slices, each streaming on its *own*
    /// node's NIC while random background traffic contends for the same
    /// links. Every slice's completion must match the oracle's
    /// re-integration bit-for-bit, and the slices together must drain
    /// exactly the payload's solo seconds — splitting never creates or
    /// destroys bytes.
    #[test]
    fn pipelined_k_way_slices_conserve_bytes_and_match_oracle() {
        for seed in [3u64, 11, 29] {
            let mut rng = Lcg(seed);
            let k = 2 + rng.below(4) as usize; // 2..=5 slices
            let mut net = FlowNet::new(k);
            let mut history: Vec<Record> = Vec::new();
            let total_solo = 5.0 + rng.f01() * 20.0; // payload at solo bw
            let slice = total_solo / k as f64;

            // Slices are batches 0..k, all joining at the same instant
            // (the coldstart module launches them in one event); the
            // background flows (ids 1000+) arrive throughout.
            let t0 = 0.25;
            let mut arrivals: Vec<(f64, usize, LinkKind, u64, f64)> = (0..k)
                .map(|i| (t0, i, NIC, i as u64, slice))
                .collect();
            for b in 0..12u64 {
                let t = rng.f01() * total_solo;
                let node = rng.below(k as u64) as usize;
                let solo = 0.5 + rng.f01() * 6.0;
                arrivals.push((t, node, NIC, 1000 + b, solo));
            }
            arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.3.cmp(&b.3)));
            arrivals.reverse();

            let mut active: Vec<(f64, u64, usize, LinkKind, u64)> = Vec::new();
            let mut seq = 0u64;
            let mut completions = 0u64;
            let mut sliced_drained = 0.0f64;
            let mut residue = 0.0f64;

            loop {
                let next_arrival = arrivals.last().map(|a| a.0);
                let next_done = active
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1 .0.total_cmp(&b.1 .0).then(a.1 .1.cmp(&b.1 .1)))
                    .map(|(i, c)| (i, *c));
                let (t, is_arrival) = match (next_arrival, next_done) {
                    (None, None) => break,
                    (Some(ta), None) => (ta, true),
                    (None, Some((_, c))) => (c.0, false),
                    (Some(ta), Some((_, c))) => {
                        if ta < c.0 {
                            (ta, true)
                        } else {
                            (c.0, false)
                        }
                    }
                };

                if is_arrival {
                    let (t, node, link, batch, solo) = arrivals.pop().unwrap();
                    let nominal = t + solo;
                    history.push((
                        t,
                        Op::Join { node, link, batch, solo_s: solo, nominal_end_s: nominal },
                    ));
                    let (end, retimes) = net.join(node, link, batch, solo, nominal, t);
                    active.push((end, seq, node, link, batch));
                    seq += 1;
                    for r in retimes {
                        let slot =
                            active.iter_mut().find(|c| c.4 == r.batch).expect("retime target");
                        slot.0 = r.end_s;
                        slot.1 = seq;
                        seq += 1;
                    }
                } else {
                    let (idx, (end, _, node, link, batch)) = next_done.unwrap();
                    active.swap_remove(idx);
                    let (remaining, predicted, epochs) = integrate(&history, batch);
                    assert_eq!(
                        predicted.to_bits(),
                        end.to_bits(),
                        "seed {seed}: flow {batch} end diverged from the oracle"
                    );
                    if batch < k as u64 {
                        sliced_drained += epochs.iter().map(|(dt, n)| dt / n).sum::<f64>();
                        residue += remaining.abs();
                    }
                    history.push((end, Op::Finish { node, link, batch }));
                    let (_, retimes) = net.finish(node, link, batch, end);
                    completions += 1;
                    for r in retimes {
                        let slot =
                            active.iter_mut().find(|c| c.4 == r.batch).expect("retime target");
                        slot.0 = r.end_s;
                        slot.1 = seq;
                        seq += 1;
                    }
                }
                net.check(t);
            }

            assert_eq!(completions, k as u64 + 12, "seed {seed}: lost flows");
            assert_eq!(net.total_active(), 0);
            // Conservation across the split: K slices of payload/K drain
            // the whole payload (up to the scheduler's sub-ulp clamp
            // residue per slice).
            assert!(
                (sliced_drained - total_solo).abs() <= 1e-9 * total_solo + residue,
                "seed {seed}: k={k} slices drained {sliced_drained} of {total_solo}"
            );
        }
    }
}
