//! Deterministic fault injection: GPU crash/recover schedules and
//! transient cold-load failures, plus the retry/timeout policy applied
//! to requests that hit them.
//!
//! The injector owns its own seeded RNG **stream** (`FAULT_STREAM`),
//! split off the run seed exactly like `OpportunisticPreload`'s policy
//! stream — so enabling faults never perturbs the workload's arrival
//! or token draws, and `faults: None` runs stay bit-identical to a
//! build without this module. Crash/repair gaps are exponential with
//! means `mtbf_s` / `mttr_s`; cold-load failures are Bernoulli with
//! probability `load_fail_prob`, drawn once per cold dispatch.
//!
//! Determinism under zone sharding: every zone engine is built with the
//! same run seed (`sim/sharded.rs`), so each zone's injector replays an
//! identical stream over its own GPUs in dense order — the sharded run
//! needs no cross-zone RNG coordination to stay reproducible.

use crate::cluster::GpuId;
use crate::util::rng::Pcg64;

/// Dedicated RNG stream for the fault injector, disjoint from the
/// workload stream (Pcg64 default) and the preload-policy stream.
pub const FAULT_STREAM: u64 = 0xfa_17_5e_ed;

/// Retry/timeout policy for requests that hit a transient fault.
///
/// A request whose cold load fails transiently is retried after a
/// bounded exponential backoff (`backoff_base_s · 2^attempt`, capped at
/// `backoff_cap_s`), at most `max_retries` times. Independently, any
/// request — including one re-dispatched after a GPU crash — fails
/// permanently once `deadline_s` has elapsed since its arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetrySpec {
    /// Maximum transient-failure retries before the request fails.
    pub max_retries: u32,
    /// First retry backoff (seconds); doubles per attempt.
    pub backoff_base_s: f64,
    /// Upper bound on any single backoff gap (seconds).
    pub backoff_cap_s: f64,
    /// Per-request deadline since arrival (seconds).
    pub deadline_s: f64,
}

impl Default for RetrySpec {
    fn default() -> Self {
        RetrySpec {
            max_retries: 3,
            backoff_base_s: 0.25,
            backoff_cap_s: 8.0,
            deadline_s: 120.0,
        }
    }
}

/// Fault-injection configuration. `SystemConfig::faults: None` (the
/// default) disables the subsystem entirely — no injector is built, no
/// RNG is drawn, no events are scheduled.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures per GPU (seconds, exponential).
    pub mtbf_s: f64,
    /// Mean time to repair per crash (seconds, exponential).
    pub mttr_s: f64,
    /// Probability a cold load fails transiently (drawn per dispatch).
    pub load_fail_prob: f64,
    /// Retry/timeout policy for faulted requests.
    pub retry: RetrySpec,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            mtbf_s: 1800.0,
            mttr_s: 30.0,
            load_fail_prob: 0.0,
            retry: RetrySpec::default(),
        }
    }
}

/// What happened — delivered to `Observer::on_fault`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEvent {
    /// A GPU went down: its in-flight batches were killed and their
    /// requests re-enqueued for re-dispatch.
    GpuCrash {
        gpu: GpuId,
        killed_batches: usize,
        redispatched: usize,
    },
    /// A GPU came back up (cold: residency was lost at crash time).
    GpuRecover { gpu: GpuId },
    /// A cold load failed transiently; the batch's requests enter the
    /// retry/backoff path.
    LoadFailure { gpu: GpuId, function: usize },
}

/// The injector: spec + its dedicated RNG stream. Owned by the engine,
/// present only when `SystemConfig::faults` is `Some`.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    pub spec: FaultSpec,
    rng: Pcg64,
}

impl FaultInjector {
    pub fn new(spec: FaultSpec, seed: u64) -> Self {
        FaultInjector {
            spec,
            rng: Pcg64::with_stream(seed, FAULT_STREAM),
        }
    }

    /// Gap until the next crash of an up GPU (exponential, mean MTBF).
    pub fn crash_delay_s(&mut self) -> f64 {
        self.rng.exp(1.0 / self.spec.mtbf_s)
    }

    /// Downtime of a crashed GPU (exponential, mean MTTR).
    pub fn repair_delay_s(&mut self) -> f64 {
        self.rng.exp(1.0 / self.spec.mttr_s)
    }

    /// Bernoulli draw: does this cold load fail transiently?
    pub fn load_fails(&mut self) -> bool {
        self.spec.load_fail_prob > 0.0 && self.rng.f64() < self.spec.load_fail_prob
    }

    /// Backoff before retry number `attempt` (0-based): bounded
    /// exponential, `base · 2^attempt` capped at `backoff_cap_s`.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        let r = &self.spec.retry;
        (r.backoff_base_s * 2f64.powi(attempt.min(62) as i32)).min(r.backoff_cap_s)
    }
}

// --------------------------------------------------------------------
// Engine-side fault mechanism: crash kills, residency invalidation,
// retry/backoff, permanent failure. Lives here (dispatch.rs-style
// `impl Engine` split) so the whole subsystem reads in one file.

use std::collections::BTreeMap;

use crate::artifact::ArtifactKind;
use crate::coordinator::Queued;
use crate::metrics::RequestOutcome;
use crate::sim::dispatch::BatchState;
use crate::sim::engine::Engine;
use crate::sim::events::EventKind;
use crate::trace::Request;

impl Engine {
    /// Schedule the first crash of every GPU (dense order — the draw
    /// order is part of the deterministic contract). Called once from
    /// `Engine::new`; a no-op when `cfg.faults` is `None`. Crashes past
    /// the workload horizon are not scheduled, so a faulted run still
    /// drains.
    pub(super) fn schedule_initial_crashes(&mut self) {
        if self.injector.is_none() {
            return;
        }
        for d in 0..self.gpu_map.len() {
            let g = self.gpu_map.id(d);
            let delay = self.injector.as_mut().unwrap().crash_delay_s();
            let t = self.now + delay;
            if t <= self.duration_s {
                self.events.push(t, EventKind::GpuCrash(g));
            }
        }
    }

    /// A GPU went down: kill its in-flight batches (requests re-enqueue
    /// for re-dispatch — no retry budget consumed, the failure was not
    /// theirs), invalidate everything resident on it, and schedule the
    /// repair. Routing sees the health flip immediately; billing
    /// reclassifies through the same O(1) machinery as any state change.
    pub(super) fn on_gpu_crash(&mut self, g: crate::cluster::GpuId) {
        self.stats.gpu_crashes += 1;
        self.cluster.set_gpu_health(g, false);
        // Repair is always scheduled (never horizon-gated): a down GPU
        // must come back up or the tail of the run serves degraded.
        let repair = self.injector.as_mut().expect("faults on").repair_delay_s();
        self.events.push(self.now + repair, EventKind::GpuRecover(g));
        let victims: Vec<u64> = self
            .batches
            .iter()
            .filter(|(_, b)| b.gpu == g)
            .map(|(&id, _)| id)
            .collect();
        let killed_batches = victims.len();
        let mut redispatched = 0usize;
        for id in victims {
            redispatched += self.kill_batch(id);
        }
        self.invalidate_gpu(g);
        self.emit_fault(FaultEvent::GpuCrash { gpu: g, killed_batches, redispatched });
        // The cluster's routable surface changed: blocked functions get
        // a retry, and the re-enqueued requests re-route to up GPUs.
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// The repair completed: the GPU is routable again (cold — its
    /// residency died with the crash) and, if the horizon allows, its
    /// next crash is drawn.
    pub(super) fn on_gpu_recover(&mut self, g: crate::cluster::GpuId) {
        self.stats.gpu_recoveries += 1;
        self.cluster.set_gpu_health(g, true);
        let next = self.injector.as_mut().expect("faults on").crash_delay_s();
        let t = self.now + next;
        if t <= self.duration_s {
            self.events.push(t, EventKind::GpuCrash(g));
        }
        self.emit_fault(FaultEvent::GpuRecover { gpu: g });
        // A fresh GPU may unblock memory-starved functions.
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Kill one in-flight batch on a crashing GPU, unwinding exactly the
    /// state its lifecycle stage holds: pending load events (flat token
    /// or segmented run + live flow), exec jobs, busy/loading counts, KV
    /// reservation, backbone attachment. Returns how many of its
    /// requests were re-enqueued (the rest failed their deadline).
    fn kill_batch(&mut self, batch_id: u64) -> usize {
        let batch = self.batches.remove(&batch_id).expect("batch exists");
        let gpu = batch.gpu;
        let f = batch.function;
        let d = self.gpu_map.dense(gpu);
        match batch.state {
            BatchState::Loading => {
                self.gpu_loading[d] -= 1;
                self.gpu_busy[d] -= 1;
                if let Some(tok) = batch.load_token {
                    self.events.cancel(tok);
                }
                if let Some(run) = self.load_runs.remove(&batch_id) {
                    if let Some(tok) = run.token {
                        self.events.cancel(tok);
                    }
                    // Mid-transfer: pull the flow off the link and
                    // re-time the survivors at their fatter share.
                    if let Some(link) = run.segs[run.cursor].link {
                        let (_, retimes) = self.flows.finish(run.node, link, batch_id, self.now);
                        self.apply_load_retimes(retimes);
                    }
                }
            }
            BatchState::Prefill => {
                self.gpu_busy[d] -= 1;
                self.execs[d].remove(self.now, batch_id);
                self.schedule_tick(gpu);
            }
            BatchState::Decode => {
                // Busy already dropped at the Prefill → Decode edge.
                self.execs[d].remove(self.now, batch_id);
                self.schedule_tick(gpu);
            }
        }
        self.fn_inflight[f] -= 1;
        self.cluster.gpu_mut(gpu).release_kv(batch_id);
        if batch.attached_backbone {
            let model = self.spec(f).model.name.to_string();
            let _ = self.registry.detach(
                &mut self.cluster,
                &crate::sharing::IpcHandle { model, gpu, function: f },
            );
        }
        self.reclassify_gpu(gpu);
        let deadline = self.injector.as_ref().expect("faults on").spec.retry.deadline_s;
        let mut redispatched = 0usize;
        for r in batch.requests {
            if self.now - r.arrival_s >= deadline {
                self.fail_request(&r);
            } else {
                self.queues[f].push(Queued { request: r.id, arrival_s: r.arrival_s });
                self.active.insert(f);
                redispatched += 1;
            }
        }
        self.stats.redispatched += redispatched as u64;
        self.arm_queue_wakeups(f);
        redispatched
    }

    /// Drop everything resident on a crashed GPU: private artifacts and
    /// CUDA contexts, shared backbone segments (refcounts are zero — the
    /// batches died first), and the node's host-RAM checkpoint cache
    /// (the crash takes the whole worker process down with it).
    /// Keep-alive warmth is *not* force-dropped: a function warm on a
    /// surviving GPU stays warm, and the billing warm counts reconcile
    /// through the same per-GPU residency journal as any eviction.
    fn invalidate_gpu(&mut self, g: crate::cluster::GpuId) {
        let mut fns: Vec<usize> = Vec::new();
        self.cluster.for_each_resident(g, |f| fns.push(f));
        for f in fns {
            let gpu = self.cluster.gpu_mut(g);
            let _ = gpu.evict_artifact(f, ArtifactKind::Adapter);
            let _ = gpu.evict_artifact(f, ArtifactKind::CudaKernel);
            let _ = gpu.evict_artifact(f, ArtifactKind::Backbone);
            gpu.destroy_cuda_context(f);
        }
        let models: Vec<&'static str> = self
            .model_peers
            .keys()
            .copied()
            .filter(|m| self.registry.hosts(m).contains(&g))
            .collect();
        for m in models {
            let _ = self.registry.unload(&mut self.cluster, m, g);
        }
        let cache = &mut self.cluster.nodes[g.node].cache;
        if cache.enabled() && cache.len() > 0 {
            let staged: Vec<&'static str> = cache.entries().map(|(m, _)| m).collect();
            for m in staged {
                cache.remove(m);
                self.stats.cache_evictions += 1;
            }
        }
    }

    /// A batch's cold load completed as a drawn transient failure: the
    /// batch dies without executing and its requests enter the
    /// retry/backoff path. Artifacts staged by the load *stay* resident
    /// (the bytes moved; what failed is the instance bring-up), so a
    /// retry typically finds them warm — the modeling choice that keeps
    /// the residency ledger append-only under faults.
    pub(super) fn on_load_failed(&mut self, batch_id: u64) {
        let batch = self.batches.remove(&batch_id).expect("batch exists");
        let gpu = batch.gpu;
        let f = batch.function;
        let d = self.gpu_map.dense(gpu);
        self.gpu_loading[d] -= 1;
        self.gpu_busy[d] -= 1;
        self.fn_inflight[f] -= 1;
        self.cluster.gpu_mut(gpu).release_kv(batch_id);
        if batch.attached_backbone {
            let model = self.spec(f).model.name.to_string();
            let _ = self.registry.detach(
                &mut self.cluster,
                &crate::sharing::IpcHandle { model, gpu, function: f },
            );
        }
        self.reclassify_gpu(gpu);
        self.stats.load_failures += 1;
        self.emit_fault(FaultEvent::LoadFailure { gpu, function: f });
        for r in batch.requests {
            self.fail_or_retry(r);
        }
        // KV freed: memory-blocked functions get their retry.
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Route a transiently-failed request: permanent failure when its
    /// deadline passed or its retry budget is spent, otherwise a
    /// `RetryWake` after the bounded exponential backoff.
    fn fail_or_retry(&mut self, req: Request) {
        let retry = self.injector.as_ref().expect("faults on").spec.retry;
        let attempt = self.retry_count.get(&req.id).copied().unwrap_or(0);
        if self.now - req.arrival_s >= retry.deadline_s || attempt >= retry.max_retries {
            return self.fail_request(&req);
        }
        self.retry_count.insert(req.id, attempt + 1);
        let backoff = self.injector.as_ref().expect("faults on").backoff_s(attempt);
        self.events.push(self.now + backoff, EventKind::RetryWake(req.id));
        self.retry_pending += 1;
        self.stats.retries += 1;
    }

    /// A retry backoff expired: re-enqueue the request (it keeps its
    /// original arrival time — deadlines and queue-wait metrics are
    /// measured from first arrival), unless its deadline lapsed while it
    /// slept.
    pub(super) fn on_retry_wake(&mut self, id: u64) {
        self.retry_pending -= 1;
        let req = self.requests[self.request_index[&id]].clone();
        let retry = self.injector.as_ref().expect("faults on").spec.retry;
        if self.now - req.arrival_s >= retry.deadline_s {
            return self.fail_request(&req);
        }
        let f = req.function;
        self.queues[f].push(Queued { request: id, arrival_s: req.arrival_s });
        self.active.insert(f);
        let armed = self.queue_wakeups[f];
        self.try_dispatch_all(Some(f));
        if self.queue_wakeups[f] == armed {
            self.arm_queue_wakeups(f);
        }
    }

    /// Permanent failure: deadline exceeded or retry budget exhausted.
    /// Counted (never silently dropped — the conservation invariant
    /// includes it) and surfaced to observers as a synthesized outcome
    /// with `e2e_s` = arrival → failure and no phases.
    pub(super) fn fail_request(&mut self, req: &Request) {
        self.stats.requests_failed += 1;
        self.metrics.failed += 1;
        self.retry_count.remove(&req.id);
        let outcome = RequestOutcome {
            id: req.id,
            function: req.function,
            arrival_s: req.arrival_s,
            phases: BTreeMap::new(),
            ttft_s: 0.0,
            tpot_s: 0.0,
            e2e_s: self.now - req.arrival_s,
            output_tokens: 0,
            batch_size: 0,
            backbone_tier: None,
        };
        self.emit_request_failed(&outcome);
    }

    pub(super) fn emit_fault(&mut self, event: FaultEvent) {
        if self.series.is_none() && self.observers.is_empty() {
            return;
        }
        let t = self.now;
        if let Some(s) = self.series.as_mut() {
            s.on_fault(t, &event);
        }
        for ob in &mut self.observers {
            ob.on_fault(t, &event);
        }
    }

    pub(super) fn emit_request_failed(&mut self, outcome: &RequestOutcome) {
        let t = self.now;
        if let Some(s) = self.series.as_mut() {
            s.on_request_failed(t, outcome);
        }
        for ob in &mut self.observers {
            ob.on_request_failed(t, outcome);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn injector_is_deterministic_per_seed() {
        let spec = FaultSpec { load_fail_prob: 0.3, ..FaultSpec::default() };
        let mut a = FaultInjector::new(spec, 42);
        let mut b = FaultInjector::new(spec, 42);
        for _ in 0..100 {
            assert_eq!(a.crash_delay_s().to_bits(), b.crash_delay_s().to_bits());
            assert_eq!(a.repair_delay_s().to_bits(), b.repair_delay_s().to_bits());
            assert_eq!(a.load_fails(), b.load_fails());
        }
        let mut c = FaultInjector::new(spec, 43);
        let differs = (0..100).any(|_| a.crash_delay_s().to_bits() != c.crash_delay_s().to_bits());
        assert!(differs, "different seeds must diverge");
    }

    #[test]
    fn crash_gap_mean_tracks_mtbf() {
        let spec = FaultSpec { mtbf_s: 600.0, mttr_s: 20.0, ..FaultSpec::default() };
        let mut inj = FaultInjector::new(spec, 7);
        let n = 20_000;
        let mean_crash: f64 = (0..n).map(|_| inj.crash_delay_s()).sum::<f64>() / n as f64;
        let mean_repair: f64 = (0..n).map(|_| inj.repair_delay_s()).sum::<f64>() / n as f64;
        assert!((mean_crash - 600.0).abs() < 30.0, "mean crash gap {mean_crash}");
        assert!((mean_repair - 20.0).abs() < 1.0, "mean repair gap {mean_repair}");
    }

    #[test]
    fn load_fail_prob_extremes() {
        let mut never = FaultInjector::new(
            FaultSpec { load_fail_prob: 0.0, ..FaultSpec::default() },
            1,
        );
        assert!((0..1000).all(|_| !never.load_fails()));
        let mut always = FaultInjector::new(
            FaultSpec { load_fail_prob: 1.0, ..FaultSpec::default() },
            1,
        );
        assert!((0..1000).all(|_| always.load_fails()));
    }

    #[test]
    fn backoff_doubles_then_caps() {
        let spec = FaultSpec {
            retry: RetrySpec {
                max_retries: 10,
                backoff_base_s: 0.5,
                backoff_cap_s: 3.0,
                deadline_s: 60.0,
            },
            ..FaultSpec::default()
        };
        let inj = FaultInjector::new(spec, 1);
        assert_eq!(inj.backoff_s(0), 0.5);
        assert_eq!(inj.backoff_s(1), 1.0);
        assert_eq!(inj.backoff_s(2), 2.0);
        assert_eq!(inj.backoff_s(3), 3.0, "capped");
        assert_eq!(inj.backoff_s(40), 3.0, "stays capped, no overflow");
    }

    #[test]
    fn fault_draws_share_one_stream_in_schedule_order() {
        // The injector is one stream: interleaving crash and load draws
        // consumes it in call order, which the single-threaded event
        // loop makes deterministic.
        let spec = FaultSpec { load_fail_prob: 0.5, ..FaultSpec::default() };
        let mut a = FaultInjector::new(spec, 9);
        let seq_a: Vec<u64> = (0..8).map(|_| a.crash_delay_s().to_bits()).collect();
        let mut b = FaultInjector::new(spec, 9);
        let _ = b.load_fails(); // one extra draw shifts everything after
        let seq_b: Vec<u64> = (0..8).map(|_| b.crash_delay_s().to_bits()).collect();
        assert_ne!(seq_a, seq_b);
    }
}
