//! Deterministic fault injection: GPU crash/recover schedules and
//! transient cold-load failures, plus the retry/timeout policy applied
//! to requests that hit them.
//!
//! The injector owns its own seeded RNG **stream** (`FAULT_STREAM`),
//! split off the run seed exactly like `OpportunisticPreload`'s policy
//! stream — so enabling faults never perturbs the workload's arrival
//! or token draws, and `faults: None` runs stay bit-identical to a
//! build without this module. Crash/repair gaps are exponential with
//! means `mtbf_s` / `mttr_s`; cold-load failures are Bernoulli with
//! probability `load_fail_prob`, drawn once per cold dispatch.
//!
//! Determinism under zone sharding: every zone engine is built with the
//! same run seed (`sim/sharded.rs`), so each zone's injector replays an
//! identical stream over its own GPUs in dense order — the sharded run
//! needs no cross-zone RNG coordination to stay reproducible.

use crate::cluster::GpuId;
use crate::util::rng::Pcg64;

/// Dedicated RNG stream for the fault injector, disjoint from the
/// workload stream (Pcg64 default) and the preload-policy stream.
pub const FAULT_STREAM: u64 = 0xfa_17_5e_ed;

/// Retry/timeout policy for requests that hit a transient fault.
///
/// A request whose cold load fails transiently is retried after a
/// bounded exponential backoff (`backoff_base_s · 2^attempt`, capped at
/// `backoff_cap_s`), at most `max_retries` times. Independently, any
/// request — including one re-dispatched after a GPU crash — fails
/// permanently once `deadline_s` has elapsed since its arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetrySpec {
    /// Maximum transient-failure retries before the request fails.
    pub max_retries: u32,
    /// First retry backoff (seconds); doubles per attempt.
    pub backoff_base_s: f64,
    /// Upper bound on any single backoff gap (seconds).
    pub backoff_cap_s: f64,
    /// Per-request deadline since arrival (seconds).
    pub deadline_s: f64,
}

impl Default for RetrySpec {
    fn default() -> Self {
        RetrySpec {
            max_retries: 3,
            backoff_base_s: 0.25,
            backoff_cap_s: 8.0,
            deadline_s: 120.0,
        }
    }
}

/// One correlated failure-domain level: exponential outage gaps (mean
/// `mtbf_s`) and repair times (mean `mttr_s`), drawn on the shared
/// fault stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DomainLevel {
    pub mtbf_s: f64,
    pub mttr_s: f64,
}

/// Correlated failure domains above single GPUs. A **node** outage
/// atomically takes down every GPU the node hosts and wipes its
/// host-RAM checkpoint cache once; a **zone** outage takes the engine's
/// whole cluster down (under zone sharding each zone engine is one
/// zone). Either level may be absent; `None` at a level draws nothing
/// from the stream, so a spec without it stays bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DomainSpec {
    pub node: Option<DomainLevel>,
    pub zone: Option<DomainLevel>,
}

/// Degraded-mode fault class: instead of dying, a GPU runs slow for a
/// while — the SM-throttling/ECC-retirement regime. Episodes recur per
/// GPU with exponential gaps (mean `mtbf_s`); each episode draws an
/// exponential duration (mean `duration_s`) and a uniform slowdown
/// factor in `[factor_min, factor_max]` (wall time of compute on the
/// GPU stretches by that factor). Degraded is not down: routing still
/// sees the GPU, billing classes are unchanged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradeSpec {
    /// Mean gap between degrade episodes per GPU (seconds).
    pub mtbf_s: f64,
    /// Mean episode duration (seconds, exponential).
    pub duration_s: f64,
    /// Slowdown factor range (≥ 1; uniform draw per episode).
    pub factor_min: f64,
    pub factor_max: f64,
}

impl Default for DegradeSpec {
    fn default() -> Self {
        DegradeSpec { mtbf_s: 3600.0, duration_s: 60.0, factor_min: 1.5, factor_max: 4.0 }
    }
}

/// Fault-injection configuration. `SystemConfig::faults: None` (the
/// default) disables the subsystem entirely — no injector is built, no
/// RNG is drawn, no events are scheduled. Every optional sub-spec
/// (`domains`, `degrade`) gates its own draws the same way, so a spec
/// without them replays the exact pre-domain stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures per GPU (seconds, exponential).
    pub mtbf_s: f64,
    /// Mean time to repair per crash (seconds, exponential).
    pub mttr_s: f64,
    /// Probability a cold load fails transiently (drawn per dispatch).
    pub load_fail_prob: f64,
    /// Retry/timeout policy for faulted requests.
    pub retry: RetrySpec,
    /// Correlated node/zone outages (`None` = GPU-level faults only).
    pub domains: Option<DomainSpec>,
    /// Degraded-mode episodes (`None` = GPUs never run slow).
    pub degrade: Option<DegradeSpec>,
    /// Let the router/preloader penalize crash-prone or degraded
    /// hardware (observed failure-history EWMA). Off by default: the
    /// penalty term is then exactly 0.0 and scores are bit-identical.
    pub failure_aware: bool,
    /// EWMA decay time constant for the crash history (seconds).
    pub failure_tau_s: f64,
    /// Router-score penalty (GB-equivalent units) per decayed crash and
    /// per unit of excess slowdown factor.
    pub failure_penalty_gb: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            mtbf_s: 1800.0,
            mttr_s: 30.0,
            load_fail_prob: 0.0,
            retry: RetrySpec::default(),
            domains: None,
            degrade: None,
            failure_aware: false,
            failure_tau_s: 600.0,
            failure_penalty_gb: 4.0,
        }
    }
}

/// What happened — delivered to `Observer::on_fault`. (`Eq` is off the
/// derive list because `GpuDegrade` carries its drawn f64 factor.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// A GPU went down: its in-flight batches were killed and their
    /// requests re-enqueued for re-dispatch.
    GpuCrash {
        gpu: GpuId,
        killed_batches: usize,
        redispatched: usize,
    },
    /// A GPU came back up (cold: residency was lost at crash time).
    GpuRecover { gpu: GpuId },
    /// A cold load failed transiently; the batch's requests enter the
    /// retry/backoff path.
    LoadFailure { gpu: GpuId, function: usize },
    /// A whole node went down: every hosted GPU's batches were killed
    /// and the node's host-RAM cache was wiped once.
    NodeOutage {
        node: usize,
        killed_batches: usize,
        redispatched: usize,
    },
    /// The node came back up (cold). GPUs on it that also crashed
    /// individually stay down until their own repair.
    NodeRepair { node: usize },
    /// The engine's whole zone went down (every node at once).
    ZoneOutage {
        killed_batches: usize,
        redispatched: usize,
    },
    /// The zone came back: all nodes up.
    ZoneRepair,
    /// The GPU entered degraded mode: compute on it stretches by
    /// `factor` until the matching `GpuRestore` (or a crash).
    GpuDegrade { gpu: GpuId, factor: f64 },
    /// The GPU returned to full speed.
    GpuRestore { gpu: GpuId },
}

/// The injector: spec + its dedicated RNG stream. Owned by the engine,
/// present only when `SystemConfig::faults` is `Some`.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    pub spec: FaultSpec,
    rng: Pcg64,
}

impl FaultInjector {
    pub fn new(spec: FaultSpec, seed: u64) -> Self {
        FaultInjector {
            spec,
            rng: Pcg64::with_stream(seed, FAULT_STREAM),
        }
    }

    /// Gap until the next crash of an up GPU (exponential, mean MTBF).
    pub fn crash_delay_s(&mut self) -> f64 {
        self.rng.exp(1.0 / self.spec.mtbf_s)
    }

    /// Downtime of a crashed GPU (exponential, mean MTTR).
    pub fn repair_delay_s(&mut self) -> f64 {
        self.rng.exp(1.0 / self.spec.mttr_s)
    }

    /// Bernoulli draw: does this cold load fail transiently?
    pub fn load_fails(&mut self) -> bool {
        self.spec.load_fail_prob > 0.0 && self.rng.f64() < self.spec.load_fail_prob
    }

    /// Backoff before retry number `attempt` (0-based): bounded
    /// exponential, `base · 2^attempt` capped at `backoff_cap_s`.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        let r = &self.spec.retry;
        (r.backoff_base_s * 2f64.powi(attempt.min(62) as i32)).min(r.backoff_cap_s)
    }

    /// Gap until a node's next outage. Callers gate on the level being
    /// configured; drawing is unconditional so the stream position is a
    /// pure function of the spec shape.
    pub fn node_crash_delay_s(&mut self) -> f64 {
        let lvl = self.spec.domains.and_then(|d| d.node).expect("node domain on");
        self.rng.exp(1.0 / lvl.mtbf_s)
    }

    /// Downtime of a node outage (exponential, mean node MTTR).
    pub fn node_repair_delay_s(&mut self) -> f64 {
        let lvl = self.spec.domains.and_then(|d| d.node).expect("node domain on");
        self.rng.exp(1.0 / lvl.mttr_s)
    }

    /// Gap until the zone's next outage.
    pub fn zone_outage_delay_s(&mut self) -> f64 {
        let lvl = self.spec.domains.and_then(|d| d.zone).expect("zone domain on");
        self.rng.exp(1.0 / lvl.mtbf_s)
    }

    /// Downtime of a zone outage (exponential, mean zone MTTR).
    pub fn zone_repair_delay_s(&mut self) -> f64 {
        let lvl = self.spec.domains.and_then(|d| d.zone).expect("zone domain on");
        self.rng.exp(1.0 / lvl.mttr_s)
    }

    /// Gap until a GPU's next degrade episode.
    pub fn degrade_gap_s(&mut self) -> f64 {
        let d = self.spec.degrade.expect("degrade on");
        self.rng.exp(1.0 / d.mtbf_s)
    }

    /// Length of a degrade episode (exponential, mean `duration_s`).
    pub fn degrade_duration_s(&mut self) -> f64 {
        let d = self.spec.degrade.expect("degrade on");
        self.rng.exp(1.0 / d.duration_s)
    }

    /// Slowdown factor of a degrade episode (uniform in the spec range,
    /// clamped to ≥ 1 so a misconfigured range can never speed a GPU up).
    pub fn degrade_factor(&mut self) -> f64 {
        let d = self.spec.degrade.expect("degrade on");
        self.rng.uniform(d.factor_min, d.factor_max).max(1.0)
    }
}

// --------------------------------------------------------------------
// Engine-side fault mechanism: crash kills, residency invalidation,
// retry/backoff, permanent failure. Lives here (dispatch.rs-style
// `impl Engine` split) so the whole subsystem reads in one file.

use std::collections::BTreeMap;

use crate::artifact::ArtifactKind;
use crate::coordinator::Queued;
use crate::metrics::RequestOutcome;
use crate::sim::dispatch::BatchState;
use crate::sim::engine::Engine;
use crate::sim::events::EventKind;
use crate::trace::Request;

impl Engine {
    /// Schedule the first crash of every GPU (dense order — the draw
    /// order is part of the deterministic contract), then the first
    /// outage of every node, the zone, and every GPU's first degrade
    /// episode — in that fixed block order, each block drawing **only**
    /// when its sub-spec is present, so a spec without `domains` /
    /// `degrade` consumes the exact historical stream. Called once from
    /// `Engine::new`; a no-op when `cfg.faults` is `None`. Initial
    /// events past the workload horizon are not scheduled, so a faulted
    /// run still drains.
    pub(super) fn schedule_initial_crashes(&mut self) {
        let Some(spec) = self.injector.as_ref().map(|i| i.spec) else {
            return;
        };
        for d in 0..self.gpu_map.len() {
            let g = self.gpu_map.id(d);
            let delay = self.injector.as_mut().unwrap().crash_delay_s();
            let t = self.now + delay;
            if t <= self.duration_s {
                self.events.push(t, EventKind::GpuCrash(g));
            }
        }
        if spec.domains.and_then(|d| d.node).is_some() {
            for node in 0..self.cluster.nodes.len() {
                let delay = self.injector.as_mut().unwrap().node_crash_delay_s();
                let t = self.now + delay;
                if t <= self.duration_s {
                    self.events.push(t, EventKind::NodeCrash(node));
                }
            }
        }
        if spec.domains.and_then(|d| d.zone).is_some() {
            let delay = self.injector.as_mut().unwrap().zone_outage_delay_s();
            let t = self.now + delay;
            if t <= self.duration_s {
                self.events.push(t, EventKind::ZoneOutage);
            }
        }
        if spec.degrade.is_some() {
            for d in 0..self.gpu_map.len() {
                let g = self.gpu_map.id(d);
                let delay = self.injector.as_mut().unwrap().degrade_gap_s();
                let t = self.now + delay;
                if t <= self.duration_s {
                    self.events.push(t, EventKind::GpuDegrade(g));
                }
            }
        }
    }

    /// A GPU went down: kill its in-flight batches (requests re-enqueue
    /// for re-dispatch — no retry budget consumed, the failure was not
    /// theirs), invalidate everything resident on it, and schedule the
    /// repair. Routing sees the health flip immediately; billing
    /// reclassifies through the same O(1) machinery as any state change.
    pub(super) fn on_gpu_crash(&mut self, g: crate::cluster::GpuId) {
        self.stats.gpu_crashes += 1;
        self.cluster.set_gpu_health(g, false);
        // Repair is always scheduled (never horizon-gated): a down GPU
        // must come back up or the tail of the run serves degraded.
        let repair = self.injector.as_mut().expect("faults on").repair_delay_s();
        self.events.push(self.now + repair, EventKind::GpuRecover(g));
        // A crash mid-degrade supersedes the episode: the restore event
        // is cancelled and the GPU comes back from repair at full speed.
        self.clear_degrade_on_crash(g);
        let (killed_batches, redispatched) = self.kill_batches_on(g);
        self.invalidate_gpu(g);
        self.cluster.note_crash(g, self.now);
        self.emit_fault(FaultEvent::GpuCrash { gpu: g, killed_batches, redispatched });
        // The cluster's routable surface changed: blocked functions get
        // a retry, and the re-enqueued requests re-route to up GPUs.
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Kill every in-flight batch on one GPU (dense victim order).
    /// Returns (killed batches, re-enqueued requests).
    fn kill_batches_on(&mut self, g: crate::cluster::GpuId) -> (usize, usize) {
        let victims: Vec<u64> = self
            .batches
            .iter()
            .filter(|(_, b)| b.gpu == g)
            .map(|(&id, _)| id)
            .collect();
        let killed = victims.len();
        let mut redispatched = 0usize;
        for id in victims {
            redispatched += self.kill_batch(id);
        }
        (killed, redispatched)
    }

    /// Tear down an active degrade episode because the GPU is going
    /// down: cancel the pending restore and reset the service rate.
    /// Fully gated on an episode being active, so the dormant path does
    /// not touch the exec.
    fn clear_degrade_on_crash(&mut self, g: crate::cluster::GpuId) {
        let d = self.gpu_map.dense(g);
        if let Some(tok) = self.restore_tokens[d].take() {
            self.events.cancel(tok);
        }
        if self.degrade_factor[d] != 1.0 {
            self.degrade_factor[d] = 1.0;
            self.execs[d].set_rate(self.now, 1.0);
            self.cluster.note_degrade(g, 1.0);
        }
    }

    /// The repair completed: the GPU is routable again (cold — its
    /// residency died with the crash) and, if the horizon allows, its
    /// next crash is drawn.
    pub(super) fn on_gpu_recover(&mut self, g: crate::cluster::GpuId) {
        self.stats.gpu_recoveries += 1;
        self.cluster.set_gpu_health(g, true);
        let next = self.injector.as_mut().expect("faults on").crash_delay_s();
        let t = self.now + next;
        if t <= self.duration_s {
            self.events.push(t, EventKind::GpuCrash(g));
        }
        self.emit_fault(FaultEvent::GpuRecover { gpu: g });
        // A fresh GPU may unblock memory-starved functions.
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// A whole node went down. The repair is drawn and scheduled
    /// *before* any kill work — mirroring the GPU path — so a member
    /// GPU's independent crash landing on the same tick orders against
    /// the repair purely by the queue's (t, seq) tie-break, never by
    /// handler side-effects.
    pub(super) fn on_node_crash(&mut self, node: usize) {
        self.stats.node_outages += 1;
        let repair = self.injector.as_mut().expect("faults on").node_repair_delay_s();
        self.events.push(self.now + repair, EventKind::NodeRecover(node));
        let (killed_batches, redispatched) = self.take_node_down(node);
        self.emit_fault(FaultEvent::NodeOutage { node, killed_batches, redispatched });
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Node repair: the node dimension comes back up (member GPUs that
    /// crashed individually stay down until their own repair), and the
    /// next node outage is drawn if the horizon allows.
    pub(super) fn on_node_recover(&mut self, node: usize) {
        self.stats.node_repairs += 1;
        self.cluster.set_node_health(node, true);
        let next = self.injector.as_mut().expect("faults on").node_crash_delay_s();
        let t = self.now + next;
        if t <= self.duration_s {
            self.events.push(t, EventKind::NodeCrash(node));
        }
        self.emit_fault(FaultEvent::NodeRepair { node });
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Zone outage: every node of this engine's cluster goes down
    /// atomically (under zone sharding each zone engine *is* one zone).
    /// In-flight work dies, requests re-enqueue or fail their deadline,
    /// and new dispatches block until the zone repairs — the
    /// conservation invariant holds throughout.
    pub(super) fn on_zone_outage(&mut self) {
        self.stats.zone_outages += 1;
        let repair = self.injector.as_mut().expect("faults on").zone_repair_delay_s();
        self.events.push(self.now + repair, EventKind::ZoneRecover);
        let mut killed_batches = 0usize;
        let mut redispatched = 0usize;
        for node in 0..self.cluster.nodes.len() {
            let (k, r) = self.take_node_down(node);
            killed_batches += k;
            redispatched += r;
        }
        self.emit_fault(FaultEvent::ZoneOutage { killed_batches, redispatched });
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Zone repair: every node comes back up, including any that was
    /// also down from its own node-level outage (the zone power-cycle
    /// subsumes the node repair; the node's pending `NodeRecover` then
    /// fires as an idempotent no-op that draws its next outage).
    /// Individually-crashed GPUs stay down.
    pub(super) fn on_zone_recover(&mut self) {
        self.stats.zone_repairs += 1;
        for node in 0..self.cluster.nodes.len() {
            self.cluster.set_node_health(node, true);
        }
        let next = self.injector.as_mut().expect("faults on").zone_outage_delay_s();
        let t = self.now + next;
        if t <= self.duration_s {
            self.events.push(t, EventKind::ZoneOutage);
        }
        self.emit_fault(FaultEvent::ZoneRepair);
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Take one node down: health flip, then per member GPU in dense
    /// order — degrade teardown, batch kills, residency invalidation,
    /// failure-history note — then one host-cache wipe for the whole
    /// node (the ISSUE's "once, not per-GPU" contract). Shared by node
    /// and zone outages; idempotent on an already-down node.
    fn take_node_down(&mut self, node: usize) -> (usize, usize) {
        self.cluster.set_node_health(node, false);
        let gpus: Vec<crate::cluster::GpuId> =
            self.cluster.nodes[node].gpus.iter().map(|g| g.id).collect();
        let mut killed = 0usize;
        let mut redispatched = 0usize;
        for g in gpus {
            self.clear_degrade_on_crash(g);
            let (k, r) = self.kill_batches_on(g);
            killed += k;
            redispatched += r;
            self.invalidate_gpu_residency(g);
            self.cluster.note_crash(g, self.now);
        }
        self.wipe_node_cache(node);
        self.coldstart_node_failed(node);
        (killed, redispatched)
    }

    /// A degrade episode begins. The duration, factor, and next-onset
    /// gap are always drawn (fixed order — the stream position never
    /// depends on health state); on a down GPU the episode itself is a
    /// no-op (the crash already superseded it). An episode never
    /// overlaps the next onset: the gap is drawn from the episode's
    /// *end*.
    pub(super) fn on_gpu_degrade(&mut self, g: crate::cluster::GpuId) {
        let inj = self.injector.as_mut().expect("faults on");
        let duration = inj.degrade_duration_s();
        let factor = inj.degrade_factor();
        let gap = inj.degrade_gap_s();
        let next = self.now + duration + gap;
        if next <= self.duration_s {
            self.events.push(next, EventKind::GpuDegrade(g));
        }
        if !self.cluster.gpu_is_up(g) {
            return;
        }
        let d = self.gpu_map.dense(g);
        // Defensive: a lingering restore (cannot arise from the
        // non-overlapping onset chain) would be superseded here.
        if let Some(tok) = self.restore_tokens[d].take() {
            self.events.cancel(tok);
        }
        let old = self.degrade_factor[d];
        self.degrade_factor[d] = factor;
        self.restore_tokens[d] =
            Some(self.events.push(self.now + duration, EventKind::GpuRestore(g)));
        self.stats.degrades += 1;
        self.retime_gpu_rate(g, old, factor);
        self.cluster.note_degrade(g, factor);
        self.emit_fault(FaultEvent::GpuDegrade { gpu: g, factor });
    }

    /// The degrade episode ends: full speed again. Only a live restore
    /// token reaches here (crashes cancel it), so the GPU is up and
    /// currently degraded.
    pub(super) fn on_gpu_restore(&mut self, g: crate::cluster::GpuId) {
        let d = self.gpu_map.dense(g);
        self.restore_tokens[d] = None;
        let old = self.degrade_factor[d];
        self.degrade_factor[d] = 1.0;
        self.stats.degrade_restores += 1;
        self.retime_gpu_rate(g, old, 1.0);
        self.cluster.note_degrade(g, 1.0);
        self.emit_fault(FaultEvent::GpuRestore { gpu: g });
    }

    /// Re-time everything on `g` whose wall time depends on the GPU's
    /// service rate, after the slowdown factor changed `old → new`:
    ///
    /// * exec jobs — progress settles at the old rate, then the one
    ///   outstanding completion tick is cancelled and re-pushed
    ///   (`set_rate` + `schedule_tick`, both O(1) per change);
    /// * flat (single-timer) cold loads — remaining wall time scales by
    ///   `new/old`; the delta folds into the batch's last recorded load
    ///   phase so TTFT still equals the phase sum.
    ///
    /// Segmented (tiered) loads are deliberately *not* re-timed: their
    /// wall time is DMA/link-bound, which SM throttling does not slow
    /// (see DESIGN.md "Correlated faults & degraded mode").
    fn retime_gpu_rate(&mut self, g: crate::cluster::GpuId, old: f64, new: f64) {
        if old == new {
            return;
        }
        let d = self.gpu_map.dense(g);
        let had_jobs = self.execs[d].is_active();
        self.execs[d].set_rate(self.now, 1.0 / new);
        if had_jobs {
            self.schedule_tick(g);
            self.stats.degrade_retimes += 1;
        }
        let batches = &self.batches;
        let runs = &self.load_runs;
        let victims: Vec<u64> = batches
            .iter()
            .filter(|(id, b)| {
                b.gpu == g
                    && matches!(b.state, BatchState::Loading)
                    && b.load_token.is_some()
                    && !runs.contains_key(id)
            })
            .map(|(&id, _)| id)
            .collect();
        for id in victims {
            let batch = self.batches.get_mut(&id).expect("victim exists");
            let tok = batch.load_token.take().expect("flat load token");
            let end = self.events.get(tok).expect("load event live").t;
            let new_end = self.now + (end - self.now) * (new / old);
            self.events.cancel(tok);
            batch.load_token = Some(self.events.push(new_end, EventKind::LoadDone(id)));
            let delta = new_end - end;
            if delta != 0.0 {
                if let Some((_, v)) = batch.load_phases.iter_mut().next_back() {
                    *v += delta;
                }
            }
            self.stats.degrade_retimes += 1;
        }
    }

    /// Kill one in-flight batch on a crashing GPU, unwinding exactly the
    /// state its lifecycle stage holds: pending load events (flat token
    /// or segmented run + live flow), exec jobs, busy/loading counts, KV
    /// reservation, backbone attachment. Returns how many of its
    /// requests were re-enqueued (the rest failed their deadline).
    pub(super) fn kill_batch(&mut self, batch_id: u64) -> usize {
        // A pipelined cold load dies with its batch: cancel the sibling
        // shards and any consolidation first (idempotent no-op for the
        // overwhelmingly common non-pipelined batch), and force the
        // function's retry onto the tiered path.
        self.abort_pipe_run(batch_id);
        let batch = self.batches.remove(&batch_id).expect("batch exists");
        let gpu = batch.gpu;
        let f = batch.function;
        let d = self.gpu_map.dense(gpu);
        match batch.state {
            BatchState::Loading => {
                self.gpu_loading[d] -= 1;
                self.gpu_busy[d] -= 1;
                if let Some(tok) = batch.load_token {
                    self.events.cancel(tok);
                }
                if let Some(run) = self.load_runs.remove(&batch_id) {
                    if let Some(tok) = run.token {
                        self.events.cancel(tok);
                    }
                    // Mid-transfer: pull the flow off the link and
                    // re-time the survivors at their fatter share.
                    if let Some(link) = run.segs[run.cursor].link {
                        let (_, retimes) = self.flows.finish(run.node, link, batch_id, self.now);
                        self.apply_load_retimes(retimes);
                    }
                }
            }
            BatchState::Prefill => {
                self.gpu_busy[d] -= 1;
                self.execs[d].remove(self.now, batch_id);
                self.schedule_tick(gpu);
            }
            BatchState::Decode => {
                // Busy already dropped at the Prefill → Decode edge.
                self.execs[d].remove(self.now, batch_id);
                self.schedule_tick(gpu);
            }
        }
        self.fn_inflight[f] -= 1;
        self.cluster.gpu_mut(gpu).release_kv(batch_id);
        if batch.attached_backbone {
            let model = self.spec(f).model.name.to_string();
            let _ = self.registry.detach(
                &mut self.cluster,
                &crate::sharing::IpcHandle { model, gpu, function: f },
            );
        }
        self.reclassify_gpu(gpu);
        let deadline = self.injector.as_ref().expect("faults on").spec.retry.deadline_s;
        let mut redispatched = 0usize;
        for r in batch.requests {
            if self.now - r.arrival_s >= deadline {
                self.fail_request(&r);
            } else {
                self.queues[f].push(Queued { request: r.id, arrival_s: r.arrival_s });
                self.active.insert(f);
                redispatched += 1;
            }
        }
        self.stats.redispatched += redispatched as u64;
        self.arm_queue_wakeups(f);
        redispatched
    }

    /// Drop everything resident on a crashed GPU: private artifacts and
    /// CUDA contexts, shared backbone segments (refcounts are zero — the
    /// batches died first), and the node's host-RAM checkpoint cache
    /// (the crash takes the whole worker process down with it).
    /// Keep-alive warmth is *not* force-dropped: a function warm on a
    /// surviving GPU stays warm, and the billing warm counts reconcile
    /// through the same per-GPU residency journal as any eviction.
    fn invalidate_gpu(&mut self, g: crate::cluster::GpuId) {
        self.invalidate_gpu_residency(g);
        self.wipe_node_cache(g.node);
        // The worker process died: snapshot builds serializing on this
        // node cancel, pipelined shards streaming from it kill their
        // batches, and the surcharge integrand drops with the wiped
        // cache (no-op when the cold-start subsystem is off).
        self.coldstart_node_failed(g.node);
    }

    /// The GPU-local half of crash invalidation (no host-cache wipe):
    /// node outages call this per member GPU but wipe the node's cache
    /// exactly once.
    fn invalidate_gpu_residency(&mut self, g: crate::cluster::GpuId) {
        let mut fns: Vec<usize> = Vec::new();
        self.cluster.for_each_resident(g, |f| fns.push(f));
        for f in fns {
            let gpu = self.cluster.gpu_mut(g);
            let _ = gpu.evict_artifact(f, ArtifactKind::Adapter);
            let _ = gpu.evict_artifact(f, ArtifactKind::CudaKernel);
            let _ = gpu.evict_artifact(f, ArtifactKind::Backbone);
            gpu.destroy_cuda_context(f);
        }
        let models: Vec<&'static str> = self
            .model_peers
            .keys()
            .copied()
            .filter(|m| self.registry.hosts(m).contains(&g))
            .collect();
        for m in models {
            let _ = self.registry.unload(&mut self.cluster, m, g);
        }
    }

    /// Wipe one node's host-RAM checkpoint cache (the worker process
    /// died; staged checkpoints died with it).
    fn wipe_node_cache(&mut self, node: usize) {
        let cache = &mut self.cluster.nodes[node].cache;
        if cache.enabled() {
            self.stats.cache_evictions += cache.drain() as u64;
        }
    }

    /// A batch's cold load completed as a drawn transient failure: the
    /// batch dies without executing and its requests enter the
    /// retry/backoff path. Artifacts staged by the load *stay* resident
    /// (the bytes moved; what failed is the instance bring-up), so a
    /// retry typically finds them warm — the modeling choice that keeps
    /// the residency ledger append-only under faults.
    pub(super) fn on_load_failed(&mut self, batch_id: u64) {
        let batch = self.batches.remove(&batch_id).expect("batch exists");
        let gpu = batch.gpu;
        let f = batch.function;
        let d = self.gpu_map.dense(gpu);
        self.gpu_loading[d] -= 1;
        self.gpu_busy[d] -= 1;
        self.fn_inflight[f] -= 1;
        self.cluster.gpu_mut(gpu).release_kv(batch_id);
        if batch.attached_backbone {
            let model = self.spec(f).model.name.to_string();
            let _ = self.registry.detach(
                &mut self.cluster,
                &crate::sharing::IpcHandle { model, gpu, function: f },
            );
        }
        self.reclassify_gpu(gpu);
        self.stats.load_failures += 1;
        self.emit_fault(FaultEvent::LoadFailure { gpu, function: f });
        for r in batch.requests {
            self.fail_or_retry(r);
        }
        // KV freed: memory-blocked functions get their retry.
        if !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
        }
        self.try_dispatch_all(None);
    }

    /// Route a transiently-failed request: permanent failure when its
    /// deadline passed or its retry budget is spent, otherwise a
    /// `RetryWake` after the bounded exponential backoff.
    fn fail_or_retry(&mut self, req: Request) {
        let retry = self.injector.as_ref().expect("faults on").spec.retry;
        let attempt = self.retry_count.get(&req.id).copied().unwrap_or(0);
        if self.now - req.arrival_s >= retry.deadline_s || attempt >= retry.max_retries {
            return self.fail_request(&req);
        }
        self.retry_count.insert(req.id, attempt + 1);
        let backoff = self.injector.as_ref().expect("faults on").backoff_s(attempt);
        self.events.push(self.now + backoff, EventKind::RetryWake(req.id));
        self.retry_pending += 1;
        self.stats.retries += 1;
    }

    /// A retry backoff expired: re-enqueue the request (it keeps its
    /// original arrival time — deadlines and queue-wait metrics are
    /// measured from first arrival), unless its deadline lapsed while it
    /// slept.
    pub(super) fn on_retry_wake(&mut self, id: u64) {
        self.retry_pending -= 1;
        let req = self.requests[self.request_index[&id]].clone();
        let retry = self.injector.as_ref().expect("faults on").spec.retry;
        if self.now - req.arrival_s >= retry.deadline_s {
            return self.fail_request(&req);
        }
        let f = req.function;
        self.queues[f].push(Queued { request: id, arrival_s: req.arrival_s });
        self.active.insert(f);
        let armed = self.queue_wakeups[f];
        self.try_dispatch_all(Some(f));
        if self.queue_wakeups[f] == armed {
            self.arm_queue_wakeups(f);
        }
    }

    /// Permanent failure: deadline exceeded or retry budget exhausted.
    /// Counted (never silently dropped — the conservation invariant
    /// includes it) and surfaced to observers as a synthesized outcome
    /// with `e2e_s` = arrival → failure and no phases.
    pub(super) fn fail_request(&mut self, req: &Request) {
        self.stats.requests_failed += 1;
        self.metrics.failed += 1;
        *self.metrics.failed_by_function.entry(req.function).or_insert(0) += 1;
        self.retry_count.remove(&req.id);
        let outcome = RequestOutcome {
            id: req.id,
            function: req.function,
            arrival_s: req.arrival_s,
            phases: BTreeMap::new(),
            ttft_s: 0.0,
            tpot_s: 0.0,
            e2e_s: self.now - req.arrival_s,
            output_tokens: 0,
            batch_size: 0,
            backbone_tier: None,
            cold_path: Default::default(),
        };
        self.emit_request_failed(&outcome);
    }

    pub(super) fn emit_fault(&mut self, event: FaultEvent) {
        if self.series.is_none() && self.observers.is_empty() {
            return;
        }
        let t = self.now;
        if let Some(s) = self.series.as_mut() {
            s.on_fault(t, &event);
        }
        for ob in &mut self.observers {
            ob.on_fault(t, &event);
        }
    }

    pub(super) fn emit_request_failed(&mut self, outcome: &RequestOutcome) {
        let t = self.now;
        if let Some(s) = self.series.as_mut() {
            s.on_request_failed(t, outcome);
        }
        for ob in &mut self.observers {
            ob.on_request_failed(t, outcome);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn injector_is_deterministic_per_seed() {
        let spec = FaultSpec { load_fail_prob: 0.3, ..FaultSpec::default() };
        let mut a = FaultInjector::new(spec, 42);
        let mut b = FaultInjector::new(spec, 42);
        for _ in 0..100 {
            assert_eq!(a.crash_delay_s().to_bits(), b.crash_delay_s().to_bits());
            assert_eq!(a.repair_delay_s().to_bits(), b.repair_delay_s().to_bits());
            assert_eq!(a.load_fails(), b.load_fails());
        }
        let mut c = FaultInjector::new(spec, 43);
        let differs = (0..100).any(|_| a.crash_delay_s().to_bits() != c.crash_delay_s().to_bits());
        assert!(differs, "different seeds must diverge");
    }

    #[test]
    fn crash_gap_mean_tracks_mtbf() {
        let spec = FaultSpec { mtbf_s: 600.0, mttr_s: 20.0, ..FaultSpec::default() };
        let mut inj = FaultInjector::new(spec, 7);
        let n = 20_000;
        let mean_crash: f64 = (0..n).map(|_| inj.crash_delay_s()).sum::<f64>() / n as f64;
        let mean_repair: f64 = (0..n).map(|_| inj.repair_delay_s()).sum::<f64>() / n as f64;
        assert!((mean_crash - 600.0).abs() < 30.0, "mean crash gap {mean_crash}");
        assert!((mean_repair - 20.0).abs() < 1.0, "mean repair gap {mean_repair}");
    }

    #[test]
    fn load_fail_prob_extremes() {
        let mut never = FaultInjector::new(
            FaultSpec { load_fail_prob: 0.0, ..FaultSpec::default() },
            1,
        );
        assert!((0..1000).all(|_| !never.load_fails()));
        let mut always = FaultInjector::new(
            FaultSpec { load_fail_prob: 1.0, ..FaultSpec::default() },
            1,
        );
        assert!((0..1000).all(|_| always.load_fails()));
    }

    #[test]
    fn backoff_doubles_then_caps() {
        let spec = FaultSpec {
            retry: RetrySpec {
                max_retries: 10,
                backoff_base_s: 0.5,
                backoff_cap_s: 3.0,
                deadline_s: 60.0,
            },
            ..FaultSpec::default()
        };
        let inj = FaultInjector::new(spec, 1);
        assert_eq!(inj.backoff_s(0), 0.5);
        assert_eq!(inj.backoff_s(1), 1.0);
        assert_eq!(inj.backoff_s(2), 2.0);
        assert_eq!(inj.backoff_s(3), 3.0, "capped");
        assert_eq!(inj.backoff_s(40), 3.0, "stays capped, no overflow");
    }

    use crate::artifact::{FunctionSpec, ModelProfile};
    use crate::cluster::Cluster;
    use crate::sim::config::SystemConfig;
    use crate::sim::engine::{Engine, Workload};

    /// An idle engine (no requests) with faults configured but pushed
    /// past the horizon — a blank canvas for driving the fault handlers
    /// by hand and inspecting the health machinery.
    fn idle_engine(spec: FaultSpec) -> Engine {
        let w = Workload {
            functions: vec![FunctionSpec::new(0, ModelProfile::llama2_7b(), 0)],
            requests: Vec::new(),
            duration_s: 10.0,
            rates: vec![0.0],
        };
        let cfg = SystemConfig::serverless_lora().with_faults(spec);
        Engine::new(cfg, Cluster::new(1, 2, 4), w, 1)
    }

    /// A spec whose every fault class is configured (so the handlers'
    /// draws have levels to read) but can never fire on its own.
    fn quiet_full_spec() -> FaultSpec {
        FaultSpec {
            mtbf_s: 1e15,
            load_fail_prob: 0.0,
            domains: Some(DomainSpec {
                node: Some(DomainLevel { mtbf_s: 1e15, mttr_s: 5.0 }),
                zone: Some(DomainLevel { mtbf_s: 1e15, mttr_s: 5.0 }),
            }),
            degrade: Some(DegradeSpec { mtbf_s: 1e15, ..DegradeSpec::default() }),
            ..FaultSpec::default()
        }
    }

    #[test]
    fn gpu_recover_under_node_outage_stays_unroutable() {
        // Health is two-dimensional: a GPU whose own repair lands while
        // its node is still down must not become routable, and the node
        // repair must not resurrect a GPU that crashed individually.
        let mut e = idle_engine(quiet_full_spec());
        let g = e.gpu_map.id(0);
        e.on_gpu_crash(g);
        e.on_node_crash(g.node);
        assert!(!e.cluster.gpu_is_up(g));
        e.on_gpu_recover(g);
        assert!(
            !e.cluster.gpu_is_up(g),
            "GPU repair under a node outage must not mark it routable"
        );
        assert!(!e.cluster.node_is_up(g.node));
        e.on_node_recover(g.node);
        assert!(e.cluster.gpu_is_up(g), "both dimensions up ⇒ routable");
        // Other order: node repairs first, the GPU's own crash persists.
        let h = e.gpu_map.id(1);
        e.on_node_crash(h.node);
        e.on_gpu_crash(h);
        e.on_node_recover(h.node);
        assert!(
            !e.cluster.gpu_is_up(h),
            "node repair must not resurrect an individually-crashed GPU"
        );
        e.on_gpu_recover(h);
        assert!(e.cluster.gpu_is_up(h));
    }

    #[test]
    fn degrade_on_down_gpu_is_a_noop() {
        let mut e = idle_engine(quiet_full_spec());
        let g = e.gpu_map.id(0);
        let d = e.gpu_map.dense(g);
        e.on_gpu_crash(g);
        e.on_gpu_degrade(g);
        assert_eq!(e.stats.degrades, 0, "down GPU cannot degrade");
        assert_eq!(e.degrade_factor[d], 1.0);
        assert!(e.restore_tokens[d].is_none());
        assert_eq!(e.execs[d].rate().to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn crash_during_degrade_cancels_restore() {
        let mut e = idle_engine(quiet_full_spec());
        let g = e.gpu_map.id(0);
        let d = e.gpu_map.dense(g);
        e.on_gpu_degrade(g);
        assert_eq!(e.stats.degrades, 1);
        assert!(e.degrade_factor[d] > 1.0, "factor range starts above 1");
        let tok = e.restore_tokens[d].expect("restore pending");
        assert!(e.events.is_live(tok));
        let cancelled_before = e.events.cancelled();
        e.on_gpu_crash(g);
        assert!(e.restore_tokens[d].is_none(), "crash must cancel the restore");
        assert_eq!(e.events.cancelled(), cancelled_before + 1);
        assert_eq!(e.degrade_factor[d], 1.0);
        assert_eq!(e.execs[d].rate().to_bits(), 1.0f64.to_bits());
        assert_eq!(e.stats.degrade_restores, 0, "the cancelled restore never fires");
        e.check_indexes();
    }

    #[test]
    fn node_outage_wipes_each_cache_once_via_take_node_down() {
        // Two GPUs share node 0's host cache; the node outage must
        // count the staged checkpoint as one eviction, not one per GPU.
        let mut e = idle_engine(quiet_full_spec());
        e.cluster.nodes[0].cache = crate::cluster::HostCache::new(64.0);
        e.cluster.nodes[0].cache.insert("llama2-7b", 13.5, 0.0);
        let before = e.stats.cache_evictions;
        e.on_node_crash(0);
        assert_eq!(
            e.stats.cache_evictions,
            before + 1,
            "node outage wipes the host cache exactly once"
        );
        assert!(e.cluster.nodes[0].cache.is_empty());
    }

    #[test]
    fn same_tick_node_repair_and_gpu_crash_order_by_push_seq() {
        // The ordering lock from the ISSUE: when a node repair and a
        // member GPU's independent crash land on the same tick, the
        // queue's (t, seq) tie-break — push order — decides, never
        // handler side-effects. Here the repair was pushed first, so
        // after the tick the node is up but the GPU is freshly down.
        let mut e = idle_engine(quiet_full_spec());
        let g = e.gpu_map.id(0);
        e.cluster.set_node_health(g.node, false);
        e.events.push(1.0, EventKind::NodeRecover(g.node));
        e.events.push(1.0, EventKind::GpuCrash(g));
        assert!(e.step(), "node repair pops first");
        assert!(e.cluster.node_is_up(g.node));
        assert!(e.cluster.gpu_is_up(g), "crash has not fired yet");
        assert_eq!((e.stats.node_repairs, e.stats.gpu_crashes), (1, 0));
        assert!(e.step(), "member crash pops second");
        assert!(!e.cluster.gpu_is_up(g));
        assert!(e.cluster.node_is_up(g.node), "crash must not re-down the node");
        assert_eq!((e.stats.node_repairs, e.stats.gpu_crashes), (1, 1));
        e.check_indexes();
    }

    #[test]
    fn zone_recover_revives_node_outage_and_keeps_chains_paired() {
        // A zone power-cycle subsumes a pending node repair: the node
        // comes back at zone-recover time, and the node's own
        // `NodeRecover` later fires as an idempotent no-op that still
        // draws the next node outage — crash/repair chains stay 1:1.
        let mut e = idle_engine(quiet_full_spec());
        e.on_node_crash(0);
        e.on_zone_outage();
        assert_eq!(e.cluster.n_nodes_down(), 1);
        e.on_zone_recover();
        assert_eq!(e.cluster.n_nodes_down(), 0, "zone repair revives every node");
        e.on_node_recover(0); // the pending repair, now a health no-op
        assert!(e.cluster.node_is_up(0));
        assert_eq!(e.stats.node_repairs, e.stats.node_outages);
        assert_eq!(e.stats.zone_repairs, e.stats.zone_outages);
    }

    #[test]
    fn domain_draw_means_track_their_levels() {
        let spec = FaultSpec {
            domains: Some(DomainSpec {
                node: Some(DomainLevel { mtbf_s: 300.0, mttr_s: 40.0 }),
                zone: Some(DomainLevel { mtbf_s: 900.0, mttr_s: 15.0 }),
            }),
            degrade: Some(DegradeSpec {
                mtbf_s: 500.0,
                duration_s: 80.0,
                factor_min: 2.0,
                factor_max: 3.0,
            }),
            ..FaultSpec::default()
        };
        let mut inj = FaultInjector::new(spec, 11);
        let n = 20_000;
        let node: f64 = (0..n).map(|_| inj.node_crash_delay_s()).sum::<f64>() / n as f64;
        let zone: f64 = (0..n).map(|_| inj.zone_repair_delay_s()).sum::<f64>() / n as f64;
        let dur: f64 = (0..n).map(|_| inj.degrade_duration_s()).sum::<f64>() / n as f64;
        assert!((node - 300.0).abs() < 15.0, "node outage gap mean {node}");
        assert!((zone - 15.0).abs() < 1.0, "zone repair mean {zone}");
        assert!((dur - 80.0).abs() < 4.0, "degrade duration mean {dur}");
        for _ in 0..1000 {
            let f = inj.degrade_factor();
            assert!((2.0..3.0).contains(&f), "factor {f} outside the spec range");
        }
    }

    #[test]
    fn fault_draws_share_one_stream_in_schedule_order() {
        // The injector is one stream: interleaving crash and load draws
        // consumes it in call order, which the single-threaded event
        // loop makes deterministic.
        let spec = FaultSpec { load_fail_prob: 0.5, ..FaultSpec::default() };
        let mut a = FaultInjector::new(spec, 9);
        let seq_a: Vec<u64> = (0..8).map(|_| a.crash_delay_s().to_bits()).collect();
        let mut b = FaultInjector::new(spec, 9);
        let _ = b.load_fails(); // one extra draw shifts everything after
        let seq_b: Vec<u64> = (0..8).map(|_| b.crash_delay_s().to_bits()).collect();
        assert_ne!(seq_a, seq_b);
    }
}
