//! The engine's **output surface**: the [`Observer`] hook contract.
//!
//! Historically the engine hard-wired its outputs — `RunMetrics` absorbed
//! completions, `CostTracker` absorbed billing samples — and anything
//! else (per-class cost trajectories, completion logs, live dashboards)
//! meant editing the event loop. This module inverts that: the engine
//! *emits* a small set of typed events and every consumer — built-in or
//! attached — is an [`Observer`].
//!
//! ## Hooks
//!
//! * [`Observer::on_request_complete`] — once per finished request, in
//!   completion order, with the full [`RequestOutcome`].
//! * [`Observer::on_bill_sample`] — once per positive-width inter-event
//!   interval on interval-billed (serverless) runs: the aggregate
//!   per-class footprint that was live over `[t0, t0+dt)`. Serverful
//!   runs never sample (flat billing), so observers see nothing there —
//!   the same contract `RunStats::bill_samples` records.
//! * [`Observer::on_gpu_reclass`] — when a GPU's billing **class
//!   transitions** (`from != to`). Same-class footprint updates do not
//!   fire it. `from == None` marks snapshot entries: the deploy-time
//!   classification, replayed to each observer when it is attached.
//! * [`Observer::on_keepalive`] — when a function actually enters
//!   (`warm == true`) or leaves (`warm == false`) the keep-alive warm
//!   set. Window extensions of an already-warm function do not fire it.
//! * [`Observer::on_finish`] — once, after the final billing interval
//!   and settlement, with the billing end time.
//!
//! ## Built-ins
//!
//! The engine's two historical outputs are now observers of this same
//! contract: [`RunMetrics`] (completion hook) and [`BilledCost`] (the
//! billing model pricing each aggregate sample into its `CostTracker`).
//! Attached observers receive the same hooks but only ever see borrowed
//! event data and hold no reference into the engine, so they cannot
//! perturb a run's metrics or cost by a single bit. (`BilledCost` is
//! invoked before the fan-out; the metrics sink takes the outcome by
//! move after it — an ordering no observer can detect.) The opt-in
//! [`BillSeriesSampler`] (per-billing-class time series, the §6.4
//! cost-breakdown trajectory) is the third built-in, enabled with
//! [`Engine::enable_bill_series`].
//!
//! Attached observers ([`Engine::attach_observer`]) are push-based
//! sinks: the engine does not return them. An observer that needs to
//! surface state after the run should share it (e.g. an
//! `Arc<Mutex<_>>` clone kept by the caller).
//!
//! [`Engine::enable_bill_series`]: crate::sim::Engine::enable_bill_series
//! [`Engine::attach_observer`]: crate::sim::Engine::attach_observer

use crate::cluster::GpuId;
use crate::coordinator::policy::{AggregateBillSample, BillingModel, ClassBillSample};
use crate::cost::CostTracker;
use crate::metrics::{RequestOutcome, RunMetrics, RunStats};
use crate::sim::billing::BillClass;
use crate::sim::fault::FaultEvent;
use crate::util::json::{arr, num, obj, Json};

/// Engine output hooks. Every method has a no-op default so observers
/// implement only what they consume. See the module docs for the exact
/// firing contract of each hook.
pub trait Observer: Send {
    /// A request finished (its batch's decode completed) at `t_s`.
    fn on_request_complete(&mut self, _t_s: f64, _outcome: &RequestOutcome) {}

    /// The cluster's aggregate billable state over `[t0_s, t0_s + dt_s)`.
    fn on_bill_sample(&mut self, _t0_s: f64, _dt_s: f64, _sample: &AggregateBillSample) {}

    /// GPU `gpu` moved between billing classes at `t_s` (`from` is
    /// `None` for snapshot entries: the deploy-time classification,
    /// replayed when an observer is attached).
    fn on_gpu_reclass(&mut self, _t_s: f64, _gpu: GpuId, _from: Option<BillClass>, _to: BillClass) {
    }

    /// Function `function` entered (`warm`) or left (`!warm`) the
    /// keep-alive warm set at `t_s`.
    fn on_keepalive(&mut self, _t_s: f64, _function: usize, _warm: bool) {}

    /// A fault fired at `t_s` (GPU crash/recover, transient load
    /// failure). Never fires when `SystemConfig::faults` is `None`.
    fn on_fault(&mut self, _t_s: f64, _event: &FaultEvent) {}

    /// A request failed permanently at `t_s` (deadline exceeded or retry
    /// budget exhausted). The outcome is synthesized — `e2e_s` is
    /// arrival → failure, latency/phase fields are zero — and it never
    /// reaches `on_request_complete`.
    fn on_request_failed(&mut self, _t_s: f64, _outcome: &RequestOutcome) {}

    /// The run is over; `end_s` is the billing end instant.
    fn on_finish(&mut self, _end_s: f64) {}
}

/// `RunMetrics` is the built-in completion observer: it records every
/// outcome it is handed. (The engine hands it the outcome by move — no
/// clone on the hot path — but the contract is exactly this hook.)
impl Observer for RunMetrics {
    fn on_request_complete(&mut self, _t_s: f64, outcome: &RequestOutcome) {
        self.record(outcome.clone());
    }
}

/// The built-in cost observer: a [`BillingModel`] pricing each aggregate
/// bill sample into a [`CostTracker`]. This is the engine's money path —
/// `Engine::finish` returns `self.cost_obs.cost` — kept bit-identical to
/// the historical inline `billing.bill(...)` call (same sample, same
/// float-op order).
pub struct BilledCost {
    pub model: Box<dyn BillingModel>,
    pub cost: CostTracker,
}

impl BilledCost {
    pub fn new(model: Box<dyn BillingModel>) -> Self {
        BilledCost { model, cost: CostTracker::default() }
    }

    /// End-of-run settlement (serverful flat GPU-hours).
    pub fn finalize(&mut self, dedicated_gpus: usize, end_s: f64) {
        self.model.finalize(dedicated_gpus, end_s, &mut self.cost);
    }
}

impl Observer for BilledCost {
    fn on_bill_sample(&mut self, _t0_s: f64, dt_s: f64, sample: &AggregateBillSample) {
        self.model.bill(sample, dt_s, &mut self.cost);
    }
}

/// Everything one engine run produced. `Engine::run_full` /
/// `finish_full` return this; the historical `(RunMetrics, CostTracker,
/// RunStats)` tuple API survives as a thin projection of it.
pub struct RunOutput {
    pub metrics: RunMetrics,
    pub cost: CostTracker,
    pub stats: RunStats,
    /// The per-billing-class time series, when
    /// `Engine::enable_bill_series` was called; `None` otherwise.
    pub bill_series: Option<BillSeries>,
}

/// One coarse bucket of the per-class cost trajectory: each billing
/// class's GB·s and GPU·s integrated over `[i·bucket_s, (i+1)·bucket_s)`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BillBucket {
    pub active_gb_s: f64,
    pub active_gpu_s: f64,
    pub loading_gb_s: f64,
    pub loading_gpu_s: f64,
    pub idle_warm_gb_s: f64,
    pub idle_warm_gpu_s: f64,
    pub idle_cold_gb_s: f64,
    pub idle_cold_gpu_s: f64,
}

impl BillBucket {
    fn add(&mut self, s: &AggregateBillSample, w: f64) {
        let acc = |gb: &mut f64, gpu: &mut f64, c: &ClassBillSample| {
            *gb += c.used_gb * w;
            *gpu += c.gpus as f64 * w;
        };
        acc(&mut self.active_gb_s, &mut self.active_gpu_s, &s.active);
        acc(&mut self.loading_gb_s, &mut self.loading_gpu_s, &s.loading);
        acc(&mut self.idle_warm_gb_s, &mut self.idle_warm_gpu_s, &s.idle_warm);
        acc(&mut self.idle_cold_gb_s, &mut self.idle_cold_gpu_s, &s.idle_cold);
    }
}

/// The finished per-billing-class time series (§6.4 cost-breakdown
/// trajectory): bucket `i` covers `[i·bucket_s, (i+1)·bucket_s)` of sim
/// time. Buckets past the last billed instant are simply absent.
#[derive(Debug, Clone, PartialEq)]
pub struct BillSeries {
    pub bucket_s: f64,
    pub buckets: Vec<BillBucket>,
}

impl BillSeries {
    /// Σ over buckets of a class's GB·s (cross-check against the cost
    /// tracker's integrals).
    pub fn total_gb_s(&self, class: BillClass) -> f64 {
        self.buckets
            .iter()
            .map(|b| match class {
                BillClass::ActiveExec => b.active_gb_s,
                BillClass::ActiveLoading => b.loading_gb_s,
                BillClass::IdleWarm => b.idle_warm_gb_s,
                BillClass::IdleCold => b.idle_cold_gb_s,
                BillClass::Empty => 0.0,
            })
            .sum()
    }

    pub fn to_json(&self) -> Json {
        let buckets = self.buckets.iter().enumerate().map(|(i, b)| {
            obj(vec![
                ("t0_s", num(i as f64 * self.bucket_s)),
                ("active_gb_s", num(b.active_gb_s)),
                ("active_gpu_s", num(b.active_gpu_s)),
                ("loading_gb_s", num(b.loading_gb_s)),
                ("loading_gpu_s", num(b.loading_gpu_s)),
                ("idle_warm_gb_s", num(b.idle_warm_gb_s)),
                ("idle_warm_gpu_s", num(b.idle_warm_gpu_s)),
                ("idle_cold_gb_s", num(b.idle_cold_gb_s)),
                ("idle_cold_gpu_s", num(b.idle_cold_gpu_s)),
            ])
        });
        obj(vec![("bucket_s", num(self.bucket_s)), ("buckets", arr(buckets))])
    }
}

/// Opt-in coarse per-billing-class time-series sampler — the third
/// built-in observer. It rides the existing `on_bill_sample` stream (it
/// takes **zero** additional samples: `RunStats::bill_samples` is
/// unchanged whether it is enabled or not), splitting each inter-event
/// interval across its coarse buckets. Cost model: O(1) amortized per
/// sample (an interval touches ⌈dt/bucket_s⌉ buckets and intervals are
/// almost always far shorter than a bucket), memory O(horizon /
/// bucket_s) — which is why the bucket is coarse and validated against
/// the horizon by the scenario layer.
pub struct BillSeriesSampler {
    bucket_s: f64,
    buckets: Vec<BillBucket>,
}

impl BillSeriesSampler {
    pub fn new(bucket_s: f64) -> Self {
        assert!(
            bucket_s.is_finite() && bucket_s > 0.0,
            "bill-series bucket must be a positive number of seconds"
        );
        BillSeriesSampler { bucket_s, buckets: Vec::new() }
    }

    pub fn into_series(self) -> BillSeries {
        BillSeries { bucket_s: self.bucket_s, buckets: self.buckets }
    }
}

impl Observer for BillSeriesSampler {
    fn on_bill_sample(&mut self, t0_s: f64, dt_s: f64, sample: &AggregateBillSample) {
        let lo = t0_s.max(0.0);
        let t1 = t0_s + dt_s;
        if t1 <= lo {
            return;
        }
        // Walk the bucket *indices* overlapping [lo, t1) and clip the
        // interval against each bucket's own bounds. (A cursor that
        // advances `lo` to the computed bucket edge can strand the rest
        // of an interval when `lo/bucket` floor-rounds into the
        // previous bucket at an exact boundary; clipping per index
        // conserves the integral up to float slivers instead.)
        let i0 = (lo / self.bucket_s).floor() as usize;
        let i1 = ((t1 / self.bucket_s).ceil() as usize).max(i0 + 1);
        if self.buckets.len() < i1 {
            self.buckets.resize(i1, BillBucket::default());
        }
        for idx in i0..i1 {
            let b_lo = idx as f64 * self.bucket_s;
            let b_hi = b_lo + self.bucket_s;
            let w = t1.min(b_hi) - lo.max(b_lo);
            if w > 0.0 {
                self.buckets[idx].add(sample, w);
            }
        }
    }
}

// ---------------------------------------------------------- trace export

/// Per-request trace exporter: buffers every [`RequestOutcome`] — both
/// completions and permanent failures, each tagged with a terminal
/// `status` — and writes one file at `on_finish`: CSV (fixed columns,
/// one row per request, completion order) or JSON (a top-level array of
/// objects). Pure observer: it only ever clones borrowed outcomes, so
/// enabling it cannot perturb metrics or cost by a single bit. A failed
/// write is reported on stderr (observers have no error channel) and
/// the run's in-memory results are unaffected.
pub struct TraceExport {
    path: String,
    json: bool,
    rows: Vec<(RequestOutcome, &'static str)>,
}

impl TraceExport {
    pub fn csv(path: &str) -> Self {
        TraceExport { path: path.to_string(), json: false, rows: Vec::new() }
    }

    pub fn json(path: &str) -> Self {
        TraceExport { path: path.to_string(), json: true, rows: Vec::new() }
    }

    /// The CSV column set, in order: identity, latencies, one `<phase>_s`
    /// column per [`Phase`] (zero when absent), then the terminal
    /// `status` (`completed` | `failed`).
    pub fn csv_header() -> String {
        let mut cols = vec![
            "id".to_string(),
            "function".to_string(),
            "arrival_s".to_string(),
            "ttft_s".to_string(),
            "e2e_s".to_string(),
            "tpot_s".to_string(),
            "output_tokens".to_string(),
            "batch_size".to_string(),
            "cold_start_s".to_string(),
            "backbone_tier".to_string(),
            "cold_path".to_string(),
        ];
        cols.extend(
            crate::metrics::Phase::ALL
                .iter()
                .map(|p| format!("{}_s", p.name().replace('-', "_"))),
        );
        cols.push("status".to_string());
        cols.join(",")
    }

    /// Render the buffered rows to the selected format (also the unit
    /// tests' seam — rendering is deterministic, file I/O is not).
    pub fn render(&self) -> String {
        if self.json {
            return arr(self.rows.iter().map(|(o, status)| {
                let mut fields = vec![
                    ("id", num(o.id as f64)),
                    ("function", num(o.function as f64)),
                    ("arrival_s", num(o.arrival_s)),
                    ("ttft_s", num(o.ttft_s)),
                    ("e2e_s", num(o.e2e_s)),
                    ("tpot_s", num(o.tpot_s)),
                    ("output_tokens", num(o.output_tokens as f64)),
                    ("batch_size", num(o.batch_size as f64)),
                    ("cold_start_s", num(o.cold_start_s())),
                ];
                if let Some(t) = o.backbone_tier {
                    fields.push(("backbone_tier", crate::util::json::s(t.name())));
                }
                fields.push(("cold_path", crate::util::json::s(o.cold_path.name())));
                fields.push((
                    "phases",
                    Json::Obj(
                        o.phases
                            .iter()
                            .map(|(p, &d)| (p.name().to_string(), num(d)))
                            .collect(),
                    ),
                ));
                fields.push(("status", crate::util::json::s(status)));
                obj(fields)
            }))
            .dump();
        }
        let mut out = Self::csv_header();
        out.push('\n');
        for (o, status) in &self.rows {
            let tier = o.backbone_tier.map(|t| t.name()).unwrap_or("");
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{}",
                o.id,
                o.function,
                o.arrival_s,
                o.ttft_s,
                o.e2e_s,
                o.tpot_s,
                o.output_tokens,
                o.batch_size,
                o.cold_start_s(),
                tier,
                o.cold_path.name()
            ));
            for p in crate::metrics::Phase::ALL {
                out.push_str(&format!(",{}", o.phases.get(&p).copied().unwrap_or(0.0)));
            }
            out.push_str(&format!(",{status}\n"));
        }
        out
    }
}

impl Observer for TraceExport {
    fn on_request_complete(&mut self, _t_s: f64, outcome: &RequestOutcome) {
        self.rows.push((outcome.clone(), "completed"));
    }

    fn on_request_failed(&mut self, _t_s: f64, outcome: &RequestOutcome) {
        self.rows.push((outcome.clone(), "failed"));
    }

    fn on_finish(&mut self, _end_s: f64) {
        if let Err(e) = std::fs::write(&self.path, self.render()) {
            eprintln!("request-trace export to '{}' failed: {e}", self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(active_gb: f64, warm_gb: f64) -> AggregateBillSample {
        AggregateBillSample {
            active: ClassBillSample { gpus: 1, used_gb: active_gb, total_gb: 48.0 },
            loading: ClassBillSample::default(),
            idle_warm: ClassBillSample { gpus: 2, used_gb: warm_gb, total_gb: 96.0 },
            idle_cold: ClassBillSample::default(),
        }
    }

    #[test]
    fn sampler_splits_interval_across_buckets_exactly() {
        let mut s = BillSeriesSampler::new(10.0);
        // [5, 25) at 4 GB active: 5 s in bucket 0, 10 s in bucket 1,
        // 5 s in bucket 2.
        s.on_bill_sample(5.0, 20.0, &sample(4.0, 1.0));
        let series = s.into_series();
        assert_eq!(series.buckets.len(), 3);
        assert!((series.buckets[0].active_gb_s - 20.0).abs() < 1e-9);
        assert!((series.buckets[1].active_gb_s - 40.0).abs() < 1e-9);
        assert!((series.buckets[2].active_gb_s - 20.0).abs() < 1e-9);
        // GPU·s track the class counts (2 idle-warm GPUs).
        assert!((series.buckets[1].idle_warm_gpu_s - 20.0).abs() < 1e-9);
        // Totals conserve the interval integral.
        assert!((series.total_gb_s(BillClass::ActiveExec) - 80.0).abs() < 1e-9);
        assert!((series.total_gb_s(BillClass::IdleWarm) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn sampler_handles_exact_bucket_boundaries() {
        let mut s = BillSeriesSampler::new(10.0);
        s.on_bill_sample(10.0, 10.0, &sample(1.0, 0.0));
        let series = s.into_series();
        assert_eq!(series.buckets.len(), 2);
        assert_eq!(series.buckets[0].active_gb_s, 0.0);
        assert!((series.buckets[1].active_gb_s - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sampler_accumulates_many_short_intervals() {
        let mut s = BillSeriesSampler::new(60.0);
        for i in 0..600 {
            s.on_bill_sample(i as f64 * 0.1, 0.1, &sample(2.0, 0.0));
        }
        let series = s.into_series();
        // Float noise near the 60 s edge may spill an ulp-scale sliver
        // into a second bucket — the integral must still conserve.
        assert!(series.buckets.len() <= 2, "{}", series.buckets.len());
        assert!((series.buckets[0].active_gb_s - 120.0).abs() < 1e-6);
        assert!((series.total_gb_s(BillClass::ActiveExec) - 120.0).abs() < 1e-6);
    }

    #[test]
    fn series_json_shape() {
        let mut s = BillSeriesSampler::new(10.0);
        s.on_bill_sample(0.0, 10.0, &sample(3.0, 1.5));
        let j = s.into_series().to_json();
        assert_eq!(j.get("bucket_s").unwrap().as_f64(), Some(10.0));
        let b0 = j.get("buckets").unwrap().idx(0).unwrap();
        assert_eq!(b0.get("t0_s").unwrap().as_f64(), Some(0.0));
        assert!((b0.get("active_gb_s").unwrap().as_f64().unwrap() - 30.0).abs() < 1e-9);
        assert!((b0.get("idle_warm_gb_s").unwrap().as_f64().unwrap() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn billed_cost_prices_like_the_model() {
        use crate::coordinator::policy::ServerlessBilling;
        let mut obs = BilledCost::new(Box::new(ServerlessBilling { sharing: true }));
        obs.on_bill_sample(0.0, 2.0, &sample(10.0, 4.0));
        // active 10 GB × 2 s; idle-warm 4 GB × 2 s.
        assert!((obs.cost.gpu_active_gb_s - 20.0).abs() < 1e-9);
        assert!((obs.cost.gpu_idle_gb_s - 8.0).abs() < 1e-9);
    }

    #[test]
    fn trace_export_tags_terminal_status() {
        let o = RequestOutcome {
            id: 1,
            function: 0,
            arrival_s: 0.5,
            phases: Default::default(),
            ttft_s: 0.2,
            tpot_s: 0.01,
            e2e_s: 1.0,
            output_tokens: 10,
            batch_size: 1,
            backbone_tier: None,
            cold_path: Default::default(),
        };
        let mut failed = o.clone();
        failed.id = 2;
        let mut t = TraceExport::csv("unused.csv");
        Observer::on_request_complete(&mut t, 1.5, &o);
        Observer::on_request_failed(&mut t, 2.5, &failed);
        let csv = t.render();
        let mut lines = csv.lines();
        assert!(lines.next().unwrap().ends_with(",status"), "status is the last column");
        assert!(lines.next().unwrap().ends_with(",completed"));
        assert!(lines.next().unwrap().ends_with(",failed"));
        let mut tj = TraceExport::json("unused.json");
        Observer::on_request_failed(&mut tj, 2.5, &failed);
        let json = tj.render();
        assert!(json.contains("\"status\""), "{json}");
        assert!(json.contains("failed"), "{json}");
    }

    #[test]
    fn run_metrics_is_a_completion_observer() {
        let mut m = RunMetrics::default();
        let o = RequestOutcome {
            id: 7,
            function: 0,
            arrival_s: 1.0,
            phases: Default::default(),
            ttft_s: 0.5,
            tpot_s: 0.01,
            e2e_s: 2.0,
            output_tokens: 10,
            batch_size: 1,
            backbone_tier: None,
            cold_path: Default::default(),
        };
        Observer::on_request_complete(&mut m, 3.0, &o);
        assert_eq!(m.outcomes.len(), 1);
        assert_eq!(m.outcomes[0].id, 7);
    }
}
