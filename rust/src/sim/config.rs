//! System configurations: ServerlessLoRA, its ablation variants (§6.6),
//! the four baselines (§6.1), and plug-in systems (Predictive-LoRA) — all
//! expressed as policy knobs over the same cluster substrate, so every
//! comparison is policy-vs-policy on equal hardware (see DESIGN.md §1
//! "Substitutions").
//!
//! A `SystemConfig` is a *builder of policy bundles*: [`SystemConfig::bundle`]
//! turns the declarative knobs into the trait objects
//! (`coordinator::policy::{PreloadPolicy, BatchingPolicy, OffloadPolicy,
//! BillingModel}`) the engine actually consults. Adding a new system means
//! adding a bundle constructor here — never touching the engine core.

use crate::coordinator::policy::{
    AdaptiveBatching, BatchingPolicy, BillingModel, DynamicOffload, FastCheckpointPreload,
    FixedBatching, FullPreload, NoOffload, NoPreload, OffloadPolicy, OpportunisticPreload,
    PolicyBundle, PredictivePreload, PreloadPolicy, ServerfulBilling, ServerfulResident,
    ServerlessBilling,
};
use crate::trace::Pattern;

/// How cold artifacts are staged before an invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PreloadMode {
    /// No pre-loading at all: every cold start walks the full path
    /// (container → libraries → backbone from SSD → adapter → JIT).
    None,
    /// ServerlessLLM: no artifact pre-loading, but its multi-tier
    /// checkpoint store makes *backbone* loads run at PCIe speed.
    FastCheckpoint,
    /// InstaInfer: opportunistically pre-loads libraries + models into
    /// idle containers' RAM (never kernels; never GPU-resident); its
    /// predictive pre-loading churns, so a mispredicted invocation waits
    /// for the in-flight preload before loading its own artifacts.
    ContainerOpportunistic {
        /// Predictor hit rate (pattern-dependent: bursty traffic defeats
        /// time-series prediction).
        hit_rate: f64,
    },
    /// ServerlessLoRA §4.1: full PCKP pre-loading of libraries (container),
    /// backbone+adapter+kernels (GPU), CUDA context pre-warmed.
    Full,
    /// Predictive pre-loading (Predictive-LoRA-style): per-function EWMA
    /// arrival-rate forecast; artifacts are staged ahead of predicted
    /// bursts instead of exhaustively at deploy time.
    Predictive,
}

/// Batching policy (§4.2 / §6.6 NAB variants).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchingMode {
    /// Two-layer adaptive batching (Eq. 2–5).
    Adaptive,
    /// Fixed batch size + fixed delay (NAB ablations, baseline batchers).
    Fixed { size: usize, delay_s: f64 },
}

/// A complete system-under-test description.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub name: &'static str,
    /// Serverful systems run always-on dedicated GPUs: zero cold starts,
    /// flat per-GPU-hour billing.
    pub serverful: bool,
    /// §4.4 backbone sharing across functions (one copy per GPU).
    pub backbone_sharing: bool,
    pub preload: PreloadMode,
    /// §4.3 dynamic offloading (vs blocking until memory frees).
    pub dynamic_offload: bool,
    pub batching: BatchingMode,
    /// Keep-alive window for function instances, seconds.
    pub keepalive_s: f64,
}

impl SystemConfig {
    // ------------------------------------------------------------ systems

    pub fn serverless_lora() -> Self {
        SystemConfig {
            name: "ServerlessLoRA",
            serverful: false,
            backbone_sharing: true,
            preload: PreloadMode::Full,
            dynamic_offload: true,
            batching: BatchingMode::Adaptive,
            keepalive_s: 180.0,
        }
    }

    pub fn serverless_llm() -> Self {
        SystemConfig {
            name: "ServerlessLLM",
            serverful: false,
            backbone_sharing: false,
            preload: PreloadMode::FastCheckpoint,
            dynamic_offload: false,
            // Fixed batching at the memory-bound size the paper's Table 2
            // reports for the baselines (peak batch 32).
            batching: BatchingMode::Fixed { size: 32, delay_s: 0.25 },
            keepalive_s: 180.0,
        }
    }

    pub fn instainfer(pattern: Pattern) -> Self {
        let hit_rate = match pattern {
            Pattern::Predictable => 0.7,
            Pattern::Normal => 0.5,
            Pattern::Bursty => 0.3,
        };
        SystemConfig {
            name: "InstaInfer",
            serverful: false,
            backbone_sharing: false,
            preload: PreloadMode::ContainerOpportunistic { hit_rate },
            dynamic_offload: false,
            batching: BatchingMode::Fixed { size: 32, delay_s: 0.25 },
            keepalive_s: 180.0,
        }
    }

    pub fn vllm() -> Self {
        SystemConfig {
            name: "vLLM",
            serverful: true,
            backbone_sharing: false, // one dedicated deployment per function
            preload: PreloadMode::Full,
            dynamic_offload: false,
            // vLLM's continuous (iteration-level) batching is approximated
            // by the slot-aware adaptive batcher: coalesce co-arriving
            // requests, dispatch the moment a prefill slot frees.
            batching: BatchingMode::Adaptive,
            keepalive_s: f64::INFINITY,
        }
    }

    pub fn dlora() -> Self {
        SystemConfig {
            name: "dLoRA",
            serverful: true,
            backbone_sharing: true, // shares backbone across adapters
            preload: PreloadMode::Full,
            dynamic_offload: false,
            batching: BatchingMode::Adaptive, // continuous batching too
            keepalive_s: f64::INFINITY,
        }
    }

    /// Predictive-LoRA: a pure policy plug-in — ServerlessLoRA's substrate
    /// (sharing, adaptive batching, dynamic offload) with forecast-driven
    /// pre-staging instead of exhaustive deploy-time PCKP.
    pub fn predictive() -> Self {
        SystemConfig {
            name: "Predictive-LoRA",
            preload: PreloadMode::Predictive,
            ..Self::serverless_lora()
        }
    }

    // ---------------------------------------------------------- ablations

    /// NBS: no backbone sharing — each function holds a private backbone.
    pub fn nbs() -> Self {
        SystemConfig {
            name: "ServerlessLoRA-NBS",
            backbone_sharing: false,
            ..Self::serverless_lora()
        }
    }

    /// NPL: no pre-loading.
    pub fn npl() -> Self {
        SystemConfig {
            name: "ServerlessLoRA-NPL",
            preload: PreloadMode::None,
            ..Self::serverless_lora()
        }
    }

    /// NDO: no dynamic offloading (block until memory frees).
    pub fn ndo() -> Self {
        SystemConfig {
            name: "ServerlessLoRA-NDO",
            dynamic_offload: false,
            ..Self::serverless_lora()
        }
    }

    /// NAB #1–#3: fixed batching strategies from §6.6.
    pub fn nab(variant: usize) -> Self {
        let batching = match variant {
            1 => BatchingMode::Fixed { size: 1, delay_s: 0.0 },
            2 => BatchingMode::Fixed { size: 10, delay_s: 0.5 },
            3 => BatchingMode::Fixed { size: 20, delay_s: 1.0 },
            _ => panic!("NAB variants are 1..=3"),
        };
        let name = match variant {
            1 => "ServerlessLoRA-NAB#1",
            2 => "ServerlessLoRA-NAB#2",
            _ => "ServerlessLoRA-NAB#3",
        };
        SystemConfig { name, batching, ..Self::serverless_lora() }
    }

    pub fn is_serverless(&self) -> bool {
        !self.serverful
    }

    // ------------------------------------------------------ policy bundles

    /// Build the policy bundle this configuration describes. `seed` feeds
    /// policy-internal randomness (InstaInfer's predictor churn keeps the
    /// engine's historical rng stream, so metrics are bit-stable).
    pub fn bundle(&self, seed: u64) -> PolicyBundle {
        let preload: Box<dyn PreloadPolicy> = if self.serverful {
            Box::new(ServerfulResident)
        } else {
            match self.preload {
                PreloadMode::None => Box::new(NoPreload),
                PreloadMode::FastCheckpoint => Box::new(FastCheckpointPreload),
                PreloadMode::ContainerOpportunistic { hit_rate } => {
                    Box::new(OpportunisticPreload::new(hit_rate, seed))
                }
                PreloadMode::Full => Box::new(FullPreload),
                PreloadMode::Predictive => Box::new(PredictivePreload::default()),
            }
        };
        let batching: Box<dyn BatchingPolicy> = match self.batching {
            BatchingMode::Adaptive => Box::new(AdaptiveBatching),
            BatchingMode::Fixed { size, delay_s } => {
                Box::new(FixedBatching { size, delay_s })
            }
        };
        let offload: Box<dyn OffloadPolicy> = if self.dynamic_offload {
            Box::new(DynamicOffload)
        } else {
            Box::new(NoOffload)
        };
        let billing: Box<dyn BillingModel> = if self.serverful {
            Box::new(ServerfulBilling)
        } else {
            Box::new(ServerlessBilling { sharing: self.backbone_sharing })
        };
        PolicyBundle { preload, batching, offload, billing }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_differ_in_exactly_one_knob() {
        let full = SystemConfig::serverless_lora();
        assert!(!SystemConfig::nbs().backbone_sharing && full.backbone_sharing);
        assert_eq!(SystemConfig::npl().preload, PreloadMode::None);
        assert!(!SystemConfig::ndo().dynamic_offload && full.dynamic_offload);
        assert!(matches!(
            SystemConfig::nab(1).batching,
            BatchingMode::Fixed { size: 1, .. }
        ));
    }

    #[test]
    fn instainfer_hit_rate_degrades_with_burstiness() {
        let get = |p| match SystemConfig::instainfer(p).preload {
            PreloadMode::ContainerOpportunistic { hit_rate } => hit_rate,
            _ => unreachable!(),
        };
        assert!(get(Pattern::Predictable) > get(Pattern::Normal));
        assert!(get(Pattern::Normal) > get(Pattern::Bursty));
    }

    #[test]
    fn serverful_systems_marked() {
        assert!(SystemConfig::vllm().serverful);
        assert!(SystemConfig::dlora().serverful);
        assert!(SystemConfig::serverless_lora().is_serverless());
    }

    #[test]
    #[should_panic]
    fn nab_out_of_range_panics() {
        SystemConfig::nab(4);
    }

    #[test]
    fn predictive_differs_only_in_preload() {
        let p = SystemConfig::predictive();
        let full = SystemConfig::serverless_lora();
        assert_eq!(p.preload, PreloadMode::Predictive);
        assert_eq!(p.backbone_sharing, full.backbone_sharing);
        assert_eq!(p.dynamic_offload, full.dynamic_offload);
        assert!(matches!(p.batching, BatchingMode::Adaptive));
        assert!(p.is_serverless());
    }

    #[test]
    fn bundles_map_knobs_to_policies() {
        let b = SystemConfig::serverless_lora().bundle(1);
        assert_eq!(b.preload.name(), "full-pckp");
        assert_eq!(b.batching.name(), "adaptive");
        assert_eq!(b.offload.name(), "dynamic");
        assert_eq!(b.billing.name(), "serverless");

        let b = SystemConfig::serverless_llm().bundle(1);
        assert_eq!(b.preload.name(), "fast-checkpoint");
        assert_eq!(b.batching.name(), "fixed");
        assert_eq!(b.offload.name(), "block");

        let b = SystemConfig::instainfer(Pattern::Normal).bundle(1);
        assert_eq!(b.preload.name(), "container-opportunistic");

        let b = SystemConfig::vllm().bundle(1);
        assert_eq!(b.preload.name(), "serverful-resident");
        assert_eq!(b.billing.name(), "serverful");

        let b = SystemConfig::npl().bundle(1);
        assert_eq!(b.preload.name(), "none");
        let b = SystemConfig::ndo().bundle(1);
        assert_eq!(b.offload.name(), "block");
        let b = SystemConfig::predictive().bundle(1);
        assert_eq!(b.preload.name(), "predictive-ewma");
    }
}
