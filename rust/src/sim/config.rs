//! System configurations: ServerlessLoRA, its ablation variants (§6.6),
//! the four baselines (§6.1), and plug-in systems (Predictive-LoRA) — all
//! expressed as policy knobs over the same cluster substrate, so every
//! comparison is policy-vs-policy on equal hardware (see DESIGN.md §1
//! "Substitutions").
//!
//! A `SystemConfig` is a *builder of policy bundles*: [`SystemConfig::bundle`]
//! turns the declarative knobs into the trait objects
//! (`coordinator::policy::{PreloadPolicy, BatchingPolicy, OffloadPolicy,
//! BillingModel}`) the engine actually consults. Adding a new system means
//! adding a bundle constructor here — never touching the engine core.

use crate::artifact::{params, LinkCaps};
use crate::coldstart::ColdStartSpec;
use crate::coordinator::policy::{
    AdaptiveBatching, BatchingPolicy, BillingModel, CachePolicy, ColdStartPolicy,
    DynamicOffload, FastCheckpointPreload, FixedBatching, FullPreload, LruCache, NoOffload,
    NoPreload, OffloadPolicy, OpportunisticPreload, PinHotCache, PolicyBundle,
    PredictivePreload, PreloadPolicy, ServerfulBilling, ServerfulResident,
    ServerlessBilling, SizeAwareLruCache, SpecColdStart, TieredColdStart,
};
use crate::sim::fault::FaultSpec;
use crate::trace::Pattern;

/// How cold artifacts are staged before an invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PreloadMode {
    /// No pre-loading at all: every cold start walks the full path
    /// (container → libraries → backbone from SSD → adapter → JIT).
    None,
    /// ServerlessLLM: no artifact pre-loading, but its multi-tier
    /// checkpoint store makes *backbone* loads run at PCIe speed.
    FastCheckpoint,
    /// InstaInfer: opportunistically pre-loads libraries + models into
    /// idle containers' RAM (never kernels; never GPU-resident); its
    /// predictive pre-loading churns, so a mispredicted invocation waits
    /// for the in-flight preload before loading its own artifacts.
    ContainerOpportunistic {
        /// Predictor hit rate (pattern-dependent: bursty traffic defeats
        /// time-series prediction).
        hit_rate: f64,
    },
    /// ServerlessLoRA §4.1: full PCKP pre-loading of libraries (container),
    /// backbone+adapter+kernels (GPU), CUDA context pre-warmed.
    Full,
    /// Predictive pre-loading (Predictive-LoRA-style): per-function EWMA
    /// arrival-rate forecast; artifacts are staged ahead of predicted
    /// bursts instead of exhaustively at deploy time.
    Predictive,
}

/// Batching policy (§4.2 / §6.6 NAB variants).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchingMode {
    /// Two-layer adaptive batching (Eq. 2–5).
    Adaptive,
    /// Fixed batch size + fixed delay (NAB ablations, baseline batchers).
    Fixed { size: usize, delay_s: f64 },
}

/// Host-cache admission/eviction policy selector (the fifth policy knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// Always admit; evict least-recently-used.
    Lru,
    /// Always admit; evict largest-first (ties toward older).
    SizeAwareLru,
    /// Frequently-hit checkpoints are pinned; decline admissions that
    /// would require evicting a pin.
    PinHot,
}

impl CacheMode {
    pub const IDS: [&'static str; 3] = ["lru", "size-aware-lru", "pin-hot"];

    pub fn id(self) -> &'static str {
        match self {
            CacheMode::Lru => "lru",
            CacheMode::SizeAwareLru => "size-aware-lru",
            CacheMode::PinHot => "pin-hot",
        }
    }

    pub fn from_id(s: &str) -> Option<CacheMode> {
        match s {
            "lru" => Some(CacheMode::Lru),
            "size-aware-lru" => Some(CacheMode::SizeAwareLru),
            "pin-hot" => Some(CacheMode::PinHot),
            _ => None,
        }
    }
}

/// Tiered-store configuration: turns on the dynamic memory hierarchy —
/// per-node host-RAM checkpoint cache plus fair-share (processor-sharing)
/// link contention on NIC/NVMe/PCIe.  `None` on a [`SystemConfig`] keeps
/// the historical flat-latency fast path, bit-identical to pre-tiered
/// runs; with tiers on, a *solo* flow on default bandwidths still
/// reproduces the flat latencies exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    /// Host-RAM checkpoint cache per node, GB (0: contention modelling
    /// without a cache tier).
    pub host_cache_gb: f64,
    /// Per-node link bandwidths, GB/s.
    pub nic_gbps: f64,
    pub nvme_gbps: f64,
    pub pcie_gbps: f64,
    /// Node-local NVMe holds every checkpoint (deployment pre-seeded) —
    /// the historical assumption.  When false, a host-cache miss streams
    /// from the remote store over the NIC instead of reading NVMe.
    pub ssd_seeded: bool,
    /// Host-cache admission/eviction policy.
    pub cache: CacheMode,
}

impl Default for TierSpec {
    fn default() -> Self {
        TierSpec {
            host_cache_gb: 64.0,
            nic_gbps: params::BW_REMOTE_GBPS,
            nvme_gbps: params::BW_SSD_GBPS,
            pcie_gbps: params::BW_PCIE_GBPS,
            ssd_seeded: true,
            cache: CacheMode::Lru,
        }
    }
}

impl TierSpec {
    pub fn caps(&self) -> LinkCaps {
        LinkCaps {
            nic_gbps: self.nic_gbps,
            nvme_gbps: self.nvme_gbps,
            pcie_gbps: self.pcie_gbps,
        }
    }
}

/// A complete system-under-test description.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub name: &'static str,
    /// Serverful systems run always-on dedicated GPUs: zero cold starts,
    /// flat per-GPU-hour billing.
    pub serverful: bool,
    /// §4.4 backbone sharing across functions (one copy per GPU).
    pub backbone_sharing: bool,
    pub preload: PreloadMode,
    /// §4.3 dynamic offloading (vs blocking until memory frees).
    pub dynamic_offload: bool,
    pub batching: BatchingMode,
    /// Keep-alive window for function instances, seconds.
    pub keepalive_s: f64,
    /// Tiered artifact store + link contention.  `None` (the default for
    /// every named system) keeps the flat-latency fast path.
    pub tiers: Option<TierSpec>,
    /// Fault injection (GPU crash/recover, transient load failures) and
    /// the retry/timeout policy.  `None` (the default for every named
    /// system) builds no injector, draws no RNG, schedules no events —
    /// bit-identical to a faultless build.
    pub faults: Option<FaultSpec>,
    /// Cold-start strategy (sixth policy axis): snapshot-restore and
    /// pipelined multi-GPU loading as alternatives to the tiered walk.
    /// `None` (the default for every named system) selects the tiered
    /// strategy and performs zero additional work — bit-identical to
    /// pre-subsystem builds.  Requires `tiers` to be set to take effect
    /// (the alternative paths are defined over the tiered machinery).
    pub cold_start: Option<ColdStartSpec>,
}

impl SystemConfig {
    // ------------------------------------------------------------ systems

    pub fn serverless_lora() -> Self {
        SystemConfig {
            name: "ServerlessLoRA",
            serverful: false,
            backbone_sharing: true,
            preload: PreloadMode::Full,
            dynamic_offload: true,
            batching: BatchingMode::Adaptive,
            keepalive_s: 180.0,
            tiers: None,
            faults: None,
            cold_start: None,
        }
    }

    pub fn serverless_llm() -> Self {
        SystemConfig {
            name: "ServerlessLLM",
            serverful: false,
            backbone_sharing: false,
            preload: PreloadMode::FastCheckpoint,
            dynamic_offload: false,
            // Fixed batching at the memory-bound size the paper's Table 2
            // reports for the baselines (peak batch 32).
            batching: BatchingMode::Fixed { size: 32, delay_s: 0.25 },
            keepalive_s: 180.0,
            tiers: None,
            faults: None,
            cold_start: None,
        }
    }

    pub fn instainfer(pattern: Pattern) -> Self {
        let hit_rate = match pattern {
            Pattern::Predictable => 0.7,
            Pattern::Normal => 0.5,
            Pattern::Bursty => 0.3,
        };
        SystemConfig {
            name: "InstaInfer",
            serverful: false,
            backbone_sharing: false,
            preload: PreloadMode::ContainerOpportunistic { hit_rate },
            dynamic_offload: false,
            batching: BatchingMode::Fixed { size: 32, delay_s: 0.25 },
            keepalive_s: 180.0,
            tiers: None,
            faults: None,
            cold_start: None,
        }
    }

    pub fn vllm() -> Self {
        SystemConfig {
            name: "vLLM",
            serverful: true,
            backbone_sharing: false, // one dedicated deployment per function
            preload: PreloadMode::Full,
            dynamic_offload: false,
            // vLLM's continuous (iteration-level) batching is approximated
            // by the slot-aware adaptive batcher: coalesce co-arriving
            // requests, dispatch the moment a prefill slot frees.
            batching: BatchingMode::Adaptive,
            keepalive_s: f64::INFINITY,
            tiers: None,
            faults: None,
            cold_start: None,
        }
    }

    pub fn dlora() -> Self {
        SystemConfig {
            name: "dLoRA",
            serverful: true,
            backbone_sharing: true, // shares backbone across adapters
            preload: PreloadMode::Full,
            dynamic_offload: false,
            batching: BatchingMode::Adaptive, // continuous batching too
            keepalive_s: f64::INFINITY,
            tiers: None,
            faults: None,
            cold_start: None,
        }
    }

    /// Predictive-LoRA: a pure policy plug-in — ServerlessLoRA's substrate
    /// (sharing, adaptive batching, dynamic offload) with forecast-driven
    /// pre-staging instead of exhaustive deploy-time PCKP.
    pub fn predictive() -> Self {
        SystemConfig {
            name: "Predictive-LoRA",
            preload: PreloadMode::Predictive,
            ..Self::serverless_lora()
        }
    }

    // ---------------------------------------------------------- ablations

    /// NBS: no backbone sharing — each function holds a private backbone.
    pub fn nbs() -> Self {
        SystemConfig {
            name: "ServerlessLoRA-NBS",
            backbone_sharing: false,
            ..Self::serverless_lora()
        }
    }

    /// NPL: no pre-loading.
    pub fn npl() -> Self {
        SystemConfig {
            name: "ServerlessLoRA-NPL",
            preload: PreloadMode::None,
            ..Self::serverless_lora()
        }
    }

    /// NDO: no dynamic offloading (block until memory frees).
    pub fn ndo() -> Self {
        SystemConfig {
            name: "ServerlessLoRA-NDO",
            dynamic_offload: false,
            ..Self::serverless_lora()
        }
    }

    /// NAB #1–#3: fixed batching strategies from §6.6.
    pub fn nab(variant: usize) -> Self {
        let batching = match variant {
            1 => BatchingMode::Fixed { size: 1, delay_s: 0.0 },
            2 => BatchingMode::Fixed { size: 10, delay_s: 0.5 },
            3 => BatchingMode::Fixed { size: 20, delay_s: 1.0 },
            _ => panic!("NAB variants are 1..=3"),
        };
        let name = match variant {
            1 => "ServerlessLoRA-NAB#1",
            2 => "ServerlessLoRA-NAB#2",
            _ => "ServerlessLoRA-NAB#3",
        };
        SystemConfig { name, batching, ..Self::serverless_lora() }
    }

    pub fn is_serverless(&self) -> bool {
        !self.serverful
    }

    /// Enable the tiered store on any named system (builder style).
    pub fn with_tiers(mut self, tiers: TierSpec) -> Self {
        self.tiers = Some(tiers);
        self
    }

    /// Enable fault injection on any named system (builder style).
    pub fn with_faults(mut self, faults: FaultSpec) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Select a cold-start strategy on any named system (builder style).
    pub fn with_cold_start(mut self, cold_start: ColdStartSpec) -> Self {
        self.cold_start = Some(cold_start);
        self
    }

    // ------------------------------------------------------ policy bundles

    /// Build the policy bundle this configuration describes. `seed` feeds
    /// policy-internal randomness (InstaInfer's predictor churn keeps the
    /// engine's historical rng stream, so metrics are bit-stable).
    pub fn bundle(&self, seed: u64) -> PolicyBundle {
        let preload: Box<dyn PreloadPolicy> = if self.serverful {
            Box::new(ServerfulResident)
        } else {
            match self.preload {
                PreloadMode::None => Box::new(NoPreload),
                PreloadMode::FastCheckpoint => Box::new(FastCheckpointPreload),
                PreloadMode::ContainerOpportunistic { hit_rate } => {
                    Box::new(OpportunisticPreload::new(hit_rate, seed))
                }
                PreloadMode::Full => Box::new(FullPreload),
                PreloadMode::Predictive => Box::new(PredictivePreload::default()),
            }
        };
        let batching: Box<dyn BatchingPolicy> = match self.batching {
            BatchingMode::Adaptive => Box::new(AdaptiveBatching),
            BatchingMode::Fixed { size, delay_s } => {
                Box::new(FixedBatching { size, delay_s })
            }
        };
        let offload: Box<dyn OffloadPolicy> = if self.dynamic_offload {
            Box::new(DynamicOffload)
        } else {
            Box::new(NoOffload)
        };
        let billing: Box<dyn BillingModel> = if self.serverful {
            Box::new(ServerfulBilling)
        } else {
            Box::new(ServerlessBilling { sharing: self.backbone_sharing })
        };
        let cache: Box<dyn CachePolicy> =
            match self.tiers.map(|t| t.cache).unwrap_or(CacheMode::Lru) {
                CacheMode::Lru => Box::new(LruCache),
                CacheMode::SizeAwareLru => Box::new(SizeAwareLruCache),
                CacheMode::PinHot => Box::new(PinHotCache::default()),
            };
        let cold_start: Box<dyn ColdStartPolicy> = match self.cold_start {
            Some(cs) => Box::new(SpecColdStart::new(cs)),
            // `None` carries the inert tiered default; the engine never
            // walks the cold-start branches without the spec anyway.
            None => Box::new(TieredColdStart::default()),
        };
        PolicyBundle { preload, batching, offload, billing, cache, cold_start }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_differ_in_exactly_one_knob() {
        let full = SystemConfig::serverless_lora();
        assert!(!SystemConfig::nbs().backbone_sharing && full.backbone_sharing);
        assert_eq!(SystemConfig::npl().preload, PreloadMode::None);
        assert!(!SystemConfig::ndo().dynamic_offload && full.dynamic_offload);
        assert!(matches!(
            SystemConfig::nab(1).batching,
            BatchingMode::Fixed { size: 1, .. }
        ));
    }

    #[test]
    fn instainfer_hit_rate_degrades_with_burstiness() {
        let get = |p| match SystemConfig::instainfer(p).preload {
            PreloadMode::ContainerOpportunistic { hit_rate } => hit_rate,
            _ => unreachable!(),
        };
        assert!(get(Pattern::Predictable) > get(Pattern::Normal));
        assert!(get(Pattern::Normal) > get(Pattern::Bursty));
    }

    #[test]
    fn serverful_systems_marked() {
        assert!(SystemConfig::vllm().serverful);
        assert!(SystemConfig::dlora().serverful);
        assert!(SystemConfig::serverless_lora().is_serverless());
    }

    #[test]
    #[should_panic]
    fn nab_out_of_range_panics() {
        SystemConfig::nab(4);
    }

    #[test]
    fn predictive_differs_only_in_preload() {
        let p = SystemConfig::predictive();
        let full = SystemConfig::serverless_lora();
        assert_eq!(p.preload, PreloadMode::Predictive);
        assert_eq!(p.backbone_sharing, full.backbone_sharing);
        assert_eq!(p.dynamic_offload, full.dynamic_offload);
        assert!(matches!(p.batching, BatchingMode::Adaptive));
        assert!(p.is_serverless());
    }

    #[test]
    fn bundles_map_knobs_to_policies() {
        let b = SystemConfig::serverless_lora().bundle(1);
        assert_eq!(b.preload.name(), "full-pckp");
        assert_eq!(b.batching.name(), "adaptive");
        assert_eq!(b.offload.name(), "dynamic");
        assert_eq!(b.billing.name(), "serverless");
        // Flat (tiers: None) still carries a cache policy; it is simply
        // never consulted — LRU is the inert default.
        assert_eq!(b.cache.name(), "lru");

        let b = SystemConfig::serverless_llm().bundle(1);
        assert_eq!(b.preload.name(), "fast-checkpoint");
        assert_eq!(b.batching.name(), "fixed");
        assert_eq!(b.offload.name(), "block");

        let b = SystemConfig::instainfer(Pattern::Normal).bundle(1);
        assert_eq!(b.preload.name(), "container-opportunistic");

        let b = SystemConfig::vllm().bundle(1);
        assert_eq!(b.preload.name(), "serverful-resident");
        assert_eq!(b.billing.name(), "serverful");

        let b = SystemConfig::npl().bundle(1);
        assert_eq!(b.preload.name(), "none");
        let b = SystemConfig::ndo().bundle(1);
        assert_eq!(b.offload.name(), "block");
        let b = SystemConfig::predictive().bundle(1);
        assert_eq!(b.preload.name(), "predictive-ewma");
    }

    #[test]
    fn tier_spec_defaults_match_flat_bandwidths_and_select_cache_policy() {
        // Every named system ships with the flat fast path.
        assert!(SystemConfig::serverless_lora().tiers.is_none());
        assert!(SystemConfig::vllm().tiers.is_none());
        assert!(SystemConfig::nab(2).tiers.is_none());

        // Default TierSpec bandwidths are exactly the flat-model constants:
        // a solo flow under tiers reproduces today's latencies bitwise.
        let t = TierSpec::default();
        assert_eq!(t.nic_gbps.to_bits(), params::BW_REMOTE_GBPS.to_bits());
        assert_eq!(t.nvme_gbps.to_bits(), params::BW_SSD_GBPS.to_bits());
        assert_eq!(t.pcie_gbps.to_bits(), params::BW_PCIE_GBPS.to_bits());
        assert_eq!(t.caps(), LinkCaps::DEFAULT);
        assert!(t.ssd_seeded);

        // The cache knob maps onto the fifth policy trait.
        let cfg = SystemConfig::serverless_lora().with_tiers(TierSpec {
            cache: CacheMode::SizeAwareLru,
            ..TierSpec::default()
        });
        assert_eq!(cfg.bundle(1).cache.name(), "size-aware-lru");
        let cfg = SystemConfig::serverless_lora()
            .with_tiers(TierSpec { cache: CacheMode::PinHot, ..TierSpec::default() });
        assert_eq!(cfg.bundle(1).cache.name(), "pin-hot");

        // Round-trip of the scenario-facing ids.
        for id in CacheMode::IDS {
            assert_eq!(CacheMode::from_id(id).unwrap().id(), id);
        }
        assert!(CacheMode::from_id("mru").is_none());
    }

    #[test]
    fn cold_start_knob_maps_onto_the_sixth_policy() {
        use crate::coldstart::{ColdStartKind, ColdStartSpec};
        // Every named system ships without a cold-start spec (tiered).
        assert!(SystemConfig::serverless_lora().cold_start.is_none());
        assert!(SystemConfig::vllm().cold_start.is_none());
        let b = SystemConfig::serverless_lora().bundle(1);
        assert_eq!(b.cold_start.name(), "tiered");
        assert_eq!(b.cold_start.strategy(0), ColdStartKind::Tiered);

        let cfg = SystemConfig::npl()
            .with_tiers(TierSpec::default())
            .with_cold_start(ColdStartSpec::uniform(ColdStartKind::SnapshotRestore));
        let b = cfg.bundle(1);
        assert_eq!(b.cold_start.name(), "snapshot-restore");
        assert_eq!(b.cold_start.strategy(7), ColdStartKind::SnapshotRestore);

        // Head/tail mixing answers per function id.
        let mixed = SystemConfig::npl().with_cold_start(ColdStartSpec {
            strategy: ColdStartKind::Pipelined,
            head: Some(ColdStartKind::SnapshotRestore),
            head_fns: 1,
            ..ColdStartSpec::default()
        });
        let b = mixed.bundle(1);
        assert_eq!(b.cold_start.name(), "mixed");
        assert_eq!(b.cold_start.strategy(0), ColdStartKind::SnapshotRestore);
        assert_eq!(b.cold_start.strategy(1), ColdStartKind::Pipelined);
        assert!(b.cold_start.pipeline().k >= 2);
        assert!(b.cold_start.snapshot().restore_s > 0.0);

        for id in ColdStartKind::IDS {
            assert_eq!(ColdStartKind::from_id(id).unwrap().id(), id);
        }
        assert!(ColdStartKind::from_id("flash").is_none());
    }
}
