//! The simulator's event core: event kinds, the deterministic total order
//! (time, then insertion sequence), and the queue itself — a hierarchical
//! timing wheel (calendar queue) with **O(1) cancellation handles**.
//!
//! Every `schedule` returns an [`EventToken`]; `cancel(token)` unlinks the
//! slot entry in O(1), so producers that supersede their own wakeups
//! (queue re-arms, GPU-tick re-schedules, keep-alive moves) remove the
//! dead event outright instead of carrying generation/version staleness
//! guards and letting stale entries bloat the queue until their instant.
//!
//! ## Structure
//!
//! Simulated time is discretized into `TICK_S`-second ticks. Six wheel
//! levels of 64 slots each cover `64^6` ticks (≈ 2.2 simulated years at
//! the 1 ms tick); events beyond that horizon wait in a small overflow
//! map and are promoted when the wheel rolls toward them. Each slot is an
//! intrusive doubly-linked list over a slab, which is what makes
//! cancellation O(1). Expiring slots drain into a `ready` buffer sorted
//! by exact `(t, seq)`, so the pop order is **identical** to a binary
//! min-heap over `(t, seq)` — discretization never reorders events, it
//! only buckets them. The pre-wheel heap is kept under `#[cfg(test)]` as
//! the differential oracle (`heap::HeapEventQueue`).
//!
//! Ordering contract (unchanged from the heap era): pops ascend by time,
//! with same-instant ties in insertion order — what makes same-seed runs
//! bit-identical.

use std::collections::BTreeMap;

use crate::cluster::GpuId;

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Request `i` (index into the workload stream) arrives.
    Arrival(usize),
    /// Re-check function `f`'s queue (debounce settle / Eq. 3 expiry).
    /// Superseded checks are *cancelled* by their producer, so a check
    /// that fires is always current — no staleness stamp needed.
    QueueCheck(usize),
    /// Batch `b` finished loading its artifacts — or, for a segmented
    /// (tiered) load, finished its *current* segment: the dispatch layer
    /// re-pushes one `LoadDone` per segment, and fair-share retimes
    /// cancel + re-push the outstanding one (`sim::flow`). A firing
    /// `LoadDone` is always current; stale ones are cancelled O(1).
    LoadDone(u64),
    /// Processor-sharing completion sweep on a GPU. Exactly one is
    /// outstanding per GPU; re-scheduling cancels the previous one.
    GpuTick(GpuId),
    /// Keep-alive expiry sweep. Exactly one is outstanding at any time;
    /// it is re-armed (cancel + push) whenever the earliest expiry moves.
    KeepaliveCheck,
    /// Fault injection: the GPU goes down. Its in-flight batches are
    /// killed and their requests re-enqueued. Scheduled only when
    /// `SystemConfig::faults` is `Some`.
    GpuCrash(GpuId),
    /// Fault injection: the GPU comes back up (cold — residency was
    /// invalidated at crash time).
    GpuRecover(GpuId),
    /// Retry backoff expired for request `id`: re-enqueue it for
    /// dispatch. One live wake per retrying request.
    RetryWake(u64),
    /// Correlated-domain fault: node `n` goes down — every hosted GPU's
    /// batches are killed and the node's host-RAM cache is wiped once.
    /// Scheduled only when `FaultSpec::domains.node` is set.
    NodeCrash(usize),
    /// Correlated-domain fault: node `n` comes back up (cold).
    NodeRecover(usize),
    /// Correlated-domain fault: the engine's whole zone browns out —
    /// every node goes down atomically. Scheduled only when
    /// `FaultSpec::domains.zone` is set.
    ZoneOutage,
    /// The zone comes back: every node is marked up (individually
    /// crashed GPUs stay down).
    ZoneRecover,
    /// Degraded-mode fault: the GPU enters a drawn slowdown for a drawn
    /// duration (it keeps running — billing classes are unchanged).
    /// Scheduled only when `FaultSpec::degrade` is set.
    GpuDegrade(GpuId),
    /// The degraded GPU returns to full speed. Exactly one is
    /// outstanding per degraded GPU; a crash mid-degrade cancels it.
    GpuRestore(GpuId),
    /// Cold-start subsystem: the snapshot of function `f` being built on
    /// node `n` is ready for admission into the node's host cache.
    /// Scheduled only when `SystemConfig::cold_start` selects the
    /// snapshot-restore strategy; a node/GPU failure cancels it.
    SnapshotReady(usize, usize),
    /// Cold-start subsystem: one sibling shard of a pipelined multi-GPU
    /// backbone load finished its transfer. The id is synthetic
    /// (`>= 1 << 48`, see `sim::coldstart`), disjoint from batch ids.
    ShardDone(u64),
    /// Cold-start subsystem: the post-load consolidation transfer of a
    /// pipelined load finished; the batch may now finalize.
    ConsolidateDone(u64),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub t: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Handle to one scheduled event. Cancelling a token whose event already
/// fired (or was already cancelled) is a safe no-op: the slab slot's
/// generation is bumped on every free, so stale handles never touch a
/// reused slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EventToken {
    idx: u32,
    gen: u32,
}

/// A pending event, as seen by invariant checks / hygiene tests (never by
/// the simulation itself).
#[derive(Debug)]
pub struct Pending<'a> {
    pub t: f64,
    pub seq: u64,
    pub kind: &'a EventKind,
}

const LEVEL_BITS: u32 = 6;
const SLOTS: usize = 1 << LEVEL_BITS; // 64
const LEVELS: usize = 6;
/// Ticks addressable by the wheel: `64^LEVELS = 2^36`.
const WHEEL_BITS: u32 = LEVEL_BITS * LEVELS as u32;
/// Wheel tick size in simulated seconds. Order-correctness does not
/// depend on this (slots sort by exact `(t, seq)` on expiry); it only
/// sets how many events share a slot.
const TICK_S: f64 = 1e-3;
const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Loc {
    /// On the free list.
    Free,
    /// Linked into wheel slot `heads[level][slot]`.
    Wheel { level: u8, slot: u8 },
    /// In the far-future overflow map, keyed `(tick, seq)`.
    Overflow,
    /// In the sorted `ready` buffer (its tick has expired).
    Ready,
}

#[derive(Debug, Clone)]
struct Entry {
    t: f64,
    tick: u64,
    seq: u64,
    kind: EventKind,
    gen: u32,
    prev: u32,
    next: u32,
    loc: Loc,
}

/// Min-queue over `(t, seq)` with O(1) amortized insert and O(1) cancel.
#[derive(Debug)]
pub struct EventQueue {
    slab: Vec<Entry>,
    free_head: u32,
    heads: [[u32; SLOTS]; LEVELS],
    /// Slot-list tails: entries append here, so every slot list stays
    /// **insertion-ordered** (arrival order at the slot — ascending seq
    /// for direct pushes, `(t, seq)`-ascending for overflow promotions,
    /// order-preserving under cascades). Expiring slots then sort with
    /// an adaptive merge sort that sees the pre-sorted runs hot
    /// same-tick slots produce — O(k) on the common monotone case,
    /// instead of the old push-front + full `O(k log k)` re-sort.
    tails: [[u32; SLOTS]; LEVELS],
    /// Per-level slot-occupancy bitmap (64 slots ⇒ one word per level).
    occupied: [u64; LEVELS],
    /// Far-future events: `(tick, seq) → slab index`.
    overflow: BTreeMap<(u64, u64), u32>,
    /// Expired-slot contents, sorted **descending** by `(t, seq)`: the
    /// global minimum is at the back, so pop is a `Vec::pop`.
    ready: Vec<u32>,
    cur_tick: u64,
    len: usize,
    seq: u64,
    cancelled: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue {
            slab: Vec::new(),
            free_head: NIL,
            heads: [[NIL; SLOTS]; LEVELS],
            tails: [[NIL; SLOTS]; LEVELS],
            occupied: [0; LEVELS],
            overflow: BTreeMap::new(),
            ready: Vec::new(),
            cur_tick: 0,
            len: 0,
            seq: 0,
            cancelled: 0,
        }
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    fn tick_of(t: f64) -> u64 {
        // `as` saturates: non-finite / huge instants land in overflow.
        (t.max(0.0) / TICK_S) as u64
    }

    /// Schedule `kind` at time `t`, returning its cancellation handle.
    /// `t` may be at or before the current instant (the event pops next,
    /// in exact `(t, seq)` order among the already-expired events).
    pub fn push(&mut self, t: f64, kind: EventKind) -> EventToken {
        self.seq += 1;
        let seq = self.seq;
        let tick = Self::tick_of(t);
        let idx = self.alloc(t, tick, seq, kind);
        self.place(idx);
        self.len += 1;
        EventToken { idx, gen: self.slab[idx as usize].gen }
    }

    /// Remove a pending event in O(1) (wheel) / O(log) (overflow/ready).
    /// Returns false if the event already fired or was already cancelled.
    pub fn cancel(&mut self, tok: EventToken) -> bool {
        let Some(e) = self.slab.get(tok.idx as usize) else { return false };
        if e.gen != tok.gen || e.loc == Loc::Free {
            return false;
        }
        self.unlink(tok.idx);
        self.free_entry(tok.idx);
        self.len -= 1;
        self.cancelled += 1;
        true
    }

    /// Is this token's event still pending?
    pub fn is_live(&self, tok: EventToken) -> bool {
        self.slab
            .get(tok.idx as usize)
            .map(|e| e.gen == tok.gen && e.loc != Loc::Free)
            .unwrap_or(false)
    }

    /// The pending event behind a token, if still live.
    pub fn get(&self, tok: EventToken) -> Option<Pending<'_>> {
        let e = self.slab.get(tok.idx as usize)?;
        if e.gen != tok.gen || e.loc == Loc::Free {
            return None;
        }
        Some(Pending { t: e.t, seq: e.seq, kind: &e.kind })
    }

    pub fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        loop {
            if let Some(idx) = self.ready.pop() {
                let e = &self.slab[idx as usize];
                let ev = Event { t: e.t, seq: e.seq, kind: e.kind.clone() };
                self.free_entry(idx);
                self.len -= 1;
                return Some(ev);
            }
            self.advance();
        }
    }

    /// Time of the next event without popping it. Advances the wheel far
    /// enough to expose the global minimum in `ready` (a pure peek:
    /// pre-advancing never reorders pops, it only moves entries from
    /// wheel slots into the sorted ready buffer earlier than `pop` would
    /// have).
    pub fn next_t(&mut self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        while self.ready.is_empty() {
            self.advance();
        }
        Some(self.slab[*self.ready.last().unwrap() as usize].t)
    }

    /// Live (non-cancelled) pending events.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total events removed via `cancel` over this queue's lifetime.
    pub fn cancelled(&self) -> u64 {
        self.cancelled
    }

    /// Iterate over pending events in no particular order. Used by
    /// invariant checks and hygiene tests, never by the simulation.
    pub fn iter(&self) -> impl Iterator<Item = Pending<'_>> {
        self.slab.iter().filter(|e| e.loc != Loc::Free).map(|e| Pending {
            t: e.t,
            seq: e.seq,
            kind: &e.kind,
        })
    }

    // ------------------------------------------------------------- internals

    fn alloc(&mut self, t: f64, tick: u64, seq: u64, kind: EventKind) -> u32 {
        if self.free_head != NIL {
            let idx = self.free_head;
            let e = &mut self.slab[idx as usize];
            self.free_head = e.next;
            e.t = t;
            e.tick = tick;
            e.seq = seq;
            e.kind = kind;
            e.prev = NIL;
            e.next = NIL;
            idx
        } else {
            self.slab.push(Entry {
                t,
                tick,
                seq,
                kind,
                gen: 0,
                prev: NIL,
                next: NIL,
                loc: Loc::Free,
            });
            (self.slab.len() - 1) as u32
        }
    }

    fn free_entry(&mut self, idx: u32) {
        let e = &mut self.slab[idx as usize];
        e.loc = Loc::Free;
        e.gen = e.gen.wrapping_add(1);
        e.prev = NIL;
        e.next = self.free_head;
        self.free_head = idx;
    }

    /// File `idx` under ready / wheel / overflow by its tick. The entry
    /// must not currently be linked anywhere.
    fn place(&mut self, idx: u32) {
        let (tick, seq) = {
            let e = &self.slab[idx as usize];
            (e.tick, e.seq)
        };
        if tick <= self.cur_tick {
            self.ready_insert(idx);
        } else if (tick ^ self.cur_tick) >> WHEEL_BITS != 0 {
            self.overflow.insert((tick, seq), idx);
            self.slab[idx as usize].loc = Loc::Overflow;
        } else {
            let masked = tick ^ self.cur_tick; // != 0 here
            let level = ((63 - masked.leading_zeros()) / LEVEL_BITS) as usize;
            let slot =
                ((tick >> (LEVEL_BITS * level as u32)) & (SLOTS as u64 - 1)) as usize;
            // Append at the tail: slot lists stay insertion-ordered
            // (ascending seq), which is what the adaptive drain sort
            // exploits — and what keeps cascades order-preserving.
            let tail = self.tails[level][slot];
            {
                let e = &mut self.slab[idx as usize];
                e.prev = tail;
                e.next = NIL;
                e.loc = Loc::Wheel { level: level as u8, slot: slot as u8 };
            }
            if tail != NIL {
                self.slab[tail as usize].next = idx;
            } else {
                self.heads[level][slot] = idx;
            }
            self.tails[level][slot] = idx;
            self.occupied[level] |= 1u64 << slot;
        }
    }

    /// Insert into the descending-sorted ready buffer at the exact
    /// `(t, seq)` position.
    fn ready_insert(&mut self, idx: u32) {
        let (t, seq) = {
            let e = &self.slab[idx as usize];
            (e.t, e.seq)
        };
        let slab = &self.slab;
        let pos = self.ready.partition_point(|&i| {
            let e = &slab[i as usize];
            e.t.total_cmp(&t).then(e.seq.cmp(&seq)).is_gt()
        });
        self.ready.insert(pos, idx);
        self.slab[idx as usize].loc = Loc::Ready;
    }

    fn unlink(&mut self, idx: u32) {
        match self.slab[idx as usize].loc {
            Loc::Free => unreachable!("unlinking a free entry"),
            Loc::Wheel { level, slot } => {
                let (level, slot) = (level as usize, slot as usize);
                let (prev, next) = {
                    let e = &self.slab[idx as usize];
                    (e.prev, e.next)
                };
                if prev != NIL {
                    self.slab[prev as usize].next = next;
                } else {
                    self.heads[level][slot] = next;
                }
                if next != NIL {
                    self.slab[next as usize].prev = prev;
                } else {
                    self.tails[level][slot] = prev;
                }
                if self.heads[level][slot] == NIL {
                    self.occupied[level] &= !(1u64 << slot);
                }
            }
            Loc::Overflow => {
                let key = {
                    let e = &self.slab[idx as usize];
                    (e.tick, e.seq)
                };
                let removed = self.overflow.remove(&key);
                debug_assert_eq!(removed, Some(idx));
            }
            Loc::Ready => {
                let (t, seq) = {
                    let e = &self.slab[idx as usize];
                    (e.t, e.seq)
                };
                let slab = &self.slab;
                let pos = self.ready.partition_point(|&i| {
                    let e = &slab[i as usize];
                    e.t.total_cmp(&t).then(e.seq.cmp(&seq)).is_gt()
                });
                debug_assert_eq!(self.ready.get(pos), Some(&idx));
                self.ready.remove(pos);
            }
        }
    }

    /// Roll the wheel forward to the next occupied expiration: drain a
    /// level-0 slot into `ready`, or cascade one higher-level slot down,
    /// or jump to the overflow horizon. Called only with `ready` empty
    /// and `len > 0`.
    fn advance(&mut self) {
        debug_assert!(self.ready.is_empty());
        debug_assert!(self.len > 0);
        self.migrate_overflow();
        for level in 0..LEVELS {
            let width = LEVEL_BITS * level as u32;
            let cursor = ((self.cur_tick >> width) & (SLOTS as u64 - 1)) as u32;
            let bits = self.occupied[level] >> cursor;
            if bits == 0 {
                continue;
            }
            let slot = cursor + bits.trailing_zeros();
            let high = self.cur_tick >> (width + LEVEL_BITS);
            let deadline = ((high << LEVEL_BITS) | slot as u64) << width;
            debug_assert!(deadline >= self.cur_tick, "wheel deadline went backwards");
            self.cur_tick = deadline;
            // Detach the whole slot list (head → tail = insertion
            // order, ascending seq).
            let mut idx = self.heads[level][slot as usize];
            self.heads[level][slot as usize] = NIL;
            self.tails[level][slot as usize] = NIL;
            self.occupied[level] &= !(1u64 << slot);
            if level == 0 {
                // Expire: merge-sort the slot's entries by exact
                // (t, seq) into the (empty) ready buffer. The stable
                // sort is adaptive: an insertion-ordered hot slot whose
                // times arrived monotone (the common same-tick case) is
                // one pre-sorted run — O(k), no re-sort.
                let mut items = Vec::new();
                while idx != NIL {
                    let next = self.slab[idx as usize].next;
                    let e = &mut self.slab[idx as usize];
                    e.prev = NIL;
                    e.next = NIL;
                    e.loc = Loc::Ready;
                    items.push(idx);
                    idx = next;
                }
                let slab = &self.slab;
                items.sort_by(|&a, &b| {
                    let (ea, eb) = (&slab[a as usize], &slab[b as usize]);
                    ea.t.total_cmp(&eb.t).then(ea.seq.cmp(&eb.seq))
                });
                // `ready` pops from the back: reverse into descending.
                items.reverse();
                self.ready = items;
            } else {
                // Cascade: re-file each entry at a finer level (or into
                // ready, when its tick equals the new current tick).
                // Walking head→tail and appending keeps every target
                // slot insertion-ordered too.
                while idx != NIL {
                    let next = self.slab[idx as usize].next;
                    let e = &mut self.slab[idx as usize];
                    e.prev = NIL;
                    e.next = NIL;
                    self.place(idx);
                    idx = next;
                }
            }
            return;
        }
        // Wheels empty: jump to the overflow horizon and promote.
        if let Some((&(tick, _), _)) = self.overflow.first_key_value() {
            let aligned = tick & !((1u64 << WHEEL_BITS) - 1);
            debug_assert!(aligned > self.cur_tick);
            self.cur_tick = aligned;
            self.migrate_overflow();
        }
    }

    /// Promote overflow entries that the wheel can now address.
    fn migrate_overflow(&mut self) {
        while let Some((&(tick, _), _)) = self.overflow.first_key_value() {
            if (tick ^ self.cur_tick) >> WHEEL_BITS != 0 {
                break;
            }
            let ((_, _), idx) = self.overflow.pop_first().expect("peeked above");
            self.place(idx);
        }
    }

    /// Brute-force structural invariants: slab bookkeeping vs the slot
    /// lists, occupancy bitmaps, ready ordering, and the tick geometry.
    /// Called by `Engine::check_indexes` and the wheel tests; never by
    /// the simulation itself.
    pub fn check_invariants(&self) {
        let mut live = 0usize;
        let mut wheel_count = 0usize;
        for (i, e) in self.slab.iter().enumerate() {
            if e.loc == Loc::Free {
                continue;
            }
            live += 1;
            match e.loc {
                Loc::Free => unreachable!(),
                Loc::Wheel { level, slot } => {
                    wheel_count += 1;
                    let (level, slot) = (level as usize, slot as usize);
                    let width = LEVEL_BITS * level as u32;
                    assert!(e.tick > self.cur_tick, "wheel entry not in the future");
                    assert_eq!(
                        ((e.tick >> width) & (SLOTS as u64 - 1)) as usize,
                        slot,
                        "entry {i} filed in the wrong slot"
                    );
                    let cursor = ((self.cur_tick >> width) & (SLOTS as u64 - 1)) as usize;
                    assert!(
                        slot > cursor,
                        "entry {i} at level {level} slot {slot} behind cursor {cursor}"
                    );
                    assert!(
                        self.occupied[level] & (1u64 << slot) != 0,
                        "occupied bit clear for a non-empty slot"
                    );
                }
                Loc::Overflow => {
                    assert!(
                        (e.tick ^ self.cur_tick) >> WHEEL_BITS != 0,
                        "overflow entry {i} is wheel-addressable"
                    );
                    assert_eq!(self.overflow.get(&(e.tick, e.seq)), Some(&(i as u32)));
                }
                Loc::Ready => {
                    assert!(e.tick <= self.cur_tick, "ready entry in the future");
                }
            }
        }
        assert_eq!(live, self.len, "live-entry count drifted from len");
        assert_eq!(
            self.ready.len() + self.overflow.len() + wheel_count,
            self.len,
            "location counts do not partition the live set"
        );
        // Slot lists: every linked entry agrees with its location; the
        // occupancy bit is set iff the list is non-empty.
        let mut linked = 0usize;
        for level in 0..LEVELS {
            for slot in 0..SLOTS {
                let mut idx = self.heads[level][slot];
                assert_eq!(
                    self.occupied[level] & (1u64 << slot) != 0,
                    idx != NIL,
                    "occupancy bitmap out of sync at level {level} slot {slot}"
                );
                let mut prev = NIL;
                while idx != NIL {
                    let e = &self.slab[idx as usize];
                    assert_eq!(
                        e.loc,
                        Loc::Wheel { level: level as u8, slot: slot as u8 },
                        "linked entry has a different recorded location"
                    );
                    assert_eq!(e.prev, prev, "prev link broken");
                    prev = idx;
                    idx = e.next;
                    linked += 1;
                }
                assert_eq!(
                    self.tails[level][slot], prev,
                    "tail pointer broken at level {level} slot {slot}"
                );
            }
        }
        assert_eq!(linked, wheel_count, "slot lists disagree with slab locations");
        // Ready buffer strictly descending by (t, seq).
        for w in self.ready.windows(2) {
            let (a, b) = (&self.slab[w[0] as usize], &self.slab[w[1] as usize]);
            assert!(
                a.t.total_cmp(&b.t).then(a.seq.cmp(&b.seq)).is_gt(),
                "ready buffer out of order"
            );
        }
    }
}

/// The pre-timing-wheel binary-heap queue, kept as the differential
/// oracle for the wheel's ordering contract. Cancellation is emulated
/// lazily (skip-on-pop) — exactly the stale-entry behavior the wheel
/// removes structurally.
#[cfg(test)]
pub(crate) mod heap {
    use super::{Event, EventKind};
    use std::cmp::Reverse;
    use std::collections::{BTreeSet, BinaryHeap};

    #[derive(Debug, Default)]
    pub struct HeapEventQueue {
        heap: BinaryHeap<Reverse<Event>>,
        pending: BTreeSet<u64>,
        cancelled: BTreeSet<u64>,
        seq: u64,
    }

    impl HeapEventQueue {
        pub fn new() -> Self {
            Self::default()
        }

        /// Returns the event's seq as its (lazy) cancellation handle.
        pub fn push(&mut self, t: f64, kind: EventKind) -> u64 {
            self.seq += 1;
            self.pending.insert(self.seq);
            self.heap.push(Reverse(Event { t, seq: self.seq, kind }));
            self.seq
        }

        pub fn cancel(&mut self, seq: u64) -> bool {
            if self.pending.remove(&seq) {
                self.cancelled.insert(seq);
                true
            } else {
                false
            }
        }

        pub fn pop(&mut self) -> Option<Event> {
            while let Some(Reverse(e)) = self.heap.pop() {
                if self.cancelled.remove(&e.seq) {
                    continue; // lazy deletion: skip the stale entry
                }
                self.pending.remove(&e.seq);
                return Some(e);
            }
            None
        }

        pub fn len(&self) -> usize {
            self.pending.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::heap::HeapEventQueue;
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::KeepaliveCheck);
        q.push(1.0, EventKind::Arrival(0));
        q.push(3.0, EventKind::QueueCheck(1));
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival(0));
        assert_eq!(q.pop().unwrap().t, 2.0);
        assert_eq!(q.pop().unwrap().t, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn iter_sees_all_pending() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::KeepaliveCheck);
        q.push(2.0, EventKind::Arrival(3));
        assert_eq!(q.iter().count(), 2);
        let ka = q.iter().filter(|e| matches!(e.kind, &EventKind::KeepaliveCheck));
        assert_eq!(ka.count(), 1);
        q.pop();
        assert_eq!(q.iter().count(), 1);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Arrival(7));
        q.push(1.0, EventKind::Arrival(8));
        q.push(1.0, EventKind::Arrival(9));
        let kinds: Vec<EventKind> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![EventKind::Arrival(7), EventKind::Arrival(8), EventKind::Arrival(9)]
        );
    }

    #[test]
    fn len_tracks() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.5, EventKind::KeepaliveCheck);
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }

    #[test]
    fn cancel_is_o1_removal() {
        let mut q = EventQueue::new();
        let a = q.push(1.0, EventKind::Arrival(1));
        let b = q.push(2.0, EventKind::Arrival(2));
        let c = q.push(3.0, EventKind::Arrival(3));
        assert_eq!(q.len(), 3);
        assert!(q.cancel(b));
        assert_eq!(q.len(), 2, "cancel removes immediately, not lazily");
        assert_eq!(q.cancelled(), 1);
        assert!(!q.cancel(b), "double cancel is a no-op");
        assert!(q.is_live(a) && !q.is_live(b) && q.is_live(c));
        q.check_invariants();
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival(1));
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival(3));
        assert!(q.pop().is_none());
    }

    #[test]
    fn cancelled_events_never_fire() {
        // Regression: a cancelled event must never pop — including events
        // already expired into the ready buffer, wheel entries at every
        // level, and overflow entries.
        let mut q = EventQueue::new();
        let mut keep = Vec::new();
        let mut dead = Vec::new();
        for i in 0..200usize {
            let t = match i % 4 {
                0 => i as f64 * 1e-4,       // sub-tick cluster
                1 => i as f64 * 0.05,       // level-0/1 range
                2 => i as f64 * 37.0,       // level-2/3 range
                _ => 1e8 + i as f64,        // overflow band
            };
            let tok = q.push(t, EventKind::Arrival(i));
            if i % 3 == 0 {
                dead.push((i, tok));
            } else {
                keep.push(i);
            }
        }
        // Expire part of the stream into ready before cancelling.
        let first = q.pop().unwrap();
        let fired0 = match first.kind {
            EventKind::Arrival(i) => i,
            _ => unreachable!(),
        };
        keep.retain(|&i| i != fired0);
        for &(_, tok) in &dead {
            q.cancel(tok); // the popped one (if in dead) reports false
        }
        q.check_invariants();
        let mut fired = Vec::new();
        while let Some(e) = q.pop() {
            if let EventKind::Arrival(i) = e.kind {
                fired.push(i);
            }
        }
        for (i, _) in dead {
            assert!(i == fired0 || !fired.contains(&i), "cancelled event {i} fired");
        }
        let mut keep_sorted = keep.clone();
        keep_sorted.sort_unstable();
        let mut fired_sorted = fired.clone();
        fired_sorted.sort_unstable();
        assert_eq!(fired_sorted, keep_sorted, "a live event was lost");
    }

    #[test]
    fn hot_same_tick_slot_drains_in_exact_order() {
        // Many events inside one 1 ms tick, pushed as a monotone run,
        // then a burst of exact ties, then a reversed run: the
        // insertion-ordered slot must still pop ascending (t, seq) —
        // the adaptive merge-sort drain cannot change the contract.
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::KeepaliveCheck); // park the wheel mid-range
        let base = 7.0;
        let mut expect: Vec<(f64, usize)> = Vec::new();
        for i in 0..200usize {
            let off = match i {
                0..=79 => i as f64 * 1e-6,
                80..=139 => 40e-6,
                _ => (260 - i) as f64 * 1e-6,
            };
            q.push(base + off, EventKind::Arrival(i));
            expect.push((base + off, i));
        }
        q.check_invariants();
        assert_eq!(q.pop().unwrap().kind, EventKind::KeepaliveCheck);
        // Ascending time, insertion order among exact ties.
        expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for &(t, i) in &expect {
            let e = q.pop().unwrap();
            assert_eq!(e.t.to_bits(), t.to_bits());
            assert_eq!(e.kind, EventKind::Arrival(i));
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn slot_boundary_events_keep_exact_order() {
        // Events exactly on level boundaries (t = 64^k ticks) and a hair
        // on either side must pop in exact time order.
        let mut q = EventQueue::new();
        let mut expect = Vec::new();
        for level in 0..4u32 {
            let span = TICK_S * 64f64.powi(level as i32);
            for mult in [1.0, 2.0, 63.0] {
                for eps in [-1e-9, 0.0, 1e-9] {
                    let t = span * mult + eps;
                    if t > 0.0 {
                        q.push(t, EventKind::LoadDone((expect.len()) as u64));
                        expect.push(t);
                    }
                }
            }
        }
        q.check_invariants();
        let mut popped = Vec::new();
        while let Some(e) = q.pop() {
            popped.push(e.t);
        }
        let mut sorted = expect.clone();
        sorted.sort_by(f64::total_cmp);
        assert_eq!(popped, sorted);
    }

    #[test]
    fn far_future_overflow_promotes() {
        // 2^36 ticks at 1 ms ≈ 6.87e7 s: anything beyond sits in overflow
        // until the wheel rolls toward it.
        let horizon_s = TICK_S * (1u64 << WHEEL_BITS) as f64;
        let mut q = EventQueue::new();
        q.push(horizon_s * 3.5, EventKind::Arrival(2));
        q.push(1.0, EventKind::Arrival(0));
        q.push(horizon_s * 2.0, EventKind::Arrival(1));
        q.check_invariants();
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival(0));
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival(1));
        q.check_invariants();
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival(2));
        assert!(q.pop().is_none());
    }

    #[test]
    fn rollover_across_many_rotations() {
        // March time across thousands of level-0 rotations with
        // interleaved pushes; order must stay exact throughout.
        let mut q = EventQueue::new();
        let mut now = 0.0;
        let mut next_id = 0usize;
        let mut rng = Pcg64::new(99);
        let mut last = f64::NEG_INFINITY;
        for _ in 0..3000 {
            if rng.f64() < 0.6 || q.is_empty() {
                let t = now + rng.f64() * 10.0;
                q.push(t, EventKind::Arrival(next_id));
                next_id += 1;
            } else {
                let e = q.pop().unwrap();
                assert!(e.t >= last, "time went backwards: {} < {last}", e.t);
                last = e.t;
                now = e.t;
            }
        }
        q.check_invariants();
        while let Some(e) = q.pop() {
            assert!(e.t >= last);
            last = e.t;
        }
    }

    /// Differential property test: the wheel and the heap oracle must pop
    /// identical `(t, seq, kind)` sequences under randomized interleaved
    /// push / cancel / pop traffic — including same-tick collisions,
    /// exact slot boundaries, past-time pushes, and the overflow band.
    #[test]
    fn differential_wheel_matches_heap_multi_seed() {
        for seed in [1u64, 7, 23, 101, 4096] {
            let mut rng = Pcg64::new(seed);
            let mut wheel = EventQueue::new();
            let mut oracle = HeapEventQueue::new();
            let mut live: Vec<(EventToken, u64)> = Vec::new();
            let mut now = 0.0f64;
            let mut id = 0usize;
            for step in 0..4000 {
                let r = rng.f64();
                if r < 0.55 || wheel.is_empty() {
                    let off = match rng.below(8) {
                        0 => rng.f64() * TICK_S,                    // same tick
                        1 => rng.f64() * 64.0 * TICK_S,             // level 0
                        2 => rng.f64() * 4.0,                       // level 1
                        3 => rng.f64() * 260.0,                     // level 2
                        4 => rng.f64() * 17_000.0,                  // level 3
                        5 => TICK_S * 64f64.powi(rng.below(4) as i32 + 1)
                            * rng.below(5) as f64,                  // boundaries
                        6 => 1e8 + rng.f64() * 1e9,                 // overflow
                        _ => -rng.f64(),                            // the past
                    };
                    let t = now + off;
                    id += 1;
                    let kind = EventKind::Arrival(id);
                    let tok = wheel.push(t, kind.clone());
                    let h = oracle.push(t, kind);
                    live.push((tok, h));
                } else if r < 0.72 && !live.is_empty() {
                    let k = rng.below(live.len());
                    let (tok, h) = live.swap_remove(k);
                    assert_eq!(
                        wheel.cancel(tok),
                        oracle.cancel(h),
                        "seed {seed} step {step}: cancel outcomes diverged"
                    );
                } else {
                    let (a, b) = (wheel.pop(), oracle.pop());
                    match (&a, &b) {
                        (Some(x), Some(y)) => {
                            assert_eq!(x.t.to_bits(), y.t.to_bits(), "seed {seed} step {step}");
                            assert_eq!(x.seq, y.seq, "seed {seed} step {step}");
                            assert_eq!(x.kind, y.kind, "seed {seed} step {step}");
                            now = now.max(x.t);
                        }
                        (None, None) => {}
                        _ => panic!("seed {seed} step {step}: one queue drained early"),
                    }
                }
                assert_eq!(wheel.len(), oracle.len(), "seed {seed} step {step}");
                if step % 61 == 0 {
                    wheel.check_invariants();
                }
            }
            // Drain both fully.
            loop {
                let (a, b) = (wheel.pop(), oracle.pop());
                match (&a, &b) {
                    (Some(x), Some(y)) => {
                        assert_eq!(x.t.to_bits(), y.t.to_bits(), "seed {seed} drain");
                        assert_eq!(x.seq, y.seq, "seed {seed} drain");
                    }
                    (None, None) => break,
                    _ => panic!("seed {seed}: drain length mismatch"),
                }
            }
            wheel.check_invariants();
        }
    }

    #[test]
    fn slab_slots_are_reused_and_generation_guards_tokens() {
        let mut q = EventQueue::new();
        let a = q.push(1.0, EventKind::Arrival(1));
        q.pop();
        // The freed slot is reused; the old token must stay inert.
        let b = q.push(2.0, EventKind::Arrival(2));
        assert_eq!(q.slab.len(), 1, "slab did not reuse the freed slot");
        assert!(!q.cancel(a), "stale token cancelled a reused slot");
        assert!(q.is_live(b));
        assert_eq!(q.len(), 1);
    }
}
