//! The simulator's event queue: event kinds, the total order that keeps
//! runs deterministic (time, then insertion sequence), and the queue
//! itself. Split out of the engine so the event plumbing is reusable and
//! testable without a full `Engine`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::GpuId;

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Request `i` (index into the workload stream) arrives.
    Arrival(usize),
    /// Re-check function `f`'s queue (debounce settle / Eq. 3 expiry).
    /// The `u64` is the queue generation the check was scheduled
    /// against: any push/take on the queue bumps the generation and
    /// re-arms fresh wakeups, so a stale check is skipped in O(1)
    /// instead of re-running the dispatch path (the same guard shape as
    /// `GpuTick`'s exec version).
    QueueCheck(usize, u64),
    /// Batch `b` finished loading its artifacts.
    LoadDone(u64),
    /// Processor-sharing completion sweep on a GPU; the `u64` is the
    /// exec version the event was scheduled against (staleness guard).
    GpuTick(GpuId, u64),
    /// Keep-alive expiry sweep. At most one is outstanding at any time
    /// (the engine arms it lazily at `KeepAlive::next_expiry`), so the
    /// queue no longer accumulates one check per completion.
    KeepaliveCheck,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub t: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Min-queue over `(t, seq)`: ties at the same instant pop in insertion
/// order, which is what makes same-seed runs bit-identical.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse(Event { t, seq: self.seq, kind }));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Iterate over the pending events in no particular order (heap
    /// order). Used by invariant checks and hygiene tests, never by the
    /// simulation itself.
    pub fn iter(&self) -> impl Iterator<Item = &Event> {
        self.heap.iter().map(|r| &r.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::KeepaliveCheck);
        q.push(1.0, EventKind::Arrival(0));
        q.push(3.0, EventKind::QueueCheck(1, 0));
        assert_eq!(q.pop().unwrap().kind, EventKind::Arrival(0));
        assert_eq!(q.pop().unwrap().t, 2.0);
        assert_eq!(q.pop().unwrap().t, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn iter_sees_all_pending() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::KeepaliveCheck);
        q.push(2.0, EventKind::Arrival(3));
        assert_eq!(q.iter().count(), 2);
        let ka = q.iter().filter(|e| matches!(e.kind, EventKind::KeepaliveCheck));
        assert_eq!(ka.count(), 1);
        q.pop();
        assert_eq!(q.iter().count(), 1);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::Arrival(7));
        q.push(1.0, EventKind::Arrival(8));
        q.push(1.0, EventKind::Arrival(9));
        let kinds: Vec<EventKind> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![EventKind::Arrival(7), EventKind::Arrival(8), EventKind::Arrival(9)]
        );
    }

    #[test]
    fn len_tracks() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.5, EventKind::KeepaliveCheck);
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
