//! The paper's standard workloads (§6.1): eight LoRA functions — four on
//! Llama2-7B, four on Llama2-13B — driven by 4-hour CoV-classed traces
//! with heterogeneous per-function rates (Azure functions are wildly
//! skewed: some fire every few seconds, some a few times an hour).

use crate::artifact::{FunctionSpec, ModelProfile};
use crate::sim::engine::Workload;
use crate::trace::{merge, GsmLengths, Pattern, Request, TraceSpec};
use crate::util::rng::{Pcg64, ZipfCdf};

/// Heterogeneous per-function mean rates (req/s). Means chosen so that the
/// hottest function stays keep-alive-warm while the coldest almost always
/// cold-starts — the regime where the paper's Fig. 6 gaps appear.
pub const RATE_TIERS: [f64; 4] = [1.0 / 45.0, 1.0 / 90.0, 1.0 / 180.0, 1.0 / 420.0];

/// The paper's 8-function deployment: functions 0..4 are 7B-series,
/// 4..8 are 13B-series; adapter ids 0..4 within each series.
pub fn paper_functions() -> Vec<FunctionSpec> {
    let mut v = Vec::new();
    for i in 0..4 {
        v.push(FunctionSpec::new(i, ModelProfile::llama2_7b(), i));
    }
    for i in 0..4 {
        v.push(FunctionSpec::new(4 + i, ModelProfile::llama2_13b(), i));
    }
    v
}

pub fn series_7b() -> Vec<usize> {
    (0..4).collect()
}

pub fn series_13b() -> Vec<usize> {
    (4..8).collect()
}

/// Standard evaluation workload: 8 functions, one arrival pattern,
/// heterogeneous rates, `duration_s` horizon.
pub fn paper_workload(pattern: Pattern, duration_s: f64, seed: u64) -> Workload {
    let functions = paper_functions();
    let rates: Vec<f64> = (0..functions.len())
        .map(|i| RATE_TIERS[i % RATE_TIERS.len()])
        .collect();
    let traces: Vec<Vec<Request>> = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, pattern, rates[f.id], seed + f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

/// §6.5 throughput setup: four 7B functions saturating two GPUs.
/// High offered load so each system runs at its peak batch size.
pub fn throughput_workload(duration_s: f64, seed: u64) -> Workload {
    let functions: Vec<FunctionSpec> = (0..4)
        .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
        .collect();
    let rate = 12.0; // req/s per function — far above service capacity
    let traces: Vec<Vec<Request>> = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, Pattern::Predictable, rate, seed + f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload {
        functions,
        requests: merge(traces),
        duration_s,
        rates: vec![rate; 4],
    }
}

/// Fig. 2 motivation workload: `n_fns` Llama2-7B LoRA functions
/// splitting ONE hot function's demand (`RATE_TIERS[0]`) evenly —
/// Fig. 2a is the single-function case, Fig. 2b the four-way split
/// where naive serverless loses its edge to backbone redundancy.
pub fn small_multi_workload(n_fns: usize, duration_s: f64, seed: u64) -> Workload {
    let functions: Vec<FunctionSpec> = (0..n_fns)
        .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
        .collect();
    let total = RATE_TIERS[0];
    let rates: Vec<f64> = (0..n_fns).map(|_| total / n_fns as f64).collect();
    let traces = functions
        .iter()
        .map(|fx| {
            TraceSpec::new(fx.id, Pattern::Normal, rates[fx.id], seed + fx.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

/// Fig. 1 motivation workload: three Llama2-13B LoRA functions on the
/// Azure-like Normal trace with descending rates.
pub fn breakdown_13b_workload(duration_s: f64, seed: u64) -> Workload {
    let functions: Vec<FunctionSpec> = (0..3)
        .map(|i| FunctionSpec::new(i, ModelProfile::llama2_13b(), i))
        .collect();
    let rates = vec![1.0 / 120.0, 1.0 / 300.0, 1.0 / 600.0];
    let traces = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, Pattern::Normal, rates[f.id], seed + f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

/// §6.3 single-invocation breakdown: one function, one request.
pub fn single_invocation(model: ModelProfile) -> Workload {
    let f = FunctionSpec::new(0, model, 0);
    let req = Request {
        id: 1,
        function: 0,
        arrival_s: 1.0,
        prompt_tokens: 60,
        output_tokens: 110,
    };
    Workload {
        functions: vec![f],
        requests: vec![req],
        duration_s: 30.0,
        rates: vec![0.05],
    }
}

/// Weak-scaling workload: `scale` × the base deployment (8·scale
/// functions), same per-function rates.
pub fn scaled_workload(pattern: Pattern, duration_s: f64, scale: usize, seed: u64) -> Workload {
    let mut functions = Vec::new();
    for s in 0..scale {
        for i in 0..4 {
            functions.push(FunctionSpec::new(
                s * 8 + i,
                ModelProfile::llama2_7b(),
                i,
            ));
        }
        for i in 0..4 {
            functions.push(FunctionSpec::new(
                s * 8 + 4 + i,
                ModelProfile::llama2_13b(),
                i,
            ));
        }
    }
    let rates: Vec<f64> = (0..functions.len())
        .map(|i| RATE_TIERS[i % RATE_TIERS.len()])
        .collect();
    let traces: Vec<Vec<Request>> = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, pattern, rates[f.id], seed + 31 * f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

/// Fleet-scale workload: `n_fns` functions (rounded up to a multiple of
/// the 8-function base deployment) with the standard heterogeneous
/// rates. Drives the engine-scaling experiment (`exp/fleet.rs`).
pub fn fleet_workload(n_fns: usize, duration_s: f64, seed: u64) -> Workload {
    let scale = n_fns.div_ceil(8).max(1);
    scaled_workload(Pattern::Normal, duration_s, scale, seed)
}

/// The fleet deployment shape shared by the Zipf generators: `scale` ×
/// the 8-function base deployment (4× 7B, 4× 13B), ids dense from 0.
fn fleet_functions(scale: usize) -> Vec<FunctionSpec> {
    let mut functions = Vec::with_capacity(scale * 8);
    for s in 0..scale {
        for i in 0..4 {
            functions.push(FunctionSpec::new(s * 8 + i, ModelProfile::llama2_7b(), i));
        }
        for i in 0..4 {
            functions.push(FunctionSpec::new(s * 8 + 4 + i, ModelProfile::llama2_13b(), i));
        }
    }
    functions
}

/// Ranks `0..head_count(n)` are the Zipf head (the hottest eighth of the
/// deployment, at least one function) for the CoV-classed generator.
pub fn zipf_head_count(n_fns: usize) -> usize {
    (n_fns / 8).max(1)
}

/// Shared Zipf preamble: the deployment, the per-rank expected rates at
/// the uniform fleet's total offered load, the sampling CDF, and that
/// total rate. Both Zipf generators build from this, so their offered
/// loads stay comparable point-for-point by construction.
fn zipf_fleet_base(n_fns: usize, skew: f64) -> (Vec<FunctionSpec>, Vec<f64>, ZipfCdf, f64) {
    let scale = n_fns.div_ceil(8).max(1);
    let n = scale * 8;
    let functions = fleet_functions(scale);
    let total_rate: f64 = (0..n).map(|i| RATE_TIERS[i % RATE_TIERS.len()]).sum();
    let zipf = ZipfCdf::new(n, skew);
    // Expected per-function rates (pre-loading benefit inputs, §4.1).
    let rates: Vec<f64> = (0..n).map(|r| total_rate * zipf.pmf(r)).collect();
    (functions, rates, zipf, total_rate)
}

/// Zipf-skewed fleet workload (Azure-style head-heavy popularity): one
/// aggregate Poisson arrival stream at the same total offered load as
/// [`fleet_workload`], with each arrival's function drawn rank-wise from
/// `Zipf(skew)` via the precomputed CDF (function 0 is the hottest).
/// This is the regime that stresses keep-alive and preload policies the
/// way production traces do: the head stays permanently warm while the
/// long tail almost always cold-starts — `fleet --skew S` on the CLI.
pub fn zipf_fleet_workload(n_fns: usize, duration_s: f64, skew: f64, seed: u64) -> Workload {
    let (functions, rates, zipf, total_rate) = zipf_fleet_base(n_fns, skew);
    let mut rng = Pcg64::with_stream(seed, 0x21bf);
    let mut requests = Vec::new();
    let (mut t, mut id) = (0.0, 0u64);
    loop {
        t += rng.exp(total_rate);
        if t >= duration_s {
            break;
        }
        id += 1;
        requests.push(Request {
            id,
            function: zipf.sample(&mut rng),
            arrival_s: t,
            prompt_tokens: GsmLengths::prompt(&mut rng),
            output_tokens: GsmLengths::output(&mut rng),
        });
    }
    Workload { functions, requests, duration_s, rates }
}

/// Zipf-skewed fleet workload with **CoV-classed burstiness**: the same
/// Zipf(skew) per-function offered load as [`zipf_fleet_workload`], but
/// each function draws its own renewal stream from the paper's
/// CoV-classed `TraceSpec` generators — the head (hottest eighth of
/// ranks, [`zipf_head_count`]) under `head`, the tail under `tail`.
/// Azure's LLM traces show hot functions are *also* the burstiest; the
/// aggregate-Poisson generator cannot express that (every function
/// inherits CoV ≈ 1), this one can — `fleet --skew S --cov-head H
/// --cov-tail T` on the CLI.
pub fn zipf_fleet_workload_cov(
    n_fns: usize,
    duration_s: f64,
    skew: f64,
    seed: u64,
    head: Pattern,
    tail: Pattern,
) -> Workload {
    let (functions, rates, _, _) = zipf_fleet_base(n_fns, skew);
    let head_n = zipf_head_count(functions.len());
    let traces: Vec<Vec<Request>> = functions
        .iter()
        .map(|f| {
            let pattern = if f.id < head_n { head } else { tail };
            TraceSpec::new(f.id, pattern, rates[f.id], seed + 31 * f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_workload_shape() {
        let w = paper_workload(Pattern::Normal, 3600.0, 1);
        assert_eq!(w.functions.len(), 8);
        assert_eq!(w.functions[0].model.name, "llama2-7b");
        assert_eq!(w.functions[7].model.name, "llama2-13b");
        assert!(!w.requests.is_empty());
        // Sorted stream.
        for p in w.requests.windows(2) {
            assert!(p[1].arrival_s >= p[0].arrival_s);
        }
    }

    #[test]
    fn rates_are_heterogeneous() {
        let w = paper_workload(Pattern::Normal, 3600.0, 1);
        assert!(w.rates[0] > w.rates[3] * 5.0);
    }

    #[test]
    fn throughput_workload_saturates() {
        let w = throughput_workload(120.0, 1);
        // 4 fns × 3 req/s × 120 s ≈ 1440 requests.
        assert!(w.requests.len() > 1000);
    }

    #[test]
    fn fleet_workload_rounds_up() {
        let w = fleet_workload(20, 300.0, 1);
        assert_eq!(w.functions.len(), 24);
        let w = fleet_workload(64, 300.0, 1);
        assert_eq!(w.functions.len(), 64);
    }

    #[test]
    fn zipf_fleet_workload_is_head_heavy() {
        let w = zipf_fleet_workload(64, 3600.0, 1.2, 7);
        assert_eq!(w.functions.len(), 64);
        assert_eq!(w.rates.len(), 64);
        // Rates follow the Zipf pmf: strictly decreasing, summing to the
        // uniform fleet's total offered load.
        for p in w.rates.windows(2) {
            assert!(p[0] > p[1], "rates not decreasing: {} vs {}", p[0], p[1]);
        }
        let total: f64 = w.rates.iter().sum();
        let uniform_total: f64 = (0..64).map(|i| RATE_TIERS[i % 4]).sum();
        assert!((total - uniform_total).abs() < 1e-9);
        // The realized stream is head-heavy too.
        let head = w.requests.iter().filter(|r| r.function == 0).count();
        let tail = w.requests.iter().filter(|r| r.function == 63).count();
        assert!(head > 5 * tail.max(1), "head {head} vs tail {tail}");
        // Sorted, ids unique.
        for p in w.requests.windows(2) {
            assert!(p[1].arrival_s >= p[0].arrival_s);
        }
        let mut ids: Vec<u64> = w.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.requests.len());
    }

    #[test]
    fn zipf_cov_head_and_tail_carry_their_classes() {
        use crate::trace::stream_cov;
        let w = zipf_fleet_workload_cov(
            16,
            4.0 * 3600.0,
            1.1,
            7,
            Pattern::Bursty,
            Pattern::Predictable,
        );
        assert_eq!(w.functions.len(), 16);
        assert_eq!(zipf_head_count(16), 2);
        // Offered load matches the uniform fleet exactly (comparable
        // point-for-point with the unclassed sweep).
        let total: f64 = w.rates.iter().sum();
        let uniform_total: f64 = (0..16).map(|i| RATE_TIERS[i % 4]).sum();
        assert!((total - uniform_total).abs() < 1e-9);
        // Head rank 0 is bursty, tail rank 2 predictable — the realized
        // streams must separate cleanly by inter-arrival CoV.
        let per_fn = |f: usize| -> Vec<crate::trace::Request> {
            w.requests.iter().filter(|r| r.function == f).cloned().collect()
        };
        let head = per_fn(0);
        let tail = per_fn(2);
        assert!(head.len() > 100, "head too sparse: {}", head.len());
        assert!(tail.len() > 100, "tail too sparse: {}", tail.len());
        let head_cov = stream_cov(&head);
        let tail_cov = stream_cov(&tail);
        assert!(head_cov > 2.0, "head cov {head_cov} not bursty");
        assert!(tail_cov < 1.5, "tail cov {tail_cov} not predictable");
        assert!(head_cov > 2.5 * tail_cov, "classes did not separate");
        // Merged stream stays sorted with unique ids.
        for p in w.requests.windows(2) {
            assert!(p[1].arrival_s >= p[0].arrival_s);
        }
        let mut ids: Vec<u64> = w.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.requests.len());
    }

    #[test]
    fn zipf_cov_workload_deterministic() {
        let a = zipf_fleet_workload_cov(16, 600.0, 1.1, 3, Pattern::Bursty, Pattern::Normal);
        let b = zipf_fleet_workload_cov(16, 600.0, 1.1, 3, Pattern::Bursty, Pattern::Normal);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.function, y.function);
        }
    }

    #[test]
    fn zipf_fleet_workload_deterministic() {
        let a = zipf_fleet_workload(16, 600.0, 1.1, 3);
        let b = zipf_fleet_workload(16, 600.0, 1.1, 3);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.function, y.function);
        }
    }

    #[test]
    fn small_multi_splits_one_functions_demand() {
        let w1 = small_multi_workload(1, 3600.0, 5);
        let w4 = small_multi_workload(4, 3600.0, 5);
        assert_eq!(w1.functions.len(), 1);
        assert_eq!(w4.functions.len(), 4);
        let t1: f64 = w1.rates.iter().sum();
        let t4: f64 = w4.rates.iter().sum();
        assert!((t1 - t4).abs() < 1e-12, "same total demand either way");
    }

    #[test]
    fn breakdown_13b_shape() {
        let w = breakdown_13b_workload(1800.0, 7);
        assert_eq!(w.functions.len(), 3);
        assert!(w.functions.iter().all(|f| f.model.name == "llama2-13b"));
        assert!(w.rates[0] > w.rates[2]);
    }

    #[test]
    fn scaled_workload_multiplies_functions() {
        let w = scaled_workload(Pattern::Normal, 600.0, 3, 1);
        assert_eq!(w.functions.len(), 24);
        // ids unique
        let mut ids: Vec<usize> = w.functions.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 24);
    }
}
