//! The paper's standard workloads (§6.1): eight LoRA functions — four on
//! Llama2-7B, four on Llama2-13B — driven by 4-hour CoV-classed traces
//! with heterogeneous per-function rates (Azure functions are wildly
//! skewed: some fire every few seconds, some a few times an hour).

use crate::artifact::{FunctionSpec, ModelProfile};
use crate::sim::engine::Workload;
use crate::trace::{merge, Pattern, Request, TraceSpec};

/// Heterogeneous per-function mean rates (req/s). Means chosen so that the
/// hottest function stays keep-alive-warm while the coldest almost always
/// cold-starts — the regime where the paper's Fig. 6 gaps appear.
pub const RATE_TIERS: [f64; 4] = [1.0 / 45.0, 1.0 / 90.0, 1.0 / 180.0, 1.0 / 420.0];

/// The paper's 8-function deployment: functions 0..4 are 7B-series,
/// 4..8 are 13B-series; adapter ids 0..4 within each series.
pub fn paper_functions() -> Vec<FunctionSpec> {
    let mut v = Vec::new();
    for i in 0..4 {
        v.push(FunctionSpec::new(i, ModelProfile::llama2_7b(), i));
    }
    for i in 0..4 {
        v.push(FunctionSpec::new(4 + i, ModelProfile::llama2_13b(), i));
    }
    v
}

pub fn series_7b() -> Vec<usize> {
    (0..4).collect()
}

pub fn series_13b() -> Vec<usize> {
    (4..8).collect()
}

/// Standard evaluation workload: 8 functions, one arrival pattern,
/// heterogeneous rates, `duration_s` horizon.
pub fn paper_workload(pattern: Pattern, duration_s: f64, seed: u64) -> Workload {
    let functions = paper_functions();
    let rates: Vec<f64> = (0..functions.len())
        .map(|i| RATE_TIERS[i % RATE_TIERS.len()])
        .collect();
    let traces: Vec<Vec<Request>> = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, pattern, rates[f.id], seed + f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

/// §6.5 throughput setup: four 7B functions saturating two GPUs.
/// High offered load so each system runs at its peak batch size.
pub fn throughput_workload(duration_s: f64, seed: u64) -> Workload {
    let functions: Vec<FunctionSpec> = (0..4)
        .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
        .collect();
    let rate = 12.0; // req/s per function — far above service capacity
    let traces: Vec<Vec<Request>> = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, Pattern::Predictable, rate, seed + f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload {
        functions,
        requests: merge(traces),
        duration_s,
        rates: vec![rate; 4],
    }
}

/// §6.3 single-invocation breakdown: one function, one request.
pub fn single_invocation(model: ModelProfile) -> Workload {
    let f = FunctionSpec::new(0, model, 0);
    let req = Request {
        id: 1,
        function: 0,
        arrival_s: 1.0,
        prompt_tokens: 60,
        output_tokens: 110,
    };
    Workload {
        functions: vec![f],
        requests: vec![req],
        duration_s: 30.0,
        rates: vec![0.05],
    }
}

/// Weak-scaling workload: `scale` × the base deployment (8·scale
/// functions), same per-function rates.
pub fn scaled_workload(pattern: Pattern, duration_s: f64, scale: usize, seed: u64) -> Workload {
    let mut functions = Vec::new();
    for s in 0..scale {
        for i in 0..4 {
            functions.push(FunctionSpec::new(
                s * 8 + i,
                ModelProfile::llama2_7b(),
                i,
            ));
        }
        for i in 0..4 {
            functions.push(FunctionSpec::new(
                s * 8 + 4 + i,
                ModelProfile::llama2_13b(),
                i,
            ));
        }
    }
    let rates: Vec<f64> = (0..functions.len())
        .map(|i| RATE_TIERS[i % RATE_TIERS.len()])
        .collect();
    let traces: Vec<Vec<Request>> = functions
        .iter()
        .map(|f| {
            TraceSpec::new(f.id, pattern, rates[f.id], seed + 31 * f.id as u64)
                .generate(duration_s)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s, rates }
}

/// Fleet-scale workload: `n_fns` functions (rounded up to a multiple of
/// the 8-function base deployment) with the standard heterogeneous
/// rates. Drives the engine-scaling experiment (`exp/fleet.rs`).
pub fn fleet_workload(n_fns: usize, duration_s: f64, seed: u64) -> Workload {
    let scale = n_fns.div_ceil(8).max(1);
    scaled_workload(Pattern::Normal, duration_s, scale, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_workload_shape() {
        let w = paper_workload(Pattern::Normal, 3600.0, 1);
        assert_eq!(w.functions.len(), 8);
        assert_eq!(w.functions[0].model.name, "llama2-7b");
        assert_eq!(w.functions[7].model.name, "llama2-13b");
        assert!(!w.requests.is_empty());
        // Sorted stream.
        for p in w.requests.windows(2) {
            assert!(p[1].arrival_s >= p[0].arrival_s);
        }
    }

    #[test]
    fn rates_are_heterogeneous() {
        let w = paper_workload(Pattern::Normal, 3600.0, 1);
        assert!(w.rates[0] > w.rates[3] * 5.0);
    }

    #[test]
    fn throughput_workload_saturates() {
        let w = throughput_workload(120.0, 1);
        // 4 fns × 3 req/s × 120 s ≈ 1440 requests.
        assert!(w.requests.len() > 1000);
    }

    #[test]
    fn fleet_workload_rounds_up() {
        let w = fleet_workload(20, 300.0, 1);
        assert_eq!(w.functions.len(), 24);
        let w = fleet_workload(64, 300.0, 1);
        assert_eq!(w.functions.len(), 64);
    }

    #[test]
    fn scaled_workload_multiplies_functions() {
        let w = scaled_workload(Pattern::Normal, 600.0, 3, 1);
        assert_eq!(w.functions.len(), 24);
        // ids unique
        let mut ids: Vec<usize> = w.functions.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 24);
    }
}
